"""Interpreter-startup hook for processes launched with ``PYTHONPATH=src``
(the repo's documented invocation for tests, examples and benchmarks).

Installs repro's JAX forward-compat shims (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType`` …) so code using the modern
API works unmodified on an old JAX install.  The install is deferred via a
meta-path hook until ``jax`` itself is first imported — startup of
processes that never touch JAX stays unchanged.  A no-op on new JAX;
``repro/__init__.py`` installs the shims too, as a belt-and-braces backup.
"""

import sys


class _JaxCompatFinder:
    """Meta-path finder that runs the compat install right after ``jax``
    finishes importing, then gets out of the way."""

    def find_spec(self, fullname, path=None, target=None):
        if fullname != "jax" or self not in sys.meta_path:
            return None
        import importlib.util

        sys.meta_path.remove(self)  # avoid recursion; one-shot hook
        spec = importlib.util.find_spec("jax")
        if spec is not None and spec.loader is not None:
            spec.loader = _InstallAfterLoader(spec.loader)
        return spec


class _InstallAfterLoader:
    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def create_module(self, spec):
        return self._inner.create_module(spec)

    def exec_module(self, module):
        self._inner.exec_module(module)
        try:  # pragma: no cover - best effort, never break the jax import
            from repro import _jax_compat

            _jax_compat.install()
        except Exception:
            pass


sys.meta_path.insert(0, _JaxCompatFinder())


def _chain_next_sitecustomize():
    """Python imports only the FIRST sitecustomize on sys.path — since this
    one wins under PYTHONPATH=src, execute the next one (venv / coverage /
    site-packages hooks) so environment startup customizations still run."""
    import os

    here = os.path.dirname(os.path.abspath(__file__))
    for entry in sys.path:
        try:
            base = os.path.abspath(entry or os.getcwd())
            cand = os.path.join(base, "sitecustomize.py")
            if base != here and os.path.isfile(cand):
                import runpy

                runpy.run_path(cand, run_name="sitecustomize_chained")
                return
        except Exception:  # pragma: no cover - never break startup
            continue


_chain_next_sitecustomize()
