"""repro.obs — hierarchical tracing, solver metrics, and profiler hooks.

See ``src/repro/obs/README.md`` for the API tour and exporter formats.
"""

from repro.obs.export import (
    SCHEMA,
    expected_span_names,
    git_sha,
    load_manifest,
    manifest_lines,
    run_path,
    to_trace_events,
    validate_manifest,
    write_manifest,
    write_trace_events,
)
from repro.obs.jaxprof import annotate, maybe_start_trace, maybe_stop_trace
from repro.obs.registry import (
    MetricDef,
    lookup,
    merge_metrics,
    register,
    registered,
)
from repro.obs.trace import (
    NOOP_SPAN,
    Span,
    counter_add,
    current_span,
    disabled,
    gauge_max,
    gauge_set,
    obs_enabled,
    percentiles,
    render,
    set_enabled,
    span,
    timed,
    trace,
)

__all__ = [
    "NOOP_SPAN", "Span", "counter_add", "current_span", "disabled",
    "gauge_max", "gauge_set", "obs_enabled", "percentiles", "render",
    "set_enabled",
    "span", "timed", "trace",
    "MetricDef", "lookup", "merge_metrics", "register", "registered",
    "SCHEMA", "expected_span_names", "git_sha", "load_manifest",
    "manifest_lines", "run_path", "to_trace_events", "validate_manifest",
    "write_manifest", "write_trace_events",
    "annotate", "maybe_start_trace", "maybe_stop_trace",
]
