"""Exporters: JSONL run manifests and Chrome/Perfetto trace JSON.

Manifest format (``repro.obs/v1``) — one JSONL file per traced run:

* line 1: ``{"type": "manifest", "schema": "repro.obs/v1", "name": ...,
  "created": ..., "git_sha": ..., "config": {...}, "totals": {...}}``
* one line per span, flattened pre-order:
  ``{"type": "span", "id": N, "parent": M|null, "name": ..., "t0": ...,
  "seconds": ..., "tags": {...}, "counters": {...}, "gauges": {...}}``

``load_manifest`` reverses this exactly (header dict + rebuilt
:class:`~repro.obs.trace.Span` tree), so manifests are both the archival
record under ``runs/`` and the interchange format the benchmark tables
read.  ``to_trace_events`` converts a span tree to the Chrome
``trace_event`` format — open the file at https://ui.perfetto.dev or
``chrome://tracing`` to get the flamegraph.
"""

from __future__ import annotations

import json
import os
import subprocess
import time

from repro.obs.trace import Span

SCHEMA = "repro.obs/v1"

_GIT_SHA: str | None = None


def git_sha(repo_dir: str | None = None) -> str:
    """Current git SHA, cached after first lookup; "unknown" on failure."""
    global _GIT_SHA
    if _GIT_SHA is None:
        try:
            _GIT_SHA = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=repo_dir or os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=5,
            ).stdout.strip() or "unknown"
        except Exception:
            _GIT_SHA = "unknown"
    return _GIT_SHA


def _flatten(root: Span) -> list:
    """Pre-order (span, parent_id) rows with stable integer ids."""
    rows: list = []

    def rec(s: Span, parent) -> None:
        sid = len(rows)
        rows.append((sid, parent, s))
        for c in s.children:
            rec(c, sid)

    rec(root, None)
    return rows


def manifest_lines(root: Span, *, name: str = "run",
                   config: dict | None = None) -> list:
    """The manifest as a list of JSON-able dicts (header first)."""
    header = {
        "type": "manifest",
        "schema": SCHEMA,
        "name": name,
        "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": git_sha(),
        "config": dict(config or {}),
        "totals": {"seconds": root.seconds,
                   "metrics": root.total_counters()},
    }
    lines = [header]
    for sid, parent, s in _flatten(root):
        lines.append({
            "type": "span", "id": sid, "parent": parent,
            "name": s.name, "t0": s.t0, "seconds": s.seconds,
            "tags": dict(s.tags), "counters": dict(s.counters),
            "gauges": dict(s.gauges),
        })
    return lines


def write_manifest(root: Span, path: str, *, name: str = "run",
                   config: dict | None = None) -> str:
    """Write the JSONL manifest for ``root`` to ``path``; returns path."""
    lines = manifest_lines(root, name=name, config=config)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        for line in lines:
            f.write(json.dumps(line, sort_keys=True) + "\n")
    return path


def load_manifest(path: str):
    """Read a JSONL manifest: returns ``(header, root_span)``."""
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    if not rows or rows[0].get("type") != "manifest":
        raise ValueError(f"{path}: not a repro.obs manifest")
    header = rows[0]
    if header.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: schema {header.get('schema')!r} != {SCHEMA!r}")
    spans: dict = {}
    root = None
    for r in rows[1:]:
        if r.get("type") != "span":
            continue
        s = Span(name=r["name"], tags=dict(r.get("tags", {})),
                 t0=r.get("t0", 0.0),
                 counters=dict(r.get("counters", {})),
                 gauges=dict(r.get("gauges", {})))
        s.t1 = s.t0 + r.get("seconds", 0.0)
        spans[r["id"]] = s
        parent = r.get("parent")
        if parent is None:
            root = s
        else:
            spans[parent].children.append(s)
    if root is None:
        raise ValueError(f"{path}: manifest has no root span")
    return header, root


def run_path(runs_dir: str, name: str) -> str:
    """A collision-free manifest path under ``runs_dir``."""
    os.makedirs(runs_dir, exist_ok=True)
    stamp = time.strftime("%Y%m%d-%H%M%S")
    base = f"{name}-{stamp}"
    path = os.path.join(runs_dir, base + ".jsonl")
    i = 1
    while os.path.exists(path):
        path = os.path.join(runs_dir, f"{base}-{i}.jsonl")
        i += 1
    return path


# ---------------------------------------------------------------------------
# Chrome / Perfetto trace_event export
# ---------------------------------------------------------------------------

def to_trace_events(root: Span, *, pid: int = 1, tid: int = 1) -> dict:
    """Span tree -> Chrome ``trace_event`` JSON (complete "X" events,
    microsecond timestamps relative to the root's t0)."""
    events = []
    base = root.t0
    for _sid, _parent, s in _flatten(root):
        args = {}
        if s.tags:
            args.update({str(k): v for k, v in s.tags.items()})
        if s.counters:
            args.update({str(k): v for k, v in s.counters.items()})
        if s.gauges:
            args.update({str(k): v for k, v in s.gauges.items()})
        events.append({
            "name": s.name, "ph": "X", "cat": "repro",
            "ts": (s.t0 - base) * 1e6, "dur": s.seconds * 1e6,
            "pid": pid, "tid": tid, "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace_events(root: Span, path: str, **kw) -> str:
    """Write the Perfetto-loadable trace JSON; returns ``path``."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_trace_events(root, **kw), f)
    return path


# ---------------------------------------------------------------------------
# Instrumentation-drift guard
# ---------------------------------------------------------------------------

def expected_span_names(config: dict) -> set:
    """Span names a partition trace MUST contain given its recorded
    pipeline config — the CI drift guard's contract.  Derived from the
    same fields ``PartitionPipeline.run`` stamps into the manifest."""
    names = {"partition"}
    if config.get("guard"):
        names.add("guard:validate")
        names.add("guard:finalize")
    pre = config.get("pre")
    if pre and pre != "none":
        names.add(f"pre:{pre}")
    bisect = config.get("bisect")
    # Per-component dispatch (disconnected input, components != 1) may hand
    # every component a budget of one part — then no spectral solve runs,
    # so only single-component runs guarantee the inner solver spans.
    single_comp = config.get("components", 1) == 1
    if bisect:
        names.add(f"bisect:{bisect}")
        if bisect in ("rsb-batched", "rsb-recursive") and single_comp:
            names.add("solve")
            names.add("split")
        elif bisect == "multilevel" and single_comp:
            # The V-cycle emits mlevel:N per ladder level, but only
            # mlevel:0 is guaranteed by construction (the stage runs the
            # level-0 boundary sweep even when the input needs no ladder).
            # "finalize" wraps the stage's closing repair + rebalance.
            names.update({"coarsen", "coarsest", "mlevel:0", "finalize"})
    for stage in config.get("post", ()) or ():
        names.add(f"post:{stage}")
    return names


def validate_manifest(path: str) -> list:
    """Check a partition manifest for missing instrumentation: every
    stage named in the recorded config must have at least one span.
    Returns the list of problems (empty == valid)."""
    problems: list = []
    try:
        header, root = load_manifest(path)
    except (OSError, ValueError, KeyError) as e:
        return [f"unreadable manifest: {e}"]
    have = {s.name for s in root.walk()}
    for want in sorted(expected_span_names(header.get("config", {}))):
        if want not in have:
            problems.append(f"missing span {want!r} "
                            f"(config={header.get('config')})")
    if root.seconds <= 0:
        problems.append("root span has non-positive duration")
    for s in root.walk():
        if s.t1 < s.t0:
            problems.append(f"span {s.name!r} ends before it starts")
    return problems
