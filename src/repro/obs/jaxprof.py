"""Optional ``jax.profiler`` hooks: attribute device time to tree levels.

The span tree measures host wall time; to see *device* time per solve in
a real profiler, set ``REPRO_OBS_JAX=1`` and the instrumented solve
sites wrap themselves in ``jax.profiler.TraceAnnotation`` — the names
then show up in a ``jax.profiler.trace`` / TensorBoard / Perfetto
capture nested exactly like the host spans.  Default is off: the hooks
must cost nothing in ordinary runs, and annotation inside jitted code
only pays off when a device trace is actually being captured.

``maybe_start_trace``/``maybe_stop_trace`` bracket a whole capture
(``REPRO_OBS_JAX_DIR`` names the output directory); both are no-ops when
the env gate is off or jax.profiler is unavailable.
"""

from __future__ import annotations

import contextlib
import os


def _jax_enabled() -> bool:
    return os.environ.get("REPRO_OBS_JAX", "").strip().lower() in (
        "1", "on", "true", "yes")


def annotate(name: str):
    """``jax.profiler.TraceAnnotation(name)`` when ``REPRO_OBS_JAX=1``,
    else a free null context."""
    if not _jax_enabled():
        return contextlib.nullcontext()
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


def maybe_start_trace(log_dir: str | None = None) -> bool:
    """Start a device-profiler capture if ``REPRO_OBS_JAX=1``.  Returns
    True when a capture actually started (pair with maybe_stop_trace)."""
    if not _jax_enabled():
        return False
    try:
        import jax.profiler
        jax.profiler.start_trace(
            log_dir or os.environ.get("REPRO_OBS_JAX_DIR", "runs/jaxprof"))
        return True
    except Exception:
        return False


def maybe_stop_trace(started: bool = True) -> None:
    """Stop the capture started by :func:`maybe_start_trace`."""
    if not started or not _jax_enabled():
        return
    try:
        import jax.profiler
        jax.profiler.stop_trace()
    except Exception:
        pass
