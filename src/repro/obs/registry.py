"""Typed metric registry: the names solver internals emit into spans.

Stages call ``counter_add``/``gauge_set`` with free-form names, but the
*known* metrics — the ones exporters label, benchmarks tabulate, and the
drift guard checks — are declared here with a kind, unit, and merge
semantics.  Registration is open (``register`` at import time for new
subsystems); emitting an unregistered name is allowed and merges with
counter semantics, it just carries no unit/description.

Merge semantics when aggregating over a span subtree:

* ``counter`` — sums (CG iterations across levels add up).
* ``gauge``   — by aggregation: ``max`` (default; e.g. ``amg_levels``
  reports the deepest hierarchy seen), ``min``, or ``last``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MetricDef:
    name: str
    kind: str                    # "counter" | "gauge"
    unit: str = ""
    description: str = ""
    agg: str = "sum"             # counters: sum; gauges: max|min|last


_REGISTRY: dict = {}


def register(name: str, kind: str, *, unit: str = "", description: str = "",
             agg: str | None = None) -> MetricDef:
    if kind not in ("counter", "gauge"):
        raise ValueError(f"metric kind must be counter|gauge, got {kind!r}")
    if agg is None:
        agg = "sum" if kind == "counter" else "max"
    if kind == "counter" and agg != "sum":
        raise ValueError("counters always aggregate by sum")
    if kind == "gauge" and agg not in ("max", "min", "last"):
        raise ValueError(f"gauge agg must be max|min|last, got {agg!r}")
    d = MetricDef(name=name, kind=kind, unit=unit,
                  description=description, agg=agg)
    _REGISTRY[name] = d
    return d


def lookup(name: str):
    """The MetricDef for ``name``, or None if unregistered."""
    return _REGISTRY.get(name)


def registered() -> dict:
    """Snapshot of the registry (name -> MetricDef)."""
    return dict(_REGISTRY)


def merge_metrics(dst: dict, src: dict, *, kind: str = "counter") -> dict:
    """Merge ``src`` into ``dst`` in place using each metric's declared
    semantics; ``kind`` is the fallback for unregistered names."""
    for name, value in src.items():
        d = _REGISTRY.get(name)
        k = d.kind if d is not None else kind
        if name not in dst:
            dst[name] = value
        elif k == "counter":
            dst[name] = dst[name] + value
        else:
            agg = d.agg if d is not None else "max"
            if agg == "max":
                dst[name] = max(dst[name], value)
            elif agg == "min":
                dst[name] = min(dst[name], value)
            else:                 # last write wins
                dst[name] = value
    return dst


# ---------------------------------------------------------------------------
# Core metric set — solver internals the paper's phase breakdowns track.
# ---------------------------------------------------------------------------

# Fiedler / eigensolvers
register("lanczos_restarts", "counter",
         description="Restarted-Lanczos restart count across solves")
register("lanczos_iters", "counter",
         description="Total Lanczos iterations (all restarts)")
register("inverse_outer_iters", "counter",
         description="Inverse-iteration outer iterations")
register("cg_inner_iters", "counter",
         description="Flex-CG inner iterations inside inverse iteration")
register("fiedler_solves", "counter",
         description="Number of Fiedler vector solves")
register("residual_max", "gauge", agg="max",
         description="Worst eigenpair residual seen in the subtree")
register("amg_levels", "gauge", agg="max",
         description="Deepest AMG/multilevel hierarchy used")
register("multilevel_levels", "gauge", agg="max",
         description="Coarse-to-fine warm-start hierarchy depth")

# Refinement / k-way FM
register("fm_moves", "counter",
         description="k-way FM moves kept after rollback")
register("fm_moves_attempted", "counter",
         description="k-way FM moves attempted")
register("fm_rollbacks", "counter",
         description="k-way FM moves rolled back past the best prefix")
register("fm_passes", "counter",
         description="k-way FM hill-climbing passes executed")
register("refine_moves", "counter",
         description="Boundary-refinement moves applied")
register("refine_sweeps", "counter",
         description="Boundary-refinement sweeps executed")
register("fragments_repaired", "counter",
         description="Disconnected fragments reassigned by repair")
register("forced_moves", "counter",
         description="Repair moves that were balance-forced")

# Multilevel k-way V-cycle (bisect="multilevel")
register("ml_levels", "gauge", agg="max",
         description="Coarsening-ladder depth of the multilevel V-cycle")
register("ml_coarsen_ratio", "gauge", agg="min",
         description="n_coarsest / n_fine of the V-cycle ladder")
register("ml_fm_moves", "counter",
         description="FM moves kept across coarsest polish + all V-cycle "
                     "refinement levels")

# Partition structure / distribution layer
register("edge_cut", "gauge", agg="last",
         description="Edge cut of the partition at this point")
register("halo_words", "counter", unit="words",
         description="Halo exchange words per feature (all shards)")
register("halo_bytes", "counter", unit="bytes",
         description="Halo exchange bytes per feature at f32")
register("halo_max_degree", "gauge", agg="max",
         description="Max neighbor count over shards in the halo plan")
register("sharded_sweeps", "counter",
         description="Device-resident sharded refinement sweeps executed")
register("sharded_gathers", "counter",
         description="Boundary-label all_gather collectives issued by the "
                     "sharded refinement loop (contract: == sharded_sweeps)")
register("sharded_moves", "counter",
         description="Moves applied by sharded refinement sweeps")

# Fault-tolerance guard (repro.guard)
register("guard_retries", "counter",
         description="Seed-perturbed Fiedler re-solves after a failed "
                     "health check")
register("guard_fallbacks", "counter",
         description="Guard escalations past retry: method switches, "
                     "geometric/index fallback vectors, finalize repairs, "
                     "halo plan rebuilds")
register("guard_sanitize_fixes", "counter",
         description="Input defects repaired by sanitize-mode validation")
register("guard_deadline_expired", "counter",
         description="Bisect stages whose guard deadline expired "
                     "(remaining solves go straight to fallback)")


# ---------------------------------------------------------------------------
# Span vocabulary — every span()/timed()/trace() name used in src/ must be
# declared here (exact name, or under one of the dynamic prefixes).  The
# static analyzer (repro.analysis, rule OBS001) enforces this at lint
# time; `expected_span_names` in repro.obs.export derives the per-config
# REQUIRED subset for the runtime drift guard from the same vocabulary.
# ---------------------------------------------------------------------------

SPAN_NAMES = (
    # pipeline skeleton
    "partition", "guard:validate", "guard:finalize",
    # solver engines
    "engine", "solve", "split",
    # multilevel V-cycle
    "coarsen", "coarsest", "finalize",
    # host post chain
    "repair", "refine_sweeps", "repair_refine", "kway_fm",
    # sharded refinement
    "sharded_sweeps_total",
    # serving path
    "serve", "prefill", "decode_step",
)

SPAN_PREFIXES = (
    "pre:",        # pre:<stage>   — pipeline pre stage
    "bisect:",     # bisect:<stage>
    "post:",       # post:<stage>
    "level:",      # level:<N>     — batched-engine tree level
    "mlevel:",     # mlevel:<N>    — multilevel V-cycle ladder level
    "sweep:",      # sweep:<N>     — sharded refinement sweep
)


def span_declared(name: str) -> bool:
    """Is ``name`` part of the declared span vocabulary?"""
    return name in SPAN_NAMES or any(
        name.startswith(p) for p in SPAN_PREFIXES)


def declared_spans() -> tuple:
    """Snapshot of (names, prefixes) — what the drift guard and the
    static analyzer share."""
    return SPAN_NAMES, SPAN_PREFIXES
