"""Hierarchical tracing: the span tree every pipeline stage writes into.

parRSB's optimization story (and Sphynx's) is told in per-phase timing
breakdowns — Lanczos vs inverse iteration, coarse solves, communication.
This module is the repo's single way to collect those breakdowns: a
``span``/``trace`` context-manager API producing a tree of
:class:`Span` nodes (wall time, nesting, tags, counters), replacing the
scattered ``time.perf_counter`` pairs the stages used to hand-thread.

Three entry points, chosen by what the call site needs:

* :func:`trace` — opens a **root** span.  ``PartitionPipeline.run`` wraps
  each partition call in one; the completed tree lands on
  ``PartitionContext.trace`` and is what the exporters
  (:mod:`repro.obs.export`) serialize.  When a trace is already active
  (a partition inside a benchmark's own trace), it nests as an ordinary
  child span.
* :func:`timed` — a span whose ``.seconds`` the caller consumes (level
  solve/split timings, stage records).  It ALWAYS measures wall time:
  with observability disabled it degrades to a two-``perf_counter``
  :class:`_Timer`, so every report field that predates the obs layer is
  still populated bit-for-bit — ``REPRO_OBS=off`` is unobservable, not
  untimed.
* :func:`span` — pure structural annotation; nothing reads its time.
  Disabled (or outside any trace) it returns a shared no-op singleton:
  the fast path allocates nothing and touches one module-level bool.

Counters/gauges (:func:`counter_add`, :func:`gauge_set`) write into the
*innermost active span* — solver internals (CG iterations, Lanczos
restarts, FM moves, halo bytes) no longer need a report field threaded
through every layer to be visible; subtree aggregation
(:meth:`Span.total_counters`) merges them with the registry's semantics
(:mod:`repro.obs.registry`: counters sum, gauges max/last/min).

The kill switch is the ``REPRO_OBS`` environment variable (``off``,
``0``, ``false``, ``no`` disable; anything else enables — the default).
Tests and benchmarks can flip it at runtime with :func:`set_enabled` /
the :func:`disabled` context manager.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import time


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "on").strip().lower() not in (
        "off", "0", "false", "no")


class _State:
    __slots__ = ("enabled", "stack")

    def __init__(self):
        self.enabled = _env_enabled()
        self.stack: list = []     # innermost active span is stack[-1]


_STATE = _State()


def obs_enabled() -> bool:
    """Is the tracing layer on (``REPRO_OBS`` / :func:`set_enabled`)?"""
    return _STATE.enabled


def set_enabled(flag: bool) -> bool:
    """Flip tracing at runtime; returns the previous setting."""
    prev = _STATE.enabled
    _STATE.enabled = bool(flag)
    return prev


@contextlib.contextmanager
def disabled():
    """Run a block with tracing off (the ``REPRO_OBS=off`` escape hatch,
    scoped): spans become no-ops/timers, nothing is recorded."""
    prev = set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


def current_span():
    """The innermost active span, or None (no trace open / disabled)."""
    return _STATE.stack[-1] if _STATE.stack else None


# ---------------------------------------------------------------------------
# Span tree
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Span:
    """One timed node of the trace tree.

    Use as a context manager: ``__enter__`` stamps ``t0`` and links the
    span under the innermost active span (if any); ``__exit__`` stamps
    ``t1``.  ``counters`` accumulate sums, ``gauges`` record last-written
    values; both are merged over subtrees with the registry's semantics.
    """

    name: str
    tags: dict = dataclasses.field(default_factory=dict)
    t0: float = 0.0
    t1: float = 0.0
    children: list = dataclasses.field(default_factory=list)
    counters: dict = dataclasses.field(default_factory=dict)
    gauges: dict = dataclasses.field(default_factory=dict)

    @property
    def seconds(self) -> float:
        return max(self.t1 - self.t0, 0.0)

    def __enter__(self) -> "Span":
        stack = _STATE.stack
        if stack:
            stack[-1].children.append(self)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = time.perf_counter()
        stack = _STATE.stack
        if stack and stack[-1] is self:
            stack.pop()
        else:                      # mispaired exit: drop self wherever it is
            try:
                stack.remove(self)
            except ValueError:
                pass
        return False

    # -- tree traversal -----------------------------------------------------

    def walk(self):
        """Depth-first pre-order iteration over the subtree."""
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str):
        """First span named ``name`` in the subtree (pre-order), or None."""
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def find_all(self, name: str) -> list:
        return [s for s in self.walk() if s.name == name]

    def total_counters(self) -> dict:
        """Counters + gauges merged over the whole subtree (registry
        semantics: counters sum, gauges max/last/min)."""
        from repro.obs.registry import merge_metrics

        out: dict = {}
        for s in self.walk():
            merge_metrics(out, s.counters, kind="counter")
            merge_metrics(out, s.gauges, kind="gauge")
        return out

    # -- (de)serialization ----------------------------------------------------

    def to_dict(self) -> dict:
        """Nested JSON-able form (inverse: :meth:`from_dict`)."""
        d = {"name": self.name, "t0": self.t0, "seconds": self.seconds}
        if self.tags:
            d["tags"] = dict(self.tags)
        if self.counters:
            d["counters"] = dict(self.counters)
        if self.gauges:
            d["gauges"] = dict(self.gauges)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        s = cls(name=d["name"], tags=dict(d.get("tags", {})),
                t0=d.get("t0", 0.0),
                counters=dict(d.get("counters", {})),
                gauges=dict(d.get("gauges", {})))
        s.t1 = s.t0 + d.get("seconds", 0.0)
        s.children = [cls.from_dict(c) for c in d.get("children", [])]
        return s


class _Timer:
    """Disabled-mode stand-in for :func:`timed`: measures wall time,
    records nothing.  Keeps every pre-obs report field populated when
    ``REPRO_OBS=off``."""

    __slots__ = ("t0", "t1")

    def __enter__(self) -> "_Timer":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = time.perf_counter()
        return False

    @property
    def seconds(self) -> float:
        return max(self.t1 - self.t0, 0.0)


class _NoopSpan:
    """Shared do-nothing span: the disabled-mode fast path of :func:`span`.
    One module-level instance; entering/exiting allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    @property
    def seconds(self) -> float:
        return 0.0


NOOP_SPAN = _NoopSpan()


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def trace(name: str, **tags):
    """Open a span that may ROOT a new trace (use for whole-operation
    scopes: one ``partition()`` call, one serve run).  Returns the
    :class:`Span` — keep it; the completed tree is what the exporters
    consume.  Disabled: a :class:`_Timer` (callers may still read
    ``.seconds``; ``PartitionContext.trace`` stays None-equivalent)."""
    if _STATE.enabled:
        return Span(name=name, tags=tags)
    return _Timer()


def timed(name: str, **tags):
    """A span whose ``.seconds`` the caller reads (report timings).
    Records into the active trace when one is open; otherwise — or with
    observability disabled — it is a plain two-perf_counter timer, so the
    measurement survives ``REPRO_OBS=off`` bit-for-bit."""
    if _STATE.enabled and _STATE.stack:
        return Span(name=name, tags=tags)
    return _Timer()


def span(name: str, **tags):
    """Pure structural annotation (nothing reads its time).  Disabled or
    outside any trace this is the zero-allocation fast path: the shared
    :data:`NOOP_SPAN` singleton."""
    if _STATE.enabled and _STATE.stack:
        return Span(name=name, tags=tags)
    return NOOP_SPAN


def counter_add(name: str, value: float = 1.0) -> None:
    """Accumulate ``value`` into the innermost active span's counter
    ``name``.  No-op (one bool test) when disabled or outside a trace."""
    stack = _STATE.stack
    if not stack:
        return
    c = stack[-1].counters
    c[name] = c.get(name, 0.0) + value


def gauge_set(name: str, value) -> None:
    """Set gauge ``name`` on the innermost active span (last write wins
    within a span; subtree merges follow the registry's gauge agg)."""
    stack = _STATE.stack
    if not stack:
        return
    stack[-1].gauges[name] = value


def gauge_max(name: str, value) -> None:
    """Raise gauge ``name`` on the innermost active span to at least
    ``value`` (running max within the span — e.g. worst residual)."""
    stack = _STATE.stack
    if not stack:
        return
    g = stack[-1].gauges
    g[name] = value if name not in g else max(g[name], value)


# ---------------------------------------------------------------------------
# Rendering (the examples' indented stage/level breakdown)
# ---------------------------------------------------------------------------

def render(root, *, max_depth: int = 4, min_share: float = 0.005) -> str:
    """Indented span-tree summary: name, wall seconds, % of the root's
    wall, and any counters — the human-readable flamegraph.  Subtrees
    below ``min_share`` of the root wall or deeper than ``max_depth``
    are elided (noted as ``…``)."""
    if root is None or not isinstance(root, Span):
        return "(no trace recorded — REPRO_OBS=off?)"
    total = max(root.seconds, 1e-12)
    lines: list = []

    def fmt_extras(s: Span) -> str:
        bits = []
        for k, v in list(s.tags.items())[:4]:
            bits.append(f"{k}={v}")
        for k, v in list(s.counters.items())[:4]:
            vv = int(v) if float(v).is_integer() else round(float(v), 3)
            bits.append(f"{k}={vv}")
        return ("  [" + " ".join(bits) + "]") if bits else ""

    def rec(s: Span, depth: int) -> None:
        share = s.seconds / total
        lines.append(f"{'  ' * depth}{s.name:<24s}"
                     f"{s.seconds * 1e3:9.1f} ms  {share:6.1%}"
                     f"{fmt_extras(s)}")
        if depth + 1 > max_depth:
            if s.children:
                lines.append(f"{'  ' * (depth + 1)}…")
            return
        elided = 0
        for c in s.children:
            if c.seconds / total >= min_share:
                rec(c, depth + 1)
            else:
                elided += 1
        if elided:
            lines.append(f"{'  ' * (depth + 1)}… ({elided} spans "
                         f"< {min_share:.1%} of wall)")

    rec(root, 0)
    return "\n".join(lines)


def percentiles(seconds: list, qs=(0.5, 0.99)) -> dict:
    """p50/p99-style summary of a list of durations (serve-path span
    histograms).  Nearest-rank; empty input → zeros."""
    if not seconds:
        return {f"p{int(q * 100)}": 0.0 for q in qs}
    xs = sorted(seconds)
    out = {}
    for q in qs:
        k = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        out[f"p{int(q * 100)}"] = xs[k]
    return out
