"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all **per device** (the compiled
module is the post-SPMD per-device program, so `cost_analysis()` FLOPs /
bytes and HLO shapes are already per-device):

    compute    = HLO_FLOPs / peak_FLOP/s            (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw                 (819 GB/s)
    collective = wire_bytes / link_bw               (50 GB/s/link ICI)

`wire_bytes` is NOT in cost_analysis — we parse the compiled HLO text and
sum ring-model wire traffic over every collective op:

    all-reduce        2·b·(g−1)/g     (reduce-scatter + all-gather ring)
    all-gather        b_out·(g−1)/g
    reduce-scatter    b_out·(g−1)
    all-to-all        b·(g−1)/g
    collective-permute b

with b = the op's local output bytes and g its replica-group size.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link (1-link-equivalent model)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return n_devices


@dataclasses.dataclass
class CollectiveStats:
    per_op: dict              # op kind → wire bytes (per device)
    counts: dict              # op kind → #ops
    total_wire_bytes: float

    def row(self):
        return {
            "wire_bytes": self.total_wire_bytes,
            "counts": dict(self.counts),
            "bytes_by_kind": {k: v for k, v in self.per_op.items() if v},
        }


def collective_wire_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    per_op = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"\b(all-reduce-start|all-reduce|all-gather-start|all-gather|"
        r"reduce-scatter|all-to-all|collective-permute-start|"
        r"collective-permute)\("
    )
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3 :]
        m = op_re.search(rhs)
        if not m:
            continue
        kind = m.group(1).replace("-start", "")
        if kind not in per_op:
            continue
        # output shape(s) sit between '=' and the op name (layouts included)
        b = _shape_bytes(rhs[: m.start()])
        g = _group_size(s, n_devices)
        if g <= 1:
            continue
        if kind == "all-reduce":
            wire = 2.0 * b * (g - 1) / g
        elif kind == "all-gather":
            wire = b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = b * (g - 1)
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = float(b)
        per_op[kind] += wire
        counts[kind] += 1
    return CollectiveStats(
        per_op=per_op, counts=counts,
        total_wire_bytes=sum(per_op.values()),
    )


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_fraction: float     # MODEL_FLOPS / (HLO_FLOPs · n_dev)
    roofline_fraction: float   # compute_s / max(all terms) — how close the
                               # step is to being compute-bound at peak

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline(cost: dict, hlo_text: str, n_devices: int,
             model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    wire = collective_wire_bytes(hlo_text, n_devices).total_wire_bytes
    ct = flops / PEAK_FLOPS
    mt = byts / HBM_BW
    lt = wire / LINK_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dominant = max(terms, key=terms.get)
    total_flops = flops * n_devices
    useful = model_flops / total_flops if total_flops else 0.0
    bound = max(ct, mt, lt)
    return Roofline(
        flops_per_dev=flops, bytes_per_dev=byts, wire_bytes_per_dev=wire,
        compute_s=ct, memory_s=mt, collective_s=lt, dominant=dominant,
        model_flops=model_flops, useful_fraction=useful,
        roofline_fraction=(ct / bound) if bound > 0 else 0.0,
    )
