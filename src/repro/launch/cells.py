"""Cell builder: one (architecture × shape × mesh) → jit-able step.

`build_cell` returns everything the dry-run needs: the step function,
abstract inputs (`input_specs()` — ShapeDtypeStructs, NO allocation),
in/out PartitionSpecs, and the MODEL_FLOPS accounting for §Roofline.

Node/edge counts of GNN cells are padded up to a multiple of the device
count (mask arrays preserve semantics) — recorded in `Cell.notes`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchDef, ShapeCell
from repro.dist.sharding import (
    batch_specs_lm,
    cache_specs_lm,
    gnn_rules,
    lm_rules,
    param_specs_lm,
    recsys_rules,
)
from repro.models.gnn.common import GraphBatch
from repro.train.optimizer import AdamWConfig, abstract_opt_state, adamw_update

OPT_CFG = AdamWConfig(lr=1e-4)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable                 # positional args matching abstract_args
    abstract_args: tuple
    in_specs: tuple
    out_specs: Any               # None → infer
    model_flops: float           # useful-math FLOPs per step (6ND etc.)
    notes: str = ""

    def donate(self):
        """Donated arg indices (params/opt/cache buffers) for memory truth."""
        if self.kind == "train":
            return (0, 1)
        if self.kind == "decode":
            return (1,)
        return ()


def _pad_to(x: int, m: int) -> int:
    return int(-(-x // m) * m)


def _n_devices(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_train_cell(arch: ArchDef, cell: ShapeCell, mesh, unroll: bool = False,
                   seq_shard: bool = True, moe_impl: str | None = None,
                   microbatch: int = 1) -> Cell:
    from repro.models import transformer as T

    cfg = dataclasses.replace(arch.make_config(), unroll=unroll)
    if moe_impl and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl=moe_impl))
    rules = lm_rules(mesh, seq_shard=seq_shard)
    B, S = cell["global_batch"], cell["seq_len"]
    params_abs = T.abstract_params(cfg)
    opt_abs = abstract_opt_state(params_abs)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }

    def step(params, opt_state, batch):
        if microbatch > 1:
            # gradient accumulation: activations live for ONE microbatch
            mb = {k: v.reshape(microbatch, B // microbatch, S)
                  for k, v in batch.items()}

            def acc(carry, mbatch):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, mbatch, rules)
                )(params)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (loss_sum + l, gsum), None

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), mb)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, batch, rules)
            )(params)
        params, opt_state, gnorm = adamw_update(OPT_CFG, grads, opt_state, params)
        return params, opt_state, loss

    pspec = param_specs_lm(cfg, params_abs, mesh)
    rules.layer_specs = pspec["layers"]
    ospec = {"m": pspec, "v": pspec, "count": P()}
    bspec = batch_specs_lm(mesh)
    n_active = cfg.n_active_params()
    return Cell(
        arch_id=arch.arch_id, shape_name=cell.name, kind="train",
        fn=step, abstract_args=(params_abs, opt_abs, batch_abs),
        in_specs=(pspec, ospec, bspec), out_specs=(pspec, ospec, P()),
        model_flops=6.0 * n_active * B * S,
        notes=f"N_active={n_active:.3e}",
    )


def _lm_prefill_cell(arch: ArchDef, cell: ShapeCell, mesh, unroll: bool = False) -> Cell:
    from repro.models import transformer as T

    cfg = dataclasses.replace(arch.make_config(), unroll=unroll)
    rules = lm_rules(mesh)
    B, S = cell["global_batch"], cell["seq_len"]
    params_abs = T.abstract_params(cfg)
    tokens_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def step(params, tokens):
        return T.prefill(cfg, params, tokens, rules)

    pspec = param_specs_lm(cfg, params_abs, mesh)
    rules.layer_specs = pspec["layers"]
    cspec = cache_specs_lm(cfg, mesh)
    names = tuple(mesh.axis_names)
    data = tuple(a for a in ("pod", "data") if a in names)
    n_active = cfg.n_active_params()
    attn = 4.0 * B * S * S * cfg.n_heads * cfg.d_head / 2  # causal half
    return Cell(
        arch_id=arch.arch_id, shape_name=cell.name, kind="prefill",
        fn=step, abstract_args=(params_abs, tokens_abs),
        in_specs=(pspec, P(data, None)),
        out_specs=(P(data, None, "model"), cspec),
        model_flops=2.0 * n_active * B * S + attn,
        notes=f"N_active={n_active:.3e}",
    )


def _lm_decode_cell(arch: ArchDef, cell: ShapeCell, mesh, unroll: bool = False) -> Cell:
    from repro.models import transformer as T

    cfg = dataclasses.replace(arch.make_config(), unroll=unroll)
    rules = lm_rules(mesh)
    B, S = cell["global_batch"], cell["seq_len"]
    params_abs = T.abstract_params(cfg)
    cache_abs = T.abstract_cache(cfg, B, S)
    tokens_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos, rules)

    pspec = param_specs_lm(cfg, params_abs, mesh)
    rules.layer_specs = pspec["layers"]
    cspec = cache_specs_lm(cfg, mesh)
    names = tuple(mesh.axis_names)
    data = tuple(a for a in ("pod", "data") if a in names)
    n_active = cfg.n_active_params()
    attn = 4.0 * B * S * cfg.n_heads * cfg.d_head
    return Cell(
        arch_id=arch.arch_id, shape_name=cell.name, kind="decode",
        fn=step, abstract_args=(params_abs, cache_abs, tokens_abs, pos_abs),
        in_specs=(pspec, cspec, P(data, None), P()),
        out_specs=(P(data, None, "model"), cspec),
        model_flops=2.0 * n_active * B + attn,
        notes=f"N_active={n_active:.3e} kv_cache_tokens={S}",
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_batch_abstract(cell: ShapeCell, mesh, *, d_feat: int,
                        needs_geometry: bool, d_out: int,
                        energy_targets: bool | None = None):
    if energy_targets is None:
        energy_targets = needs_geometry
    if cell.name == "molecule":
        needs_geometry = True         # molecules always carry positions
        d_feat = max(d_feat, 4)       # synthesized node features if absent
    D = _n_devices(mesh)
    if cell.name == "molecule":
        n_nodes = cell["n_nodes"] * cell["batch"]
        n_edges = cell["n_edges"] * cell["batch"]
        n_graphs = cell["batch"]
    elif cell.name == "minibatch_lg":
        n_nodes, n_edges, n_graphs = cell["sub_nodes"], cell["sub_edges"], 1
    else:
        n_nodes, n_edges, n_graphs = cell["n_nodes"], cell["n_edges"], 1
    n_pad = _pad_to(n_nodes, D)
    e_pad = _pad_to(n_edges, D)
    f32, i32 = jnp.float32, jnp.int32
    batch = GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n_pad, d_feat), f32),
        edge_src=jax.ShapeDtypeStruct((e_pad,), i32),
        edge_dst=jax.ShapeDtypeStruct((e_pad,), i32),
        node_mask=jax.ShapeDtypeStruct((n_pad,), f32),
        edge_mask=jax.ShapeDtypeStruct((e_pad,), f32),
        positions=jax.ShapeDtypeStruct((n_pad, 3), f32) if needs_geometry else None,
        species=jax.ShapeDtypeStruct((n_pad,), i32) if needs_geometry else None,
        graph_ids=jax.ShapeDtypeStruct((n_pad,), i32) if needs_geometry else None,
        targets=jax.ShapeDtypeStruct(
            (n_graphs,) if energy_targets else (n_pad, d_out), f32
        ),
        n_graphs=n_graphs,
    )
    every = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    spec = GraphBatch(
        node_feat=P(every, None),
        edge_src=P(every), edge_dst=P(every),
        node_mask=P(every), edge_mask=P(every),
        positions=P(every, None) if needs_geometry else None,
        species=P(every) if needs_geometry else None,
        graph_ids=P(every) if needs_geometry else None,
        targets=P() if energy_targets else P(every, None),
        n_graphs=n_graphs,
    )
    note = f"padded nodes {n_nodes}->{n_pad}, edges {n_edges}->{e_pad}"
    return batch, spec, n_pad, e_pad, note


_GNN_FLOP_MODELS = {}


def _gnn_cell(arch: ArchDef, cell: ShapeCell, mesh, unroll: bool = False) -> Cell:
    aid = arch.arch_id
    d_feat = cell.meta.get("d_feat", 0)
    if cell.name == "molecule":
        d_feat = max(d_feat, 4)
    needs_geometry = aid in ("mace", "nequip")
    if aid == "meshgraphnet":
        from repro.models.gnn.meshgraphnet import init_mgn, mgn_loss

        cfg = dataclasses.replace(arch.make_config(d_in=max(d_feat, 3), d_out=3), unroll=unroll)
        loss = mgn_loss
        init = init_mgn
        d_out = 3
        # per-edge: edge MLP 2 layers of 3d→d,d→d; per-node: 2d→d,d→d
        d = cfg.d_hidden
        per_edge = 2 * (3 * d * d + d * d)
        per_node = 2 * (2 * d * d + d * d)
    elif aid == "graphcast":
        from repro.models.gnn.graphcast import graphcast_loss, init_graphcast

        cfg = dataclasses.replace(arch.make_config(d_in=max(d_feat, 1)), unroll=unroll)
        loss = graphcast_loss
        init = init_graphcast
        d_out = cfg.n_vars
        d = cfg.d_hidden
        per_edge = 2 * (3 * d * d + d * d)
        per_node = 2 * (2 * d * d + d * d)
    elif aid == "nequip":
        from repro.models.gnn.nequip import init_nequip, nequip_loss
        from repro.models.gnn.equivariant import n_paths

        cfg = dataclasses.replace(arch.make_config(d_feat_in=d_feat), unroll=unroll)
        loss = nequip_loss
        init = init_nequip
        d_out = 1
        C, Pn = cfg.d_hidden, n_paths()
        per_edge = 2 * (cfg.n_rbf * 64 + 64 * C * Pn) + 2 * Pn * 81 * C
        per_node = 6 * C * C * 9
    else:  # mace
        from repro.models.gnn.mace import init_mace, mace_loss
        from repro.models.gnn.equivariant import n_paths

        cfg = dataclasses.replace(arch.make_config(d_feat_in=d_feat), unroll=unroll)
        loss = mace_loss
        init = init_mace
        d_out = 1
        C, Pn = cfg.d_hidden, n_paths()
        per_edge = 2 * (cfg.n_rbf * 64 + 64 * C * Pn) + 2 * Pn * 81 * C
        per_node = (cfg.correlation - 1) * 2 * Pn * 729 * C + 10 * C * C * 9

    batch_abs, bspec, n_pad, e_pad, note = _gnn_batch_abstract(
        cell, mesh, d_feat=d_feat, needs_geometry=needs_geometry,
        d_out=d_out, energy_targets=needs_geometry,
    )
    params_abs = jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))
    opt_abs = abstract_opt_state(params_abs)
    rules = gnn_rules(mesh)

    def step(params, opt_state, batch):
        l, grads = jax.value_and_grad(
            lambda p: loss(cfg, p, batch, rules)
        )(params)
        params, opt_state, gnorm = adamw_update(OPT_CFG, grads, opt_state, params)
        return params, opt_state, l

    pspec = jax.tree_util.tree_map(lambda _: P(), params_abs)
    ospec = {"m": pspec, "v": pspec, "count": P()}
    n_layers = cfg.n_layers
    flops = 3.0 * n_layers * (per_edge * e_pad + per_node * n_pad)  # fwd+bwd
    return Cell(
        arch_id=aid, shape_name=cell.name, kind="train",
        fn=step, abstract_args=(params_abs, opt_abs, batch_abs),
        in_specs=(pspec, ospec, bspec), out_specs=(pspec, ospec, P()),
        model_flops=flops, notes=note,
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_cell(arch: ArchDef, cell: ShapeCell, mesh) -> Cell:
    from repro.models.recsys import sasrec as R

    cfg = arch.make_config()
    rules = recsys_rules(mesh)
    params_abs = jax.eval_shape(lambda: R.init_sasrec(cfg, jax.random.PRNGKey(0)))
    names = tuple(mesh.axis_names)
    data = tuple(a for a in ("pod", "data") if a in names)
    pspec = jax.tree_util.tree_map(lambda _: P(), params_abs)
    pspec["item_embed"] = P("model", None)
    d = cfg.embed_dim
    S = cfg.seq_len
    blk_flops = 2 * (4 * d * d + 2 * d * cfg.d_ff) + 4 * S * d  # per token

    if cell.kind == "train":
        B = cell["batch"]
        opt_abs = abstract_opt_state(params_abs)
        batch_abs = {
            "item_seq": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "pos_items": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "neg_items": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }

        def step(params, opt_state, batch):
            l, grads = jax.value_and_grad(
                lambda p: R.sasrec_train_loss(cfg, p, batch, rules)
            )(params)
            params, opt_state, _ = adamw_update(OPT_CFG, grads, opt_state, params)
            return params, opt_state, l

        ospec = {"m": pspec, "v": pspec, "count": P()}
        bspec = {k: P(data, None) for k in batch_abs}
        return Cell(
            arch_id=arch.arch_id, shape_name=cell.name, kind="train",
            fn=step, abstract_args=(params_abs, opt_abs, batch_abs),
            in_specs=(pspec, ospec, bspec), out_specs=(pspec, ospec, P()),
            model_flops=3.0 * B * S * cfg.n_blocks * blk_flops,
        )

    if cell.kind == "serve":
        B = cell["batch"]
        seq_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        k = 100
        # bulk scoring streams user chunks — offline scoring never holds all
        # user states (or a B×V score matrix) at once
        user_chunk = min(B, 8192)

        def step(params, item_seq):
            table = rules.shard(params["item_embed"], ("vocab", None))
            n_cat_chunks = 64
            chunk = table.shape[0] // n_cat_chunks

            def score_users(seq_chunk):
                h = R.sasrec_user_state(cfg, params, seq_chunk, rules)[:, -1]

                def body(carry, i):
                    best_v, best_i = carry
                    rows = jax.lax.dynamic_slice_in_dim(table, i * chunk, chunk, 0)
                    scores = h @ rows.T                  # (uc, chunk)
                    ids = i * chunk + jnp.arange(chunk)
                    allv = jnp.concatenate([best_v, scores], axis=1)
                    alli = jnp.concatenate(
                        [best_i, jnp.broadcast_to(ids, scores.shape)], axis=1
                    )
                    v, idx = jax.lax.top_k(allv, k)
                    return (v, jnp.take_along_axis(alli, idx, axis=1)), None

                init = (jnp.full((h.shape[0], k), -jnp.inf),
                        jnp.zeros((h.shape[0], k), jnp.int32))
                (vals, ids), _ = jax.lax.scan(body, init, jnp.arange(n_cat_chunks))
                return vals, ids

            if B > user_chunk:
                seqs = item_seq.reshape(B // user_chunk, user_chunk, S)
                vals, ids = jax.lax.map(score_users, seqs)
                return vals.reshape(B, k), ids.reshape(B, k)
            return score_users(item_seq)

        V = cfg.table_rows
        return Cell(
            arch_id=arch.arch_id, shape_name=cell.name, kind="serve",
            fn=step, abstract_args=(params_abs, seq_abs),
            in_specs=(pspec, P(data, None)),
            out_specs=(P(data, None), P(data, None)),
            model_flops=B * S * cfg.n_blocks * blk_flops + 2.0 * B * V * d,
            notes=f"top-{k} over {V}-row catalog; user_chunk={user_chunk}",
        )

    # retrieval: one user, 1M candidate scores as a single matmul
    B = cell["batch"]
    NC = cell["n_candidates"]
    seq_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    cand_abs = jax.ShapeDtypeStruct((NC,), jnp.int32)

    def step(params, item_seq, candidates):
        return R.sasrec_score_candidates(cfg, params, item_seq, candidates, rules)

    return Cell(
        arch_id=arch.arch_id, shape_name=cell.name, kind="retrieval",
        fn=step, abstract_args=(params_abs, seq_abs, cand_abs),
        in_specs=(pspec, P(None, None), P("model")),
        out_specs=P(None, "model"),
        model_flops=B * S * cfg.n_blocks * blk_flops + 2.0 * B * NC * d,
    )


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh, *, unroll: bool = False,
               n_layers: int | None = None, seq_shard: bool = True,
               moe_impl: str | None = None, microbatch: int = 1) -> Cell:
    """`n_layers` overrides the config depth (layer-diff profiling)."""
    arch = get_arch(arch_id)
    if n_layers is not None:
        base = arch.make_config

        def _shallow(*a, **kw):
            return dataclasses.replace(base(*a, **kw), n_layers=n_layers)

        arch = dataclasses.replace(arch, make_config=_shallow)
    if shape_name not in arch.shapes:
        raise KeyError(f"{arch_id} has no shape {shape_name}")
    if shape_name in arch.skips:
        raise ValueError(
            f"cell ({arch_id} × {shape_name}) is skipped: {arch.skips[shape_name]}"
        )
    cell = arch.shapes[shape_name]
    if arch.family == "lm":
        if cell.kind == "train":
            return _lm_train_cell(arch, cell, mesh, unroll,
                                  seq_shard=seq_shard, moe_impl=moe_impl,
                                  microbatch=microbatch)
        if cell.kind == "prefill":
            return _lm_prefill_cell(arch, cell, mesh, unroll)
        return _lm_decode_cell(arch, cell, mesh, unroll)
    if arch.family == "gnn":
        return _gnn_cell(arch, cell, mesh, unroll)
    return _recsys_cell(arch, cell, mesh)
