import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / roofline evidence.

The two lines above MUST precede any jax import — jax locks the device
count at first initialization (see the assignment's MULTI-POD DRY-RUN §0).

Methodology (two compiles per cell, both recorded):
  * EXEC compile — scan-over-layers, exactly the production step.  Its
    `memory_analysis()` is the memory-fit evidence (loop temps = one live
    layer).  XLA's `cost_analysis()` counts a while-loop body ONCE, so
    exec FLOPs understate per-step work — hence:
  * PROFILE compile — layers unrolled.  Its `cost_analysis()` FLOPs/bytes
    and HLO collective census are the per-step roofline inputs.

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out runs/dryrun
Each cell writes runs/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import all_cells, get_arch
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_wire_bytes, roofline


def _compile(cell, mesh):
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_specs,
            out_shardings=cell.out_specs,
            donate_argnums=cell.donate(),
        )
        lowered = jitted.lower(*cell.abstract_args)
        compiled = lowered.compile()
    return lowered, compiled


PROFILE_CAP = 6   # unroll directly up to this depth; layer-diff beyond


def _n_layers_of(arch_id: str) -> int | None:
    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config()
    full = arch.make_config()
    return getattr(full, "n_layers", None)


def _census(compiled, n_dev):
    cost = compiled.cost_analysis()
    coll = collective_wire_bytes(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.total_wire_bytes,
        "per_op": dict(coll.per_op),
        "counts": dict(coll.counts),
    }


def _profile_census(arch_id, shape_name, mesh, n_dev):
    """Per-step FLOPs/bytes/collectives with unrolled layers.

    Deep models (> PROFILE_CAP layers) are profiled by LAYER DIFFERENCING:
    compile 2- and 4-layer unrolled variants; Q(L) = c + m·L is exact since
    layers are identical, so Q(n) = Q(2) + (n−2)·(Q(4)−Q(2))/2.
    """
    L = _n_layers_of(arch_id)
    if L is None or L <= PROFILE_CAP:
        cell = build_cell(arch_id, shape_name, mesh, unroll=True)
        _, c = _compile(cell, mesh)
        return _census(c, n_dev), {"profile_method": "unrolled-full"}
    qs = {}
    for l in (2, 4):
        cell = build_cell(arch_id, shape_name, mesh, unroll=True, n_layers=l)
        _, c = _compile(cell, mesh)
        qs[l] = _census(c, n_dev)

    def lerp(key):
        m = (qs[4][key] - qs[2][key]) / 2.0
        return qs[2][key] + m * (L - 2)

    out = {k: lerp(k) for k in ("flops", "bytes", "wire")}
    out["per_op"] = {
        k: qs[2]["per_op"][k]
        + (qs[4]["per_op"][k] - qs[2]["per_op"][k]) / 2.0 * (L - 2)
        for k in qs[2]["per_op"]
    }
    out["counts"] = {
        k: int(round(qs[2]["counts"][k]
                     + (qs[4]["counts"][k] - qs[2]["counts"][k]) / 2.0 * (L - 2)))
        for k in qs[2]["counts"]
    }
    return out, {"profile_method": f"layer-diff(2,4)->L={L}"}


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, profile: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))

    # --- EXEC compile: production scan step → memory evidence ---
    t0 = time.perf_counter()
    cell = build_cell(arch_id, shape_name, mesh, unroll=False)
    _, compiled = _compile(cell, mesh)
    t_exec = time.perf_counter() - t0
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes": int(ma.peak_memory_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    live = (mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"])

    # --- PROFILE: FLOPs / bytes / collective census (per-step truth) ---
    if profile:
        t1 = time.perf_counter()
        census, pmeta = _profile_census(arch_id, shape_name, mesh, n_dev)
        t_prof = time.perf_counter() - t1
    else:
        t_prof = 0.0
        census, pmeta = _census(compiled, n_dev), {"profile_method": "exec-scan"}
    cost = {"flops": census["flops"], "bytes accessed": census["bytes"]}

    class _Coll:
        def row(self):
            return {
                "wire_bytes": census["wire"],
                "counts": census["counts"],
                "bytes_by_kind": {k: v for k, v in census["per_op"].items() if v},
            }

    coll = _Coll()
    from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline

    ct = census["flops"] / PEAK_FLOPS
    mt = census["bytes"] / HBM_BW
    lt = census["wire"] / LINK_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    dominant = max(terms, key=terms.get)
    total_flops = census["flops"] * n_dev
    bound = max(ct, mt, lt)
    rl = Roofline(
        flops_per_dev=census["flops"], bytes_per_dev=census["bytes"],
        wire_bytes_per_dev=census["wire"], compute_s=ct, memory_s=mt,
        collective_s=lt, dominant=dominant, model_flops=cell.model_flops,
        useful_fraction=(cell.model_flops / total_flops) if total_flops else 0.0,
        roofline_fraction=(ct / bound) if bound > 0 else 0.0,
    )

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": cell.kind,
        "notes": cell.notes,
        "exec_compile_s": round(t_exec, 2),
        "profile_compile_s": round(t_prof, 2),
        "memory_analysis": mem,
        "live_bytes_per_device": int(live),
        "fits_16gb": bool(live < 16e9),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if k in ("flops", "bytes accessed")},
        "collectives": coll.row(),
        "roofline": rl.row(),
        "status": "ok",
        **pmeta,
    }
    if verbose:
        print(f"== {arch_id} × {shape_name} × {record['mesh']} ==")
        print(f"  memory_analysis(exec): {mem}")
        print(f"  live/device: {live/1e9:.2f} GB  fits16GB={record['fits_16gb']}")
        print(f"  cost_analysis(profile): flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {coll.row()}")
        print(f"  roofline: compute={rl.compute_s:.4e}s memory={rl.memory_s:.4e}s "
              f"collective={rl.collective_s:.4e}s dominant={rl.dominant} "
              f"useful={rl.useful_fraction:.3f}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-profile", action="store_true",
                    help="skip the unrolled profile compile (faster)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        targets = [(a, s) for a, s, _, skip in all_cells() if skip is None]
        skipped = [(a, s, skip) for a, s, _, skip in all_cells() if skip]
    elif args.arch and args.shape is None:
        arch = get_arch(args.arch)
        targets = [(args.arch, s) for s, c, skip in arch.cells() if skip is None]
        skipped = [(args.arch, s, skip) for s, c, skip in arch.cells() if skip]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets = [(args.arch, args.shape)]
        skipped = []

    for a, s, reason in skipped:
        rec = {"arch": a, "shape": s, "status": "skip", "reason": reason}
        with open(os.path.join(args.out, f"{a}__{s}__skip.json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(f"SKIP {a} × {s}: {reason}")

    failures = 0
    for a, s in targets:
        for mp in meshes:
            tag = "2x16x16" if mp else "16x16"
            path = os.path.join(args.out, f"{a}__{s}__{tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"cached {a} × {s} × {tag}")
                continue
            try:
                rec = run_cell(a, s, multi_pod=mp, profile=not args.no_profile)
            except Exception as e:  # record, keep sweeping
                failures += 1
                rec = {
                    "arch": a, "shape": s, "mesh": tag, "status": "fail",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:],
                }
                print(f"FAIL {a} × {s} × {tag}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            jax.clear_caches()  # bound compile-cache memory across the sweep
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
