"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Runs REAL training steps (reduced configs on CPU; the same code path scales
to the production mesh — the dry-run proves the sharded step compiles).
Fault tolerance: checkpoint/resume via CheckpointManager; `--preempt-at N`
simulates a node failure for testing.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import (
    gnn_full_batch,
    molecule_batches,
    recsys_batches,
    token_batches,
)
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import fit


def make_loss_and_data(arch_id: str, smoke: bool, batch: int, seq: int, seed: int):
    arch = get_arch(arch_id)
    cfg = arch.make_smoke_config() if smoke else arch.make_config()
    key = jax.random.PRNGKey(seed)
    if arch.family == "lm":
        from repro.models.transformer import init_params, loss_fn

        params = init_params(cfg, key)
        data = token_batches(batch, seq, cfg.vocab, seed=seed)
        return cfg, params, (lambda p, b: loss_fn(cfg, p, b)), data
    if arch.family == "recsys":
        from repro.models.recsys import init_sasrec, sasrec_train_loss

        params = init_sasrec(cfg, key)
        data = recsys_batches(batch, cfg.seq_len, cfg.n_items, seed=seed)
        return cfg, params, (lambda p, b: sasrec_train_loss(cfg, p, b)), data
    # gnn
    if arch_id in ("mace", "nequip"):
        if arch_id == "mace":
            from repro.models.gnn.mace import init_mace as init, mace_loss as loss
        else:
            from repro.models.gnn.nequip import init_nequip as init, nequip_loss as loss
        params = init(cfg, key)
        data = molecule_batches(max(batch // 8, 2), 10, 20, seed=seed)
        return cfg, params, (lambda p, b: loss(cfg, p, b)), data
    from repro.mesh.graphs import rmat_graph

    g = rmat_graph(256, 1024, seed=seed)
    if arch_id == "graphcast":
        from repro.models.gnn.graphcast import graphcast_loss as loss, init_graphcast as init

        b = gnn_full_batch(g, d_feat=cfg.d_in, d_out=cfg.n_vars, seed=seed)
    else:
        from repro.models.gnn.meshgraphnet import init_mgn as init, mgn_loss as loss

        b = gnn_full_batch(g, d_feat=cfg.d_in, d_out=cfg.d_out, seed=seed)
    params = init(cfg, key)
    return cfg, params, (lambda p, bb: loss(cfg, p, bb)), iter(lambda: b, None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full published config (default: smoke config)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, params, loss_fn, data = make_loss_and_data(
        args.arch, smoke=not args.full, batch=args.batch, seq=args.seq,
        seed=args.seed,
    )
    from repro.models.common import count_params

    print(f"[train] arch={args.arch} params={count_params(params):,} "
          f"steps={args.steps}")

    hook = None
    if args.preempt_at is not None:
        def hook(step, _n=args.preempt_at):
            if step == _n:
                raise SystemExit(f"[train] simulated preemption at step {_n}")

    res = fit(
        loss_fn, params, Prefetcher(data, depth=2),
        steps=args.steps,
        opt_cfg=AdamWConfig(lr=args.lr, weight_decay=0.0),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=max(args.steps // 20, 1), preemption_hook=hook,
    )
    first = res.losses[0][1] if res.losses else float("nan")
    last = res.losses[-1][1] if res.losses else float("nan")
    print(f"[train] done: loss {first:.4f} → {last:.4f}")


if __name__ == "__main__":
    main()
