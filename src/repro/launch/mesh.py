"""Production mesh definitions.

A *function* (not a module-level constant) so importing this module never
touches JAX device state — only launch/dryrun.py forces 512 host devices.

Topology: one pod = 16×16 = 256 chips (v5e pod), axes ("data", "model");
multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") where the
"pod" axis crosses DCN/ICI pod boundaries and carries only data-parallel
traffic (gradient all-reduce) by construction of the sharding rules.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_devices: int | None = None, axis: str = "data"):
    """Small mesh over however many (host) devices exist — tests only."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,), axis_types=(AxisType.Auto,))
