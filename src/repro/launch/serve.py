"""Serving launcher: batched KV-cache autoregressive decoding.

`python -m repro.launch.serve --arch tinyllama-1.1b --batch 4 --steps 32`
runs prefill + N decode steps on the smoke config (CPU) — the same
prefill/decode_step functions the dry-run lowers at production shape.
"""

from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_arch
from repro.guard import GuardError, check_positive_int


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    # sizes stay untyped here: the guard's front door turns a bad value
    # into a diagnostic instead of argparse's bare "invalid int value"
    ap.add_argument("--batch", default=4)
    ap.add_argument("--prompt-len", default=16)
    ap.add_argument("--steps", default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    try:
        args.batch = check_positive_int("batch", args.batch)
        args.prompt_len = check_positive_int("prompt-len", args.prompt_len)
        args.steps = check_positive_int("steps", args.steps, minimum=2)
        if not (np.isfinite(args.temperature) and args.temperature >= 0):
            raise GuardError(
                "bad-argument",
                f"temperature must be a finite float >= 0, "
                f"got {args.temperature!r}",
                details={"name": "temperature",
                         "value": args.temperature})
    except GuardError as err:
        print(err.diagnostic(), file=sys.stderr)
        sys.exit(2)

    arch = get_arch(args.arch)
    assert arch.family == "lm", "serve launcher is for LM archs"
    cfg = arch.make_config() if args.full else arch.make_smoke_config()
    from repro.models.transformer import decode_step, init_params, prefill

    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    max_seq = args.prompt_len + args.steps

    # One serve-run trace: a prefill span + one span per decode step.  The
    # per-step spans block on the step's result — that per-token sync IS
    # the serving latency a client sees, and it feeds the p50/p99 summary.
    root = obs.trace("serve", arch=cfg.name, batch=args.batch,
                     steps=args.steps)
    with root:
        with obs.timed("prefill", prompt_len=args.prompt_len) as t_pre:
            logits, cache = jax.jit(
                lambda p, t: prefill(cfg, p, t))(params, prompts)
            cache = {
                k: jnp.pad(v,
                           ((0, 0), (0, 0), (0, args.steps), (0, 0), (0, 0)))
                for k, v in cache.items()
            }
            jax.block_until_ready(logits)
        t_prefill = t_pre.seconds

        step_fn = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos)
        )
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out_tokens = [tok]
        step_secs = []
        for i in range(args.steps - 1):
            with obs.timed("decode_step", step=i) as t_step:
                logits, cache = step_fn(params, cache, tok,
                                        jnp.int32(args.prompt_len + i))
                if args.temperature > 0:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(
                        sub, logits[:, -1] / args.temperature
                    )[:, None].astype(jnp.int32)
                else:
                    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
                jax.block_until_ready(tok)
            step_secs.append(t_step.seconds)
            out_tokens.append(tok)
    t_decode = sum(step_secs)

    toks = np.asarray(jnp.concatenate(out_tokens, axis=1))
    tps = args.batch * (args.steps - 1) / max(t_decode, 1e-9)
    pct = obs.percentiles(step_secs)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prefill={t_prefill*1e3:.1f}ms decode={t_decode*1e3:.1f}ms "
          f"({tps:.1f} tok/s)")
    print(f"[serve] decode step p50={pct['p50']*1e3:.2f}ms "
          f"p99={pct['p99']*1e3:.2f}ms over {len(step_secs)} steps")
    print(f"[serve] sample token ids: {toks[0, :12].tolist()}")


if __name__ == "__main__":
    main()
