"""Host-side double-buffered prefetcher (compute/IO overlap).

JAX dispatch is async; overlapping the *host* data generation with device
compute needs a thread.  `Prefetcher` keeps `depth` batches in flight —
the standard input-pipeline pattern for TPU training loops.
"""

from __future__ import annotations

import queue
import threading


class Prefetcher:
    def __init__(self, iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._src = iterator
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self._src:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is StopIteration:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
