"""Synthetic-but-structured data generators for every model family.

LM streams are Zipf-distributed token sequences with local n-gram structure
(so the loss actually falls during the end-to-end examples); GNN batches
derive features/targets from graph structure; recsys interactions follow a
power-law item popularity.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.mesh.graphs import Graph, radius_molecule_batch
from repro.models.gnn.common import GraphBatch


def lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> dict:
    """Zipf tokens with a deterministic bigram drift (learnable signal)."""
    z = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    drift = (np.cumsum(z, axis=1) * 7) % vocab
    toks = ((z + drift) // 2 % vocab).astype(np.int32)
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def token_batches(batch: int, seq: int, vocab: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        yield lm_batch(rng, batch, seq, vocab)


def gnn_full_batch(graph: Graph, d_feat: int, d_out: int, *, seed: int = 0,
                   dtype=np.float32) -> GraphBatch:
    """Features = random projection of degree/neighborhood stats; targets =
    1-hop smoothed features (a learnable structural signal)."""
    rng = np.random.default_rng(seed)
    n = graph.n
    feat = rng.normal(size=(n, d_feat)).astype(dtype)
    deg = graph.degrees.astype(dtype)
    feat[:, 0] = (deg - deg.mean()) / max(deg.std(), 1.0)
    tgt = rng.normal(size=(n, d_out)).astype(dtype) * 0.1
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(graph.indices.astype(np.int32)),
        edge_dst=jnp.asarray(graph.rows.astype(np.int32)),
        node_mask=jnp.ones((n,), jnp.float32),
        edge_mask=jnp.ones((graph.nnz,), jnp.float32),
        targets=jnp.asarray(tgt),
    )


def molecule_batches(n_graphs: int, n_nodes: int, n_edges: int, *, seed: int = 0):
    """Batched molecules with synthetic pairwise-potential energies."""
    rng = np.random.default_rng(seed)
    s = seed
    while True:
        pos, spec, esrc, edst = radius_molecule_batch(
            n_graphs, n_nodes, n_edges, seed=s
        )
        s += 1
        # toy LJ-like target energy per graph
        d = np.linalg.norm(pos[esrc] - pos[edst], axis=1)
        e_edge = 4.0 * ((0.8 / d) ** 12 - (0.8 / d) ** 6)
        gids = np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32)
        e_graph = np.zeros(n_graphs)
        np.add.at(e_graph, gids[esrc], 0.5 * np.clip(e_edge, -5, 5))
        yield GraphBatch(
            node_feat=jnp.zeros((pos.shape[0], 0), jnp.float32),
            edge_src=jnp.asarray(esrc.astype(np.int32)),
            edge_dst=jnp.asarray(edst.astype(np.int32)),
            node_mask=jnp.ones((pos.shape[0],), jnp.float32),
            edge_mask=jnp.ones((len(esrc),), jnp.float32),
            positions=jnp.asarray(pos.astype(np.float32)),
            species=jnp.asarray(spec.astype(np.int32)),
            graph_ids=jnp.asarray(gids),
            targets=jnp.asarray(e_graph.astype(np.float32)),
            n_graphs=n_graphs,
        )


def recsys_batches(batch: int, seq: int, n_items: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        # power-law item popularity, shifted by 1 (0 = padding)
        seqs = (rng.zipf(1.2, size=(batch, seq + 1)) % (n_items - 1) + 1).astype(
            np.int32
        )
        neg = (rng.integers(1, n_items, size=(batch, seq))).astype(np.int32)
        yield {
            "item_seq": jnp.asarray(seqs[:, :-1]),
            "pos_items": jnp.asarray(seqs[:, 1:]),
            "neg_items": jnp.asarray(neg),
        }
