"""Synthetic data pipelines with host-side double-buffered prefetch."""

from repro.data.pipeline import Prefetcher
from repro.data.synthetic import (
    gnn_full_batch,
    lm_batch,
    molecule_batches,
    recsys_batches,
    token_batches,
)
