"""Synthetic data pipelines with host-side double-buffered prefetch."""

from repro.data.synthetic import (
    token_batches,
    lm_batch,
    gnn_full_batch,
    molecule_batches,
    recsys_batches,
)
from repro.data.pipeline import Prefetcher
