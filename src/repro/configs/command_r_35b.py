"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.shapes import LM_SHAPES, LM_SKIPS
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="command-r-35b", n_layers=40, d_model=8192, n_heads=64,
        n_kv_heads=8, d_head=128, d_ff=22528, vocab=256000, rope_theta=8e6,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="command-r-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=176, vocab=1024, dtype=jnp.float32,
    )


ARCH = ArchDef(
    arch_id="command-r-35b", family="lm",
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES, skips=dict(LM_SKIPS),
)
