"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.shapes import LM_SHAPES, LM_SKIPS
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, d_head=64, d_ff=5632, vocab=32000, rope_theta=1e4,
    )


def make_sliding_window_config(window: int = 4096) -> LMConfig:
    """Beyond-table variant: lets long_500k compile sub-quadratically."""
    import dataclasses

    return dataclasses.replace(make_config(), attn="sliding_window", window=window)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="tinyllama-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, dtype=jnp.float32,
    )


ARCH = ArchDef(
    arch_id="tinyllama-1.1b", family="lm", source="arXiv:2401.02385; hf",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES, skips=dict(LM_SKIPS),
)
