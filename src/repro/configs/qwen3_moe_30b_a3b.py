"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936, MoE 128e top-8.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.shapes import LM_SHAPES, LM_SKIPS
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=768, vocab=151936, rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, n_shared=0, d_ff_expert=768,
                      capacity_factor=1.25),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=64, vocab=512, dtype=jnp.float32,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_ff_expert=32,
                      capacity_factor=2.0),
    )


ARCH = ArchDef(
    arch_id="qwen3-moe-30b-a3b", family="lm", source="hf:Qwen/Qwen3-30B-A3B; hf",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES, skips=dict(LM_SKIPS),
)
