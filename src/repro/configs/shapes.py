"""Assigned input-shape suites (verbatim from the assignment)."""

from __future__ import annotations

from repro.configs.base import ShapeCell
from repro.models.gnn.sampler import subgraph_capacity

LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train",
                          {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeCell("prefill_32k", "prefill",
                             {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeCell("decode_32k", "decode",
                            {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeCell("long_500k", "decode",
                           {"seq_len": 524288, "global_batch": 1}),
}

# long_500k requires sub-quadratic attention; all five assigned LM archs are
# pure full-attention (GQA) → per instructions the cell is skipped and noted
# in DESIGN.md §6.  tinyllama additionally exposes an optional
# sliding-window variant exercised OUTSIDE the 40-cell table.
LM_SKIPS = {
    "long_500k": "pure full-attention arch (assignment rule: skip; "
                 "see DESIGN.md §6)",
}

_MB_NODES, _MB_EDGES = subgraph_capacity(1024, (15, 10))

GNN_SHAPES = {
    "full_graph_sm": ShapeCell("full_graph_sm", "train",
                               {"n_nodes": 2708, "n_edges": 10556,
                                "d_feat": 1433}),
    "minibatch_lg": ShapeCell("minibatch_lg", "train",
                              {"n_nodes": 232965, "n_edges": 114615892,
                               "batch_nodes": 1024, "fanout": (15, 10),
                               "sub_nodes": _MB_NODES, "sub_edges": _MB_EDGES,
                               "d_feat": 602}),
    "ogb_products": ShapeCell("ogb_products", "train",
                              {"n_nodes": 2449029, "n_edges": 61859140,
                               "d_feat": 100}),
    "molecule": ShapeCell("molecule", "train",
                          {"n_nodes": 30, "n_edges": 64, "batch": 128}),
}

RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeCell("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1_000_000}),
}
