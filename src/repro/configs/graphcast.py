"""graphcast [gnn] — encoder-processor-decoder mesh GNN
[arXiv:2212.12794; unverified].

n_layers=16 d_hidden=512 mesh_refinement=6 aggregator=sum n_vars=227.
Adaptation: processor runs on the assigned generic graph (DESIGN.md §6).
"""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.graphcast import GraphCastConfig


def make_config(d_in: int = 227) -> GraphCastConfig:
    return GraphCastConfig(name="graphcast", n_layers=16, d_hidden=512,
                           mesh_refinement=6, n_vars=227, d_in=d_in)


def make_smoke_config() -> GraphCastConfig:
    return GraphCastConfig(name="graphcast-smoke", n_layers=2, d_hidden=32,
                           n_vars=8, d_in=8)


ARCH = ArchDef(
    arch_id="graphcast", family="gnn", source="arXiv:2212.12794; unverified",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES,
)
