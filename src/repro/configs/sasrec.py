"""sasrec [recsys] — self-attentive sequential recommendation
[arXiv:1808.09781; paper].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50; 10⁶-item table.
"""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys.sasrec import SASRecConfig


def make_config() -> SASRecConfig:
    return SASRecConfig(name="sasrec", n_items=1_000_000, embed_dim=50,
                        n_blocks=2, n_heads=1, seq_len=50, d_ff=50)


def make_smoke_config() -> SASRecConfig:
    return SASRecConfig(name="sasrec-smoke", n_items=1000, embed_dim=16,
                        n_blocks=2, n_heads=1, seq_len=10, d_ff=16)


ARCH = ArchDef(
    arch_id="sasrec", family="recsys", source="arXiv:1808.09781; paper",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=RECSYS_SHAPES,
)
