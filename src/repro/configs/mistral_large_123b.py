"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.shapes import LM_SHAPES, LM_SKIPS
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
        n_kv_heads=8, d_head=128, d_ff=28672, vocab=32768, rope_theta=1e6,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="mistral-large-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=224, vocab=512, dtype=jnp.float32,
    )


ARCH = ArchDef(
    arch_id="mistral-large-123b", family="lm",
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES, skips=dict(LM_SKIPS),
)
