"""Architecture registry: `--arch <id>` resolves here."""

from repro.configs import (
    command_r_35b,
    deepseek_moe_16b,
    graphcast,
    mace,
    meshgraphnet,
    mistral_large_123b,
    nequip,
    qwen3_moe_30b_a3b,
    sasrec,
    tinyllama_1_1b,
)
from repro.configs.base import ArchDef, ShapeCell

REGISTRY = {
    m.ARCH.arch_id: m.ARCH
    for m in (
        deepseek_moe_16b,
        qwen3_moe_30b_a3b,
        mistral_large_123b,
        tinyllama_1_1b,
        command_r_35b,
        mace,
        nequip,
        graphcast,
        meshgraphnet,
        sasrec,
    )
}


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}"
        )
    return REGISTRY[arch_id]


def all_cells():
    """Every (arch × shape) cell with its skip reason (None = runnable)."""
    for arch_id, arch in REGISTRY.items():
        for shape_name, cell, skip in arch.cells():
            yield arch_id, shape_name, cell, skip
