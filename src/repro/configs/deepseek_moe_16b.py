"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400, MoE 64e top-6.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.configs.shapes import LM_SHAPES, LM_SKIPS
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=1408, vocab=102400, rope_theta=1e4,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                      capacity_factor=1.25),
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=64, vocab=512, dtype=jnp.float32,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32,
                      capacity_factor=2.0),
    )


ARCH = ArchDef(
    arch_id="deepseek-moe-16b", family="lm", source="arXiv:2401.06066; hf",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES, skips=dict(LM_SKIPS),
)
