"""mace [gnn] — higher-order equivariant message passing (E(3)-ACE)
[arXiv:2206.07697; paper].

n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8.
"""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.mace import MACEConfig


def make_config(d_feat_in: int = 0) -> MACEConfig:
    return MACEConfig(name="mace", n_layers=2, d_hidden=128, l_max=2,
                      correlation=3, n_rbf=8, cutoff=5.0, d_feat_in=d_feat_in)


def make_smoke_config() -> MACEConfig:
    return MACEConfig(name="mace-smoke", n_layers=2, d_hidden=8, l_max=2,
                      correlation=3, n_rbf=4, cutoff=5.0)


ARCH = ArchDef(
    arch_id="mace", family="gnn", source="arXiv:2206.07697; paper",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES,
)
