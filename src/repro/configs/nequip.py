"""nequip [gnn] — O(3)-equivariant interatomic potentials
[arXiv:2101.03164; paper].

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5.
"""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.nequip import NequIPConfig


def make_config(d_feat_in: int = 0) -> NequIPConfig:
    return NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                        n_rbf=8, cutoff=5.0, d_feat_in=d_feat_in)


def make_smoke_config() -> NequIPConfig:
    return NequIPConfig(name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2,
                        n_rbf=4, cutoff=5.0)


ARCH = ArchDef(
    arch_id="nequip", family="gnn", source="arXiv:2101.03164; paper",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES,
)
