"""Architecture registry scaffolding."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assigned suite."""

    name: str
    kind: str         # train | prefill | decode | serve | retrieval
    meta: dict        # family-specific shape numbers

    def __getitem__(self, k):
        return self.meta[k]


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                      # lm | gnn | recsys
    source: str                      # citation tag from the assignment
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict
    skips: dict = dataclasses.field(default_factory=dict)  # shape → reason

    def cells(self):
        for name, cell in self.shapes.items():
            yield name, cell, self.skips.get(name)
