"""meshgraphnet [gnn] [arXiv:2010.03409; unverified].

n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2.
"""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn.meshgraphnet import MGNConfig


def make_config(d_in: int = 3, d_out: int = 3) -> MGNConfig:
    return MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                     mlp_layers=2, d_in=d_in, d_out=d_out)


def make_smoke_config() -> MGNConfig:
    return MGNConfig(name="meshgraphnet-smoke", n_layers=2, d_hidden=16,
                     mlp_layers=2, d_in=3, d_out=3)


ARCH = ArchDef(
    arch_id="meshgraphnet", family="gnn", source="arXiv:2010.03409; unverified",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=GNN_SHAPES,
)
