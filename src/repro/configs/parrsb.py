"""The paper's own workload: parRSB partitioning configurations.

Mesh-size / processor-count grids mirroring the paper's experiments,
scaled to this container (benchmarks extrapolate; see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ParRSBConfig:
    name: str = "parrsb"
    # Table 1–2 analogue: pebble-bed-like mesh, Lanczos vs inverse iteration
    pebble_dims: tuple = (24, 24, 24)
    pebble_pebbles: int = 10
    quality_parts: tuple = (8, 16, 32, 64)
    # Table 4 analogue: weak scaling on cube meshes, E/P held constant
    weak_e_per_p: int = 1000
    weak_parts: tuple = (8, 16, 32, 64, 128)
    lanczos_window: int = 30
    max_restarts: int = 50
    tol: float = 1e-3


def make_config() -> ParRSBConfig:
    return ParRSBConfig()


def make_smoke_config() -> ParRSBConfig:
    return ParRSBConfig(name="parrsb-smoke", pebble_dims=(8, 8, 8),
                        pebble_pebbles=3, quality_parts=(4,),
                        weak_e_per_p=64, weak_parts=(4, 8))
