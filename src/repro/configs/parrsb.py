"""The paper's own workload: parRSB partitioning configurations.

Mesh-size / processor-count grids mirroring the paper's experiments,
scaled to this container (benchmarks extrapolate; see EXPERIMENTS.md),
plus the named partition-pipeline presets the front door and benchmarks
compose from (pre → bisect → post; see ``repro.core.pipeline``).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ParRSBConfig:
    name: str = "parrsb"
    # Table 1–2 analogue: pebble-bed-like mesh, Lanczos vs inverse iteration
    pebble_dims: tuple = (24, 24, 24)
    pebble_pebbles: int = 10
    quality_parts: tuple = (8, 16, 32, 64)
    # Table 4 analogue: weak scaling on cube meshes, E/P held constant
    weak_e_per_p: int = 1000
    weak_parts: tuple = (8, 16, 32, 64, 128)
    lanczos_window: int = 30
    max_restarts: int = 50
    tol: float = 1e-3
    # Post-bisection quality stage (repair + FM boundary refinement)
    refine_sweeps: int = 4
    kway_passes: int = 8
    balance_tol: float = 0.05
    pipeline: str = "default"
    # Multilevel V-cycle knobs (bisect="multilevel"): coarsen to
    # ~coarse_factor*nparts nodes; per-level boundary FM is capped at
    # ml_refine_passes sweeps with a tight stall so refinement stays
    # O(boundary) at every level.
    coarse_factor: int = 8
    ml_refine_passes: int = 2
    ml_stall: int = 32
    # Fault-tolerance guard (repro.guard): validation front door, solver
    # escalation ladder, output-invariant finalizer.  None defers to
    # REPRO_GUARD (default on); a healthy guarded run is bit-identical to
    # guard-off, so presets stay comparable across the switch.
    guard: bool | None = None


def make_config() -> ParRSBConfig:
    return ParRSBConfig()


def make_smoke_config() -> ParRSBConfig:
    return ParRSBConfig(name="parrsb-smoke", pebble_dims=(8, 8, 8),
                        pebble_pebbles=3, quality_parts=(4,),
                        weak_e_per_p=64, weak_parts=(4, 8))


# ---------------------------------------------------------------------------
# Pipeline presets: named (pre, bisect, post) compositions
# ---------------------------------------------------------------------------

PIPELINE_PRESETS: dict = {
    # The parRSB shape: per-level RCB reorder, batched spectral bisection,
    # repair + FM smoothing.  What `partition()` runs by default.
    "default": dict(pre="rcb", bisect="rsb-batched",
                    post=("repair", "refine")),
    # Raw bisection labels (PR 3 behaviour) — parity baselines, debugging.
    "raw": dict(pre="rcb", bisect="rsb-batched", post=()),
    # Quality-first: inertial per-level reorder, hill-climbing k-way FM
    # post chain with a deeper climb and tighter corridor.  The post chain
    # flipped from greedy sweeps to repair+kway once the multilevel bisect
    # stage landed (PR 5's core/README.md rationale: with a cheap bisector
    # available, post wall-share is negligible and the stronger refiner
    # wins on every bench combination); the greedy chain remains the
    # default for "default"/"raw"-style fast presets.
    "quality": dict(pre="rib", bisect="rsb-batched",
                    post=("repair", "kway"),
                    post_kw=dict(passes=12, balance_tol=0.03)),
    # Geometry-only fast path: RCB labels healed by the post stage — no
    # eigensolves at all (Kong et al.'s point: the repair/balance stage is
    # where the cheap-bisector pipelines earn their keep).
    "geometric": dict(pre="none", bisect="rcb", post=("repair", "refine")),
    # Recursive reference engine, refined — parity testing at full quality.
    "reference": dict(pre="rcb", bisect="rsb-recursive",
                      post=("repair", "refine")),
    # Hill-climbing k-way FM post stage (repro.core.kway): negative-gain
    # prefixes + rollback recover cut the greedy sweeps cannot.  Greedy
    # stays the "default" preset until the bench gate proves k-way ≥
    # greedy across suites.
    "kway": dict(pre="rcb", bisect="rsb-batched", post=("repair", "kway")),
    # Quality-first k-way: inertial reorder, deeper climb, tighter corridor.
    "quality-kway": dict(pre="rib", bisect="rsb-batched",
                         post=("repair", "kway"),
                         post_kw=dict(passes=12, balance_tol=0.03)),
    # Multilevel k-way V-cycle (repro.core.multilevel): coarsen →
    # partition-coarsest → prolong+refine, no eigensolves on the fine
    # graph — the raw-speed engine at scale.  Knobs come from the config
    # (coarse_factor/ml_stall/ml_refine_passes) via make_pipeline.
    "multilevel": dict(pre="none", bisect="multilevel",
                       post=("repair", "kway")),
    # Quality-leaning V-cycle: coarser target (shallower ladder), more
    # refinement per level, deeper final climb.
    "multilevel-quality": dict(pre="none", bisect="multilevel",
                               post=("repair", "kway"),
                               bisect_kw=dict(coarse_factor=16,
                                              refine_passes=4, stall=128),
                               post_kw=dict(passes=12, balance_tol=0.03)),
}


def make_pipeline(preset: str | None = None, *,
                  config: ParRSBConfig | None = None, **overrides):
    """Build a :class:`~repro.core.pipeline.PartitionPipeline` from a named
    preset.  The config supplies the base post-stage knobs
    (``refine_sweeps``/``balance_tol``) and the default preset name
    (``pipeline``); preset-specific ``post_kw`` overrides them and keyword
    overrides win over both (`post_kw` merges, other fields replace)."""
    from repro.core.pipeline import PartitionPipeline

    cfg = make_config() if config is None else config
    preset = cfg.pipeline if preset is None else preset
    if preset not in PIPELINE_PRESETS:
        raise ValueError(
            f"unknown pipeline preset: {preset!r} "
            f"(have {tuple(PIPELINE_PRESETS)})")
    spec = {k: (dict(v) if isinstance(v, dict) else v)
            for k, v in PIPELINE_PRESETS[preset].items()}
    post_kw = dict(sweeps=cfg.refine_sweeps, passes=cfg.kway_passes,
                   balance_tol=cfg.balance_tol)
    post_kw.update(spec.pop("post_kw", {}))
    post_kw.update(overrides.pop("post_kw", {}))
    bisect_kw = {}
    if spec.get("bisect") == "multilevel":
        # V-cycle presets get their base knobs from the config, same
        # layering as post_kw: preset bisect_kw overrides, caller wins.
        bisect_kw = dict(coarse_factor=cfg.coarse_factor,
                         refine_passes=cfg.ml_refine_passes,
                         stall=cfg.ml_stall, balance_tol=cfg.balance_tol)
    bisect_kw.update(spec.pop("bisect_kw", {}))
    bisect_kw.update(overrides.pop("bisect_kw", {}))
    spec.setdefault("guard", cfg.guard)
    spec.update(overrides)
    return PartitionPipeline(post_kw=post_kw, bisect_kw=bisect_kw, **spec)
