"""Solver health policy, escalation ladder, and the output invariant.

One policy object (:class:`GuardPolicy`) replaces the scattered inline
``isfinite`` checks: every Fiedler solve in both RSB engines is admitted
through a :class:`SolverGuard`, which detects breakdown (non-finite
λ/residual, a solver-reported breakdown flag, a degenerate vector whose
sign split would empty one side, a hopelessly stalled residual) and
escalates deterministically:

1. retry with a seed-derived perturbation — the retry seed is a function
   of ``(seed, level, p_lo, attempt)``, so a retry never replays the
   identical failing solve (counted in ``guard_retries``);
2. switch method (lanczos <-> inverse) — counted in ``guard_fallbacks``;
3. drop to the geometric/index fallback vector — always succeeds, counted
   in ``guard_fallbacks`` and tagged in ``GuardReport.degraded``.

The guard carries a per-stage attempt budget and an optional wall-clock
deadline; once the deadline expires every remaining solve goes straight
to the fallback rung.  :func:`enforce_output` is the pipeline's graceful
degradation closer: it guarantees valid labels, connected parts, and the
weight corridor even when every spectral attempt failed.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.guard import chaos
from repro.guard.errors import GuardReport
from repro.mesh.graphs import connected_labels

#: A residual this many times |λ| is garbage, not "slow convergence".
_RESIDUAL_LIMIT = 1e4


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Attempt budgets and repair switches for one pipeline run."""

    enabled: bool = True
    sanitize: bool = False        # validation repairs instead of raising
    max_retries: int = 1          # seed-perturbed retries per solve
    switch_method: bool = True    # lanczos <-> inverse rung
    deadline: float | None = None  # seconds per bisect stage
    balance_tol: float = 0.05     # corridor used by enforce_output

    @classmethod
    def from_kw(cls, kw: dict | None) -> "GuardPolicy":
        kw = dict(kw or {})
        kw.pop("chaos", None)
        kw.pop("chaos_seed", None)
        kw.pop("chaos_rate", None)
        return cls(**kw)


def corrupt_result(res, *, level: int, p_lo: int, attempt: int = 0):
    """Apply the solver-facing chaos sites to a Fiedler result."""
    if res is None:
        return None
    if chaos.should_fire("solver_nan", level, p_lo, attempt):
        v = np.asarray(res.vector, np.float64).copy()
        v[:: max(1, v.size // 4)] = np.nan
        res = dataclasses.replace(res, vector=v, eigenvalue=float("nan"))
    if chaos.should_fire("empty_split", level, p_lo, attempt):
        v = np.zeros(np.asarray(res.vector).shape, np.float64)
        res = dataclasses.replace(res, vector=v)
    return res


def failure_reason(res, size: int) -> str | None:
    """Why a Fiedler result is unusable, or ``None`` when healthy."""
    if res is None:
        return "exception"
    if getattr(res, "breakdown", False):
        return "breakdown"
    v = np.asarray(res.vector)
    if not np.all(np.isfinite(v)):
        return "nonfinite-vector"
    lam, residual = float(res.eigenvalue), float(res.residual)
    if not (np.isfinite(lam) and np.isfinite(residual)):
        return "nonfinite-eigenpair"
    if size > 1 and float(np.ptp(v)) <= 1e-12 * max(
            1.0, float(np.max(np.abs(v)))):
        return "degenerate-vector"      # sign split would empty one side
    if residual > _RESIDUAL_LIMIT * max(abs(lam), 1e-12):
        return "stalled-residual"
    return None


def fallback_vector(size: int, coords=None) -> np.ndarray:
    """Deterministic last-rung Fiedler surrogate: the longest coordinate
    axis (an RCB-style geometric ordering) or the index ramp."""
    if coords is not None:
        c = np.asarray(coords, np.float64).reshape(size, -1)
        spans = np.ptp(c, axis=0)
        axis = int(np.argmax(spans))
        if float(spans[axis]) > 0:
            return c[:, axis].copy()
    return np.arange(size, dtype=np.float64)


class SolverGuard:
    """Admits every Fiedler solve of one bisect stage through the
    escalation ladder.  Create one per stage run; it carries the stage
    deadline and streams events into the shared :class:`GuardReport`."""

    def __init__(self, policy: GuardPolicy, *, seed: int, method: str,
                 report: GuardReport | None = None):
        self.policy = policy
        self.seed = int(seed)
        self.method = method
        self.report = report if report is not None else GuardReport()
        self._t0 = time.monotonic()
        self._deadline = (None if policy.deadline is None
                          else self._t0 + float(policy.deadline))
        self._chaos_deadline = chaos.enabled("deadline")

    def expired(self) -> bool:
        if self._chaos_deadline:
            return True
        return (self._deadline is not None
                and time.monotonic() > self._deadline)

    def admit(self, res, *, level: int, p_lo: int, size: int,
              attempt: int = 0):
        """Chaos-corrupt (when enabled) then health-check one result.
        Returns ``(res, why)`` with ``why is None`` for a healthy solve."""
        res = corrupt_result(res, level=level, p_lo=p_lo, attempt=attempt)
        return res, failure_reason(res, size)

    def _mark_deadline(self) -> None:
        if not self.report.deadline_expired:
            self.report.deadline_expired = True
            self.report.degrade("deadline-expired")
            obs.counter_add("guard_deadline_expired", 1)

    def rescue(self, solve_fn, first_why: str, *, level: int, p_lo: int,
               size: int, coords=None):
        """Run the ladder for one failed solve.  ``solve_fn(method, seed)``
        re-solves the node's problem; exceptions count as failures.
        Always returns a usable FiedlerResult."""
        from repro.core.fiedler import FiedlerResult

        why = first_why
        if not self.expired():
            # Rung 1: seed-perturbed retries with the primary method.
            for attempt in range(1, self.policy.max_retries + 1):
                res = self._attempt(solve_fn, self.method,
                                    level, p_lo, attempt)
                res, why = self.admit(res, level=level, p_lo=p_lo,
                                      size=size, attempt=attempt)
                self.report.retries += 1
                obs.counter_add("guard_retries", 1)
                if why is None:
                    return res
                if self.expired():
                    break
            # Rung 2: switch solver family.
            if self.policy.switch_method and not self.expired():
                alt = "inverse" if self.method == "lanczos" else "lanczos"
                attempt = self.policy.max_retries + 1
                res = self._attempt(solve_fn, alt, level, p_lo, attempt)
                res, why = self.admit(res, level=level, p_lo=p_lo,
                                      size=size, attempt=attempt)
                self.report.fallbacks += 1
                obs.counter_add("guard_fallbacks", 1)
                if why is None:
                    self.report.degrade(
                        f"solver:switched-to-{alt}@L{level}:{p_lo}")
                    return res
        else:
            self._mark_deadline()
        if self.expired():
            self._mark_deadline()
        # Rung 3: deterministic geometric/index fallback — cannot fail.
        vec = fallback_vector(size, coords)
        self.report.fallbacks += 1
        obs.counter_add("guard_fallbacks", 1)
        kind = "geom" if coords is not None else "index"
        self.report.degrade(f"solver:fallback-{kind}@L{level}:{p_lo}"
                            f" ({why})")
        return FiedlerResult(vector=vec, eigenvalue=0.0, residual=0.0,
                             iterations=0, method=f"fallback-{kind}",
                             breakdown=True)

    def _attempt(self, solve_fn, method: str, level: int, p_lo: int,
                 attempt: int):
        from repro.core.rsb import _node_seed
        try:
            return solve_fn(method,
                            _node_seed(self.seed, level, p_lo, attempt))
        except Exception:
            return None


# ---------------------------------------------------------------------------
# Output invariant: check + graceful-degradation closer
# ---------------------------------------------------------------------------

def count_disconnected(graph, parts: np.ndarray, nparts: int) -> int:
    """Number of extra fragments beyond one component per non-empty part."""
    rows, cols = graph.rows, graph.indices
    same = parts[rows] == parts[cols]
    # Every component of the same-part-filtered graph lies inside exactly
    # one part, so: fragments = components - non-empty parts.
    labels = connected_labels(graph.n, rows[same], cols[same])
    return int(np.unique(labels).size - np.unique(parts).size)


def check_output(graph, parts, nparts: int, *, weights=None,
                 balance_tol: float = 0.05,
                 expected_disconnected: int = 0) -> list:
    """Problems with a finished labeling (empty list == invariant holds)."""
    n = int(graph.n)
    problems: list = []
    if parts is None or np.asarray(parts).shape != (n,):
        return ["labels-missing"]
    p = np.asarray(parts)
    if not np.issubdtype(p.dtype, np.integer):
        return ["labels-not-integer"]
    if p.size and (p.min() < 0 or p.max() >= nparts):
        return [f"labels-out-of-range [{p.min()}, {p.max()}] "
                f"vs nparts={nparts}"]
    extra = count_disconnected(graph, p, nparts)
    if extra > expected_disconnected:
        problems.append(f"disconnected-parts: {extra} fragments")
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    pw = np.bincount(p, weights=w, minlength=nparts)
    mean = float(w.sum()) / nparts
    cap = (1.0 + balance_tol) * mean
    if float(pw.max(initial=0.0)) > cap * (1.0 + 1e-9):
        problems.append(f"corridor: max part weight {pw.max():.4g} "
                        f"> cap {cap:.4g}")
    return problems


def _balanced_reassign(n: int, nparts: int, weights) -> np.ndarray:
    """Deterministic zero-assumption labeling: contiguous index blocks
    with (approximately) equal weight — the ultimate fallback."""
    w = np.ones(n) if weights is None else \
        np.maximum(np.asarray(weights, np.float64), 0.0)
    cum = np.cumsum(w)
    total = float(cum[-1]) if n else 0.0
    if total <= 0:
        return (np.arange(n, dtype=np.int64) * nparts) // max(n, 1)
    parts = np.minimum((cum - 0.5 * w) * nparts // total,
                       nparts - 1).astype(np.int64)
    return np.maximum(parts, 0)


def enforce_output(graph, parts, nparts: int, *, weights=None,
                   balance_tol: float = 0.05,
                   report: GuardReport | None = None) -> np.ndarray:
    """Force the output invariant: valid labels, connected parts, weight
    corridor.  Mutating repairs are recorded in ``report.degraded`` and
    ``guard_fallbacks``; a no-op when the labeling is already valid."""
    from repro.core.refine import repair_components
    from repro.core.multilevel import _rebalance

    n = int(graph.n)
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    p = None if parts is None else np.asarray(parts)
    if p is None or p.shape != (n,) or \
            not np.issubdtype(p.dtype, np.integer) or \
            (p.size and (p.min() < 0 or p.max() >= nparts)):
        p = _balanced_reassign(n, nparts, w)
        if report is not None:
            report.degrade("finalize:reassigned-labels")
            report.fallbacks += 1
        obs.counter_add("guard_fallbacks", 1)
    p = p.astype(np.int64, copy=True)

    mean = float(w.sum()) / nparts
    corridor = ((1.0 - balance_tol) * mean, (1.0 + balance_tol) * mean)

    moved = False
    if count_disconnected(graph, p, nparts) > 0:
        p, _stats = repair_components(graph, p, nparts, weights=weights,
                                      balance_tol=balance_tol)
        moved = True
    pw = np.bincount(p, weights=w, minlength=nparts)
    if float(pw.max(initial=0.0)) > corridor[1] * (1.0 + 1e-9) or \
            float(pw.min(initial=0.0)) < corridor[0] * (1.0 - 1e-9):
        _rebalance(graph, p, nparts, w, corridor)
        p, _stats = repair_components(graph, p, nparts, weights=weights,
                                      balance_tol=balance_tol)
        moved = True
    if moved and report is not None:
        report.degrade("finalize:repaired")
    return p
