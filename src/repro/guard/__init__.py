"""repro.guard: fault-tolerant partitioning.

Validation front door (:mod:`repro.guard.validate`), solver escalation
policy (:mod:`repro.guard.policy`), typed diagnostics
(:mod:`repro.guard.errors`), and the deterministic fault-injection
harness (:mod:`repro.guard.chaos`).  See ``core/README.md`` ("Failure
modes & degradation ladder") for the full contract.
"""

from repro.guard import chaos
from repro.guard.errors import GuardError, GuardIssue, GuardReport
from repro.guard.policy import (
    GuardPolicy,
    SolverGuard,
    check_output,
    count_disconnected,
    enforce_output,
    failure_reason,
    fallback_vector,
)
from repro.guard.validate import (
    check_positive_int,
    component_labels,
    pack_components,
    proportional_budgets,
    validate_graph,
    validate_mesh,
    validate_nparts,
)

__all__ = [
    "GuardError", "GuardIssue", "GuardReport", "chaos",
    "check_positive_int", "component_labels", "pack_components",
    "proportional_budgets", "validate_graph", "validate_mesh",
    "validate_nparts", "GuardPolicy", "SolverGuard", "check_output",
    "count_disconnected", "enforce_output", "failure_reason",
    "fallback_vector",
]
