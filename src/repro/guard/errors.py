"""Typed guard diagnostics.

One exception class, many machine-readable codes.  ``GuardError`` is what
the validation front door raises in strict mode and what the CLI entry
points catch and pretty-print — ``code`` is a stable kebab-case slug a
caller can branch on, ``details`` carries the numbers (offending counts,
indices, value ranges) so the message never has to be parsed.
"""

from __future__ import annotations

import dataclasses

# The catalog of stable diagnostic codes.  Every literal code passed to
# GuardError/GuardIssue anywhere in src/ must come from this tuple (the
# static analyzer, rule GRD002, enforces it), and the tuple must be
# duplicate-free — callers branch on these strings, so a code's meaning
# must be unique repo-wide.
KNOWN_CODES = (
    # argument validation
    "bad-argument", "bad-nparts",
    # graph structure
    "malformed-csr", "self-loop", "duplicate-edge", "zero-degree-node",
    # values
    "nonfinite-coords", "nonfinite-edge-weight", "nonpositive-edge-weight",
    "bad-node-weight",
    # mesh
    "empty-mesh",
)


class GuardError(ValueError):
    """A precise, actionable input/solver diagnostic.

    Subclasses ``ValueError`` so legacy ``except ValueError`` call sites
    keep working, but carries a stable ``code`` and a ``details`` dict.
    """

    def __init__(self, code: str, message: str, *,
                 details: dict | None = None):
        self.code = str(code)
        self.details = dict(details or {})
        super().__init__(f"[{self.code}] {message}")

    @property
    def message(self) -> str:
        return str(self.args[0]) if self.args else ""

    def diagnostic(self) -> str:
        """Multi-line human rendering for CLI front doors."""
        lines = [f"guard: {self.message}"]
        for k in sorted(self.details):
            lines.append(f"  {k} = {self.details[k]!r}")
        lines.append("  (fix the input, or pass sanitize=True to let the "
                     "guard repair what is repairable)")
        return "\n".join(lines)


@dataclasses.dataclass
class GuardIssue:
    """One defect found by validation (and possibly repaired)."""

    code: str
    message: str
    count: int = 1
    fixed: bool = False

    def to_dict(self) -> dict:
        return {"code": self.code, "message": self.message,
                "count": int(self.count), "fixed": bool(self.fixed)}


@dataclasses.dataclass
class GuardReport:
    """What the guard saw and did during one pipeline run.

    Attached to ``RSBReport.guard`` and serialized into the run manifest
    config — degradation is observable, never silent.
    """

    validated: bool = False
    sanitized: bool = False
    issues: list = dataclasses.field(default_factory=list)   # [GuardIssue]
    components: int = 1
    retries: int = 0
    fallbacks: int = 0
    sanitize_fixes: int = 0
    deadline_expired: bool = False
    degraded: list = dataclasses.field(default_factory=list)  # [str]

    def record(self, issue: GuardIssue) -> None:
        self.issues.append(issue)
        if issue.fixed:
            self.sanitize_fixes += int(issue.count)

    def degrade(self, what: str) -> None:
        self.degraded.append(str(what))

    @property
    def clean(self) -> bool:
        return (not self.issues and not self.degraded
                and self.retries == 0 and self.fallbacks == 0
                and not self.deadline_expired)

    def to_dict(self) -> dict:
        return {
            "validated": self.validated,
            "sanitized": self.sanitized,
            "issues": [i.to_dict() for i in self.issues],
            "components": int(self.components),
            "retries": int(self.retries),
            "fallbacks": int(self.fallbacks),
            "sanitize_fixes": int(self.sanitize_fixes),
            "deadline_expired": self.deadline_expired,
            "degraded": list(self.degraded),
        }
