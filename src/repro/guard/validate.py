"""Validation front door: typed diagnostics + optional sanitizing repair.

``validate_graph`` / ``validate_mesh`` run as the implicit first stage of
``PartitionPipeline`` (and are callable directly by CLI entry points).
Strict mode (``sanitize=False``) raises a :class:`GuardError` on the first
class of defect found; ``sanitize=True`` repairs what is repairable —
dropping self-loops and non-positive/non-finite edge weights, coalescing
duplicate edges, patching non-finite coordinates and node weights — and
records every fix in the :class:`GuardReport`.

Disconnected inputs (including zero-degree nodes, which are singleton
components) are *handled*, not rejected: the Fiedler vector is undefined
there, so the pipeline partitions each component independently with
proportional part budgets (:func:`proportional_budgets`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.guard.errors import GuardError, GuardIssue, GuardReport
from repro.mesh.graphs import Graph, build_csr, connected_labels


# ---------------------------------------------------------------------------
# Scalar / CLI checks
# ---------------------------------------------------------------------------

def check_positive_int(name: str, value, *, minimum: int = 1,
                       maximum: int | None = None) -> int:
    """CLI front-door check: ``value`` must be an int >= ``minimum``."""
    try:
        v = int(value)
    except (TypeError, ValueError):
        raise GuardError("bad-argument",
                         f"{name} must be an integer, got {value!r}",
                         details={"name": name, "value": value}) from None
    if v != float(value) or v < minimum or (maximum is not None
                                            and v > maximum):
        lo_hi = f">= {minimum}" if maximum is None else \
            f"in [{minimum}, {maximum}]"
        raise GuardError("bad-argument",
                         f"{name} must be {lo_hi}, got {value!r}",
                         details={"name": name, "value": value,
                                  "minimum": minimum, "maximum": maximum})
    return v


def validate_nparts(nparts, n: int) -> int:
    """``nparts`` must be an integer in ``[1, n]``."""
    try:
        k = int(nparts)
    except (TypeError, ValueError):
        raise GuardError("bad-nparts",
                         f"nparts must be an integer, got {nparts!r}",
                         details={"nparts": nparts, "n": n}) from None
    if k < 1 or k > max(int(n), 1):
        raise GuardError("bad-nparts",
                         f"nparts={k} out of range [1, {n}] "
                         f"for an input with {n} nodes",
                         details={"nparts": k, "n": int(n)})
    return k


# ---------------------------------------------------------------------------
# Graph validation
# ---------------------------------------------------------------------------

def _patch_nonfinite_rows(arr: np.ndarray) -> tuple[np.ndarray, int]:
    """Replace rows containing non-finite entries with the column means of
    the finite rows (0 when no row is finite).  Returns (fixed, n_bad)."""
    a = np.asarray(arr, np.float64)
    flat = a.reshape(a.shape[0], -1)
    bad = ~np.isfinite(flat).all(axis=1)
    n_bad = int(bad.sum())
    if n_bad == 0:
        return arr, 0
    good = flat[~bad]
    fill = good.mean(axis=0) if good.size else np.zeros(flat.shape[1])
    flat = flat.copy()
    flat[bad] = fill
    return flat.reshape(a.shape).astype(np.asarray(arr).dtype, copy=False), \
        n_bad


def _raise_or_record(report: GuardReport | None, sanitize: bool,
                     code: str, message: str, count: int,
                     details: dict) -> None:
    """Strict mode raises; sanitize mode records a fixed issue."""
    if not sanitize:
        raise GuardError(code, message, details=details)
    if report is not None:
        report.record(GuardIssue(code, message, count=count, fixed=True))


def validate_graph(graph: Graph, *, coords=None, weights=None,
                   nparts=None, sanitize: bool = False,
                   report: GuardReport | None = None):
    """Validate (and optionally repair) a CSR graph + optional per-node
    coords/weights.  Returns ``(graph, coords, weights)`` — identical
    objects when nothing needed fixing.

    Strict mode raises :class:`GuardError`; ``sanitize=True`` repairs and
    records into ``report``.  Structural CSR corruption and out-of-range
    ``nparts`` are never repairable.
    """
    n = int(graph.n)
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    w_edge = np.asarray(graph.weights)

    if indptr.shape != (n + 1,) or int(indptr[0]) != 0 or \
            int(indptr[-1]) != indices.size or np.any(np.diff(indptr) < 0):
        raise GuardError("malformed-csr",
                         "indptr is not a monotone [0..nnz] prefix array",
                         details={"n": n, "nnz": int(indices.size)})
    if indices.size and (indices.min() < 0 or indices.max() >= n):
        raise GuardError("malformed-csr",
                         "column indices out of range [0, n)",
                         details={"n": n, "min": int(indices.min()),
                                  "max": int(indices.max())})
    if nparts is not None:
        validate_nparts(nparts, n)

    rows = graph.rows
    nonfinite = int((~np.isfinite(w_edge)).sum())
    nonpos = int((np.isfinite(w_edge) & (w_edge <= 0)).sum())
    loops = int((rows == indices).sum())
    key = rows.astype(np.int64) * n + indices.astype(np.int64)
    dups = int(key.size - np.unique(key).size)

    if nonfinite:
        _raise_or_record(report, sanitize, "nonfinite-edge-weight",
                         f"{nonfinite} edge weights are NaN/Inf",
                         nonfinite, {"count": nonfinite})
    if nonpos:
        _raise_or_record(report, sanitize, "nonpositive-edge-weight",
                         f"{nonpos} edge weights are <= 0",
                         nonpos, {"count": nonpos})
    if loops:
        _raise_or_record(report, sanitize, "self-loop",
                         f"{loops} self-loop entries", loops,
                         {"count": loops})
    if dups:
        _raise_or_record(report, sanitize, "duplicate-edge",
                         f"{dups} duplicate (row, col) entries coalesced",
                         dups, {"count": dups})
    if sanitize and (nonfinite or nonpos or loops or dups):
        keep = np.isfinite(w_edge) & (w_edge > 0) & (rows != indices)
        graph = build_csr(rows[keep], indices[keep], n,
                          weights=w_edge[keep], symmetrize=False,
                          sum_duplicates=True)

    if weights is not None:
        w = np.asarray(weights, np.float64)
        if w.shape[0] != n:
            raise GuardError("bad-node-weight",
                             f"weights has {w.shape[0]} entries for "
                             f"{n} nodes", details={"n": n,
                                                    "len": int(w.shape[0])})
        bad = ~np.isfinite(w) | (w < 0)
        n_bad = int(bad.sum())
        if n_bad:
            _raise_or_record(report, sanitize, "bad-node-weight",
                             f"{n_bad} node weights are NaN/Inf/negative",
                             n_bad, {"count": n_bad})
            w = w.copy()
            w[bad] = 1.0
            weights = w

    if coords is not None:
        c = np.asarray(coords)
        n_bad = int((~np.isfinite(
            c.reshape(c.shape[0], -1)).all(axis=1)).sum())
        if n_bad:
            _raise_or_record(report, sanitize, "nonfinite-coords",
                             f"{n_bad} coordinate rows are NaN/Inf",
                             n_bad, {"count": n_bad})
            coords, _ = _patch_nonfinite_rows(c)

    # Zero-degree nodes and multiple components are *handled* downstream
    # (per-component partitioning) — record them, never raise.
    if report is not None:
        zdeg = int((np.diff(np.asarray(graph.indptr)) == 0).sum())
        if zdeg:
            report.record(GuardIssue(
                "zero-degree-node",
                f"{zdeg} nodes have no incident edges "
                "(partitioned as singleton components)", count=zdeg))
        report.validated = True
        report.sanitized = report.sanitized or sanitize
    return graph, coords, weights


def validate_mesh(mesh, *, nparts=None, sanitize: bool = False,
                  report: GuardReport | None = None):
    """Validate (and optionally repair) a ``HexMesh``: finite coordinates
    and non-negative finite element weights; ``nparts`` in range."""
    nelems = int(mesh.nelems)
    if nelems < 1:
        raise GuardError("empty-mesh", "mesh has no elements",
                         details={"nelems": nelems})
    if nparts is not None:
        validate_nparts(nparts, nelems)

    coords = np.asarray(mesh.coords)
    weights = np.asarray(mesh.weights, np.float64)
    patch: dict = {}

    bad_c = int((~np.isfinite(
        coords.reshape(nelems, -1)).all(axis=1)).sum())
    if bad_c:
        _raise_or_record(report, sanitize, "nonfinite-coords",
                         f"{bad_c} element centroids are NaN/Inf",
                         bad_c, {"count": bad_c})
        patch["coords"], _ = _patch_nonfinite_rows(coords)

    bad_w = int((~np.isfinite(weights) | (weights < 0)).sum())
    if bad_w:
        _raise_or_record(report, sanitize, "bad-node-weight",
                         f"{bad_w} element weights are NaN/Inf/negative",
                         bad_w, {"count": bad_w})
        w = weights.copy()
        w[~np.isfinite(w) | (w < 0)] = 1.0
        patch["weights"] = w.astype(np.asarray(mesh.weights).dtype,
                                    copy=False)

    if report is not None:
        report.validated = True
        report.sanitized = report.sanitized or sanitize
    return dataclasses.replace(mesh, **patch) if patch else mesh


# ---------------------------------------------------------------------------
# Connected components + proportional part budgets
# ---------------------------------------------------------------------------

def component_labels(graph: Graph) -> tuple[np.ndarray, int]:
    """Compacted component label per node and the component count."""
    labels = connected_labels(graph.n, graph.rows, graph.indices)
    ncomp = int(labels.max()) + 1 if labels.size else 0
    return labels, ncomp


def proportional_budgets(comp_weights, nparts: int) -> np.ndarray:
    """Largest-remainder apportionment of ``nparts`` over components,
    with a floor of one part per component (requires
    ``nparts >= len(comp_weights)``)."""
    w = np.asarray(comp_weights, np.float64)
    k = w.size
    if k == 0 or nparts < k:
        raise GuardError("bad-nparts",
                         f"cannot give {k} components >=1 part each "
                         f"with nparts={nparts}",
                         details={"components": k, "nparts": int(nparts)})
    total = float(w.sum())
    raw = (nparts * w / total) if total > 0 else np.full(k, nparts / k)
    b = np.maximum(1, np.floor(raw).astype(np.int64))
    rem = raw - np.floor(raw)
    diff = int(nparts - b.sum())
    order = np.argsort(-rem, kind="stable")
    i = 0
    while diff > 0:                      # hand out leftovers by remainder
        b[order[i % k]] += 1
        diff -= 1
        i += 1
    order_take = np.argsort(rem, kind="stable")
    i = 0
    while diff < 0:                      # claw back over-floored budgets
        c = order_take[i % k]
        if b[c] > 1:
            b[c] -= 1
            diff += 1
        i += 1
    return b


def pack_components(comp_weights, nparts: int) -> np.ndarray:
    """When there are more components than parts, group whole components
    into ``nparts`` bins (greedy heaviest-first onto the lightest bin).
    Returns the bin id per component."""
    w = np.asarray(comp_weights, np.float64)
    k = w.size
    bins = np.zeros(nparts, np.float64)
    group = np.empty(k, np.int64)
    for c in np.argsort(-w, kind="stable"):
        g = int(np.argmin(bins))
        group[c] = g
        bins[g] += w[c]
    return group
