"""Deterministic fault injection (``guard.chaos``).

Fault *sites* are named hooks compiled into the production code paths:

==================  ========================================================
``solver_nan``      corrupt a Fiedler result with NaNs (any method)
``empty_split``     replace a Fiedler vector with a constant vector, so the
                    sign split would put every node on one side
``cg_divergence``   force the inverse-iteration outer loop to a non-finite
                    Rayleigh quotient (exercises the breakdown path)
``deadline``        make every ``SolverGuard`` deadline appear expired
``halo_truncate``   drop export rows from a freshly built ``HaloPlan``
==================  ========================================================

A site only does anything when *enabled* (via :func:`configure`, the
:func:`overlay` context manager, or the ``REPRO_CHAOS`` env var — a
comma-separated site list, with ``REPRO_CHAOS_SEED`` / ``REPRO_CHAOS_RATE``
alongside).  Firing is a pure function of ``(seed, site, *key)`` — the same
run replays the same faults, which is what makes the chaos test suite and
the smoke-check chaos gate deterministic.  ``rate >= 1`` means an enabled
site *always* fires, so escalation ladders provably exhaust.
"""

from __future__ import annotations

import contextlib
import os

FAULT_SITES = ("solver_nan", "empty_split", "cg_divergence",
               "deadline", "halo_truncate")

_state = {"sites": frozenset(), "seed": 0, "rate": 1.0, "suppress": 0}


def _load_env() -> None:
    raw = os.environ.get("REPRO_CHAOS", "")
    sites = frozenset(s.strip() for s in raw.split(",") if s.strip())
    bad = sites - set(FAULT_SITES)
    if bad:
        raise ValueError(f"REPRO_CHAOS: unknown fault sites {sorted(bad)} "
                         f"(have {FAULT_SITES})")
    _state["sites"] = sites
    _state["seed"] = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
    _state["rate"] = float(os.environ.get("REPRO_CHAOS_RATE", "1.0"))


_load_env()


def configure(sites=(), *, seed: int = 0, rate: float = 1.0) -> None:
    """Enable exactly ``sites`` (an iterable of names; empty disables)."""
    sites = frozenset(sites)
    bad = sites - set(FAULT_SITES)
    if bad:
        raise ValueError(f"unknown fault sites {sorted(bad)} "
                         f"(have {FAULT_SITES})")
    _state["sites"] = sites
    _state["seed"] = int(seed)
    _state["rate"] = float(rate)


def clear() -> None:
    """Disable every fault site."""
    _state["sites"] = frozenset()


def active() -> bool:
    return bool(_state["sites"]) and not _state["suppress"]


def enabled(site: str) -> bool:
    return site in _state["sites"] and not _state["suppress"]


def _mix(*vals) -> int:
    """FNV-1a over the repr of the key tuple — stable across processes."""
    h = 0x811C9DC5
    for v in vals:
        for b in repr(v).encode():
            h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h


def should_fire(site: str, *key) -> bool:
    """True iff ``site`` is enabled and its seed-keyed draw fires."""
    if not enabled(site):
        return False
    rate = _state["rate"]
    if rate >= 1.0:
        return True
    return (_mix(_state["seed"], site, *key) % 10_000) < rate * 10_000


@contextlib.contextmanager
def suppressed():
    """Temporarily mute every site (used by repair paths rebuilding a
    corrupted artifact — the rebuild must not be re-corrupted)."""
    _state["suppress"] += 1
    try:
        yield
    finally:
        _state["suppress"] -= 1


@contextlib.contextmanager
def overlay(sites, *, seed: int = 0, rate: float = 1.0):
    """Enable ``sites`` for the duration of the block, then restore."""
    saved = dict(_state)
    configure(sites, seed=seed, rate=rate)
    try:
        yield
    finally:
        _state.update({k: saved[k] for k in ("sites", "seed", "rate")})
