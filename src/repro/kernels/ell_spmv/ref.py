"""Pure-jnp oracle for the transposed-ELL Laplacian matvec."""

from __future__ import annotations

import jax.numpy as jnp


def ell_spmv_ref(cols_t: jnp.ndarray, vals_t: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """A·x with A in transposed ELL: cols_t/vals_t (w, n); pad val = 0.

    out[i] = Σ_k vals_t[k, i] · x[cols_t[k, i]]
    """
    return (vals_t * jnp.take(x, cols_t, axis=0)).sum(axis=0)


def ell_spmv_batched_ref(cols_t: jnp.ndarray, vals_t: jnp.ndarray,
                         x: jnp.ndarray) -> jnp.ndarray:
    """Batched oracle: cols_t/vals_t (B, w, n); x (B, n).

    out[b, i] = Σ_k vals_t[b, k, i] · x[b, cols_t[b, k, i]]
    """
    B = cols_t.shape[0]
    taken = jnp.take_along_axis(
        x, cols_t.reshape(B, -1), axis=-1
    ).reshape(cols_t.shape)
    return (vals_t * taken).sum(axis=1)


def lap_apply_ref(cols_t, vals_t, diag, x):
    """L·x = diag ⊙ x − A·x."""
    return diag * x - ell_spmv_ref(cols_t, vals_t, x)
