"""Public jit'd wrapper for the ELL SpMV kernel (CPU → interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ell_spmv.kernel import ell_spmv_batched_pallas, ell_spmv_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block(n: int) -> int:
    for b in (1024, 512, 256, 128):
        if n % b == 0:
            return b
    return 0


def ell_spmv(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """A·x with row-major ELL inputs (n, w) — transposes to ELLPACK-T and
    dispatches to the Pallas kernel (interpret mode off-TPU), padding n to a
    lane-aligned block size."""
    n, w = cols.shape
    block = _pick_block(n)
    if block == 0:
        n_pad = -(-n // 128) * 128
        cols = jnp.pad(cols, ((0, n_pad - n), (0, 0)))
        vals = jnp.pad(vals, ((0, n_pad - n), (0, 0)))
        xp = jnp.pad(x, (0, n_pad - n))
        out = ell_spmv_pallas(
            cols.T, vals.T, xp, block_n=128, interpret=not _on_tpu()
        )
        return out[:n]
    return ell_spmv_pallas(cols.T, vals.T, x, block_n=block, interpret=not _on_tpu())


def ell_spmv_batched(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """B independent A·x products with row-major ELL inputs (B, n, w) and
    per-problem vectors (B, n) — transposes to (B, w, n) ELLPACK-T and
    dispatches to the batched-grid Pallas kernel (interpret mode off-TPU),
    padding n to a lane-aligned block size."""
    B, n, w = cols.shape
    block = _pick_block(n)
    if block == 0:
        n_pad = -(-n // 128) * 128
        cols = jnp.pad(cols, ((0, 0), (0, n_pad - n), (0, 0)))
        vals = jnp.pad(vals, ((0, 0), (0, n_pad - n), (0, 0)))
        xp = jnp.pad(x, ((0, 0), (0, n_pad - n)))
        out = ell_spmv_batched_pallas(
            cols.swapaxes(-1, -2), vals.swapaxes(-1, -2), xp,
            block_n=128, interpret=not _on_tpu(),
        )
        return out[:, :n]
    return ell_spmv_batched_pallas(
        cols.swapaxes(-1, -2), vals.swapaxes(-1, -2), x,
        block_n=block, interpret=not _on_tpu(),
    )


def lap_apply(cols: jax.Array, vals: jax.Array, diag: jax.Array, x: jax.Array):
    return diag * x - ell_spmv(cols, vals, x)
