"""Public dispatch for the ELL SpMV kernel.

`prefer="auto"` (the default) runs the compiled Pallas kernel on TPU and
the jnp reference path elsewhere — interpret mode is for parity tests
(`prefer="pallas"` off-TPU), not production dispatch.  Same contract as
`segment_sum.ops`."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ell_spmv.kernel import ell_spmv_batched_pallas, ell_spmv_pallas
from repro.kernels.ell_spmv.ref import ell_spmv_batched_ref, ell_spmv_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block(n: int) -> int:
    for b in (1024, 512, 256, 128):
        if n % b == 0:
            return b
    return 0


def ell_spmv(cols: jax.Array, vals: jax.Array, x: jax.Array, *,
             prefer: str = "auto") -> jax.Array:
    """A·x with row-major ELL inputs (n, w) — transposes to ELLPACK-T and
    dispatches per ``prefer``: "auto" (Pallas on TPU, jnp reference
    elsewhere) | "pallas" (interpret mode off-TPU) | "ref", padding n to
    a lane-aligned block size on the Pallas path."""
    if prefer == "ref" or (prefer == "auto" and not _on_tpu()):
        return ell_spmv_ref(cols.T, vals.T, x)
    n, w = cols.shape
    block = _pick_block(n)
    if block == 0:
        n_pad = -(-n // 128) * 128
        cols = jnp.pad(cols, ((0, n_pad - n), (0, 0)))
        vals = jnp.pad(vals, ((0, n_pad - n), (0, 0)))
        xp = jnp.pad(x, (0, n_pad - n))
        out = ell_spmv_pallas(
            cols.T, vals.T, xp, block_n=128, interpret=not _on_tpu()
        )
        return out[:n]
    return ell_spmv_pallas(cols.T, vals.T, x, block_n=block, interpret=not _on_tpu())


def ell_spmv_batched(cols: jax.Array, vals: jax.Array, x: jax.Array, *,
                     prefer: str = "auto") -> jax.Array:
    """B independent A·x products with row-major ELL inputs (B, n, w) and
    per-problem vectors (B, n) — transposes to (B, w, n) ELLPACK-T and
    dispatches per ``prefer`` (see :func:`ell_spmv`), padding n to a
    lane-aligned block size on the Pallas path."""
    if prefer == "ref" or (prefer == "auto" and not _on_tpu()):
        return ell_spmv_batched_ref(cols.swapaxes(-1, -2),
                                    vals.swapaxes(-1, -2), x)
    B, n, w = cols.shape
    block = _pick_block(n)
    if block == 0:
        n_pad = -(-n // 128) * 128
        cols = jnp.pad(cols, ((0, 0), (0, n_pad - n), (0, 0)))
        vals = jnp.pad(vals, ((0, 0), (0, n_pad - n), (0, 0)))
        xp = jnp.pad(x, ((0, 0), (0, n_pad - n)))
        out = ell_spmv_batched_pallas(
            cols.swapaxes(-1, -2), vals.swapaxes(-1, -2), xp,
            block_n=128, interpret=not _on_tpu(),
        )
        return out[:, :n]
    return ell_spmv_batched_pallas(
        cols.swapaxes(-1, -2), vals.swapaxes(-1, -2), x,
        block_n=block, interpret=not _on_tpu(),
    )


def lap_apply(cols: jax.Array, vals: jax.Array, diag: jax.Array,
              x: jax.Array, *, prefer: str = "auto"):
    return diag * x - ell_spmv(cols, vals, x, prefer=prefer)
