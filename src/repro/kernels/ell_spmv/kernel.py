"""Pallas TPU kernel: sparse matvec in transposed-ELL (ELLPACK-T) layout.

TPU adaptation of the paper's Laplacian hot loop (DESIGN.md §2): instead of
CSR rows (GPU-style one-thread-per-row), the adjacency is stored
column-major ELL — `cols_t/vals_t : (w, n)` — so the *node* axis lands on
the 128-wide vector lanes and each of the `w` neighbor slots is one fully
vectorized multiply-gather-accumulate sweep.  The dense vector `x` stays
resident in VMEM (the kernel targets AMG coarse levels and per-shard
subgraphs, n ≤ ~256k: 1 MB of fp32 — comfortably inside the 16 MB VMEM of
a v5e core); rows are streamed block-by-block.

Grid: n / block_n column blocks.  Block shapes: (w, block_n) for cols/vals,
(block_n,) for the output; x is broadcast (un-blocked) into VMEM once.
block_n is a multiple of 128 (lane width); w is the padded max degree.

**Batched variant** (`ell_spmv_batched_pallas`): B independent operators —
the level-synchronous RSB engine's leading-batch-dim layout and the packed
`BatchedAMG` level operators — add a leading batch grid dimension.  Each
(b, i) grid step loads problem b's resident vector plus one (w, block_n)
column block and writes one (block_n,) output block; column ids stay
per-problem (no cross-batch offsets), matching the jnp fallback in
`EllLaplacian.adj_apply`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(x_ref, cols_ref, vals_ref, out_ref):
    x = x_ref[...]                     # (n,) resident vector
    cols = cols_ref[...]               # (w, bn)
    vals = vals_ref[...]               # (w, bn)
    gathered = jnp.take(x, cols, axis=0)          # (w, bn) vectorized gather
    out_ref[...] = (vals.astype(jnp.float32) * gathered.astype(jnp.float32)).sum(
        axis=0
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_spmv_pallas(
    cols_t: jax.Array,    # (w, n) int32
    vals_t: jax.Array,    # (w, n)
    x: jax.Array,         # (n,)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    w, n = cols_t.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),            # x: whole vector
            pl.BlockSpec((w, block_n), lambda i: (0, i)),  # cols block
            pl.BlockSpec((w, block_n), lambda i: (0, i)),  # vals block
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, cols_t, vals_t)


def _spmv_batched_kernel(x_ref, cols_ref, vals_ref, out_ref):
    x = x_ref[0]                       # (n,) problem b's resident vector
    cols = cols_ref[0]                 # (w, bn)
    vals = vals_ref[0]                 # (w, bn)
    gathered = jnp.take(x, cols, axis=0)          # (w, bn) vectorized gather
    out_ref[0, :] = (vals.astype(jnp.float32) * gathered.astype(jnp.float32)).sum(
        axis=0
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_spmv_batched_pallas(
    cols_t: jax.Array,    # (B, w, n) int32 — per-problem column ids
    vals_t: jax.Array,    # (B, w, n)
    x: jax.Array,         # (B, n)
    *,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    B, w, n = cols_t.shape
    assert n % block_n == 0, (n, block_n)
    grid = (B, n // block_n)
    return pl.pallas_call(
        _spmv_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),            # x row b
            pl.BlockSpec((1, w, block_n), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, w, block_n), lambda b, i: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, n), x.dtype),
        interpret=interpret,
    )(x, cols_t, vals_t)
