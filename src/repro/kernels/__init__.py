"""Pallas TPU kernels for the framework's compute hot-spots.

ell_spmv/         Laplacian matvec in transposed-ELL layout — the paper's
                  hot loop (Lanczos / CG / AMG smoothing are all matvec-bound).
segment_sum/      batched row-wise segment sum — the (boundary × nparts)
                  connection table of the sharded FM refinement sweep.
embedding_bag/    recsys lookup-reduce (gather rows + segment-sum).
flash_attention/  online-softmax attention for the LM archs.

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle used by tests).
"""
