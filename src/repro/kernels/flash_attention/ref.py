"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _softmax(s):
    m = s.max(-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / e.sum(-1, keepdims=True)


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D); H = G·Hkv.  fp32 softmax.

    Queries are end-aligned with keys (decode convention: the last query
    attends to every key).
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) / np.sqrt(D)
    if causal:
        Skv = k.shape[1]
        qpos = jnp.arange(Sq) + (Skv - Sq)
        mask = qpos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = _softmax(s)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(q.dtype), v)
    return o.reshape(B, Sq, H, D)
