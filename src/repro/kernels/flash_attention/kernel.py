"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Grid: (B·Hkv·G, nQ, nK) with the KV axis innermost, so the output block
(block_q, D) and the fp32 scratch accumulators (m, l, acc) persist in VMEM
across the KV sweep (Pallas revisits the same out block sequentially).
Block shapes — q: (block_q, D), k/v: (block_k, D) — are MXU-friendly
(D ∈ {64, 128}; block_q/block_k multiples of 128 recommended on hardware).

Causal handling: KV blocks entirely above the diagonal are skipped via
`pl.when` (no FLOPs, no DMA use); the diagonal block applies the triangular
mask.  Queries are end-aligned with keys (decode convention), matching
`ref.attention_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, q_offset, kv_len, block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset        # global key-aligned q positions
    k_start = ki * block_k

    def compute():
        q = q_ref[0]                          # (block_q, D)
        k = k_ref[0]                          # (block_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                             # (block_q, block_k)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < kv_len                     # mask padded tail keys
        if causal:
            valid = jnp.logical_and(valid, qpos >= kpos)
        s = jnp.where(valid, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    in_range = k_start < kv_len
    if causal:
        # skip blocks strictly above the causal diagonal or past kv_len
        needed = jnp.logical_and(k_start <= q_start + block_q - 1, in_range)
    else:
        needed = in_range
    pl.when(needed)(compute)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "kv_len", "block_q", "block_k",
                     "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,    # (B, Sq, H, D) — may include padded tail queries
    k: jax.Array,    # (B, Skv, Hkv, D) — may include padded tail keys
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int | None = None,   # real-position offset of query 0
    kv_len: int | None = None,     # number of REAL keys (≤ Skv)
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    n_q, n_k = Sq // block_q, Skv // block_k
    kv_len = Skv if kv_len is None else kv_len
    q_offset = (kv_len - Sq) if q_offset is None else q_offset

    # fold heads: q → (B·Hkv·G, Sq, D); k/v → (B·Hkv, Skv, D)
    qf = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4).reshape(
        B * Hkv * G, Sq, D
    )
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / np.sqrt(D),
        causal=causal,
        q_offset=q_offset,
        kv_len=kv_len,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv * G, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki, g=G: (h // g, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda h, qi, ki, g=G: (h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv * G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hkv, G, Sq, D).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
