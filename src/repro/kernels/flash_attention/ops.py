"""Public dispatch: pads sequences (at the tail) to block multiples with
explicit real-length masking.  `prefer="auto"` runs the compiled Pallas
kernel on TPU and the jnp reference elsewhere; "pallas" forces the
kernel (interpret off-TPU), "ref" forces the oracle — same contract as
`segment_sum.ops`."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    prefer: str = "auto",
) -> jax.Array:
    """Causal GQA attention, queries end-aligned with keys (ref.py semantics)."""
    if prefer == "ref" or (prefer == "auto" and not _on_tpu()):
        return attention_ref(q, k, v, causal=causal)
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    sq_pad = -(-Sq // bq) * bq
    sk_pad = -(-Skv // bk) * bk
    if sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - Sq), (0, 0), (0, 0)))
    if sk_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - Skv), (0, 0), (0, 0)))
    out = flash_attention_pallas(
        q, k, v, causal=causal, q_offset=Skv - Sq, kv_len=Skv,
        block_q=bq, block_k=bk, interpret=not _on_tpu(),
    )
    return out[:, :Sq]
