"""Pallas TPU kernel: batched row-wise segment sum (connection table).

The sharded-refinement hot loop (DESIGN: `dist/refine_sharded.py`) needs,
per sweep, the (boundary × nparts) *connection-weight table* of every
shard's frontier: ``conn[i, q] = Σ_k w[i, k] · [label[col[i, k]] == q]``.
That is a segment sum over the part axis, one segment per part, with the
segment ids gathered through the ELL adjacency.

Layout differs from `ell_spmv` deliberately: there the *node* axis rides
the 128 lanes (output is a vector); here the output is a table whose lane
axis is ``nparts`` (padded to 128), so ELL rows stay row-major —
``cols/wts : (B, w)`` blocked as ``(block_b, w)`` on the sublane axis —
and each of the ``w`` neighbor slots is one vectorized
gather-compare-accumulate sweep into the resident ``(block_b, npad)``
accumulator.  The combined label vector (n_local + P·halo ≤ ~256k int32 =
1 MB) stays resident in VMEM, exactly like `ell_spmv`'s dense vector.

Grid: B / block_b row blocks; the **batched variant** adds a leading
shard-group dimension — one launch computes every shard's frontier table,
which is what makes the refinement sweep a single kernel launch between
collectives.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(labels_ref, cols_ref, wts_ref, out_ref):
    labels = labels_ref[...]                     # (m,) resident labels
    cols = cols_ref[...]                         # (bn, w)
    wts = wts_ref[...].astype(jnp.float32)       # (bn, w)
    lab = jnp.take(labels, cols, axis=0)         # (bn, w) gathered seg ids
    bn, npad = out_ref.shape
    iota = jax.lax.broadcasted_iota(lab.dtype, (1, npad), 1)
    acc = jnp.zeros((bn, npad), jnp.float32)
    for k in range(cols.shape[1]):               # w is small and static
        onehot = (lab[:, k][:, None] == iota).astype(jnp.float32)
        acc = acc + wts[:, k][:, None] * onehot
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("nparts_pad", "block_b", "interpret"))
def segment_sum_pallas(
    labels: jax.Array,     # (m,) int32 — segment id per combined-space node
    cols: jax.Array,       # (B, w) int32 — indices into labels
    wts: jax.Array,        # (B, w) f32  — padding entries carry weight 0
    *,
    nparts_pad: int,       # output segments, padded to a lane multiple
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, w = cols.shape
    m = labels.shape[0]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        _segsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),            # labels: resident
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),  # cols row block
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),  # wts row block
        ],
        out_specs=pl.BlockSpec((block_b, nparts_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nparts_pad), jnp.float32),
        interpret=interpret,
    )(labels, cols, wts)


def _segsum_batched_kernel(labels_ref, cols_ref, wts_ref, out_ref):
    labels = labels_ref[0]                       # (m,) problem g's labels
    cols = cols_ref[0]                           # (bn, w)
    wts = wts_ref[0].astype(jnp.float32)         # (bn, w)
    lab = jnp.take(labels, cols, axis=0)
    _, bn, npad = out_ref.shape
    iota = jax.lax.broadcasted_iota(lab.dtype, (1, npad), 1)
    acc = jnp.zeros((bn, npad), jnp.float32)
    for k in range(cols.shape[1]):
        onehot = (lab[:, k][:, None] == iota).astype(jnp.float32)
        acc = acc + wts[:, k][:, None] * onehot
    out_ref[0, :, :] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("nparts_pad", "block_b", "interpret"))
def segment_sum_batched_pallas(
    labels: jax.Array,     # (G, m) int32 — per-problem label vectors
    cols: jax.Array,       # (G, B, w) int32
    wts: jax.Array,        # (G, B, w) f32
    *,
    nparts_pad: int,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    G, B, w = cols.shape
    m = labels.shape[1]
    assert B % block_b == 0, (B, block_b)
    grid = (G, B // block_b)
    return pl.pallas_call(
        _segsum_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m), lambda g, i: (g, 0)),
            pl.BlockSpec((1, block_b, w), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, block_b, w), lambda g, i: (g, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, nparts_pad),
                               lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, B, nparts_pad), jnp.float32),
        interpret=interpret,
    )(labels, cols, wts)
