"""Public wrappers for the segment-sum (connection table) kernel.

Dispatch policy differs from `ell_spmv`: the table build sits inside the
sharded-refinement sweep (called once per sweep, per shard group, under
``shard_map``), where Pallas *interpret* mode would dominate the sweep
wall clock off-TPU.  So ``prefer="auto"`` routes to the compiled Pallas
kernel on TPU and, everywhere else, to a jitted jnp transcription of the
kernel's own slot-loop algorithm (``_xla_loop``) — w accumulations into a
resident (B, nparts) table, never materializing the (B, w, nparts)
one-hot that makes the naive oracle 10–20× slower than even a NumPy
scatter build.  ``prefer="pallas"`` forces the kernel (interpret mode
off-TPU) for parity tests and microbenches; ``prefer="ref"`` is the
naive oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_sum.kernel import (
    segment_sum_batched_pallas,
    segment_sum_pallas,
)
from repro.kernels.segment_sum.ref import (
    connection_table_batched_ref,
    connection_table_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block_rows(b: int) -> int:
    """Largest power-of-two row block (≤ 256, ≥ 8 sublanes) dividing b."""
    for blk in (256, 128, 64, 32, 16, 8):
        if b % blk == 0:
            return blk
    return 8


@functools.partial(jax.jit, static_argnames="nparts")
def _xla_loop(labels, cols, wts, *, nparts: int):
    """The kernel's unrolled slot loop in pure jnp — the off-TPU
    production path (w is small and static, so the loop stays fused)."""
    lab = jnp.take(labels, cols, axis=0)                     # (B, w)
    iota = jnp.arange(nparts, dtype=lab.dtype)[None, :]
    acc = jnp.zeros((cols.shape[0], nparts), jnp.float32)
    for k in range(cols.shape[1]):
        onehot = (lab[:, k][:, None] == iota).astype(jnp.float32)
        acc = acc + wts[:, k][:, None].astype(jnp.float32) * onehot
    return acc


@functools.partial(jax.jit, static_argnames="nparts")
def _xla_loop_batched(labels, cols, wts, *, nparts: int):
    return jax.vmap(
        lambda l, c, v: _xla_loop(l, c, v, nparts=nparts)
    )(labels, cols, wts)


_ref_jit = jax.jit(connection_table_ref, static_argnames="nparts")
_ref_batched_jit = jax.jit(connection_table_batched_ref,
                           static_argnames="nparts")


@functools.partial(jax.jit, static_argnames=("nparts", "interpret"))
def _pallas_padded(labels, cols, wts, *, nparts: int, interpret: bool):
    B, _ = cols.shape
    npad = -(-nparts // 128) * 128
    bpad = -(-B // 8) * 8
    if bpad != B:
        cols = jnp.pad(cols, ((0, bpad - B), (0, 0)))
        wts = jnp.pad(wts, ((0, bpad - B), (0, 0)))
    out = segment_sum_pallas(labels, cols, wts, nparts_pad=npad,
                             block_b=_pick_block_rows(bpad),
                             interpret=interpret)
    return out[:B, :nparts]


@functools.partial(jax.jit, static_argnames=("nparts", "interpret"))
def _pallas_batched_padded(labels, cols, wts, *, nparts: int,
                           interpret: bool):
    _, B, _ = cols.shape
    npad = -(-nparts // 128) * 128
    bpad = -(-B // 8) * 8
    if bpad != B:
        cols = jnp.pad(cols, ((0, 0), (0, bpad - B), (0, 0)))
        wts = jnp.pad(wts, ((0, 0), (0, bpad - B), (0, 0)))
    out = segment_sum_batched_pallas(labels, cols, wts, nparts_pad=npad,
                                     block_b=_pick_block_rows(bpad),
                                     interpret=interpret)
    return out[:, :B, :nparts]


def connection_table(labels: jax.Array, cols: jax.Array, wts: jax.Array,
                     nparts: int, *, prefer: str = "auto") -> jax.Array:
    """``(B, nparts)`` table: ``conn[i, q] = Σ_k wts[i,k]·[labels[cols[i,k]]==q]``.

    Row-major ELL inputs ``cols``/``wts`` (B, w); padding entries point at
    any valid label slot with weight 0.  ``prefer``: "auto" (Pallas on
    TPU, jnp oracle elsewhere) | "pallas" | "ref".
    """
    B, w = cols.shape
    if B == 0 or w == 0:
        return jnp.zeros((B, nparts), jnp.float32)
    if prefer == "pallas" or (prefer == "auto" and _on_tpu()):
        return _pallas_padded(labels, cols, wts, nparts=nparts,
                              interpret=not _on_tpu())
    if prefer == "ref":
        return _ref_jit(labels, cols, wts, nparts=nparts)
    return _xla_loop(labels, cols, wts, nparts=nparts)


def connection_table_batched(labels: jax.Array, cols: jax.Array,
                             wts: jax.Array, nparts: int,
                             *, prefer: str = "auto") -> jax.Array:
    """Batched table build — ``labels`` (G, m), ``cols``/``wts`` (G, B, w)
    → (G, B, nparts) in ONE kernel launch (leading grid dim = shard
    group), the refinement sweep's per-collective compute step."""
    G, B, w = cols.shape
    if B == 0 or w == 0:
        return jnp.zeros((G, B, nparts), jnp.float32)
    if prefer == "pallas" or (prefer == "auto" and _on_tpu()):
        return _pallas_batched_padded(labels, cols, wts, nparts=nparts,
                                      interpret=not _on_tpu())
    if prefer == "ref":
        return _ref_batched_jit(labels, cols, wts, nparts=nparts)
    return _xla_loop_batched(labels, cols, wts, nparts=nparts)
