"""Pure-jnp oracle for the row-wise segment-sum (connection table).

The FM gain computation reduces to a batched segment sum: for every
boundary node ``i`` the edge weights of its ELL row are summed into
``nparts`` segments keyed by the *part label* of each neighbor,

    conn[i, q] = Σ_k wts[i, k] · [labels[cols[i, k]] == q]

This module is the naive jnp oracle the Pallas kernel (and the faster
``ops._xla_loop`` off-TPU production path) are tested against.  It
materializes the full (B, w, nparts) one-hot — simple to audit, too slow
to ship (see the dispatch-policy note in ``ops.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def connection_table_ref(labels: jnp.ndarray, cols: jnp.ndarray,
                         wts: jnp.ndarray, nparts: int) -> jnp.ndarray:
    """``(B, nparts)`` connection table from row-major ELL adjacency.

    ``labels``: (m,) int — part label per combined-space node;
    ``cols``/``wts``: (B, w) — neighbor indices into ``labels`` and edge
    weights (padding: any valid col with weight 0).
    """
    lab = jnp.take(labels, cols, axis=0)                      # (B, w)
    onehot = lab[..., None] == jnp.arange(nparts, dtype=lab.dtype)
    return jnp.where(onehot, wts[..., None].astype(jnp.float32),
                     0.0).sum(axis=1)


def connection_table_batched_ref(labels: jnp.ndarray, cols: jnp.ndarray,
                                 wts: jnp.ndarray, nparts: int) -> jnp.ndarray:
    """Batched oracle: ``labels`` (G, m); ``cols``/``wts`` (G, B, w) →
    (G, B, nparts).  Problem ``g`` only reads its own label vector."""
    return jax.vmap(
        lambda lab, c, v: connection_table_ref(lab, c, v, nparts)
    )(labels, cols, wts)
