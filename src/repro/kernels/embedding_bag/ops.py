"""Public dispatch: sorts segments if needed, pads dim to 128 lanes.

`prefer="auto"` runs the compiled Pallas kernel on TPU and the jnp
reference elsewhere; "pallas" forces the kernel (interpret off-TPU),
"ref" forces the oracle.  Same contract as `segment_sum.ops`."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag(
    table: jax.Array,
    indices: jax.Array,
    segments: jax.Array,
    n_bags: int,
    *,
    weights: jax.Array | None = None,
    assume_sorted: bool = True,
    prefer: str = "auto",
) -> jax.Array:
    V, d = table.shape
    nnz = indices.shape[0]
    if weights is None:
        weights = jnp.ones((nnz,), table.dtype)
    if not assume_sorted:
        order = jnp.argsort(segments)
        indices, segments, weights = indices[order], segments[order], weights[order]
    if prefer == "ref" or (prefer == "auto" and not _on_tpu()):
        return embedding_bag_ref(table, indices, segments, n_bags,
                                 weights=weights)
    d_pad = -(-d // 128) * 128
    tbl = jnp.pad(table, ((0, 0), (0, d_pad - d))) if d_pad != d else table
    out = embedding_bag_pallas(
        tbl, indices.astype(jnp.int32), segments.astype(jnp.int32), weights,
        n_bags=n_bags, interpret=not _on_tpu(),
    )
    return out[:, :d]
