"""Pure-jnp oracle for the embedding-bag kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, indices: jax.Array, segments: jax.Array,
                      n_bags: int, weights: jax.Array | None = None) -> jax.Array:
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, segments, num_segments=n_bags)
