"""Pallas TPU kernel: embedding-bag (gather rows + sum per sorted segment).

The table lives in HBM (recsys tables are 10⁶–10⁹ rows — never VMEM
resident).  The canonical TPU pattern is **scalar-prefetch row indexing**:
`PrefetchScalarGridSpec` passes the int32 `indices`/`segments` arrays ahead
of the grid so the BlockSpec `index_map` can select, per grid step, the
single table row `(indices[i], :)` to DMA into VMEM, and the *output* block
`(segments[i], :)` to accumulate into.  Because segments are sorted, the
output block is revisited on consecutive steps (Pallas keeps it resident)
and initialized exactly when the segment id changes.

Block shapes: (1, d) table row, (1, d) output row — d padded to a multiple
of 128 lanes by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, seg_ref, wgt_ref, row_ref, out_ref):
    i = pl.program_id(0)
    seg = seg_ref[i]
    is_first = jnp.where(i == 0, True, seg_ref[jnp.maximum(i - 1, 0)] != seg)
    row = row_ref[...].astype(jnp.float32) * wgt_ref[i].astype(jnp.float32)

    @pl.when(is_first)
    def _init():
        out_ref[...] = row.astype(out_ref.dtype)

    @pl.when(jnp.logical_not(is_first))
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32) + row).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,      # (V, d)
    indices: jax.Array,    # (nnz,) int32
    segments: jax.Array,   # (nnz,) int32, sorted ascending
    weights: jax.Array,    # (nnz,) per-sample weights
    *,
    n_bags: int,
    interpret: bool = False,
) -> jax.Array:
    V, d = table.shape
    nnz = indices.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nnz,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx, seg, wgt: (idx[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx, seg, wgt: (seg[i], 0)),
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), table.dtype),
        interpret=interpret,
    )(indices, segments, weights, table)
