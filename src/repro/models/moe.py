"""Mixture-of-Experts layer: shared + routed experts, top-k token choice.

TPU-static dispatch (MaxText/GShard style): token→expert assignments are
sorted by expert id and scattered into a fixed `(E, C, d)` capacity buffer
(`C = ceil(T·top_k·capacity_factor / E)`, tokens over capacity drop).  The
expert matmuls are a single batched einsum whose expert dim shards over the
"model"/"expert" mesh axis — the scatter/gather around it lowers to the EP
all-to-all.  DeepSeek/Qwen train without drops via aux-free balancing; the
capacity buffer is the static-shape TPU adaptation (DESIGN.md §2) and with
capacity_factor ≥ 2 drops are negligible at init-time routing entropy.

Routing: softmax gate, top-k, renormalized among the selected experts
(DeepSeek-MoE style); shared experts always-on (n_shared may be 0).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ShardRules, dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001  # load-balance aux loss (GShard-style)
    impl: str = "pjit"                # "pjit" (einsum dispatch) | "shardmap" (EP a2a)


def init_moe(moe: MoEConfig, d_model: int, key, dtype) -> dict:
    ks = jax.random.split(key, 7)
    e, f = moe.n_experts, moe.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, e), dtype=jnp.float32),
        "wi": dense_init(ks[1], (e, d_model, f), in_axis=1, dtype=dtype),
        "wg": dense_init(ks[2], (e, d_model, f), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[3], (e, f, d_model), in_axis=1, dtype=dtype),
    }
    if moe.n_shared:
        p["shared_wi"] = dense_init(ks[4], (d_model, f * moe.n_shared), dtype=dtype)
        p["shared_wg"] = dense_init(ks[5], (d_model, f * moe.n_shared), dtype=dtype)
        p["shared_wo"] = dense_init(ks[6], (f * moe.n_shared, d_model), dtype=dtype)
    return p


def capacity(moe: MoEConfig, n_tokens: int) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # multiple of 8 for TPU lane alignment


def moe_apply(moe: MoEConfig, p: dict, x: jax.Array, rules: ShardRules,
              dtype) -> jax.Array:
    """x: (B, S, d) → (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    # --- routing (fp32 for numerics) ---
    logits = xt.astype(jnp.float32) @ p["router"]
    gates = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    top_w, top_e = jax.lax.top_k(gates, moe.top_k)                # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- static-capacity dispatch ---
    C = capacity(moe, T)
    E = moe.n_experts
    flat_e = top_e.reshape(-1)                                    # (T·k,)
    flat_t = jnp.repeat(jnp.arange(T), moe.top_k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)                                   # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each entry within its expert's block
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")  # (E,)
    pos_in_e = jnp.arange(T * moe.top_k) - seg_start[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)              # overflow row

    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(jnp.take(xt, st, axis=0).astype(dtype))
    buf = buf[: E * C].reshape(E, C, d)
    buf = rules.shard(buf, ("experts", None, "embed"))

    # --- expert FFNs (batched over experts; shards over the expert axis) ---
    zi = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype))
    zg = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
    z = jax.nn.silu(zg) * zi
    z = rules.shard(z, ("experts", None, "expert_ffn"))
    out_buf = jnp.einsum("ecf,efd->ecd", z, p["wo"].astype(dtype))
    out_buf = out_buf.reshape(E * C, d)

    # --- combine back to tokens ---
    contrib = jnp.take(out_buf, jnp.minimum(slot, E * C - 1), axis=0)
    contrib = contrib * (sw * keep).astype(dtype)[:, None]
    y = jnp.zeros((T, d), dtype).at[st].add(contrib)

    # --- shared (always-on) experts ---
    if moe.n_shared:
        sz = jax.nn.silu(xt.astype(dtype) @ p["shared_wg"].astype(dtype))
        sz = sz * (xt.astype(dtype) @ p["shared_wi"].astype(dtype))
        y = y + sz @ p["shared_wo"].astype(dtype)

    return y.reshape(B, S, d)


def load_balance_aux(gates: jax.Array, top_e: jax.Array, n_experts: int) -> jax.Array:
    """GShard aux loss: E · Σ_e (fraction routed to e) · (mean gate of e)."""
    T = gates.shape[0]
    frac = jnp.zeros(n_experts).at[top_e.reshape(-1)].add(1.0) / (T * top_e.shape[-1])
    mean_gate = gates.mean(0)
    return n_experts * jnp.sum(frac * mean_gate)


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (EXPERIMENTS.md §Perf hillclimb #2)
# ---------------------------------------------------------------------------

def moe_apply_shardmap(moe: MoEConfig, p: dict, x: jax.Array,
                       *, data_axes, model_axis: str, dtype,
                       fsdp_gather: bool = False) -> jax.Array:
    """Expert-parallel MoE with LOCAL dispatch + all-to-all (production EP).

    Call inside shard_map, with x_loc (B_loc, S_loc, d) — each device
    routes ONLY its own tokens (no global sort/gather, the pjit baseline's
    failure mode), builds a local (E, C_loc, d) capacity buffer, and moves
    tokens to expert owners with ONE all-to-all over the model axis
    (reverse a2a on the way back).  Expert weights arrive model-sharded
    (E_loc = E/M experts per shard; optionally FSDP d-shards re-gathered
    over the data axes).

    Wire per device per layer ≈ 2 · C_loc·(M−1)/M · E_loc · d words — vs
    the pjit baseline's replicated-sort traffic (observed 30× larger).
    """
    B, S, d = x.shape
    T = B * S
    M = jax.lax.axis_size(model_axis)
    xt = x.reshape(T, d)

    router = p["router"]
    wi, wg, wo = p["wi"], p["wg"], p["wo"]           # (E_loc, d?, f)
    if fsdp_gather and data_axes:
        wi = jax.lax.all_gather(wi, data_axes, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, data_axes, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, data_axes, axis=2, tiled=True)
    E = moe.n_experts
    E_loc = wi.shape[0]
    assert E_loc * M == E, (E_loc, M, E)

    # --- local routing ---
    gates = jax.nn.softmax(xt.astype(jnp.float32) @ router, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, moe.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    C = capacity(moe, T)
    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), moe.top_k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * moe.top_k) - seg_start[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)

    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(jnp.take(xt, st, axis=0).astype(dtype))
    buf = buf[: E * C].reshape(M, E_loc, C, d)       # experts grouped by owner

    # --- dispatch a2a: shard m receives its experts' tokens from everyone ---
    recv = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)           # (M, E_loc, C, d)
    tokens = recv.transpose(1, 0, 2, 3).reshape(E_loc, M * C, d)

    # --- local expert FFNs ---
    zi = jnp.einsum("ecd,edf->ecf", tokens, wi.astype(dtype))
    zg = jnp.einsum("ecd,edf->ecf", tokens, wg.astype(dtype))
    z = jax.nn.silu(zg) * zi
    out = jnp.einsum("ecf,efd->ecd", z, wo.astype(dtype))

    # --- return a2a ---
    back = out.reshape(E_loc, M, C, d).transpose(1, 0, 2, 3)  # (M, E_loc, C, d)
    ret = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0,
                             tiled=False)            # (M, E_loc, C, d)
    out_buf = ret.reshape(E * C, d)

    # --- combine ---
    contrib = jnp.take(out_buf, jnp.minimum(slot, E * C - 1), axis=0)
    contrib = contrib * (sw * keep).astype(dtype)[:, None]
    y = jnp.zeros((T, d), dtype).at[st].add(contrib)

    if moe.n_shared:
        # gather the (small) shared-expert f-slices so each shard can apply
        # the FULL shared FFN to its own tokens (tokens may differ per model
        # shard under sequence sharding — a psum of partials would mix them)
        swi = jax.lax.all_gather(p["shared_wi"], model_axis, axis=1, tiled=True)
        swg = jax.lax.all_gather(p["shared_wg"], model_axis, axis=1, tiled=True)
        swo = jax.lax.all_gather(p["shared_wo"], model_axis, axis=0, tiled=True)
        sz = jax.nn.silu(xt.astype(dtype) @ swg.astype(dtype))
        sz = sz * (xt.astype(dtype) @ swi.astype(dtype))
        y = y + sz @ swo.astype(dtype)

    return y.reshape(B, S, d)
