"""GraphCast processor (Lam et al., arXiv:2212.12794) — adapted.

The original runs encoder (grid→mesh), a 16-layer message-passing processor
on a refinement-6 icosahedral mesh (d_hidden 512), and a decoder
(mesh→grid), predicting 227 surface/atmospheric variables.

Adaptation (DESIGN.md §6): the assigned shape suite supplies generic graphs
(n_nodes, n_edges), so the encoder/decoder become per-node MLPs
(d_feat → 512 → n_vars) and the processor — the dominant compute — runs on
the supplied graph.  Edge MLPs + node MLPs with residuals, exactly the
GraphCast interaction-network block.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARD, ShardRules, mlp_apply, mlp_init
from repro.models.gnn.common import GraphBatch, gather, scatter_sum
from repro.models.gnn.meshgraphnet import _mlp_ln, _mlp_ln_init


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    mesh_refinement: int = 6
    aggregator: str = "sum"
    n_vars: int = 227
    d_in: int = 227
    dtype: Any = jnp.float32
    unroll: bool = False


def init_graphcast(cfg: GraphCastConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_hidden
    layer_keys = jax.random.split(ks[2], cfg.n_layers)

    def one_layer(k):
        ke, kv = jax.random.split(k)
        return {
            "edge": _mlp_ln_init(ke, [3 * d, d, d], cfg.dtype),
            "node": _mlp_ln_init(kv, [2 * d, d, d], cfg.dtype),
        }

    return {
        "enc": _mlp_ln_init(ks[0], [cfg.d_in, d, d], cfg.dtype),
        "enc_edge": _mlp_ln_init(ks[1], [1, d, d], cfg.dtype),
        "layers": jax.vmap(one_layer)(layer_keys),
        "dec": mlp_init(ks[3], [d, d, cfg.n_vars], cfg.dtype),
    }


def graphcast_forward(cfg: GraphCastConfig, params: dict, batch: GraphBatch,
                      rules: ShardRules = NO_SHARD) -> jax.Array:
    n = batch.node_feat.shape[0]
    h = _mlp_ln(params["enc"], batch.node_feat.astype(cfg.dtype))
    e = _mlp_ln(
        params["enc_edge"], batch.edge_mask[:, None].astype(cfg.dtype)
    )
    h = rules.shard(h, ("nodes", None))
    e = rules.shard(e, ("edges", None))

    def body(carry, layer_p):
        h, e = carry
        hs, hd = gather(h, batch.edge_src), gather(h, batch.edge_dst)
        e = e + _mlp_ln(layer_p["edge"], jnp.concatenate([e, hs, hd], -1))
        e = e * batch.edge_mask[:, None]
        agg = scatter_sum(e, batch.edge_dst, n)
        h = h + _mlp_ln(layer_p["node"], jnp.concatenate([h, agg], -1))
        h = rules.shard(h, ("nodes", None))
        e = rules.shard(e, ("edges", None))
        return (h, e), None

    (h, _), _ = jax.lax.scan(body, (h, e), params["layers"],
                            unroll=cfg.n_layers if cfg.unroll else 1)
    return mlp_apply(params["dec"], h)


def graphcast_loss(cfg: GraphCastConfig, params: dict, batch: GraphBatch,
                   rules: ShardRules = NO_SHARD) -> jax.Array:
    pred = graphcast_forward(cfg, params, batch, rules)
    tgt = batch.targets if batch.targets is not None else jnp.zeros_like(pred)
    err = ((pred - tgt) ** 2).mean(-1) * batch.node_mask
    return err.sum() / jnp.maximum(batch.node_mask.sum(), 1.0)
