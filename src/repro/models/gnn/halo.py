"""Partition-aware (halo) GraphCast/MGN-style message passing — the
shard_map realization of the paper's partitioning output (DESIGN.md §4,
EXPERIMENTS.md §Perf hillclimb #1).

Layout (from `repro.dist.partition_aware.HaloPlan`): every shard owns a
contiguous node block (`n_local`) and the incoming edges of those nodes;
remote sources resolve into an all-gathered `(P·halo, d)` export buffer.
One collective per layer (the export all_gather) replaces the baseline's
full-activation all-reduce — volume drops from O(N·d) to O(P·halo·d),
i.e. proportional to the partition's edge cut: *the paper's min-cut
objective is the framework's communication optimizer*.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.partition_aware import halo_exchange
from repro.models.common import mlp_apply
from repro.models.gnn.graphcast import GraphCastConfig, _mlp_ln


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HaloBatch:
    """Per-shard arrays (leading dim = n_shards before shard_map)."""

    node_feat: jax.Array     # (P, n_local, F)
    node_mask: jax.Array     # (P, n_local)
    targets: jax.Array       # (P, n_local, d_out)
    export_idx: jax.Array    # (P, halo)
    export_mask: jax.Array   # (P, halo)
    edge_src: jax.Array      # (P, max_edges) combined index
    edge_dst: jax.Array      # (P, max_edges)
    edge_mask: jax.Array     # (P, max_edges)


def graphcast_halo_local(cfg: GraphCastConfig, params: dict, b, axis_name):
    """Forward on ONE shard's block (call inside shard_map; b fields have
    their leading shard dim already stripped)."""
    n_local = b.node_feat.shape[0]
    h = _mlp_ln(params["enc"], b.node_feat.astype(cfg.dtype))
    h = h * b.node_mask[:, None]
    e = _mlp_ln(params["enc_edge"], b.edge_mask[:, None].astype(cfg.dtype))

    def body(carry, layer_p):
        h, e = carry
        combined = halo_exchange(h, b.export_idx, b.export_mask, axis_name)
        hs = jnp.take(combined, b.edge_src, axis=0)
        hd = jnp.take(h, b.edge_dst, axis=0)
        e = e + _mlp_ln(layer_p["edge"], jnp.concatenate([e, hs, hd], -1))
        e = e * b.edge_mask[:, None]
        agg = jax.ops.segment_sum(e, b.edge_dst, num_segments=n_local)
        h = h + _mlp_ln(layer_p["node"], jnp.concatenate([h, agg], -1))
        h = h * b.node_mask[:, None]
        return (h, e), None

    (h, _), _ = jax.lax.scan(body, (h, e), params["layers"],
                             unroll=cfg.n_layers if cfg.unroll else 1)
    return mlp_apply(params["dec"], h)


def graphcast_halo_loss(cfg: GraphCastConfig, params: dict, b, axis_name):
    pred = graphcast_halo_local(cfg, params, b, axis_name)
    err = ((pred - b.targets) ** 2).mean(-1) * b.node_mask
    num = jax.lax.psum(err.sum(), axis_name)
    den = jax.lax.psum(b.node_mask.sum(), axis_name)
    return num / jnp.maximum(den, 1.0)


def make_halo_batch_abstract(plan, d_feat: int, d_out: int) -> HaloBatch:
    """ShapeDtypeStruct HaloBatch for the dry-run (no allocation)."""
    P_, NL, H, ME = plan.n_shards, plan.n_local, plan.halo, plan.max_edges
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    return HaloBatch(
        node_feat=sds((P_, NL, d_feat), f32),
        node_mask=sds((P_, NL), f32),
        targets=sds((P_, NL, d_out), f32),
        export_idx=sds((P_, H), i32),
        export_mask=sds((P_, H), f32),
        edge_src=sds((P_, ME), i32),
        edge_dst=sds((P_, ME), i32),
        edge_mask=sds((P_, ME), f32),
    )


def halo_batch_from_plan(plan, node_feat, targets) -> HaloBatch:
    """Concrete HaloBatch (tests / real training)."""
    import numpy as np

    from repro.dist.partition_aware import scatter_features

    nf = scatter_features(plan, node_feat)
    tg = scatter_features(plan, targets)
    mask = np.zeros((plan.n_shards, plan.n_local), np.float32)
    for s in range(plan.n_shards):
        mask[s, : int(plan.block_sizes[s])] = 1.0
    return HaloBatch(
        node_feat=jnp.asarray(nf),
        node_mask=jnp.asarray(mask),
        targets=jnp.asarray(tg),
        export_idx=jnp.asarray(plan.export_idx.astype("int32")),
        export_mask=jnp.asarray(plan.export_mask),
        edge_src=jnp.asarray(plan.edge_src.astype("int32")),
        edge_dst=jnp.asarray(plan.edge_dst.astype("int32")),
        edge_mask=jnp.asarray(plan.edge_mask),
    )
