"""MeshGraphNet (Pfaff et al., arXiv:2010.03409).

Encode-process-decode with residual edge+node MLP blocks:
    e' = e + MLP_e([e, h_src, h_dst])
    h' = h + MLP_v([h, Σ_{incoming} e'])
Assigned config: 15 layers, d_hidden 128, 2-layer MLPs (+LayerNorm).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARD, ShardRules, layer_norm, mlp_apply, mlp_init
from repro.models.gnn.common import GraphBatch, gather, scatter_sum


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_in: int = 3
    d_edge_in: int = 4      # relative displacement + norm (synthesized if absent)
    d_out: int = 3
    dtype: Any = jnp.float32
    unroll: bool = False

    def mlp_sizes(self, d_in):
        return [d_in] + [self.d_hidden] * self.mlp_layers


def _mlp_ln_init(key, sizes, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "mlp": mlp_init(k1, sizes, dtype),
        "ln_g": jnp.ones((sizes[-1],), dtype),
        "ln_b": jnp.zeros((sizes[-1],), dtype),
    }


def _mlp_ln(p, x):
    y = mlp_apply(p["mlp"], x)
    return layer_norm(y, p["ln_g"], p["ln_b"])


def init_mgn(cfg: MGNConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_hidden
    layer_keys = jax.random.split(ks[2], cfg.n_layers)

    def one_layer(k):
        ke, kv = jax.random.split(k)
        return {
            "edge": _mlp_ln_init(ke, cfg.mlp_sizes(3 * d), cfg.dtype),
            "node": _mlp_ln_init(kv, cfg.mlp_sizes(2 * d), cfg.dtype),
        }

    return {
        "enc_node": _mlp_ln_init(ks[0], cfg.mlp_sizes(cfg.d_in), cfg.dtype),
        "enc_edge": _mlp_ln_init(ks[1], cfg.mlp_sizes(cfg.d_edge_in), cfg.dtype),
        "layers": jax.vmap(one_layer)(layer_keys),
        "dec": mlp_init(ks[3], [d, d, cfg.d_out], cfg.dtype),
    }


def mgn_forward(cfg: MGNConfig, params: dict, batch: GraphBatch,
                rules: ShardRules = NO_SHARD) -> jax.Array:
    n = batch.node_feat.shape[0]
    h = _mlp_ln(params["enc_node"], batch.node_feat.astype(cfg.dtype))
    if batch.positions is not None:
        rel = gather(batch.positions, batch.edge_src) - gather(
            batch.positions, batch.edge_dst
        )
        e_in = jnp.concatenate(
            [rel, jnp.linalg.norm(rel, axis=-1, keepdims=True)], -1
        ).astype(cfg.dtype)
    else:
        e_in = jnp.zeros((batch.edge_src.shape[0], cfg.d_edge_in), cfg.dtype)
    e = _mlp_ln(params["enc_edge"], e_in)
    h = rules.shard(h, ("nodes", None))
    e = rules.shard(e, ("edges", None))

    def body(carry, layer_p):
        h, e = carry
        hs, hd = gather(h, batch.edge_src), gather(h, batch.edge_dst)
        e = e + _mlp_ln(layer_p["edge"], jnp.concatenate([e, hs, hd], -1))
        e = e * batch.edge_mask[:, None]
        agg = scatter_sum(e, batch.edge_dst, n)
        h = h + _mlp_ln(layer_p["node"], jnp.concatenate([h, agg], -1))
        h = rules.shard(h, ("nodes", None))
        e = rules.shard(e, ("edges", None))
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"],
                            unroll=cfg.n_layers if cfg.unroll else 1)
    return mlp_apply(params["dec"], h)


def mgn_loss(cfg: MGNConfig, params: dict, batch: GraphBatch,
             rules: ShardRules = NO_SHARD) -> jax.Array:
    pred = mgn_forward(cfg, params, batch, rules)
    tgt = batch.targets if batch.targets is not None else jnp.zeros_like(pred)
    err = ((pred - tgt) ** 2).sum(-1) * batch.node_mask
    return err.sum() / jnp.maximum(batch.node_mask.sum(), 1.0)
