"""Graph batch container + message-passing primitives."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    """Static-shape graph batch.

    node_feat : (N, F) float — input node features (may be zeros).
    edge_src/edge_dst : (E,) int32 — COO edge index (messages src→dst).
    node_mask / edge_mask : (N,)/(E,) float — 1 for real entries (padding).
    positions : (N, 3) float or None — for equivariant models.
    species : (N,) int32 or None — atomic species.
    graph_ids : (N,) int32 or None — graph membership (batched molecules).
    n_graphs : static int.
    targets : model-specific supervision.
    """

    node_feat: jax.Array
    edge_src: jax.Array
    edge_dst: jax.Array
    node_mask: jax.Array
    edge_mask: jax.Array
    positions: jax.Array | None = None
    species: jax.Array | None = None
    graph_ids: jax.Array | None = None
    targets: jax.Array | None = None
    n_graphs: int = dataclasses.field(default=1, metadata=dict(static=True))


def scatter_sum(values: jax.Array, index: jax.Array, n: int) -> jax.Array:
    """Σ values into n rows (the GNN aggregation primitive)."""
    return jax.ops.segment_sum(values, index, num_segments=n)


def gather(x: jax.Array, index: jax.Array) -> jax.Array:
    return jnp.take(x, index, axis=0)


def segment_softmax(logits: jax.Array, segment_ids: jax.Array, n: int) -> jax.Array:
    """Numerically-stable softmax over segments (GAT-style edge softmax)."""
    mx = jax.ops.segment_max(logits, segment_ids, num_segments=n)
    ex = jnp.exp(logits - jnp.take(mx, segment_ids, axis=0))
    z = jax.ops.segment_sum(ex, segment_ids, num_segments=n)
    return ex / jnp.maximum(jnp.take(z, segment_ids, axis=0), 1e-30)
