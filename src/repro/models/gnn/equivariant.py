"""E(3)-equivariant substrate: real spherical harmonics (l ≤ 2), Gaunt
tensor-product coefficients, radial bases, and the channelwise tensor
product used by NequIP and MACE.

Irrep layout: features are (..., C, 9) with the 9 components ordered
[l=0 (1), l=1 (3: m=−1,0,1 ≙ y,z,x), l=2 (5)] — orthonormal real SH.

Coupling coefficients: the real-SH Gaunt tensor
    G[i, j, k] = ∫_{S²} Y_i Y_j Y_k dΩ
is computed once at import by Gauss-Legendre (cosθ) × trapezoid (φ)
quadrature, which is *exact* for the degree-6 integrands arising at
l_max = 2.  Contracting features with edge harmonics through G is an
equivariant bilinear map (the l₁⊗l₂→l₃ channelwise tensor product with
Gaunt weights — the same contraction family e3nn builds from Wigner 3j;
adequate for NequIP/MACE-style networks and unit-tested for rotation
invariance of scalar outputs).
"""

from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

L_MAX = 2
N_IRREPS = (L_MAX + 1) ** 2  # 9
L_SLICES = {0: slice(0, 1), 1: slice(1, 4), 2: slice(4, 9)}
L_OF_INDEX = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2])


def sh_l2_np(r: np.ndarray) -> np.ndarray:
    """Orthonormal real spherical harmonics of unit vectors r (..., 3)."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    c0 = 0.5 / np.sqrt(np.pi)
    c1 = np.sqrt(3.0 / (4.0 * np.pi))
    c2a = 0.5 * np.sqrt(15.0 / np.pi)
    c2b = 0.25 * np.sqrt(5.0 / np.pi)
    c2c = 0.25 * np.sqrt(15.0 / np.pi)
    return np.stack(
        [
            np.full_like(x, c0),
            c1 * y, c1 * z, c1 * x,
            c2a * x * y, c2a * y * z,
            c2b * (3 * z * z - 1.0),
            c2a * x * z, c2c * (x * x - y * y),
        ],
        axis=-1,
    )


def sh_l2(r):
    """JAX version of sh_l2_np (same formulas, jnp ops)."""
    x, y, z = r[..., 0], r[..., 1], r[..., 2]
    c0 = 0.5 / np.sqrt(np.pi)
    c1 = np.sqrt(3.0 / (4.0 * np.pi))
    c2a = 0.5 * np.sqrt(15.0 / np.pi)
    c2b = 0.25 * np.sqrt(5.0 / np.pi)
    c2c = 0.25 * np.sqrt(15.0 / np.pi)
    return jnp.stack(
        [
            jnp.full_like(x, c0),
            c1 * y, c1 * z, c1 * x,
            c2a * x * y, c2a * y * z,
            c2b * (3 * z * z - 1.0),
            c2a * x * z, c2c * (x * x - y * y),
        ],
        axis=-1,
    )


@lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[i,j,k] = ∫ Y_i Y_j Y_k dΩ over the unit sphere (9,9,9)."""
    n_t, n_p = 24, 48
    ct, wt = np.polynomial.legendre.leggauss(n_t)       # cosθ nodes/weights
    phi = (np.arange(n_p) + 0.5) * (2 * np.pi / n_p)
    wp = 2 * np.pi / n_p
    st = np.sqrt(1.0 - ct**2)
    # grid of unit vectors
    x = st[:, None] * np.cos(phi)[None, :]
    y = st[:, None] * np.sin(phi)[None, :]
    z = np.broadcast_to(ct[:, None], x.shape)
    pts = np.stack([x, y, z], axis=-1).reshape(-1, 3)
    w = (wt[:, None] * wp * np.ones(n_p)[None, :]).reshape(-1)
    Y = sh_l2_np(pts)                                    # (M, 9)
    G = np.einsum("m,mi,mj,mk->ijk", w, Y, Y, Y)
    G[np.abs(G) < 1e-12] = 0.0
    return G


@lru_cache(maxsize=1)
def enumerate_paths() -> list:
    """Nonzero coupling paths (l1, l2, l3) under the Gaunt tensor."""
    G = gaunt_tensor()
    paths = []
    for l1 in range(L_MAX + 1):
        for l2 in range(L_MAX + 1):
            for l3 in range(L_MAX + 1):
                blk = G[L_SLICES[l1], L_SLICES[l2], L_SLICES[l3]]
                if np.abs(blk).max() > 1e-10:
                    paths.append((l1, l2, l3))
    return paths


@lru_cache(maxsize=1)
def path_tensors() -> np.ndarray:
    """(P, 9, 9, 9) per-path masked Gaunt blocks (zero outside the path)."""
    G = gaunt_tensor()
    out = []
    for l1, l2, l3 in enumerate_paths():
        M = np.zeros_like(G)
        M[L_SLICES[l1], L_SLICES[l2], L_SLICES[l3]] = G[
            L_SLICES[l1], L_SLICES[l2], L_SLICES[l3]
        ]
        out.append(M)
    return np.stack(out)


def n_paths() -> int:
    return len(enumerate_paths())


def tensor_product(feat: jnp.ndarray, sh: jnp.ndarray,
                   path_w: jnp.ndarray) -> jnp.ndarray:
    """Channelwise equivariant TP:  out[e,c,k] = Σ_p w[e,c,p]·(f ⊗_G sh)_p.

    feat   : (E, C, 9) — per-edge source-node features
    sh     : (E, 9)    — per-edge spherical harmonics
    path_w : (E, C, P) — per-path weights (radial MLP output or constants)
    """
    GP = jnp.asarray(path_tensors(), feat.dtype)         # (P, 9, 9, 9)
    # contract sh into the Gaunt blocks first: (E, P, 9_in, 9_out)
    W = jnp.einsum("pijk,ej->epik", GP, sh)
    return jnp.einsum("epik,eci,ecp->eck", W, feat, path_w)


# ---------------------------------------------------------------------------
# Radial basis
# ---------------------------------------------------------------------------

def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Bessel radial basis with smooth cosine cutoff (NequIP §methods)."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * np.pi * r[..., None] / cutoff) / r[..., None]
    fc = 0.5 * (jnp.cos(np.pi * jnp.clip(r / cutoff, 0, 1)) + 1.0)
    return basis * fc[..., None]
