"""MACE (Batatia et al., arXiv:2206.07697): higher-order equivariant
message passing.  Assigned config: 2 layers, 128 channels, l_max 2,
correlation order 3, 8 RBFs, E(3)-ACE basis.

Structure per layer (faithful to the ACE construction):
  * one-particle basis A_i = Σ_j R(r_ij) · (h_j ⊗_G Y(r̂_ij))   (as NequIP),
  * higher-order products B^(ν): B¹ = A, B^(ν) = B^(ν−1) ⊗_G A with learned
    per-path channel weights, up to ν = correlation (3) — this is the
    tensor-decomposed evaluation that makes MACE O(ν) instead of O(combinatorial),
  * message m_i = Σ_ν Lin_ν(B^(ν)); update h ← Lin(m) + Lin_skip(h),
  * per-layer scalar readout; total energy = Σ over layers and atoms.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARD, ShardRules, dense_init, mlp_apply, mlp_init
from repro.models.gnn.common import GraphBatch, gather, scatter_sum
from repro.models.gnn.equivariant import (
    n_paths,
    path_tensors,
    tensor_product,
)
from repro.models.gnn.nequip import (
    _edge_geometry,
    _initial_features,
    _per_l_linear,
    _per_l_linear_init,
)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    avg_neighbors: float = 16.0
    d_feat_in: int = 0
    dtype: Any = jnp.float32
    unroll: bool = False


def tensor_product_pair(f1: jax.Array, f2: jax.Array, path_w: jax.Array) -> jax.Array:
    """Node-local TP of two irrep features: (N,C,9)⊗(N,C,9) → (N,C,9).

    path_w: (C, P) learned per-channel, per-path weights.
    """
    GP = jnp.asarray(path_tensors(), f1.dtype)  # (P, 9, 9, 9)
    return jnp.einsum("pijk,nci,ncj,cp->nck", GP, f1, f2, path_w)


def init_mace(cfg: MACEConfig, key) -> dict:
    C, P = cfg.d_hidden, n_paths()
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)

    def one_layer(k):
        kk = jax.random.split(k, 4 + cfg.correlation)
        p = {
            "radial": mlp_init(kk[0], [cfg.n_rbf, 64, C * P], cfg.dtype),
            "mix_A": _per_l_linear_init(kk[1], C, C, cfg.dtype),
            "skip": _per_l_linear_init(kk[2], C, C, cfg.dtype),
            "readout": mlp_init(kk[3], [C, C, 1], cfg.dtype),
        }
        for nu in range(2, cfg.correlation + 1):
            p[f"prod_w{nu}"] = 0.1 * dense_init(kk[3 + nu], (C, P), dtype=cfg.dtype)
        for nu in range(1, cfg.correlation + 1):
            p[f"mix_B{nu}"] = _per_l_linear_init(
                jax.random.fold_in(k, 100 + nu), C, C, cfg.dtype
            )
        return p

    p = {
        "species_embed": dense_init(ks[1], (cfg.n_species, C), dtype=cfg.dtype),
        "layers": jax.vmap(one_layer)(layer_keys),
    }
    if cfg.d_feat_in:
        p["feat_proj"] = dense_init(ks[2], (cfg.d_feat_in, C), dtype=cfg.dtype)
    return p


def mace_layer(cfg: MACEConfig, layer_p: dict, h: jax.Array, batch: GraphBatch,
               sh: jax.Array, rbf: jax.Array, rules: ShardRules):
    N, C, P = h.shape[0], cfg.d_hidden, n_paths()
    radial = mlp_apply(layer_p["radial"], rbf).reshape(-1, C, P)
    msg = tensor_product(gather(h, batch.edge_src), sh, radial)
    msg = msg * batch.edge_mask[:, None, None]
    A = scatter_sum(msg, batch.edge_dst, N) / cfg.avg_neighbors
    A = _per_l_linear(layer_p["mix_A"], A)
    A = rules.shard(A, ("nodes", None, None))

    # higher-order ACE products: B¹=A, B^ν = B^{ν−1} ⊗_G A
    m = _per_l_linear(layer_p["mix_B1"], A)
    B = A
    for nu in range(2, cfg.correlation + 1):
        B = tensor_product_pair(B, A, layer_p[f"prod_w{nu}"])
        m = m + _per_l_linear(layer_p[f"mix_B{nu}"], B)

    h_new = m + _per_l_linear(layer_p["skip"], h)
    atom_e = mlp_apply(layer_p["readout"], h_new[:, :, 0])[:, 0]
    return h_new, atom_e


def mace_energy(cfg: MACEConfig, params: dict, batch: GraphBatch,
                rules: ShardRules = NO_SHARD) -> jax.Array:
    h = _initial_features(cfg, params, batch)
    sh, rbf = _edge_geometry(cfg, batch)
    h = rules.shard(h, ("nodes", None, None))

    def body(h, layer_p):
        h, atom_e = mace_layer(cfg, layer_p, h, batch, sh, rbf, rules)
        return h, atom_e

    h, atom_es = jax.lax.scan(body, h, params["layers"],
                       unroll=cfg.n_layers if cfg.unroll else 1)
    atom_e = atom_es.sum(0) * batch.node_mask
    gids = batch.graph_ids if batch.graph_ids is not None else jnp.zeros(
        (h.shape[0],), jnp.int32
    )
    return jax.ops.segment_sum(atom_e, gids, num_segments=batch.n_graphs)


def mace_loss(cfg: MACEConfig, params: dict, batch: GraphBatch,
              rules: ShardRules = NO_SHARD) -> jax.Array:
    e = mace_energy(cfg, params, batch, rules)
    tgt = batch.targets if batch.targets is not None else jnp.zeros_like(e)
    return jnp.mean((e - tgt) ** 2)
