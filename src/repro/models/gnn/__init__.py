"""GNN architectures: MeshGraphNet, GraphCast, NequIP, MACE.

Message passing is built on `jax.ops.segment_sum` over edge-index arrays —
JAX has no native sparse message-passing; this scatter/gather substrate IS
part of the system (see kernel_taxonomy §GNN).
"""

from repro.models.gnn.common import GraphBatch, segment_softmax
from repro.models.gnn.equivariant import enumerate_paths, gaunt_tensor, sh_l2
from repro.models.gnn.graphcast import (
    GraphCastConfig,
    graphcast_forward,
    graphcast_loss,
    init_graphcast,
)
from repro.models.gnn.mace import MACEConfig, init_mace, mace_energy, mace_loss
from repro.models.gnn.meshgraphnet import MGNConfig, init_mgn, mgn_forward, mgn_loss
from repro.models.gnn.nequip import (
    NequIPConfig,
    init_nequip,
    nequip_energy,
    nequip_loss,
)
from repro.models.gnn.sampler import sample_neighbors
