"""Neighbor sampler for sampled-training GNN cells (GraphSAGE-style fanout).

`minibatch_lg` samples 2-hop neighborhoods (fanout 15-10) of 1024 seed
nodes from a 233k-node graph — a *real* sampler, host-side NumPy (the data
pipeline runs on host), emitting static-shape padded subgraphs for jit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.mesh.graphs import Graph


@dataclasses.dataclass
class SampledSubgraph:
    """Static-shape padded subgraph in *local* node numbering.

    node_ids : (max_nodes,) original node ids (pad: 0)
    node_mask: (max_nodes,) 1.0 for real nodes
    edge_src/edge_dst : (max_edges,) local indices (pad: 0)
    edge_mask: (max_edges,)
    seed_mask: (max_nodes,) 1.0 for the seed (loss) nodes
    """

    node_ids: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seed_mask: np.ndarray


def subgraph_capacity(batch_nodes: int, fanout: tuple) -> tuple[int, int]:
    """Static (max_nodes, max_edges) for a fanout tree (dense worst case)."""
    nodes, frontier, edges = batch_nodes, batch_nodes, 0
    for f in fanout:
        edges += frontier * f
        frontier *= f
        nodes += frontier
    return nodes, edges


def sample_neighbors(
    graph: Graph,
    seeds: np.ndarray,
    fanout: tuple = (15, 10),
    *,
    rng: np.random.Generator | None = None,
) -> SampledSubgraph:
    rng = np.random.default_rng(0) if rng is None else rng
    max_nodes, max_edges = subgraph_capacity(len(seeds), fanout)

    local = {int(s): i for i, s in enumerate(seeds)}
    node_ids = list(int(s) for s in seeds)
    srcs, dsts = [], []
    frontier = np.asarray(seeds, dtype=np.int64)
    for f in fanout:
        next_frontier = []
        for u in frontier:
            nbrs = graph.indices[graph.indptr[u] : graph.indptr[u + 1]]
            if nbrs.size == 0:
                continue
            take = nbrs if nbrs.size <= f else rng.choice(nbrs, size=f, replace=False)
            for v in take:
                v = int(v)
                if v not in local:
                    local[v] = len(node_ids)
                    node_ids.append(v)
                    next_frontier.append(v)
                # message flows sampled-neighbor → center
                srcs.append(local[v])
                dsts.append(local[int(u)])
        frontier = np.asarray(next_frontier, dtype=np.int64)

    n, m = len(node_ids), len(srcs)
    out = SampledSubgraph(
        node_ids=np.zeros(max_nodes, np.int64),
        node_mask=np.zeros(max_nodes, np.float32),
        edge_src=np.zeros(max_edges, np.int32),
        edge_dst=np.zeros(max_edges, np.int32),
        edge_mask=np.zeros(max_edges, np.float32),
        seed_mask=np.zeros(max_nodes, np.float32),
    )
    out.node_ids[:n] = node_ids
    out.node_mask[:n] = 1.0
    out.edge_src[:m] = srcs
    out.edge_dst[:m] = dsts
    out.edge_mask[:m] = 1.0
    out.seed_mask[: len(seeds)] = 1.0
    return out
