"""NequIP (Batzner et al., arXiv:2101.03164): E(3)-equivariant interatomic
potential.  Assigned config: 5 layers, 32 channels, l_max 2, 8 Bessel RBFs,
cutoff 5 Å.

Per layer (faithful structure):
  * edge harmonics Y(r̂) and radial MLP R(r) → per-path tensor-product
    weights,
  * message m_ij = (h_j ⊗_G Y(r̂_ij)) weighted by R(r_ij)  (channelwise TP),
  * aggregation (Σ_j, normalized by avg. neighbor count),
  * per-l channelwise self-interaction (linear) + residual,
  * gate nonlinearity: SiLU on scalars, sigmoid-gated l>0 irreps.

Readout: per-atom MLP on final scalars → Σ over atoms (per graph).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARD, ShardRules, dense_init, mlp_apply, mlp_init
from repro.models.gnn.common import GraphBatch, gather, scatter_sum
from repro.models.gnn.equivariant import (
    L_MAX,
    L_SLICES,
    N_IRREPS,
    bessel_rbf,
    n_paths,
    sh_l2,
    tensor_product,
)


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    avg_neighbors: float = 16.0
    d_feat_in: int = 0          # optional extra scalar features (graph cells)
    dtype: Any = jnp.float32
    unroll: bool = False


def _per_l_linear_init(key, c_in, c_out, dtype):
    ks = jax.random.split(key, L_MAX + 1)
    return {f"l{l}": dense_init(ks[l], (c_in, c_out), dtype=dtype) for l in range(L_MAX + 1)}


def _per_l_linear(p, x):
    """x: (N, C, 9) → per-l channel mixing."""
    outs = []
    for l in range(L_MAX + 1):
        sl = L_SLICES[l]
        outs.append(jnp.einsum("nci,cd->ndi", x[:, :, sl], p[f"l{l}"]))
    return jnp.concatenate(outs, axis=-1)


def init_nequip(cfg: NequIPConfig, key) -> dict:
    C, P = cfg.d_hidden, n_paths()
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)

    def one_layer(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "radial": mlp_init(k1, [cfg.n_rbf, 64, C * P], cfg.dtype),
            "self": _per_l_linear_init(k2, C, C, cfg.dtype),
            "skip": _per_l_linear_init(k3, C, C, cfg.dtype),
            "gate": dense_init(k4, (C, 2 * C), dtype=cfg.dtype),  # SiLU+σ gates
        }

    p = {
        "species_embed": dense_init(ks[1], (cfg.n_species, C), dtype=cfg.dtype),
        "layers": jax.vmap(one_layer)(layer_keys),
        "readout": mlp_init(ks[2], [C, 2 * C, 1], cfg.dtype),
    }
    if cfg.d_feat_in:
        p["feat_proj"] = dense_init(ks[3], (cfg.d_feat_in, C), dtype=cfg.dtype)
    return p


def _initial_features(cfg: NequIPConfig, params, batch: GraphBatch) -> jax.Array:
    N = batch.node_mask.shape[0]
    C = cfg.d_hidden
    species = batch.species if batch.species is not None else jnp.zeros((N,), jnp.int32)
    scalars = jnp.take(params["species_embed"], species, axis=0)
    if cfg.d_feat_in and batch.node_feat is not None and batch.node_feat.ndim == 2:
        scalars = scalars + batch.node_feat.astype(cfg.dtype) @ params["feat_proj"]
    h = jnp.zeros((N, C, N_IRREPS), cfg.dtype)
    return h.at[:, :, 0].set(scalars)


def _edge_geometry(cfg: NequIPConfig, batch: GraphBatch):
    rel = gather(batch.positions, batch.edge_src) - gather(
        batch.positions, batch.edge_dst
    )
    r = jnp.linalg.norm(rel, axis=-1)
    rhat = rel / jnp.maximum(r, 1e-6)[:, None]
    sh = sh_l2(rhat).astype(cfg.dtype)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff).astype(cfg.dtype)
    return sh, rbf


def nequip_layer(cfg: NequIPConfig, layer_p: dict, h: jax.Array,
                 batch: GraphBatch, sh: jax.Array, rbf: jax.Array,
                 rules: ShardRules) -> jax.Array:
    N, C = h.shape[0], cfg.d_hidden
    P = n_paths()
    radial = mlp_apply(layer_p["radial"], rbf).reshape(-1, C, P)
    msg = tensor_product(gather(h, batch.edge_src), sh, radial)
    msg = msg * batch.edge_mask[:, None, None]
    agg = scatter_sum(msg, batch.edge_dst, N) / cfg.avg_neighbors
    agg = rules.shard(agg, ("nodes", None, None))
    z = _per_l_linear(layer_p["self"], agg) + _per_l_linear(layer_p["skip"], h)
    # gate nonlinearity: SiLU scalars, sigmoid-gated higher irreps
    s = z[:, :, 0]
    gates = s @ layer_p["gate"]
    s_act = jax.nn.silu(s + gates[:, :C])
    vec_gate = jax.nn.sigmoid(gates[:, C:])[:, :, None]
    out = jnp.concatenate([s_act[:, :, None], z[:, :, 1:] * vec_gate], axis=-1)
    return out


def nequip_energy(cfg: NequIPConfig, params: dict, batch: GraphBatch,
                  rules: ShardRules = NO_SHARD) -> jax.Array:
    """Per-graph potential energies (n_graphs,)."""
    h = _initial_features(cfg, params, batch)
    sh, rbf = _edge_geometry(cfg, batch)
    h = rules.shard(h, ("nodes", None, None))

    def body(h, layer_p):
        return nequip_layer(cfg, layer_p, h, batch, sh, rbf, rules), None

    h, _ = jax.lax.scan(body, h, params["layers"],
                       unroll=cfg.n_layers if cfg.unroll else 1)
    atom_e = mlp_apply(params["readout"], h[:, :, 0])[:, 0] * batch.node_mask
    gids = batch.graph_ids if batch.graph_ids is not None else jnp.zeros(
        (h.shape[0],), jnp.int32
    )
    return jax.ops.segment_sum(atom_e, gids, num_segments=batch.n_graphs)


def nequip_loss(cfg: NequIPConfig, params: dict, batch: GraphBatch,
                rules: ShardRules = NO_SHARD) -> jax.Array:
    e = nequip_energy(cfg, params, batch, rules)
    tgt = batch.targets if batch.targets is not None else jnp.zeros_like(e)
    return jnp.mean((e - tgt) ** 2)
