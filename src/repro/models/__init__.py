"""Model zoo for the assigned architectures.

transformer/ — decoder-only LMs (dense + MoE), train + KV-cache serving.
gnn/        — message-passing and equivariant GNNs.
recsys/     — embedding-table + sequential recommendation.
"""
