"""Decoder-only transformer LM: GQA + RoPE + RMSNorm + SwiGLU (+ MoE).

Covers the five assigned LM architectures (tinyllama, mistral-large,
command-r dense; deepseek-moe, qwen3-moe sparse).  Production posture:

* **scan-over-layers** with stacked parameters (compact HLO, fast compile at
  88 layers, remat-friendly) — standard MaxText structure,
* **chunked (online-softmax) attention** in pure JAX — O(S·block) memory so
  32k-token prefill lowers without materializing S×S scores; the Pallas
  `flash_attention` kernel implements the same contraction for real TPU,
* logical-axis sharding hooks (`ShardRules`) on every activation that the
  distribution layer maps to mesh axes,
* separate `train_step` (next-token CE + optimizer) and `prefill` /
  `decode_step` (KV cache) entry points — the shapes suite lowers
  `train_4k` against the former and `prefill_32k` / `decode_32k` against
  the latter.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import NO_SHARD, ShardRules, dense_init, embed_init, rms_norm
from repro.models.moe import MoEConfig, init_moe, moe_apply


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    moe: MoEConfig | None = None
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16          # compute dtype
    param_dtype: Any = jnp.float32     # master params
    attn_block_kv: int = 1024          # online-softmax KV block
    remat: bool = True
    attn: str = "full"                 # "full" | "sliding_window"
    window: int = 4096                 # for sliding_window
    # "auto": masked full attention for training seqs ≤ 8k (remat-friendly
    # backward), online-softmax chunked otherwise and for serving.
    attn_impl: str = "auto"
    unroll: bool = False               # unroll scan-over-layers (dry-run
                                       # fidelity: per-layer FLOPs/collectives
                                       # visible to cost_analysis)

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        d, h = self.d_model, self.n_heads * self.d_head
        kv = self.n_kv_heads * self.d_head
        attn = d * h + 2 * d * kv + h * d
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.moe.d_ff_expert * (self.moe.n_experts + self.moe.n_shared)
            ffn += d * self.moe.n_experts  # router
        return self.n_layers * (attn + ffn + 2 * d) + 2 * self.vocab * d + d

    def n_active_params(self) -> int:
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        h, kv = self.n_heads * self.d_head, self.n_kv_heads * self.d_head
        attn = d * h + 2 * d * kv + h * d
        ffn = 3 * d * self.moe.d_ff_expert * (self.moe.top_k + self.moe.n_shared)
        return self.n_layers * (attn + ffn + 2 * d) + 2 * self.vocab * d + d


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_layer(cfg: LMConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    d, dh = cfg.d_model, cfg.d_head
    p = {
        "attn_norm": jnp.ones((d,), cfg.param_dtype),
        "wq": dense_init(ks[0], (d, cfg.n_heads, dh), dtype=cfg.param_dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, dh), dtype=cfg.param_dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, dh), dtype=cfg.param_dtype),
        "wo": dense_init(ks[3], (cfg.n_heads, dh, d), in_axis=0, dtype=cfg.param_dtype),
        "ffn_norm": jnp.ones((d,), cfg.param_dtype),
    }
    if cfg.moe is None:
        p["ffn"] = {
            "wi": dense_init(ks[4], (d, cfg.d_ff), dtype=cfg.param_dtype),
            "wg": dense_init(ks[5], (d, cfg.d_ff), dtype=cfg.param_dtype),
            "wo": dense_init(ks[6], (cfg.d_ff, d), dtype=cfg.param_dtype),
        }
    else:
        p["moe"] = init_moe(cfg.moe, d, ks[7], cfg.param_dtype)
    return p


def init_params(cfg: LMConfig, key) -> dict:
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    # Stacked layers: every leaf gets a leading (n_layers,) dim for lax.scan.
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    return {
        "embed": embed_init(k_embed, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "head": dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    } | {"layers": layers}


def abstract_params(cfg: LMConfig) -> dict:
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# RoPE + attention
# ---------------------------------------------------------------------------

def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated by position pos (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype)
    xr2 = x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype)
    return jnp.concatenate([xr1, xr2], axis=-1)


def chunked_attention(
    q: jax.Array,           # (B, Sq, Hkv, G, D)
    k: jax.Array,           # (B, Skv, Hkv, D)
    v: jax.Array,           # (B, Skv, Hkv, D)
    *,
    q_pos: jax.Array,       # (B, Sq) global positions of queries
    block_kv: int,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Online-softmax attention over KV blocks — O(Sq·block) memory.

    Pure-JAX analogue of the Pallas flash_attention kernel (kernels/
    flash_attention/ref.py is derived from this).  Differentiable; the
    backward pass recomputes per-block scores under remat.
    """
    B, Sq, Hkv, G, D = q.shape
    Skv = k.shape[1]
    nblk = -(-Skv // block_kv)
    pad = nblk * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_kv, Hkv, D)
    vb = v.reshape(B, nblk, block_kv, Hkv, D)
    scale = 1.0 / np.sqrt(D)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, start = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kblk).astype(jnp.float32) * scale
        kv_pos = start + jnp.arange(block_kv)
        mask = jnp.ones((), bool)
        if causal:
            mask = q_pos[:, None, None, :, None] >= kv_pos[None, None, None, None, :]
        if window is not None:
            mask = mask & (
                q_pos[:, None, None, :, None] - kv_pos[None, None, None, None, :]
                < window
            )
        mask = mask & (kv_pos < Skv)[None, None, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), vblk)
        acc_new = acc * corr[..., None].astype(q.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Sq, D), q.dtype)
    starts = jnp.arange(nblk) * block_kv
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1), starts)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # (B, Sq, Hkv, G, D)


def blocked_attention(
    q: jax.Array,           # (B, S, H, D) — repeated-KV layout, H sharded
    k: jax.Array,           # (B, S, H, D)
    v: jax.Array,
    *,
    q_pos: jax.Array,       # (B, S)
    block_q: int = 512,
    block_kv: int = 1024,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Flash-structured attention for train/prefill: q-blocked outer scan,
    online-softmax inner KV sweep, per-q-block remat.

    Memory: O(block_q · block_kv) score tiles + O(S · D) accumulators per
    live block — never the S×S matrix.  K/V are closed over (scan
    constants), so the rematted backward stores them once per layer, not
    per block.  GQA is realized by KV-head repetition (Megatron style when
    TP > kv_heads), which keeps the head axis shardable over "model".
    The Pallas flash_attention kernel is the TPU-hardware twin of this
    contraction (same tiling, same masks).
    """
    B, S, H, D = q.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    nq = -(-S // block_q)
    pad_q = nq * block_q - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    nk = -(-Skv // block_kv)
    pad_k = nk * block_kv - Skv
    kk = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vv = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    kb = kk.reshape(B, nk, block_kv, H, D).swapaxes(0, 1)  # (nk, B, bk, H, D)
    vb = vv.reshape(B, nk, block_kv, H, D).swapaxes(0, 1)

    def one_q_block(q_blk, pos_blk):
        # q_blk: (B, bq, H, D); pos_blk: (B, bq)
        def kv_step(carry, xs):
            m, l, acc = carry
            k_blk, v_blk, start = xs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32)
            s = s * scale
            kv_pos = start + jnp.arange(block_kv)
            mask = (kv_pos < Skv)[None, None, None, :]
            if causal:
                mask = mask & (
                    pos_blk[:, None, :, None] >= kv_pos[None, None, None, :]
                )
            if window is not None:
                mask = mask & (
                    pos_blk[:, None, :, None] - kv_pos[None, None, None, :]
                    < window
                )
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q_blk.dtype), v_blk)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, D), jnp.float32)
        starts = jnp.arange(nk) * block_kv
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, starts))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q_blk.dtype).transpose(0, 2, 1, 3)  # (B, bq, H, D)

    body = jax.checkpoint(one_q_block,
                          policy=jax.checkpoint_policies.nothing_saveable)
    qb = q.reshape(B, nq, block_q, H, D).swapaxes(0, 1)
    pb = q_pos.reshape(B, nq, block_q).swapaxes(0, 1)
    _, outs = jax.lax.scan(lambda c, xs: (c, body(*xs)), None, (qb, pb))
    out = outs.swapaxes(0, 1).reshape(B, nq * block_q, H, D)
    return out[:, :S]


def attention_block(cfg: LMConfig, p: dict, x: jax.Array, pos: jax.Array,
                    rules: ShardRules, k_cache=None, v_cache=None):
    """Self-attention; with a cache, computes decode attention over it."""
    B, S, d = x.shape
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cfg.dtype))
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    q = rules.shard(q, ("batch", "seq", "heads", None))
    k = rules.shard(k, ("batch", "seq", "kv_heads", None))

    if k_cache is not None:
        # decode: write current k/v at `pos`, attend over the whole cache
        idx = pos[0, 0]  # uniform decode position across batch
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, idx, axis=1)
        k_all, v_all = k_cache, v_cache
    else:
        k_all, v_all = k, v

    window = cfg.window if cfg.attn == "sliding_window" else None
    if k_cache is None and cfg.attn_impl != "grouped":
        # train/prefill path: repeated-KV + q-blocked flash-structured attn
        k_rep = jnp.repeat(k_all, cfg.q_per_kv, axis=2)
        v_rep = jnp.repeat(v_all, cfg.q_per_kv, axis=2)
        k_rep = rules.shard(k_rep, ("batch", "seq", "heads", None))
        v_rep = rules.shard(v_rep, ("batch", "seq", "heads", None))
        qh = q  # (B, S, H, D), heads sharded
        out = blocked_attention(
            qh, k_rep, v_rep, q_pos=pos,
            block_q=min(512, S), block_kv=min(cfg.attn_block_kv, k_all.shape[1]),
            causal=True, window=window,
        ).reshape(B, S, cfg.n_heads, cfg.d_head)
    else:
        # decode path: GQA-grouped online softmax over the (large) cache
        qg = q.reshape(B, S, cfg.n_kv_heads, cfg.q_per_kv, cfg.d_head)
        out = chunked_attention(
            qg, k_all, v_all, q_pos=pos,
            block_kv=min(cfg.attn_block_kv, k_all.shape[1]),
            causal=True, window=window,
        )
    out = out.reshape(B, S, cfg.n_heads, cfg.d_head)
    out = rules.shard(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    y = rules.shard(y, ("batch", "act_seq", "embed"))
    return y, (k_cache, v_cache)


def _moe_shardmap_block(cfg: LMConfig, moe_p: dict, h: jax.Array,
                        rules: ShardRules) -> jax.Array:
    """Expert-parallel MoE via shard_map (EP all-to-all dispatch).

    Token layout follows the residual stream (batch over data axes, seq
    over model under SP); expert weights arrive model-sharded (+FSDP d
    shards re-gathered inside).  See moe.moe_apply_shardmap.
    """
    from repro.models.moe import moe_apply_shardmap

    E = cfg.moe.n_experts
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    names = rules.mesh_axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    sp = lambda logical, shape: rules.spec(logical, shape)
    x_spec = sp(("batch", "act_seq", "embed"), h.shape)
    pspec = {
        "router": sp((None, None), (d, E)),
        "wi": sp(("experts", "fsdp", None), (E, d, f)),
        "wg": sp(("experts", "fsdp", None), (E, d, f)),
        "wo": sp(("experts", None, "fsdp"), (E, f, d)),
    }
    fsdp_gather = pspec["wi"] != jax.sharding.PartitionSpec("model", None, None)
    if cfg.moe.n_shared:
        fs = f * cfg.moe.n_shared
        pspec["shared_wi"] = sp((None, "ffn"), (d, fs))
        pspec["shared_wg"] = sp((None, "ffn"), (d, fs))
        pspec["shared_wo"] = sp(("ffn", None), (fs, d))

    def body(xl, pl):
        return moe_apply_shardmap(
            cfg.moe, pl, xl, data_axes=data_axes, model_axis="model",
            dtype=cfg.dtype, fsdp_gather=fsdp_gather,
        )

    fn = jax.shard_map(body, in_specs=(x_spec, pspec), out_specs=x_spec,
                       check_vma=False)
    return fn(h, moe_p)


def ffn_block(cfg: LMConfig, p: dict, x: jax.Array, rules: ShardRules):
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if cfg.moe is None:
        f = p["ffn"]
        z = jax.nn.silu(h @ f["wg"].astype(cfg.dtype)) * (h @ f["wi"].astype(cfg.dtype))
        z = rules.shard(z, ("batch", "seq", "ffn"))
        y = z @ f["wo"].astype(cfg.dtype)
    elif cfg.moe.impl == "shardmap":
        y = _moe_shardmap_block(cfg, p["moe"], h, rules)
    else:
        y = moe_apply(cfg.moe, p["moe"], h, rules, cfg.dtype)
    return rules.shard(y, ("batch", "act_seq", "embed"))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _layer_fn(cfg: LMConfig, rules: ShardRules, carry, layer_p, cache_slice=None):
    x, pos = carry
    kc, vc = (None, None) if cache_slice is None else cache_slice
    a, (kc, vc) = attention_block(cfg, layer_p, x, pos, rules, kc, vc)
    x = x + a
    x = x + ffn_block(cfg, layer_p, x, rules)
    return (x, pos), (kc, vc)


def _cast_layers(cfg: LMConfig, params: dict, rules: ShardRules = NO_SHARD):
    """Cast the stacked layer params to compute dtype ONCE (outside remat),
    so FSDP all-gathers move bf16, not fp32 masters.

    The cast stack is re-constrained to the parameter PartitionSpecs
    (`rules.layer_specs`, attached by launch/cells.py) — otherwise XLA may
    hoist the per-layer FSDP all-gather out of the scan and keep ALL layers
    gathered simultaneously (observed: +15 GB/device on mistral-large)."""
    from repro.models.common import tree_cast

    layers = tree_cast(params["layers"], cfg.dtype)
    specs = getattr(rules, "layer_specs", None)
    if specs is not None:
        layers = jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), layers, specs
        )
    return layers


def forward(cfg: LMConfig, params: dict, tokens: jax.Array,
            rules: ShardRules = NO_SHARD) -> jax.Array:
    """Training/prefill forward: tokens (B, S) → logits (B, S, V)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    x = rules.shard(x, ("batch", "act_seq", "embed"))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    layers = _cast_layers(cfg, params, rules)

    def body(carry, layer_p):
        fn = _layer_fn
        if cfg.remat:
            fn = jax.checkpoint(
                partial(_layer_fn, cfg, rules),
                policy=jax.checkpoint_policies.nothing_saveable,
            )
            out, _ = fn(carry, layer_p)
        else:
            out, _ = _layer_fn(cfg, rules, carry, layer_p)
        return out, None

    (x, _), _ = jax.lax.scan(body, (x, pos), layers,
                             unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.dtype))
    return rules.shard(logits, ("batch", "seq", "vocab"))


def loss_fn(cfg: LMConfig, params: dict, batch: dict,
            rules: ShardRules = NO_SHARD) -> jax.Array:
    logits = forward(cfg, params, batch["tokens"], rules).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving (KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def abstract_cache(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.dtype),
        "v": jax.ShapeDtypeStruct(shape, cfg.dtype),
    }


def prefill(cfg: LMConfig, params: dict, tokens: jax.Array,
            rules: ShardRules = NO_SHARD) -> tuple[jax.Array, dict]:
    """Prefill: full forward that also returns the populated KV cache."""
    B, S = tokens.shape
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    x = rules.shard(x, ("batch", "act_seq", "embed"))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    # Per-layer K/V of the current tokens become the cache; they are
    # recomputed outside the layer fn so remat stays simple.
    def body_cache(carry, layer_p):
        x, pos = carry
        h = rms_norm(x, layer_p["attn_norm"], cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", h, layer_p["wk"].astype(cfg.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, layer_p["wv"].astype(cfg.dtype))
        k = rope(k, pos, cfg.rope_theta)
        (x, pos), _ = _layer_fn(cfg, rules, (x, pos), layer_p, None)
        return (x, pos), (k, v)

    (x, _), (ks, vs) = jax.lax.scan(body_cache, (x, pos),
                                    _cast_layers(cfg, params, rules),
                                    unroll=cfg.n_layers if cfg.unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["head"].astype(cfg.dtype))
    cache = {"k": rules.shard(ks, (None, "batch", "seq", "kv_heads", None)),
             "v": rules.shard(vs, (None, "batch", "seq", "kv_heads", None))}
    return logits, cache


def decode_step(cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array,
                pos_scalar: jax.Array, rules: ShardRules = NO_SHARD):
    """One decode step: tokens (B, 1) at position pos → logits, new cache."""
    B, S = tokens.shape
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    x = rules.shard(x, ("batch", None, "embed"))
    pos = jnp.broadcast_to(pos_scalar, (B, S))

    def body(carry, xs):
        layer_p, kc, vc = xs
        (x, pos), (kc, vc) = _layer_fn(cfg, rules, carry, layer_p, (kc, vc))
        return (x, pos), (kc, vc)

    (x, _), (ks, vs) = jax.lax.scan(
        body, (x, pos), (_cast_layers(cfg, params), cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.unroll else 1,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(cfg.dtype))
    return logits, {"k": ks, "v": vs}
