"""SASRec (Kang & McAuley, arXiv:1808.09781): self-attentive sequential
recommendation.  Assigned config: embed_dim 50, 2 blocks, 1 head, seq 50.

Training: next-item prediction with sampled-negative binary cross-entropy
(the paper's objective: one negative per positive).  Serving: the final
hidden state is the user representation; candidates are scored by dot
product against (row-sharded) item embeddings — `retrieval_cand` scores one
user against 10⁶ candidates as a single batched matmul, not a loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (
    NO_SHARD,
    ShardRules,
    dense_init,
    embed_init,
    layer_norm,
)


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    d_ff: int = 50
    pad_rows: int = 512     # table rows padded for clean row-sharding
    dtype: Any = jnp.float32

    @property
    def table_rows(self) -> int:
        """Row 0 is the padding item; rows padded to `pad_rows` multiple so
        the table row-shards evenly over any mesh axis ≤ pad_rows."""
        return -(-(self.n_items + 1) // self.pad_rows) * self.pad_rows

    def n_params(self) -> int:
        d = self.embed_dim
        blk = 4 * d * d + 2 * d * self.d_ff + 4 * d
        return (self.table_rows + self.seq_len) * d + self.n_blocks * blk


def init_sasrec(cfg: SASRecConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.embed_dim
    blk_keys = jax.random.split(ks[2], cfg.n_blocks)

    def one_block(k):
        kk = jax.random.split(k, 6)
        return {
            "wq": dense_init(kk[0], (d, d), dtype=cfg.dtype),
            "wk": dense_init(kk[1], (d, d), dtype=cfg.dtype),
            "wv": dense_init(kk[2], (d, d), dtype=cfg.dtype),
            "wo": dense_init(kk[3], (d, d), dtype=cfg.dtype),
            "w1": dense_init(kk[4], (d, cfg.d_ff), dtype=cfg.dtype),
            "w2": dense_init(kk[5], (cfg.d_ff, d), dtype=cfg.dtype),
            "ln1_g": jnp.ones((d,), cfg.dtype), "ln1_b": jnp.zeros((d,), cfg.dtype),
            "ln2_g": jnp.ones((d,), cfg.dtype), "ln2_b": jnp.zeros((d,), cfg.dtype),
        }

    return {
        # row 0 = padding item
        "item_embed": embed_init(ks[0], (cfg.table_rows, d), cfg.dtype),
        "pos_embed": embed_init(ks[1], (cfg.seq_len, d), cfg.dtype),
        "blocks": jax.vmap(one_block)(blk_keys),
        "final_ln_g": jnp.ones((d,), cfg.dtype),
        "final_ln_b": jnp.zeros((d,), cfg.dtype),
    }


def _block(cfg: SASRecConfig, p: dict, x: jax.Array, mask: jax.Array) -> jax.Array:
    B, S, d = x.shape
    h = layer_norm(x, p["ln1_g"], p["ln1_b"])
    q, k, v = h @ p["wq"], h @ p["wk"], h @ p["wv"]
    H = cfg.n_heads
    dh = d // H
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, H, dh)
    v = v.reshape(B, S, H, dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
    causal = jnp.tril(jnp.ones((S, S), bool))
    valid = causal[None, None] & (mask[:, None, None, :] > 0)
    s = jnp.where(valid, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, d)
    x = x + o @ p["wo"]
    h = layer_norm(x, p["ln2_g"], p["ln2_b"])
    x = x + jax.nn.relu(h @ p["w1"]) @ p["w2"]
    return x * mask[:, :, None]


def sasrec_user_state(cfg: SASRecConfig, params: dict, item_seq: jax.Array,
                      rules: ShardRules = NO_SHARD) -> jax.Array:
    """item_seq (B, S) int32 (0 = pad) → per-position user states (B, S, d)."""
    B, S = item_seq.shape
    mask = (item_seq > 0).astype(cfg.dtype)
    x = jnp.take(params["item_embed"], item_seq, axis=0) * np.sqrt(cfg.embed_dim)
    x = x + params["pos_embed"][None, :S]
    x = x * mask[:, :, None]
    x = rules.shard(x, ("batch", None, None))
    for i in range(cfg.n_blocks):
        blk = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        x = _block(cfg, blk, x, mask)
    return layer_norm(x, params["final_ln_g"], params["final_ln_b"])


def sasrec_train_loss(cfg: SASRecConfig, params: dict, batch: dict,
                      rules: ShardRules = NO_SHARD) -> jax.Array:
    """batch: item_seq (B,S), pos_items (B,S), neg_items (B,S)."""
    h = sasrec_user_state(cfg, params, batch["item_seq"], rules)
    pe = jnp.take(params["item_embed"], batch["pos_items"], axis=0)
    ne = jnp.take(params["item_embed"], batch["neg_items"], axis=0)
    pos_logit = (h * pe).sum(-1)
    neg_logit = (h * ne).sum(-1)
    mask = (batch["pos_items"] > 0).astype(cfg.dtype)
    loss = -(
        jax.nn.log_sigmoid(pos_logit) + jax.nn.log_sigmoid(-neg_logit)
    ) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)


def sasrec_score_candidates(cfg: SASRecConfig, params: dict, item_seq: jax.Array,
                            candidates: jax.Array,
                            rules: ShardRules = NO_SHARD) -> jax.Array:
    """Serve: score candidates (N_c,) for each user → (B, N_c) logits."""
    h = sasrec_user_state(cfg, params, item_seq, rules)[:, -1]   # (B, d)
    ce = jnp.take(params["item_embed"], candidates, axis=0)      # (N_c, d)
    ce = rules.shard(ce, ("vocab", None))
    return rules.shard(h @ ce.T, ("batch", "vocab"))
