"""RecSys: embedding tables + SASRec sequential recommender."""

from repro.models.recsys.embedding import embedding_bag
from repro.models.recsys.sasrec import (
    SASRecConfig,
    init_sasrec,
    sasrec_score_candidates,
    sasrec_train_loss,
    sasrec_user_state,
)
