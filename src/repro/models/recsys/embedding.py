"""EmbeddingBag for JAX: ragged multi-hot lookup + segment reduce.

JAX has no native `nn.EmbeddingBag` (kernel_taxonomy §RecSys) — this IS the
system's lookup-reduce hot path: `jnp.take` over the (row-sharded) table
followed by `segment_sum`/`segment_max`.  The Pallas `embedding_bag` kernel
implements the same contraction with VMEM tiling; this module is the
reference implementation and the single-device fallback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(
    table: jax.Array,        # (vocab, dim)
    indices: jax.Array,      # (nnz,) int — flattened multi-hot ids
    offsets_or_segments: jax.Array,  # (nnz,) segment id per index
    n_bags: int,
    *,
    mode: str = "sum",
    weights: jax.Array | None = None,
) -> jax.Array:
    """Gather rows and reduce per bag.  segment ids must be sorted for TPU
    efficiency (the data pipeline guarantees it); correctness does not
    depend on it."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, offsets_or_segments, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, offsets_or_segments, num_segments=n_bags)
        c = jax.ops.segment_sum(
            jnp.ones_like(indices, rows.dtype), offsets_or_segments, num_segments=n_bags
        )
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, offsets_or_segments, num_segments=n_bags)
    raise ValueError(mode)
