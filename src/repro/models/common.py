"""Shared model building blocks: init helpers, norms, MLPs, sharding hooks."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class ShardRules:
    """Logical-axis → PartitionSpec hook threaded through every model.

    Models annotate activations/params with *logical* axis names; the
    distribution layer (repro.dist.sharding) maps them onto mesh axes.  The
    default instance is a no-op so models run unmodified on a single device.
    """

    def spec(self, axes: Sequence[str | None]):
        return None

    def shard(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        return x


NO_SHARD = ShardRules()


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis]
    std = scale / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32, scale: float = 1.0):
    return (scale * jax.random.normal(key, shape) / np.sqrt(shape[-1])).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 accumulation (bf16-safe)."""
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * gamma.astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma.astype(x.dtype) + beta.astype(x.dtype)


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        f"w{i}": dense_init(keys[i], (sizes[i], sizes[i + 1]), dtype=dtype)
        for i in range(len(sizes) - 1)
    } | {
        f"b{i}": jnp.zeros((sizes[i + 1],), dtype) for i in range(len(sizes) - 1)
    }


def mlp_apply(params: dict, x: jax.Array, *, act=jax.nn.silu, final_act=False) -> jax.Array:
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params) -> int:
    return sum(
        int(np.prod(p.shape)) * p.dtype.itemsize
        for p in jax.tree_util.tree_leaves(params)
    )


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, tree
    )


@dataclasses.dataclass
class StepMetrics:
    loss: jax.Array
    grad_norm: jax.Array

    def __iter__(self):
        yield self.loss
        yield self.grad_norm


jax.tree_util.register_dataclass(StepMetrics)
