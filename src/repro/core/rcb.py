"""Recursive Coordinate / Inertial Bisection (paper §3 + pre-partitioner §8).

RCB: find the longest coordinate axis, sort by that coordinate, split at the
weighted median, recurse.  RIB: same, but along the principal inertial axis
(covariance eigenvector), so cuts need not be axis-aligned.

Two uses in parRSB:
  * stand-alone geometric partitioners (quality baselines, Tables 1–4), and
  * the *pre-partitioner / ordering bootstrap*: `rcb_order` produces a full
    recursive ordering (down to singletons) that (a) makes element data
    locally contiguous before Lanczos/inverse iteration (paper: ≈2× speedup)
    and (b) seeds the AMG pairwise aggregation (paper §7: "We bootstrap the
    prolongation operator from an RCB ordering of the mesh elements").

Host-side NumPy: sorting-based, O(n log² n), exactly like the production
code's parallel sort usage.
"""

from __future__ import annotations

import numpy as np


def _principal_axis(coords: np.ndarray, weights: np.ndarray) -> np.ndarray:
    w = weights / weights.sum()
    mean = (coords * w[:, None]).sum(0)
    centered = coords - mean
    cov = (centered * w[:, None]).T @ centered
    eigval, eigvec = np.linalg.eigh(cov)
    return eigvec[:, -1]


def _axis_key(coords: np.ndarray, weights: np.ndarray, *, inertial: bool) -> np.ndarray:
    if inertial:
        return coords @ _principal_axis(coords, weights)
    extent = coords.max(0) - coords.min(0)
    return coords[:, int(np.argmax(extent))]


def _global_rescale(coords: np.ndarray) -> np.ndarray:
    """Paper §3: rescale ONCE so the global bounding box is isotropic
    (average element diameters match per axis).  Rescaling per-subset would
    equalize every subset's extents and degenerate RCB into slab cuts."""
    span = coords.max(0) - coords.min(0)
    span = np.where(span > 0, span, 1.0)
    return coords / span


def _weighted_split(keys: np.ndarray, weights: np.ndarray,
                    frac: float) -> tuple[np.ndarray, np.ndarray]:
    """Sort by key; split at the weighted `frac` quantile (indices)."""
    order = np.argsort(keys, kind="stable")
    cw = np.cumsum(weights[order])
    total = cw[-1]
    # smallest prefix with ≥ frac of the weight; ties keep element counts
    # within 1 for unit weights (paper Eq. 2.6)
    k = int(np.searchsorted(cw, frac * total, side="left")) + 1
    k = min(max(k, 1), keys.size - 1) if keys.size > 1 else 0
    return order[:k], order[k:]


def _bisect_order(coords, weights, idx, *, inertial):
    """Iterative recursive-bisection ordering (DFS, left-half first)."""
    stack = [idx]
    ordered = []
    while stack:
        cur = stack.pop()
        if cur.size <= 1:
            ordered.append(cur)
            continue
        keys = _axis_key(coords[cur], weights[cur], inertial=inertial)
        lo, hi = _weighted_split(keys, weights[cur], 0.5)
        # push right first so left pops first (DFS left-to-right)
        stack.append(cur[hi])
        stack.append(cur[lo])
    return np.concatenate(ordered) if ordered else idx


def rcb_order(coords: np.ndarray, weights: np.ndarray | None = None, *,
              inertial: bool = False, rescale: bool = True) -> np.ndarray:
    """Full recursive bisection ordering (permutation of 0..n-1).

    Contiguous chunks of the result are spatially compact at every dyadic
    scale — the property both the pre-partitioner and the AMG aggregation
    bootstrap rely on.
    """
    coords = np.asarray(coords, dtype=np.float64)
    if rescale:
        coords = _global_rescale(coords)
    n = coords.shape[0]
    w = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    return _bisect_order(coords, w, np.arange(n, dtype=np.int64),
                         inertial=inertial)


def rib_order(coords: np.ndarray, weights: np.ndarray | None = None,
              *, rescale: bool = True) -> np.ndarray:
    return rcb_order(coords, weights, inertial=True, rescale=rescale)


def _parts_from_order(order: np.ndarray, weights: np.ndarray,
                      nparts: int) -> np.ndarray:
    """Split an ordering into `nparts` contiguous, weight-balanced chunks.

    Midpoint rule (cw − w/2) keeps unit-weight splits exact (≤1 element
    imbalance) instead of drifting on cumulative-sum ties."""
    w_sorted = weights[order]
    cw = np.cumsum(w_sorted)
    total = cw[-1]
    bounds = np.searchsorted(cw - w_sorted / 2,
                             total * np.arange(1, nparts) / nparts, side="left")
    parts = np.empty(order.size, dtype=np.int64)
    prev = 0
    for p, b in enumerate(np.r_[bounds, order.size]):
        parts[order[prev : b if p < nparts - 1 else order.size]] = p
        prev = b
    return parts


def rcb_parts(coords: np.ndarray, nparts: int,
              weights: np.ndarray | None = None, *, inertial: bool = False) -> np.ndarray:
    """RCB/RIB k-way partition via recursive proportional splits."""
    coords = _global_rescale(np.asarray(coords, dtype=np.float64))
    n = coords.shape[0]
    w = np.ones(n) if weights is None else np.asarray(weights, dtype=np.float64)
    parts = np.zeros(n, dtype=np.int64)

    def rec(idx: np.ndarray, p_lo: int, p_hi: int) -> None:
        np_parts = p_hi - p_lo
        if np_parts <= 1 or idx.size == 0:
            parts[idx] = p_lo
            return
        p_left = np_parts // 2
        keys = _axis_key(coords[idx], w[idx], inertial=inertial)
        lo, hi = _weighted_split(keys, w[idx], p_left / np_parts)
        rec(idx[lo], p_lo, p_lo + p_left)
        rec(idx[hi], p_lo + p_left, p_hi)

    rec(np.arange(n, dtype=np.int64), 0, nparts)
    return parts


def rib_parts(coords: np.ndarray, nparts: int,
              weights: np.ndarray | None = None) -> np.ndarray:
    return rcb_parts(coords, nparts, weights, inertial=True)
