"""Aggregation-based AMG preconditioner (paper §7, Algorithm 3).

LAMG-inspired V-cycle over Galerkin coarse operators

    L_{l+1} = J_l^{l+1} L_l J_{l+1}^l

with **piecewise-constant prolongation bootstrapped from the RCB ordering**:
nodes are permuted by `rcb_order` once at setup; level-l aggregation then
pairs consecutive nodes (`i → i // 2`), i.e. `J = I₂ ⊗ J_prev` exactly as in
the paper.  Because J is Boolean piecewise-constant, every coarse operator
remains a graph Laplacian (zero row sums, nonpositive off-diagonal), so each
level is stored as a coarse *graph* in padded-ELL form and applied with the
same `EllLaplacian` matvec (Pallas `ell_spmv` on TPU).

Smoother: damped Jacobi (σ D⁻¹), following Algorithm 3.  The coarsest level
(≤ `coarse_size` rows) is solved with a dense pseudo-inverse computed at
setup — pinv because the Laplacian is singular on the constants; this is a
robustness improvement over pure smoothing at the coarsest level (recorded
as an implementation choice, not a paper deviation: the paper's coarsest
level is "a single row per processor" and the all-ones nullspace is handled
by the outer projection either way).

Two forms share the math:

* `AMG` (`amg_setup`) — one graph, ragged per-level sizes, host recursion.
* `BatchedAMG` (`amg_setup_batched`) — B graphs padded to a shared
  power-of-two level ladder (n_pad, n_pad/2, …), each level one
  leading-batch-dim `EllLaplacian`, packed exactly like the
  level-synchronous engine packs its operators.  Because every problem is
  RCB-ordered and padded to the same n_pad, the pairwise aggregation map
  `i → i // 2` is IDENTICAL across problems and levels, so restriction is
  a reshape-sum and prolongation a repeat — no per-problem index maps on
  device, and the whole preconditioner is a pytree that rides through the
  jitted batched flexcg as a traced argument (one trace per shape bucket).
  Padding rows carry zero operator rows, so they stay zero through the
  cycle and the outer masked projection discards any prolongation spill.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EllLaplacian, ell_laplacian, ell_laplacian_batched
from repro.mesh.graphs import Graph, build_csr


def coarsen_graph(graph: Graph, agg: np.ndarray, n_coarse: int,
                  *, node_weights: np.ndarray | None = None):
    """Galerkin coarse graph: weights between aggregates are summed.

    Edges whose endpoints land in ONE aggregate become self-loops and are
    dropped (``build_csr`` filters ``src == dst``), so the coarse total
    edge weight is the fine total minus the absorbed intra-aggregate
    weight — never more.  When ``node_weights`` is given, aggregate node
    weights are accumulated and ``(coarse_graph, coarse_weights)`` is
    returned; the node-weight sum is conserved exactly level to level,
    which is what makes balance corridors computed on the FINE total valid
    at every coarse level of the multilevel V-cycle.
    """
    rows = graph.rows
    coarse = build_csr(
        agg[rows], agg[graph.indices], n_coarse,
        weights=graph.weights, symmetrize=False,
    )
    if node_weights is None:
        return coarse
    w_c = np.bincount(agg, weights=np.asarray(node_weights, np.float64),
                      minlength=n_coarse)
    return coarse, w_c


def heavy_edge_matching(graph: Graph, *, node_weights: np.ndarray | None = None,
                        max_weight: float | None = None, seed: int = 0,
                        rounds: int = 4) -> tuple[np.ndarray, int]:
    """Vectorized heavy-edge matching: a fine→coarse aggregation map.

    Generalizes ``amg_setup``'s order-dependent pairwise map (``i → i//2``
    in RCB order) into a weight-aware matching with no ordering
    prerequisite: each round, every unmatched node proposes to its
    heaviest unmatched neighbor, and mutual proposals ``i ↔ j`` become a
    two-node aggregate.  Ties break by a per-round random priority
    (deterministic in ``seed``) — with deterministic tie-breaks a
    uniform-weight mesh degenerates to O(1) matched pairs per round,
    because every proposal chain points the same way and almost none are
    mutual.  A few rounds leave only nodes with no unmatched neighbor;
    those stay singletons.

    ``max_weight`` (with ``node_weights``) caps the combined weight of a
    matched pair — the balance guard: without it, deep ladders grow coarse
    nodes as heavy as an entire part, and no downstream refinement can fix
    a partition whose granularity is one-node-per-part.  Pairs that would
    exceed the cap simply stay unmatched and coarsen no further (the
    ladder's ``min_coarsen_ratio`` stop condition fires once most nodes
    sit at the cap).

    Returns ``(agg, n_coarse)`` with aggregate sizes ≤ 2 — each coarsening
    step roughly halves the graph, the standard multilevel ladder step
    (Karypis & Kumar's HEM).  Coarse ids are assigned in fine-node order
    of each aggregate's smallest member, keeping the map deterministic.
    """
    n = graph.n
    rows, cols, w = graph.rows, graph.indices, graph.weights
    rng = np.random.default_rng(seed)
    mate = np.full(n, -1, dtype=np.int64)
    node_ids = np.arange(n, dtype=np.int64)
    fits = None
    if max_weight is not None and node_weights is not None:
        nw = np.asarray(node_weights, np.float64)
        fits = nw[rows] + nw[cols] <= max_weight

    # Per-row argmax via a segmented maximum (np.maximum.at), not a sort:
    # O(E) per round instead of the O(E log E) lexsort that dominated HEM
    # wall time on fine levels.  The random per-node priority folds into a
    # multiplicative jitter on the edge weight — it breaks exact-weight
    # ties (the degenerate uniform-mesh case) while perturbing genuinely
    # distinct weights by ≤1e-9 relative, far below anything that matters
    # to matching quality.  The jitter is fixed across rounds, which can
    # (rarely) leave a round with live edges but zero mutual proposals —
    # cyclic preferences — so a matchless round re-rolls the priorities.
    def roll_key():
        pri = rng.random(n)
        return w * (1.0 + 1e-9 * pri[cols])

    key = roll_key()
    for _ in range(rounds):
        free = mate < 0
        live = free[rows] & free[cols]
        if fits is not None:
            live &= fits
        if not live.any():
            break
        er, ec, ek = rows[live], cols[live], key[live]
        best = np.full(n, -np.inf)
        np.maximum.at(best, er, ek)
        win = ek == best[er]
        head = np.full(n, -1, dtype=np.int64)
        head[er[win]] = ec[win]
        # Mutual-proposal handshake: i matches j iff head[i]=j, head[j]=i.
        prop = np.flatnonzero(head >= 0)
        mutual = prop[head[head[prop]] == prop]
        lo = mutual[mutual < head[mutual]]
        if lo.size == 0:
            key = roll_key()
            continue
        mate[lo] = head[lo]
        mate[head[lo]] = lo
    owner = np.minimum(node_ids, np.where(mate >= 0, mate, node_ids))
    reps = np.flatnonzero(owner == node_ids)
    coarse_id = np.full(n, -1, dtype=np.int64)
    coarse_id[reps] = np.arange(reps.size, dtype=np.int64)
    return coarse_id[owner], int(reps.size)


@dataclasses.dataclass(frozen=True)
class AMG:
    """Jittable V-cycle preconditioner.  Call as `amg(r) -> u ≈ L⁻¹ r`."""

    ops: tuple            # per-level EllLaplacian (level 0 = finest)
    aggs: tuple           # per-level (n_l,) int32 fine→coarse maps
    sizes: tuple          # per-level row counts
    coarse_pinv: jax.Array
    sigma: float
    n_smooth: int

    def __hash__(self):
        return id(self)

    def __call__(self, r: jax.Array) -> jax.Array:
        return self._cycle(0, r)

    def _smooth(self, L: EllLaplacian, u, rr, inv_d):
        for _ in range(self.n_smooth):
            du = self.sigma * rr * inv_d
            u = u + du
            rr = rr - L.apply(du)
        return u, rr

    def _cycle(self, lvl: int, r: jax.Array) -> jax.Array:
        if lvl == len(self.ops):
            return self.coarse_pinv @ r
        L = self.ops[lvl]
        inv_d = jnp.where(L.diag > 0, 1.0 / jnp.maximum(L.diag, 1e-30), 0.0)
        # Alg. 3 lines 1–7: u = σDr; r = r − Lu; n_smooth more sweeps.
        u = self.sigma * r * inv_d
        rr = r - L.apply(u)
        u, rr = self._smooth(L, u, rr, inv_d)
        # restrict (Jᵀ = sum over aggregates), recurse, prolong (J = copy)
        rc = jax.ops.segment_sum(rr, self.aggs[lvl], num_segments=self.sizes[lvl + 1])
        ec = self._cycle(lvl + 1, rc)
        u = u + jnp.take(ec, self.aggs[lvl])
        # Alg. 3 lines 12–15: post-smooth against the true residual.
        rr = r - L.apply(u)
        for _ in range(self.n_smooth):
            u = u + self.sigma * rr * inv_d
            rr = r - L.apply(u)
        return u


@dataclasses.dataclass(frozen=True)
class BatchedAMG:
    """Jittable leading-batch-dim V-cycle: `pre(r) -> u ≈ L⁻¹ r` for
    r of shape (B, n_pad).

    Registered as a pytree (level operators + coarse pinv are leaves;
    sizes/sigma/n_smooth are static) so the batched inverse-iteration
    solve can take the preconditioner as a *traced* jit argument — one
    compiled trace serves every bucket of the same shape, exactly like
    the engine's operators.
    """

    ops: tuple            # per-level EllLaplacian, arrays (B, n_l, w_l)
    sizes: tuple          # per-level padded row counts (n_pad >> l)
    coarse_pinv: jax.Array  # (B, nc, nc)
    sigma: float
    n_smooth: int

    def __hash__(self):
        return id(self)

    def __call__(self, r: jax.Array) -> jax.Array:
        return self._cycle(0, r)

    def _smooth(self, L: EllLaplacian, u, rr, inv_d):
        for _ in range(self.n_smooth):
            du = self.sigma * rr * inv_d
            u = u + du
            rr = rr - L.apply(du)
        return u, rr

    def _cycle(self, lvl: int, r: jax.Array) -> jax.Array:
        if lvl == len(self.ops):
            return jnp.einsum("bij,bj->bi", self.coarse_pinv, r)
        L = self.ops[lvl]
        inv_d = jnp.where(L.diag > 0, 1.0 / jnp.maximum(L.diag, 1e-30), 0.0)
        u = self.sigma * r * inv_d
        rr = r - L.apply(u)
        u, rr = self._smooth(L, u, rr, inv_d)
        # Restrict: the shared pairwise aggregation i → i//2 is a
        # reshape-sum (Jᵀ); prolong (J) is a repeat.
        B = r.shape[0]
        rc = rr.reshape(B, self.sizes[lvl + 1], 2).sum(-1)
        ec = self._cycle(lvl + 1, rc)
        u = u + jnp.repeat(ec, 2, axis=-1)
        rr = r - L.apply(u)
        for _ in range(self.n_smooth):
            u = u + self.sigma * rr * inv_d
            rr = r - L.apply(u)
        return u


jax.tree_util.register_dataclass(
    BatchedAMG,
    data_fields=("ops", "coarse_pinv"),
    meta_fields=("sizes", "sigma", "n_smooth"),
)


def amg_setup(
    graph: Graph,
    *,
    order: np.ndarray | None = None,
    coarse_size: int = 16,
    sigma: float = 2.0 / 3.0,
    n_smooth: int = 1,
    max_levels: int = 64,
) -> AMG:
    """Build the level hierarchy (host NumPy; the `gs_setup` analogue).

    order: RCB ordering of the fine nodes (paper's bootstrap).  Identity if
    omitted (degrades quality, still converges).
    """
    n = graph.n
    perm = np.arange(n, dtype=np.int64) if order is None else np.asarray(order)
    rank = np.empty(n, dtype=np.int64)
    rank[perm] = np.arange(n)

    ops: list[EllLaplacian] = []
    aggs: list[np.ndarray] = []
    sizes: list[int] = [n]
    g = graph
    # Level-0 aggregation pairs RCB-consecutive nodes; coarser levels are
    # already RCB-ordered by construction (J = I₂ ⊗ J_prev).
    agg_of_fine = rank // 2
    lvl = 0
    while g.n > coarse_size and lvl < max_levels:
        n_c = (g.n + 1) // 2
        agg = agg_of_fine if lvl == 0 else np.arange(g.n, dtype=np.int64) // 2
        ops.append(ell_laplacian(g))
        aggs.append(agg)
        g = coarsen_graph(g, agg, n_c)
        sizes.append(n_c)
        lvl += 1

    # Dense pseudo-inverse at the coarsest level (singular Laplacian).
    from repro.core.laplacian import dense_laplacian_np

    pinv = np.linalg.pinv(dense_laplacian_np(g), rcond=1e-10)
    return AMG(
        ops=tuple(ops),
        aggs=tuple(jnp.asarray(a.astype(np.int32)) for a in aggs),
        sizes=tuple(sizes),
        coarse_pinv=jnp.asarray(pinv.astype(np.float32)),
        sigma=sigma,
        n_smooth=n_smooth,
    )


def amg_setup_batched(
    graphs: list,
    n_pad: int,
    b_pad: int,
    *,
    coarse_size: int = 16,
    sigma: float = 2.0 / 3.0,
    n_smooth: int = 1,
) -> BatchedAMG:
    """Build one packed V-cycle hierarchy for B graphs (host NumPy).

    `n_pad` (a power of two ≥ every graph's n) fixes the shared level
    ladder n_pad, n_pad/2, … down to `coarse_size`; each graph is
    Galerkin-coarsened along it (`coarsen_graph` with the same pairwise
    aggregation `amg_setup` uses — feed RCB-ordered graphs, as the engine
    does).  Graphs whose real size bottoms out early just carry empty
    coarse rows; batch-padding rows (b ≥ len(graphs)) are all-zero
    operators with a zero coarse pinv, so dummy problems stay inert.
    """
    if n_pad & (n_pad - 1):
        raise ValueError(f"n_pad must be a power of two, got {n_pad}")
    if any(g.n > n_pad for g in graphs):
        raise ValueError("n_pad below a graph size")
    level_graphs: list[list[Graph]] = [list(graphs)]
    sizes = [n_pad]
    while sizes[-1] > coarse_size:
        nxt = [
            coarsen_graph(g, np.arange(g.n, dtype=np.int64) // 2, (g.n + 1) // 2)
            for g in level_graphs[-1]
        ]
        level_graphs.append(nxt)
        sizes.append(sizes[-1] // 2)

    from repro.core.laplacian import dense_laplacian_np

    ops = []
    for lvl in range(len(sizes) - 1):
        gs = level_graphs[lvl]
        width = max([int(g.degrees.max()) if g.nnz else 1 for g in gs] + [1])
        width_pad = 1 << max(0, (max(width, 2) - 1)).bit_length()
        ops.append(ell_laplacian_batched(gs, sizes[lvl], width_pad, b_pad))

    nc = sizes[-1]
    pinv = np.zeros((b_pad, nc, nc), dtype=np.float32)
    for b, g in enumerate(level_graphs[-1]):
        Lc = np.zeros((nc, nc), dtype=np.float64)
        Lc[: g.n, : g.n] = dense_laplacian_np(g)
        pinv[b] = np.linalg.pinv(Lc, rcond=1e-10).astype(np.float32)
    return BatchedAMG(
        ops=tuple(ops),
        sizes=tuple(sizes),
        coarse_pinv=jnp.asarray(pinv),
        sigma=sigma,
        n_smooth=n_smooth,
    )
