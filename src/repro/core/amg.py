"""Aggregation-based AMG preconditioner (paper §7, Algorithm 3).

LAMG-inspired V-cycle over Galerkin coarse operators

    L_{l+1} = J_l^{l+1} L_l J_{l+1}^l

with **piecewise-constant prolongation bootstrapped from the RCB ordering**:
nodes are permuted by `rcb_order` once at setup; level-l aggregation then
pairs consecutive nodes (`i → i // 2`), i.e. `J = I₂ ⊗ J_prev` exactly as in
the paper.  Because J is Boolean piecewise-constant, every coarse operator
remains a graph Laplacian (zero row sums, nonpositive off-diagonal), so each
level is stored as a coarse *graph* in padded-ELL form and applied with the
same `EllLaplacian` matvec (Pallas `ell_spmv` on TPU).

Smoother: damped Jacobi (σ D⁻¹), following Algorithm 3.  The coarsest level
(≤ `coarse_size` rows) is solved with a dense pseudo-inverse computed at
setup — pinv because the Laplacian is singular on the constants; this is a
robustness improvement over pure smoothing at the coarsest level (recorded
as an implementation choice, not a paper deviation: the paper's coarsest
level is "a single row per processor" and the all-ones nullspace is handled
by the outer projection either way).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EllLaplacian, ell_laplacian
from repro.mesh.graphs import Graph, build_csr


def coarsen_graph(graph: Graph, agg: np.ndarray, n_coarse: int) -> Graph:
    """Galerkin coarse graph: weights between aggregates are summed."""
    rows = graph.rows
    return build_csr(
        agg[rows], agg[graph.indices], n_coarse,
        weights=graph.weights, symmetrize=False,
    )


@dataclasses.dataclass(frozen=True)
class AMG:
    """Jittable V-cycle preconditioner.  Call as `amg(r) -> u ≈ L⁻¹ r`."""

    ops: tuple            # per-level EllLaplacian (level 0 = finest)
    aggs: tuple           # per-level (n_l,) int32 fine→coarse maps
    sizes: tuple          # per-level row counts
    coarse_pinv: jax.Array
    sigma: float
    n_smooth: int

    def __hash__(self):
        return id(self)

    def __call__(self, r: jax.Array) -> jax.Array:
        return self._cycle(0, r)

    def _smooth(self, L: EllLaplacian, u, rr, inv_d):
        for _ in range(self.n_smooth):
            du = self.sigma * rr * inv_d
            u = u + du
            rr = rr - L.apply(du)
        return u, rr

    def _cycle(self, lvl: int, r: jax.Array) -> jax.Array:
        if lvl == len(self.ops):
            return self.coarse_pinv @ r
        L = self.ops[lvl]
        inv_d = jnp.where(L.diag > 0, 1.0 / jnp.maximum(L.diag, 1e-30), 0.0)
        # Alg. 3 lines 1–7: u = σDr; r = r − Lu; n_smooth more sweeps.
        u = self.sigma * r * inv_d
        rr = r - L.apply(u)
        u, rr = self._smooth(L, u, rr, inv_d)
        # restrict (Jᵀ = sum over aggregates), recurse, prolong (J = copy)
        rc = jax.ops.segment_sum(rr, self.aggs[lvl], num_segments=self.sizes[lvl + 1])
        ec = self._cycle(lvl + 1, rc)
        u = u + jnp.take(ec, self.aggs[lvl])
        # Alg. 3 lines 12–15: post-smooth against the true residual.
        rr = r - L.apply(u)
        for _ in range(self.n_smooth):
            u = u + self.sigma * rr * inv_d
            rr = r - L.apply(u)
        return u


def amg_setup(
    graph: Graph,
    *,
    order: np.ndarray | None = None,
    coarse_size: int = 16,
    sigma: float = 2.0 / 3.0,
    n_smooth: int = 1,
    max_levels: int = 64,
) -> AMG:
    """Build the level hierarchy (host NumPy; the `gs_setup` analogue).

    order: RCB ordering of the fine nodes (paper's bootstrap).  Identity if
    omitted (degrades quality, still converges).
    """
    n = graph.n
    perm = np.arange(n, dtype=np.int64) if order is None else np.asarray(order)
    rank = np.empty(n, dtype=np.int64)
    rank[perm] = np.arange(n)

    ops: list[EllLaplacian] = []
    aggs: list[np.ndarray] = []
    sizes: list[int] = [n]
    g = graph
    # Level-0 aggregation pairs RCB-consecutive nodes; coarser levels are
    # already RCB-ordered by construction (J = I₂ ⊗ J_prev).
    agg_of_fine = rank // 2
    lvl = 0
    while g.n > coarse_size and lvl < max_levels:
        n_c = (g.n + 1) // 2
        agg = agg_of_fine if lvl == 0 else np.arange(g.n, dtype=np.int64) // 2
        ops.append(ell_laplacian(g))
        aggs.append(agg)
        g = coarsen_graph(g, agg, n_c)
        sizes.append(n_c)
        lvl += 1

    # Dense pseudo-inverse at the coarsest level (singular Laplacian).
    from repro.core.laplacian import dense_laplacian_np

    pinv = np.linalg.pinv(dense_laplacian_np(g), rcond=1e-10)
    return AMG(
        ops=tuple(ops),
        aggs=tuple(jnp.asarray(a.astype(np.int32)) for a in aggs),
        sizes=tuple(sizes),
        coarse_pinv=jnp.asarray(pinv.astype(np.float32)),
        sigma=sigma,
        n_smooth=n_smooth,
    )
