"""Hill-climbing k-way Fiduccia–Mattheyses refinement (the "kway" stage).

The greedy boundary refiner (:func:`repro.core.refine.refine_boundary`)
applies strictly-positive-gain moves under a stale-gain guard, which makes
every sweep a full vectorized recompute and leaves it stuck in any local
minimum where every single move is neutral or negative.  This module is
the classic FM escape, generalized to k parts (Karypis & Kumar's k-way
refinement; Sphynx makes the same argument for GPU spectral partitioners):

* **Per-(node, part) gain structure with sorted-heap updates.**  One dense
  ``conn[node, part]`` edge-weight table is built vectorized per pass;
  after that a move updates only the mover's neighbors — O(degree)
  conn-row touches plus an O(nparts) best-target rescan per touched
  neighbor — instead of recomputing the table.  The inner structures are
  plain Python lists: at mesh-partitioning degrees (~6) and part counts
  (≤64), numpy's per-call dispatch on degree-sized arrays costs an order
  of magnitude more than the scalar arithmetic it would vectorize.  The
  heap is a lazy max-heap over (gain, version, node, target) entries:
  every conn-row change bumps the node's version stamp and pushes a fresh
  exact entry, so stale entries (older stamp, or node already locked) are
  simply discarded at pop time — the standard lazy-invalidation
  alternative to bucket deletion that also handles non-integer edge
  weights.  Part-weight drift cannot stale a gain (gains depend only on
  conn rows); it can only change *feasibility*, which is re-checked at
  pop.

* **Hill climbing with rollback to the best prefix.**  Moves are applied
  *tentatively* in best-gain-first order even when the best gain is
  negative, the running cut is tracked exactly (applied gains are exact —
  recomputed from the live ``conn`` at pop time), and at pass end every
  move after the best-prefix cut minimum is undone.  A pass therefore
  never ends worse than it started, but it can walk *through* a
  cut-increasing ridge that the greedy refiner cannot cross.

* **One corridor, one lock.**  Per-move incremental balance accounting
  runs against a ``[floor, cap]`` corridor fixed once per post chain
  (``corridor=``; never recomputed mid-chain — see
  :mod:`repro.core.refine`), and a lock array lets each node move at most
  once per pass, so passes terminate and oscillation is impossible.

Moves are restricted to *adjacent* parts (``conn[node, q] > 0``): a move
to a non-adjacent part can only increase the cut and is never the FM
escape route.  Target ties break toward the lighter part.

:func:`kway_stage` — what the pipeline registers as ``"kway"`` — closes
the FM passes with a connected-component repair pass, so the
zero-disconnected-parts invariant survives articulation moves, exactly
like the greedy ``"refine"`` stage.  :class:`KwayStats` (passes, rollback
depth, best-prefix index, per-pass cut trajectory) rides through
``PostStats.kway`` into ``RSBReport.post`` and the benchmark rows.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro import obs
from repro.core.refine import (
    PostStats,
    _balance_corridor,
    _part_weights,
    balance_corridor,
    close_with_repair,
    edge_cut,
)
from repro.mesh.graphs import Graph

_EPS = 1e-12


@dataclasses.dataclass
class KwayPassRecord:
    """One hill-climbing pass: how far it walked and what it kept."""

    pass_no: int
    attempted: int      # moves tentatively applied
    best_prefix: int    # kept prefix length (index of the cut minimum)
    rolled_back: int    # attempted − best_prefix
    cut_before: float
    cut_after: float    # cut at the best prefix (== cut_before if none)


@dataclasses.dataclass
class KwayStats:
    """The `kway` section of :class:`~repro.core.refine.PostStats`."""

    passes: int = 0
    moves_attempted: int = 0
    moves_kept: int = 0
    rolled_back: int = 0
    records: list = dataclasses.field(default_factory=list)  # [KwayPassRecord]

    def row(self) -> dict:
        return {
            "passes": self.passes,
            "moves_attempted": self.moves_attempted,
            "moves_kept": self.moves_kept,
            "rolled_back": self.rolled_back,
            "records": [dataclasses.asdict(r) for r in self.records],
        }

    def to_dict(self) -> dict:
        return self.row()

    @classmethod
    def from_dict(cls, d: dict) -> "KwayStats":
        s = cls(passes=d.get("passes", 0),
                moves_attempted=d.get("moves_attempted", 0),
                moves_kept=d.get("moves_kept", 0),
                rolled_back=d.get("rolled_back", 0))
        s.records = [KwayPassRecord(**r) for r in d.get("records", [])]
        return s


def kway_fm(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    passes: int = 8,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    stall: int | None = None,
    nodes: np.ndarray | None = None,
) -> tuple[np.ndarray, PostStats]:
    """Hill-climbing k-way FM (module docstring).  Cut-non-increasing: a
    pass is rolled back to its best prefix, so the returned cut is the
    minimum the climb visited.

    ``stall`` caps the number of consecutive non-improving tentative moves
    before a pass gives up its climb (None = exhaust the boundary: every
    unlocked feasible node moves once).  The default bounds the climb so
    the stage stays a small fraction of the solve wall; deep ridges past
    the stall horizon are reachable by raising it.  Passes end early when
    a full pass keeps no move.

    ``nodes`` restricts the movable set: only the listed nodes get conn
    rows, heap entries, or moves — everything else is frozen scenery whose
    edges still contribute to gains.  The mutable mirrors (conn table,
    adjacency, locks) are sized to the candidate set, so the per-pass cost
    is O(candidates · (degree + nparts)) plus one vectorized edge sweep —
    what makes boundary-restricted refinement O(boundary), not O(n).  With
    ``nodes=None`` the compact indexing is the identity and behavior is
    exactly the unrestricted stage.
    """
    parts_np = np.asarray(parts, dtype=np.int64).copy()
    n = graph.n
    w_np = (np.ones(n) if weights is None
            else np.asarray(weights, np.float64))
    rows, ew = graph.rows, graph.weights
    indptr, nbrs = graph.indptr, graph.indices
    if nodes is None:
        cand, pos = np.arange(n, dtype=np.int64), None
    else:
        cand = np.unique(np.asarray(nodes, dtype=np.int64))
        pos = np.full(n, -1, dtype=np.int64)
        pos[cand] = np.arange(cand.size, dtype=np.int64)
    m = cand.size
    part_w_np = _part_weights(parts_np, w_np, nparts)
    if corridor is None:
        corridor = _balance_corridor(part_w_np, balance_tol)
    floor, cap = (float(corridor[0]), float(corridor[1]))
    cap_slack, floor_slack = cap + 1e-9, floor - 1e-9
    kstats = KwayStats()
    stats = PostStats(stages=["kway"], corridor=(floor, cap), kway=kstats,
                      cut_before=edge_cut(graph, parts_np))
    with obs.timed("kway_fm") as t:
        cut = stats.cut_before
        if stall is None:
            stall = max(64, m // 8)

        # Plain-Python mirrors of the mutable state (module docstring: scalar
        # updates beat numpy dispatch at degree-sized granularity).  All of
        # them are indexed by candidate position; part weights/counts stay
        # global (frozen nodes still occupy their parts).
        if pos is None:
            parts_l = parts_np.tolist()
            w_l = w_np.tolist()
        else:
            parts_l = parts_np[cand].tolist()
            w_l = w_np[cand].tolist()
        part_w = part_w_np.tolist()
        part_n = np.bincount(parts_np, minlength=nparts).tolist()
        if pos is None:
            nbrs_l, ew_l, off = nbrs.tolist(), ew.tolist(), indptr.tolist()
            adj = [list(zip(nbrs_l[off[i]:off[i + 1]],
                            ew_l[off[i]:off[i + 1]]))
                   for i in range(n)]
        else:
            # Neighbor ids remapped to candidate positions (-1 = frozen):
            # per-candidate-row slices, so building this is O(Σ deg(cand)).
            adj = [list(zip(pos[nbrs[indptr[i]:indptr[i + 1]]].tolist(),
                            ew[indptr[i]:indptr[i + 1]].tolist()))
                   for i in cand.tolist()]
        prange = range(nparts)

        for pass_no in range(passes):
            # Dense per-(node, part) connection table, one vectorized build,
            # then scalar increments only.
            conn_np = np.zeros((m, nparts))
            if pos is None:
                np.add.at(conn_np, (rows, parts_np[nbrs]), ew)
            else:
                sel = pos[rows] >= 0
                np.add.at(conn_np, (pos[rows[sel]], parts_np[nbrs[sel]]),
                          ew[sel])
            conn = conn_np.tolist()
            locked = [False] * m
            ver = [0] * m   # conn-row version stamps
            heap: list = []
            seq = 0  # FIFO tiebreak keeps equal-gain pops deterministic

            def push(i: int):
                """Push node i's best feasible adjacent target (exact gain
                from the live conn row; ties → lighter part), stamped with the
                row's current version."""
                nonlocal seq
                row = conn[i]
                src = parts_l[i]
                wi = w_l[i]
                own = row[src]
                best_g = None
                best_t = -1
                best_w = 0.0
                for q in prange:
                    c = row[q]
                    if c <= _EPS or q == src or part_w[q] + wi > cap_slack:
                        continue
                    g = c - own
                    if (best_g is None or g > best_g + _EPS
                            or (g > best_g - _EPS and part_w[q] < best_w)):
                        best_g, best_t, best_w = g, q, part_w[q]
                if best_g is not None:
                    heapq.heappush(heap, (-best_g, seq, i, best_t, ver[i]))
                    seq += 1

            total = np.bincount(rows, weights=ew, minlength=n)
            if pos is None:
                own_all = conn_np[np.arange(n), parts_np]
                frontier = np.flatnonzero(total - own_all > _EPS)
            else:
                own_all = conn_np[np.arange(m), parts_np[cand]]
                frontier = np.flatnonzero(total[cand] - own_all > _EPS)
            for i in frontier.tolist():
                push(i)  # boundary frontier

            move_log: list = []   # (node, src, tgt, gain)
            run_cut = best_cut = cut
            best_idx = 0
            pops, max_pops = 0, 50 * m + 1000  # lazy-heap runaway backstop
            while heap and pops < max_pops:
                pops += 1
                neg_gain, _, i, tgt, entry_ver = heapq.heappop(heap)
                if locked[i] or entry_ver != ver[i]:
                    continue  # stale: a fresher exact entry was pushed
                src = parts_l[i]
                wi = w_l[i]
                if part_w[tgt] + wi > cap_slack:
                    # Target filled up since the push (part weights drift
                    # without touching conn rows).  Re-evaluate this node once
                    # against the current weights.
                    ver[i] += 1
                    push(i)
                    continue
                if part_w[src] - wi < floor_slack or part_n[src] <= 1:
                    # Source constraint: never under-floor or empty a part.
                    # No re-push (unlike the cap branch): the node's conn row
                    # is unchanged, so push() would recreate this same entry
                    # and loop.  The node returns next pass if still boundary.
                    continue
                gain = -neg_gain  # exact: conn[i] unchanged since the push
                # Tentative apply — hill climbing admits negative gains.
                parts_l[i] = tgt
                part_w[src] -= wi
                part_w[tgt] += wi
                part_n[src] -= 1
                part_n[tgt] += 1
                locked[i] = True
                run_cut -= gain
                move_log.append((i, src, tgt, gain))
                if run_cut < best_cut - _EPS:
                    best_cut, best_idx = run_cut, len(move_log)
                # O(degree) incremental gain update: only the mover's
                # neighbors' connections to (src, tgt) changed.  j < 0 is
                # a frozen neighbor (nodes= restriction): no conn row.
                for j, wij in adj[i]:
                    if j < 0:
                        continue
                    row = conn[j]
                    row[src] -= wij
                    row[tgt] += wij
                    if not locked[j]:
                        ver[j] += 1
                        push(j)
                if len(move_log) - best_idx > stall:
                    break

            # Roll back to the best prefix (the FM contract: a pass never ends
            # worse than it started; best_idx == 0 undoes the whole climb).
            attempted = len(move_log)
            for i, src, tgt, _g in reversed(move_log[best_idx:]):
                parts_l[i] = src
                part_w[src] += w_l[i]
                part_w[tgt] -= w_l[i]
                part_n[src] += 1
                part_n[tgt] -= 1
            if pos is None:
                parts_np = np.asarray(parts_l, dtype=np.int64)
            else:
                parts_np[cand] = parts_l
            kstats.passes += 1
            kstats.moves_attempted += attempted
            kstats.moves_kept += best_idx
            kstats.rolled_back += attempted - best_idx
            kstats.records.append(KwayPassRecord(
                pass_no=pass_no, attempted=attempted, best_prefix=best_idx,
                rolled_back=attempted - best_idx,
                cut_before=cut, cut_after=best_cut))
            stats.moves_applied += best_idx
            improved = cut - best_cut
            cut = best_cut
            if best_idx == 0 or improved <= _EPS:
                break

        stats.cut_after = edge_cut(graph, parts_np)
    stats.seconds = t.seconds
    obs.counter_add("fm_passes", kstats.passes)
    obs.counter_add("fm_moves_attempted", kstats.moves_attempted)
    obs.counter_add("fm_moves", kstats.moves_kept)
    obs.counter_add("fm_rollbacks", kstats.rolled_back)
    return parts_np, stats


def kway_fm_boundary(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    passes: int = 2,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    stall: int = 32,
) -> tuple[np.ndarray, PostStats]:
    """Boundary-restricted hill-climbing FM — the multilevel V-cycle's
    per-level refinement.  Each pass recomputes the boundary frontier
    (nodes with at least one cut edge) and runs ONE :func:`kway_fm` pass
    restricted to it (``nodes=``), so per-pass cost is
    O(boundary · (degree + nparts)) instead of O(n · nparts): on a freshly
    prolonged partition the boundary is a thin shell of the graph.  The
    ``stall`` default is deliberately tight (32, vs ``kway_fm``'s n//8):
    this sweep runs at EVERY ladder level, so each one must stay cheap —
    deep climbs belong to the final post chain, not the ladder."""
    parts = np.asarray(parts, dtype=np.int64).copy()
    if corridor is None:
        corridor = balance_corridor(parts, nparts, weights, balance_tol)
    agg = PostStats(stages=["kway"], corridor=tuple(corridor),
                    kway=KwayStats(), cut_before=edge_cut(graph, parts))
    rows, cols = graph.rows, graph.indices
    for _ in range(passes):
        boundary = rows[parts[rows] != parts[cols]]
        if boundary.size == 0:
            break
        parts, st = kway_fm(graph, parts, nparts, weights=weights,
                            passes=1, corridor=corridor, stall=stall,
                            nodes=boundary)
        k = st.kway
        for rec in k.records:
            rec.pass_no = len(agg.kway.records)
            agg.kway.records.append(rec)
        agg.kway.passes += k.passes
        agg.kway.moves_attempted += k.moves_attempted
        agg.kway.moves_kept += k.moves_kept
        agg.kway.rolled_back += k.rolled_back
        agg.moves_applied += st.moves_applied
        agg.seconds += st.seconds
        if st.moves_applied == 0:
            break
    agg.cut_after = edge_cut(graph, parts)
    return parts, agg


def kway_stage(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    passes: int = 8,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    stall: int | None = None,
) -> tuple[np.ndarray, PostStats]:
    """The pipeline's "kway" stage: hill-climbing FM passes + a closing
    repair pass (articulation moves cannot leave a disconnected part).
    Both are cut-non-increasing under ONE corridor, so the stage is too."""
    if corridor is None:
        corridor = balance_corridor(parts, nparts, weights, balance_tol)
    parts, stats = kway_fm(graph, parts, nparts, weights=weights,
                           passes=passes, balance_tol=balance_tol,
                           corridor=corridor, stall=stall)
    return close_with_repair(graph, parts, nparts, stats, weights=weights,
                             balance_tol=balance_tol, corridor=corridor)
