"""Gather-scatter (gslib-style) evaluation of the dual-graph Laplacian.

Paper §5: the weighted adjacency of the dual graph is never assembled —
it is applied matrix-free as

    A_w = Pᵀ Q Qᵀ P

where `P` copies one value per element to its v vertices (local, a
broadcast) and `Q Qᵀ` is the global gather-scatter over shared vertex ids
(sum values with equal global id, copy the sum back).  With
`d = A_w·1` (row sums) the weighted Laplacian action is

    L x = d ⊙ x − A_w x

— any self-contribution of an element through its own vertices appears in
both terms and cancels, and singleton vertices contribute nothing (paper's
observation).

The *unweighted* Laplacian counts each neighbor exactly once.  Paper §5
derives it by inclusion-exclusion over vertex/edge/face gather-scatters:

    A_unw = A_vtx − A_edge + A_face

(a face neighbor shares 4 vertices, 4 edges, 1 face → 4 − 4 + 1 = 1; an
edge neighbor 2 − 1 + 0 = 1; a vertex neighbor 1 − 0 + 0 = 1).

Setup (`gs_setup`) is host-side NumPy: it only compacts global ids to a
contiguous range — "minimal setup cost", as the paper stresses.  The apply
(`gs_op`) is pure jittable JAX: one `segment_sum` + one `take`.  The
distributed (shard_map) variant is
`repro.dist.collectives.dist_lap_apply_allreduce`: the same segment_sum
into the global-id space, completed by one `psum` over the mesh axis
(verified against `GSLaplacian.apply` in tests/test_distributed.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GSHandle:
    """Handle returned by :func:`gs_setup` — the `Q Qᵀ` operator.

    Attributes
    ----------
    gid : (E, K) int32 jnp array — compacted global item ids per element.
          A (B, E, K) table holds B **independent** gather-scatter problems
          (each with its own id space) — the batched-RSB layout.
    n_global : number of distinct global ids (shared upper bound for a
          batched table; ids only need to be < n_global per problem).
    """

    gid: jax.Array
    n_global: int

    def __hash__(self):  # usable as a static arg / closure capture
        return id(self)


jax.tree_util.register_dataclass(
    GSHandle, data_fields=("gid",), meta_fields=("n_global",)
)


def gs_setup(gid_table: np.ndarray) -> GSHandle:
    """Compact a global-id table to contiguous ids (host; O(E·K log) sort).

    Mirrors gslib's `gs_setup(global_num, m_L)` discovery phase.
    """
    gid_table = np.asarray(gid_table)
    uniq, inv = np.unique(gid_table, return_inverse=True)
    gid = jnp.asarray(inv.reshape(gid_table.shape).astype(np.int32))
    return GSHandle(gid=gid, n_global=int(uniq.size))


def gs_apply(handle: GSHandle, u_local: jax.Array) -> jax.Array:
    """`Q Qᵀ` — sum equal-gid entries, copy sums back.  (gslib `gs_op`.)

    u_local: (..., E, K) values on local vertices.  Batched over leading dims.
    A (B, E, K) handle table pairs problem b's gids with u_local[b] (each
    problem has its own independent id space).
    """
    if handle.gid.ndim == 3:
        def one_b(g, u):
            summed = jax.ops.segment_sum(
                u.reshape(-1), g.reshape(-1), num_segments=handle.n_global
            )
            return jnp.take(summed, g.reshape(-1)).reshape(u.shape)

        return jax.vmap(one_b)(handle.gid, u_local)

    flat_gid = handle.gid.reshape(-1)

    def one(u):
        summed = jax.ops.segment_sum(
            u.reshape(-1), flat_gid, num_segments=handle.n_global
        )
        return jnp.take(summed, flat_gid).reshape(u.shape)

    if u_local.ndim == handle.gid.ndim:
        return one(u_local)
    return jax.vmap(one)(u_local.reshape((-1,) + handle.gid.shape)).reshape(u_local.shape)


def aw_apply(handle: GSHandle, x: jax.Array) -> jax.Array:
    """`Pᵀ Q Qᵀ P x` — weighted-adjacency action (self-terms included).

    x: (..., E).  P broadcasts x_e to the element's K vertices; Pᵀ sums back.
    """
    k = handle.gid.shape[-1]
    u_local = jnp.broadcast_to(x[..., None], x.shape + (k,))
    return gs_apply(handle, u_local).sum(axis=-1)


@dataclasses.dataclass(frozen=True)
class GSLaplacian:
    """Matrix-free dual-graph Laplacian, weighted or unweighted.

    `handles` is a list of (sign, GSHandle) terms:
      weighted   : [(+1, vertex_gs)]
      unweighted : [(+1, vertex_gs), (−1, edge_gs), (+1, face_gs)]

    Batched: handles with (B, E, K) gid tables yield an operator mapping
    (B, E) → (B, E) — B independent Laplacians in one apply.

    Registered as a pytree (terms/degree_full/diag are leaves, n static)
    so batched solves can pass the operator as a traced jit argument and
    share one compiled trace per shape bucket.
    """

    terms: tuple
    n: int
    degree_full: jax.Array   # (..., E) Σ_j A[e, j]  (row sums incl. self terms)
    diag: jax.Array          # true Laplacian diagonal Σ_{j≠e} ω_ej

    def __hash__(self):
        return id(self)

    def adj_apply(self, x: jax.Array) -> jax.Array:
        y = jnp.zeros_like(x)
        for sign, h in self.terms:
            y = y + sign * aw_apply(h, x)
        return y

    def apply(self, x: jax.Array) -> jax.Array:
        """L x = (A·1) ⊙ x − A x — self terms cancel exactly."""
        return self.degree_full * x - self.adj_apply(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)


def _build(terms, n) -> GSLaplacian:
    # leading dims of the gid tables (e.g. a batch axis) carry through
    shape = terms[0][1].gid.shape[:-1]
    ones = jnp.ones(shape, dtype=jnp.float32)
    deg_full = jnp.zeros(shape, dtype=jnp.float32)
    self_count = jnp.zeros(shape, dtype=jnp.float32)
    for sign, h in terms:
        deg_full = deg_full + sign * aw_apply(h, ones)
        # self contribution of element e through table h = K (ids distinct
        # within an element for well-formed hexes)
        self_count = self_count + sign * h.gid.shape[-1]
    return GSLaplacian(
        terms=tuple(terms), n=n, degree_full=deg_full, diag=deg_full - self_count
    )


jax.tree_util.register_dataclass(
    GSLaplacian,
    data_fields=("terms", "degree_full", "diag"),
    meta_fields=("n",),
)


def weighted_laplacian(vert_gid: np.ndarray) -> GSLaplacian:
    """Weighted Laplacian (ω = number of shared vertices) from (E,8) gids."""
    h = gs_setup(vert_gid)
    return _build([(1.0, h)], vert_gid.shape[0])


def unweighted_laplacian(
    vert_gid: np.ndarray, edge_gid: np.ndarray, face_gid: np.ndarray
) -> GSLaplacian:
    """Unweighted Laplacian via vertex − edge + face inclusion-exclusion."""
    hv = gs_setup(vert_gid)
    he = gs_setup(edge_gid)
    hf = gs_setup(face_gid)
    return _build([(1.0, hv), (-1.0, he), (1.0, hf)], vert_gid.shape[0])
