"""Partition-quality metrics (paper §8 evaluation methodology).

The paper evaluates partitions by (a) load imbalance — at most one element
for unit weights (Eq. 2.6), (b) the number of neighbor partitions (message
count ∝ latency term α·M), and (c) the average communication volume per
neighbor (∝ bandwidth term β·W).  The `m₂ = α/β` crossover decides which
term dominates; for GPU/TPU-dense machines the volume dominates, which is
why RSB's min-cut objective is the right one.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.mesh.graphs import Graph, connected_labels


@dataclasses.dataclass
class PartitionMetrics:
    nparts: int
    imbalance: int              # max|V_i| − min|V_i| (elements)
    weighted_imbalance: float   # max weight / mean weight
    edge_cut: float             # Σ ω over cut edges (each edge once)
    max_neighbors: int
    avg_neighbors: float
    total_volume: float         # Σ_p outgoing volume (ω words)
    avg_message_size: float     # mean over parts of volume_p / neighbors_p
    max_message_size: float
    max_part_volume_words: float = 0.0  # max over parts of volume_p in words
    disconnected_parts: int = 0  # parts whose induced subgraph is not connected
    component_count: int = 0     # Σ_p components of part p's induced subgraph

    def row(self) -> dict:
        return dataclasses.asdict(self)


def partition_metrics(
    graph: Graph,
    parts: np.ndarray,
    nparts: int | None = None,
    *,
    weights: np.ndarray | None = None,
    dofs_per_face: int = 64,
) -> PartitionMetrics:
    """Quality metrics of `parts` over the dual graph.

    `dofs_per_face`: message words per unit shared-face; the paper's SEM
    runs exchange (N+1)² values per shared face with N=7 → 64 words.  Edge
    weight ω counts shared mesh vertices (4 per face), so message words are
    `ω / 4 · dofs_per_face`.
    """
    parts = np.asarray(parts, dtype=np.int64)
    nparts = int(parts.max()) + 1 if nparts is None else int(nparts)
    counts = np.bincount(parts, minlength=nparts)
    w = np.ones(graph.n) if weights is None else np.asarray(weights, np.float64)
    wsum = np.bincount(parts, weights=w, minlength=nparts)

    rows = graph.rows
    cols = graph.indices
    pr, pc = parts[rows], parts[cols]
    cut_mask = pr != pc
    # each undirected edge appears twice in the symmetric CSR
    edge_cut = float(graph.weights[cut_mask].sum() / 2.0)

    # per-(part, neighbor-part) volumes
    key = pr[cut_mask] * np.int64(nparts) + pc[cut_mask]
    vol = graph.weights[cut_mask]
    uniq, inv_key = np.unique(key, return_inverse=True)
    pair_vol = np.bincount(inv_key, weights=vol)
    src_part = (uniq // nparts).astype(np.int64)

    neighbors = np.bincount(src_part, minlength=nparts)
    volume = np.bincount(src_part, weights=pair_vol, minlength=nparts)
    words = volume / 4.0 * dofs_per_face
    msg = np.where(neighbors > 0, words / np.maximum(neighbors, 1), 0.0)

    # Connectivity census: components of each part's induced subgraph.
    # Intra-part edges only, so no component spans parts and the per-part
    # component counts sum to the number of distinct global labels.
    intra = ~cut_mask
    comp = connected_labels(graph.n, rows[intra], cols[intra])
    comps_per_part = np.zeros(nparts, dtype=np.int64)
    if graph.n:
        pair = np.unique(parts * np.int64(comp.max() + 1) + comp)
        np.add.at(comps_per_part, (pair // np.int64(comp.max() + 1)), 1)

    return PartitionMetrics(
        nparts=nparts,
        imbalance=int(counts.max() - counts.min()),
        weighted_imbalance=float(wsum.max() / max(wsum.mean(), 1e-30)),
        edge_cut=edge_cut,
        max_neighbors=int(neighbors.max()) if nparts > 1 else 0,
        avg_neighbors=float(neighbors.mean()) if nparts > 1 else 0.0,
        total_volume=float(volume.sum()),
        avg_message_size=float(msg[neighbors > 0].mean()) if cut_mask.any() else 0.0,
        max_message_size=float(msg.max()) if cut_mask.any() else 0.0,
        max_part_volume_words=float(words.max()) if cut_mask.any() else 0.0,
        disconnected_parts=int((comps_per_part > 1).sum()),
        component_count=int(comps_per_part.sum()),
    )


# TPU ICI postal-model constants (DESIGN.md §2): the m₂ crossover where the
# α (latency) and β (volume) terms are equal — messages larger than m₂ are
# volume-dominated, the paper's exascale regime.
ALPHA_S = 1e-6          # ~1 µs collective start-up per hop
BETA_S_PER_WORD = 8.0 / 50e9   # 64-bit words over a 50 GB/s ICI link


def m2_words(alpha: float = ALPHA_S, beta: float = BETA_S_PER_WORD) -> float:
    return alpha / beta


def comm_time_model(metrics: PartitionMetrics, *, alpha: float = ALPHA_S,
                    beta: float = BETA_S_PER_WORD) -> dict:
    """Postal-model estimate (Eq. 1.2): T_c = α·M + β·W per part.

    W is the true per-part maximum outgoing volume in words (max over
    parts of ``volume_p / 4 · dofs_per_face``).  The earlier
    ``max_message_size × max_neighbors`` estimate mixed maxima attained by
    *different* parts, overstating the bandwidth term whenever the
    largest-average-message part is not the most-connected one."""
    M = metrics.max_neighbors
    W = metrics.max_part_volume_words
    return {
        "latency_s": alpha * M,
        "volume_s": beta * W,
        "dominated_by": "volume" if beta * W > alpha * M else "latency",
        "m2_words": m2_words(alpha, beta),
        "avg_message_words": metrics.avg_message_size,
    }
