"""Flexible preconditioned conjugate gradients (paper §7).

Solves `L x = b` for the singular graph Laplacian restricted to the
complement of the constants.  Two parRSB-specific details are reproduced
faithfully:

* **The initial search direction is NOT preconditioned** (`p₀ = r₀`).
  Rationale (paper): inverse iteration feeds the previous iterate as the
  RHS; as `b → y₂` the Krylov space of L (but not of M⁻¹L) becomes
  invariant, so this flexcg converges in a *single* iteration — which the
  outer inverse iteration uses as its stopping signal.
* **Flexible β** (Polak–Ribière form, `β = ⟨z_{k+1}, r_{k+1} − r_k⟩ / ⟨z_k, r_k⟩`)
  so a variable preconditioner (AMG V-cycle) is admissible.

All dots are masked so padded (bucketed) entries never contribute; every
residual/preconditioned vector is re-projected against the constants.

The solver is **batched**: `b` may carry arbitrary leading batch dims
(the vector axis is always the last one).  Every reduction is per-problem
(`axis=-1, keepdims=True`), convergence is tracked per problem, and a
converged problem's state is frozen (`jnp.where` on the active flag) while
the while_loop keeps running until *all* problems are done — the
"masked batched iterations that stop per-element" the level-synchronous
RSB engine relies on.  For a 1-D `b` the behaviour (and the scalar
`iters`/`resnorm` in the result) is unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def _vdot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-problem dot product: reduce the vector (last) axis, keepdims."""
    return jnp.sum(a * b, axis=-1, keepdims=True)


def _project_out_ones(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Remove the (masked) constant component: x ← x − mean_mask(x).

    Batched over any leading dims (the reduction is per problem).
    """
    m = _vdot(x, mask) / jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    return (x - m) * mask


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CGResult:
    x: jax.Array
    iters: jax.Array    # per-problem iteration counts (scalar for 1-D input)
    resnorm: jax.Array  # per-problem final residual norms


def flexcg(
    op: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    *,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    x0: jax.Array | None = None,
    mask: jax.Array | None = None,
    tol: float = 1e-5,
    maxiter: int = 200,
) -> CGResult:
    """Jittable flexible-PCG.  `op`/`precond` must be jit-traceable.

    `b`: (..., n).  `op`/`precond` map (..., n) → (..., n).  `mask` is
    broadcast against `b`; each leading index is an independent problem
    whose iteration stops (state freezes) at its own convergence.
    """
    mask = jnp.ones_like(b) if mask is None else jnp.broadcast_to(
        mask.astype(b.dtype), b.shape
    )
    M = (lambda r: r) if precond is None else precond

    b = _project_out_ones(b, mask)
    bnorm = jnp.sqrt(_vdot(b, b))
    x = jnp.zeros_like(b) if x0 is None else _project_out_ones(x0, mask)
    r = _project_out_ones(b - op(x), mask)
    # Key point: first direction is the *unpreconditioned* residual.
    z = r
    p = z
    rz = _vdot(r, z)
    resnorm = jnp.sqrt(_vdot(r, r))
    tol_abs = tol * jnp.maximum(bnorm, 1e-30)
    k = jnp.zeros(b.shape[:-1] + (1,), jnp.int32)

    def active_flags(k, resnorm):
        return jnp.logical_and(k < maxiter, resnorm > tol_abs)

    def cond(state):
        x, r, z, p, rz, k, resnorm = state
        return jnp.any(active_flags(k, resnorm))

    def body(state):
        x, r, z, p, rz, k, resnorm = state
        act = active_flags(k, resnorm)          # (..., 1) bool per problem
        w = op(p)
        pw = _vdot(p, w)
        alpha = jnp.where(jnp.abs(pw) > 1e-30, rz / pw, 0.0)
        x_new = x + alpha * p
        r_new = _project_out_ones(r - alpha * w, mask)
        z_new = _project_out_ones(M(r_new), mask)
        beta = jnp.where(
            jnp.abs(rz) > 1e-30, _vdot(z_new, r_new - r) / rz, 0.0
        )
        rz_new = _vdot(r_new, z_new)
        p_new = z_new + beta * p
        res_new = jnp.sqrt(_vdot(r_new, r_new))
        # Converged problems keep their state frozen.
        return (
            jnp.where(act, x_new, x),
            jnp.where(act, r_new, r),
            jnp.where(act, z_new, z),
            jnp.where(act, p_new, p),
            jnp.where(act, rz_new, rz),
            k + act.astype(jnp.int32),
            jnp.where(act, res_new, resnorm),
        )

    state = (x, r, z, p, rz, k, resnorm)
    x, r, z, p, rz, k, resnorm = jax.lax.while_loop(cond, body, state)
    return CGResult(
        x=_project_out_ones(x, mask),
        iters=jnp.squeeze(k, axis=-1),
        resnorm=jnp.squeeze(resnorm, axis=-1),
    )
