"""Flexible preconditioned conjugate gradients (paper §7).

Solves `L x = b` for the singular graph Laplacian restricted to the
complement of the constants.  Two parRSB-specific details are reproduced
faithfully:

* **The initial search direction is NOT preconditioned** (`p₀ = r₀`).
  Rationale (paper): inverse iteration feeds the previous iterate as the
  RHS; as `b → y₂` the Krylov space of L (but not of M⁻¹L) becomes
  invariant, so this flexcg converges in a *single* iteration — which the
  outer inverse iteration uses as its stopping signal.
* **Flexible β** (Polak–Ribière form, `β = ⟨z_{k+1}, r_{k+1} − r_k⟩ / ⟨z_k, r_k⟩`)
  so a variable preconditioner (AMG V-cycle) is admissible.

All dots are masked so padded (bucketed) entries never contribute; every
residual/preconditioned vector is re-projected against the constants.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def _project_out_ones(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Remove the (masked) constant component: x ← x − mean_mask(x)."""
    m = jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return (x - m) * mask


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CGResult:
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array


def flexcg(
    op: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    *,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    x0: jax.Array | None = None,
    mask: jax.Array | None = None,
    tol: float = 1e-5,
    maxiter: int = 200,
) -> CGResult:
    """Jittable flexible-PCG.  `op`/`precond` must be jit-traceable."""
    n = b.shape[0]
    mask = jnp.ones((n,), b.dtype) if mask is None else mask.astype(b.dtype)
    M = (lambda r: r) if precond is None else precond

    b = _project_out_ones(b, mask)
    bnorm = jnp.sqrt(jnp.sum(b * b))
    x = jnp.zeros_like(b) if x0 is None else _project_out_ones(x0, mask)
    r = _project_out_ones(b - op(x), mask)
    # Key point: first direction is the *unpreconditioned* residual.
    z = r
    p = z
    rz = jnp.sum(r * z)
    resnorm = jnp.sqrt(jnp.sum(r * r))
    tol_abs = tol * jnp.maximum(bnorm, 1e-30)

    def cond(state):
        x, r, z, p, rz, k, resnorm = state
        return jnp.logical_and(k < maxiter, resnorm > tol_abs)

    def body(state):
        x, r, z, p, rz, k, _ = state
        w = op(p)
        pw = jnp.sum(p * w)
        alpha = jnp.where(jnp.abs(pw) > 1e-30, rz / pw, 0.0)
        x_new = x + alpha * p
        r_new = _project_out_ones(r - alpha * w, mask)
        z_new = _project_out_ones(M(r_new), mask)
        beta = jnp.where(
            jnp.abs(rz) > 1e-30, jnp.sum(z_new * (r_new - r)) / rz, 0.0
        )
        rz_new = jnp.sum(r_new * z_new)
        p_new = z_new + beta * p
        resnorm = jnp.sqrt(jnp.sum(r_new * r_new))
        return (x_new, r_new, z_new, p_new, rz_new, k + 1, resnorm)

    state = (x, r, z, p, rz, jnp.zeros((), jnp.int32), resnorm)
    x, r, z, p, rz, k, resnorm = jax.lax.while_loop(cond, body, state)
    return CGResult(x=_project_out_ones(x, mask), iters=k, resnorm=resnorm)
