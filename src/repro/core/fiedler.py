"""Fiedler-vector solver facade: picks Lanczos or inverse iteration.

Adds the practical glue the RSB driver needs:
  * operator construction from a mesh (gather-scatter) or a graph (ELL),
  * power-of-two bucketing/padding so the recursion reuses compiled solvers
    (pad entries are fully decoupled: dummy gids / zero rows — the self-term
    cancellation makes `L` act as 0 on them),
  * a dense NumPy path for tiny subproblems (recursion tail),
  * optional geometric warm start (beyond-paper: seed with the coordinate
    along the dominant axis instead of noise — see EXPERIMENTS.md §Perf),
  * **batched entry points** (`fiedler_from_graph_batched`,
    `fiedler_from_mesh_batched`): solve a whole RSB tree level at once.
    Subproblems are grouped into (n_pad, width_pad) **shape buckets**
    (power-of-two padded, batch padded to a power of two with fully-masked
    dummy rows), each bucket runs one vmapped solve whose compiled trace is
    shared by every bucket of the same shape for the life of the process.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amg import amg_setup
from repro.core.gather_scatter import GSHandle, GSLaplacian, gs_setup, _build
from repro.core.inverse_iteration import inverse_iteration, inverse_iteration_batched
from repro.core.laplacian import EllLaplacian, dense_laplacian_np, ell_laplacian
from repro.core.lanczos import lanczos_fiedler, lanczos_fiedler_batched
from repro.mesh.graphs import Graph, csr_to_ell

_DENSE_CUTOFF = 192


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@dataclasses.dataclass
class FiedlerResult:
    vector: np.ndarray     # (n,) float — Fiedler components (real entries only)
    eigenvalue: float
    residual: float
    iterations: int        # restarts (lanczos) or outer iters (inverse)
    method: str


def _fill_ell_block(graph: Graph, C: np.ndarray, V: np.ndarray, D: np.ndarray,
                    col_offset: int = 0) -> None:
    """Fill one graph's rows of a padded ELL block (C/V/D are views of the
    target rows; rows past graph.n keep self-columns and zero vals/diag,
    so L acts as 0 on them).  The single home of the padding invariants —
    the padded, batched, and packed builders all delegate here."""
    cols, vals = csr_to_ell(graph, max_row=None)
    nb, wb = cols.shape
    if wb > C.shape[1]:
        raise ValueError("width_pad below max degree")
    C[:nb, :wb] = cols + col_offset
    V[:nb, :wb] = vals
    np.add.at(D[:nb], graph.rows, graph.weights)


def _noise_b0(seed: int, n: int) -> np.ndarray:
    """Deterministic start-vector noise, generated on the host: identical
    between the unbatched and batched entry points (batch-of-one parity)
    and free of the threefry compile a first `jax.random.normal` costs."""
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _gs_laplacian_from_np(gid: np.ndarray, n_global: int, n: int) -> GSLaplacian:
    """GSLaplacian with host-computed degrees (aw_apply(1) ≡ per-slot sum of
    gid multiplicities) — avoids `_build`'s eager JAX dispatch on the hot
    setup path.  gid: (n, K) or (B, n, K); per-problem id spaces for 3-D."""
    K = gid.shape[-1]
    if gid.ndim == 3:
        deg_full = np.stack([
            np.bincount(g.ravel(), minlength=n_global)[g].sum(-1) for g in gid
        ])
    else:
        deg_full = np.bincount(gid.ravel(), minlength=n_global)[gid].sum(-1)
    h = GSHandle(gid=jnp.asarray(gid.astype(np.int32)), n_global=n_global)
    return GSLaplacian(
        terms=((1.0, h),), n=n,
        degree_full=jnp.asarray(deg_full.astype(np.float32)),
        diag=jnp.asarray((deg_full - K).astype(np.float32)),
    )


def _fill_gs_block(vert_gid: np.ndarray, gid_block: np.ndarray,
                   base: int) -> int:
    """Compact one sub-mesh's gids into gid_block starting at id `base`;
    rows past E get one fresh singleton id per slot (no coupling,
    self-cancelling).  Returns the next unused id."""
    E, K = vert_gid.shape
    uniq, inv = np.unique(vert_gid, return_inverse=True)
    gid_block[:E] = inv.reshape(E, K) + base
    base += uniq.size
    n_rows = gid_block.shape[0]
    if n_rows > E:
        pad = (n_rows - E) * K
        gid_block[E:] = (base + np.arange(pad)).reshape(-1, K)
        base += pad
    return base


def _padded_gs_laplacian(vert_gid: np.ndarray, n_pad: int) -> GSLaplacian:
    """Gather-scatter Laplacian padded to n_pad elements (decoupled tail)."""
    gid = np.empty((n_pad, vert_gid.shape[1]), dtype=np.int64)
    ng = _fill_gs_block(vert_gid, gid, 0)
    h = GSHandle(gid=jnp.asarray(gid.astype(np.int32)), n_global=ng)
    return _build([(1.0, h)], n_pad)


def _padded_ell_laplacian(graph: Graph, n_pad: int, width_pad: int) -> EllLaplacian:
    C = np.tile(np.arange(n_pad, dtype=np.int64)[:, None], (1, width_pad))
    V = np.zeros((n_pad, width_pad), dtype=np.float64)
    D = np.zeros(n_pad, dtype=np.float64)
    _fill_ell_block(graph, C, V, D)
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=n_pad,
    )


def _dense_fiedler(L: np.ndarray) -> tuple[np.ndarray, float]:
    w, v = np.linalg.eigh(L)
    return v[:, 1], float(w[1])


def fiedler_from_graph(
    graph: Graph,
    *,
    method: str = "lanczos",
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
    use_kernel: bool = False,
) -> FiedlerResult:
    """Fiedler vector of an assembled graph Laplacian."""
    n = graph.n
    if n <= _DENSE_CUTOFF:
        vec, lam = _dense_fiedler(dense_laplacian_np(graph))
        return FiedlerResult(vec, lam, 0.0, 0, "dense")

    n_pad = next_pow2(n) if pad else n
    width = int(graph.degrees.max()) if graph.nnz else 1
    width_pad = next_pow2(max(width, 2)) if pad else width
    op = _padded_ell_laplacian(graph, n_pad, width_pad)
    if use_kernel:
        op = dataclasses.replace(op, use_kernel=True)
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - n)))
    else:
        b0 = jnp.asarray(_noise_b0(seed, n_pad))

    if method == "lanczos":
        y, info = lanczos_fiedler(
            op.apply, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
            window=window, max_restarts=max_restarts, tol=tol,
        )
        iters = info.restarts
        lam, res = info.eigenvalue, info.residual
    elif method == "inverse":
        pre = amg_setup(graph, order=order)
        # AMG hierarchy is sized to the real graph; wrap to ignore padding.
        def precond(r):
            u = pre(r[:n])
            return jnp.pad(u, (0, n_pad - n))

        y, info = inverse_iteration(
            op.apply, n_pad, precond=precond, mask=mask,
            key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
        )
        iters = info.outer_iters
        lam, res = info.eigenvalue, info.residual
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    return FiedlerResult(np.asarray(y[:n]), lam, res, iters, method)


def fiedler_from_mesh(
    vert_gid: np.ndarray,
    *,
    method: str = "lanczos",
    graph_for_amg: Graph | None = None,
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
) -> FiedlerResult:
    """Fiedler vector via the matrix-free gather-scatter Laplacian (paper §5).

    `graph_for_amg` (the assembled dual graph) is only needed for
    method="inverse" — the AMG hierarchy requires assembled coarse levels
    (paper §7), while Lanczos runs fully matrix-free.
    """
    E = vert_gid.shape[0]
    if E <= _DENSE_CUTOFF:
        from repro.mesh.graphs import dual_graph_from_incidence

        g = dual_graph_from_incidence(vert_gid, int(vert_gid.max()) + 1, E)
        vec, lam = _dense_fiedler(dense_laplacian_np(g))
        return FiedlerResult(vec, lam, 0.0, 0, "dense")

    n_pad = next_pow2(E) if pad else E
    op = _padded_gs_laplacian(vert_gid, n_pad)
    mask = jnp.asarray((np.arange(n_pad) < E).astype(np.float32))
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - E)))
    else:
        b0 = jnp.asarray(_noise_b0(seed, n_pad))

    if method == "lanczos":
        y, info = lanczos_fiedler(
            op.apply, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
            window=window, max_restarts=max_restarts, tol=tol,
        )
        iters, lam, res = info.restarts, info.eigenvalue, info.residual
    elif method == "inverse":
        if graph_for_amg is None:
            raise ValueError("inverse iteration needs the assembled dual graph for AMG")
        pre = amg_setup(graph_for_amg, order=order)

        def precond(r):
            u = pre(r[:E])
            return jnp.pad(u, (0, n_pad - E))

        y, info = inverse_iteration(
            op.apply, n_pad, precond=precond, mask=mask,
            key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
        )
        iters, lam, res = info.outer_iters, info.eigenvalue, info.residual
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    return FiedlerResult(np.asarray(y[:E]), lam, res, iters, method)


# ---------------------------------------------------------------------------
# Batched (level-synchronous) entry points
# ---------------------------------------------------------------------------

def _padded_ell_laplacian_batched(
    graphs: list, n_pad: int, width_pad: int, b_pad: int
) -> EllLaplacian:
    """Stack B assembled Laplacians into one (b_pad, n_pad, width_pad) ELL
    operator.  Rows past each graph's n — and whole batch-padding rows —
    have zero vals and zero diag, so L acts as 0 on them."""
    C = np.tile(
        np.arange(n_pad, dtype=np.int64)[None, :, None], (b_pad, 1, width_pad)
    )
    V = np.zeros((b_pad, n_pad, width_pad), dtype=np.float64)
    D = np.zeros((b_pad, n_pad), dtype=np.float64)
    for b, g in enumerate(graphs):
        _fill_ell_block(g, C[b], V[b], D[b])
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=n_pad,
    )


def _padded_gs_laplacian_batched(
    vert_gids: list, n_pad: int, b_pad: int
) -> GSLaplacian:
    """Stack B gather-scatter Laplacians into one (b_pad, n_pad, K) handle.

    Each subproblem's gids are compacted independently (per-problem id
    space); padded element slots get fresh singleton ids (decoupled,
    self-cancelling).  `n_global` is a shared power-of-two upper bound so
    every same-shape bucket reuses one compiled trace."""
    K = vert_gids[0].shape[1]
    gid = np.empty((b_pad, n_pad, K), dtype=np.int64)
    need = 2
    for b, vg in enumerate(vert_gids):
        need = max(need, _fill_gs_block(vg, gid[b], 0))
    ng = next_pow2(need)
    for b in range(len(vert_gids), b_pad):  # batch-padding dummy problems
        gid[b] = (np.arange(n_pad * K, dtype=np.int64) % ng).reshape(n_pad, K)
    return _gs_laplacian_from_np(gid, ng, n_pad)


def _batched_b0(sizes, seeds, warms, n_pad: int, b_pad: int) -> jax.Array:
    """Per-problem start vectors: padded warm starts where given, otherwise
    seeded noise; zero rows for batch-padding dummies."""
    rows = []
    for sz, sd, warm in zip(sizes, seeds, warms):
        if warm is not None:
            w = np.asarray(warm, dtype=np.float32)
            rows.append(np.pad(w, (0, n_pad - sz)))
        else:
            rows.append(_noise_b0(sd, n_pad))
    for _ in range(b_pad - len(rows)):
        rows.append(np.zeros(n_pad, dtype=np.float32))
    return jnp.asarray(np.stack(rows))


def _normalize_batch_args(B, seeds, warms):
    seeds = list(range(B)) if seeds is None else list(seeds)
    warms = [None] * B if warms is None else list(warms)
    if len(seeds) != B or len(warms) != B:
        raise ValueError("seeds/warms must match the batch length")
    return seeds, warms


# -- packed layout (one flat vector; the Lanczos single-trace fast path) ----

def _pack_layout(sizes, pack_slots=None, pack_segs=None):
    """Pack B subproblems into one flat vector of power-of-two blocks.

    Returns (offs, N, n_seg, seg, mask): problem b owns slots
    [offs[b], offs[b+1]) with its first sizes[b] slots real (mask 1).
    `pack_slots`/`pack_segs` pin N / n_seg to run-wide values so every tree
    level of an RSB run solves in ONE compiled trace (a level's subproblems
    partition the root set, so their padded blocks always fit the root's
    padded size); they are only overridden upward if a layout overflows.
    """
    pads = [next_pow2(max(s, 2)) for s in sizes]
    offs = np.concatenate([[0], np.cumsum(pads)]).astype(np.int64)
    total = int(offs[-1])
    N = next_pow2(total)
    if pack_slots is not None:
        N = max(N, int(pack_slots))
    n_seg = next_pow2(len(sizes))
    if pack_segs is not None:
        n_seg = max(n_seg, int(pack_segs))
    seg = np.zeros(N, dtype=np.int32)
    mask = np.zeros(N, dtype=np.float32)
    for b, s in enumerate(sizes):
        seg[offs[b]:offs[b + 1]] = b
        mask[offs[b]:offs[b] + s] = 1.0
    # trailing slots: seg 0, mask 0, zero operator rows — fully inert
    return offs, N, n_seg, seg, mask


def _packed_ell_laplacian(graphs: list, offs, N: int, width_pad: int) -> EllLaplacian:
    """Block-diagonal ELL Laplacian over the packed slots (plain unbatched
    `EllLaplacian` of size N — each problem's cols are offset into its own
    block, so there is no cross-problem coupling)."""
    C = np.tile(np.arange(N, dtype=np.int64)[:, None], (1, width_pad))
    V = np.zeros((N, width_pad), dtype=np.float64)
    D = np.zeros(N, dtype=np.float64)
    for b, g in enumerate(graphs):
        o, o_next = int(offs[b]), int(offs[b + 1])
        _fill_ell_block(g, C[o:o_next], V[o:o_next], D[o:o_next], col_offset=o)
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=N,
    )


def _packed_gs_laplacian(vert_gids: list, offs, N: int) -> GSLaplacian:
    """Block-diagonal gather-scatter Laplacian over the packed slots: each
    problem's compacted gids live in a disjoint range of one shared id
    space; padding slots get fresh singleton ids (self-cancelling).
    `n_global` is the shape-stable bound next_pow2(N·K)."""
    K = vert_gids[0].shape[1]
    gid = np.empty((N, K), dtype=np.int64)
    base = 0
    for b, vg in enumerate(vert_gids):
        o, o_next = int(offs[b]), int(offs[b + 1])
        base = _fill_gs_block(vg, gid[o:o_next], base)
    tail = int(offs[-1])
    if N > tail:
        gid[tail:] = (base + np.arange((N - tail) * K)).reshape(-1, K)
    return _gs_laplacian_from_np(gid, next_pow2(N * K), N)


def _packed_b0(sizes, offs, N: int, seeds, warms) -> jax.Array:
    out = np.zeros(N, dtype=np.float32)
    for b, s in enumerate(sizes):
        o, o_next = int(offs[b]), int(offs[b + 1])
        if warms[b] is not None:
            out[o:o + s] = np.asarray(warms[b], dtype=np.float32)
        else:
            out[o:o_next] = _noise_b0(seeds[b], o_next - o)
    return jnp.asarray(out)


def _solve_inverse_buckets(results, solve_ix, size_of, bucket_key, build_op,
                           seeds, warms, tol):
    """Shared method="inverse" tail for both batched entry points: group
    problems into shape buckets, run the leading-batch-dim Jacobi solve per
    bucket, unpack FiedlerResults in place."""
    buckets: dict = {}
    for i in solve_ix:
        buckets.setdefault(bucket_key(i), []).append(i)
    for key, ix in sorted(buckets.items()):
        n_pad = key[0]
        b_pad = next_pow2(len(ix))
        op = build_op(ix, key, b_pad)
        mask = np.zeros((b_pad, n_pad), dtype=np.float32)
        for r, i in enumerate(ix):
            mask[r, : size_of(i)] = 1.0
        b0 = _batched_b0(
            [size_of(i) for i in ix], [seeds[i] for i in ix],
            [warms[i] for i in ix], n_pad, b_pad,
        )
        Y, info = inverse_iteration_batched(
            op, n_pad, mask=jnp.asarray(mask), b0=b0, tol=tol
        )
        Yh = np.asarray(Y)
        for r, i in enumerate(ix):
            results[i] = FiedlerResult(
                Yh[r, : size_of(i)], float(info.eigenvalue[r]),
                float(info.residual[r]), int(info.outer_iters[r]), "inverse",
            )


def _solve_packed_lanczos(op, offs, N, n_seg, seg, mask, b0, sizes,
                          tol, window, max_restarts):
    Y, info = lanczos_fiedler_batched(
        op, N, seg=jnp.asarray(seg), n_seg=n_seg, mask=jnp.asarray(mask),
        b0=b0, window=window, max_restarts=max_restarts, tol=tol,
    )
    Yh = np.asarray(Y)
    return [
        FiedlerResult(
            Yh[int(offs[b]):int(offs[b]) + s], float(info.eigenvalue[b]),
            float(info.residual[b]), int(info.restarts[b]), "lanczos",
        )
        for b, s in enumerate(sizes)
    ]


def fiedler_from_graph_batched(
    graphs: list,
    *,
    method: str = "lanczos",
    seeds: list | None = None,
    warms: list | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pack_slots: int | None = None,
    pack_segs: int | None = None,
    width_pad: int | None = None,
    use_kernel: bool = False,
) -> list:
    """Fiedler vectors of B independent graphs in one batched solve.

    Returns FiedlerResults aligned with the input order; problems at or
    below the dense cutoff take the same dense path as the unbatched entry
    point (exact parity on a batch of one).

    method="lanczos" packs all subproblems into one flat block-diagonal
    solve whose trace is keyed by (pack_slots, pack_segs, width_pad,
    window) — the RSB engine pins those to run-wide values so one trace
    serves the whole run.  The packed operator is an ordinary 2-D ELL, so
    `use_kernel=True` routes its matvec through the Pallas `ell_spmv`
    kernel just like the unbatched path.  method="inverse" runs
    Jacobi-preconditioned batched flexcg over leading-batch-dim operators
    bucketed by (n_pad, width_pad); the AMG hierarchy is per-graph host
    state and stays on the unbatched path (use_kernel does not apply to
    the 3-D batched operators).
    """
    B = len(graphs)
    seeds, warms = _normalize_batch_args(B, seeds, warms)
    results: list = [None] * B
    solve_ix = []
    for i, g in enumerate(graphs):
        if g.n <= _DENSE_CUTOFF:
            vec, lam = _dense_fiedler(dense_laplacian_np(g))
            results[i] = FiedlerResult(vec, lam, 0.0, 0, "dense")
        else:
            solve_ix.append(i)
    if not solve_ix:
        return results

    if method == "lanczos":
        sizes = [graphs[i].n for i in solve_ix]
        offs, N, n_seg, seg, mask = _pack_layout(sizes, pack_slots, pack_segs)
        width = max(
            int(graphs[i].degrees.max()) if graphs[i].nnz else 1
            for i in solve_ix
        )
        width = next_pow2(max(width, 2))
        if width_pad is not None:
            width = max(width, int(width_pad))
        op = _packed_ell_laplacian([graphs[i] for i in solve_ix], offs, N, width)
        if use_kernel:
            op = dataclasses.replace(op, use_kernel=True)
        b0 = _packed_b0(sizes, offs, N, [seeds[i] for i in solve_ix],
                        [warms[i] for i in solve_ix])
        packed = _solve_packed_lanczos(
            op, offs, N, n_seg, seg, mask, b0, sizes, tol, window, max_restarts
        )
        for r, i in enumerate(solve_ix):
            results[i] = packed[r]
        return results

    if method != "inverse":
        raise ValueError(f"unknown fiedler method: {method}")

    def bucket_key(i):
        g = graphs[i]
        width = int(g.degrees.max()) if g.nnz else 1
        return (next_pow2(g.n), next_pow2(max(width, 2)))

    _solve_inverse_buckets(
        results, solve_ix, lambda i: graphs[i].n, bucket_key,
        lambda ix, key, b_pad: _padded_ell_laplacian_batched(
            [graphs[i] for i in ix], key[0], key[1], b_pad
        ),
        seeds, warms, tol,
    )
    return results


def fiedler_from_mesh_batched(
    vert_gids: list,
    *,
    method: str = "lanczos",
    seeds: list | None = None,
    warms: list | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pack_slots: int | None = None,
    pack_segs: int | None = None,
) -> list:
    """Matrix-free batched analogue of :func:`fiedler_from_mesh`: B element
    sub-meshes (their (E, K) global-id tables) per call.  method="lanczos"
    packs every sub-mesh into one flat gather-scatter solve (one trace per
    run when pack_slots/pack_segs are pinned); method="inverse" uses the
    leading-batch-dim Jacobi path (AMG is per-graph host state)."""
    B = len(vert_gids)
    seeds, warms = _normalize_batch_args(B, seeds, warms)
    results: list = [None] * B
    solve_ix = []
    for i, vg in enumerate(vert_gids):
        if vg.shape[0] <= _DENSE_CUTOFF:
            from repro.mesh.graphs import dual_graph_from_incidence

            g = dual_graph_from_incidence(vg, int(vg.max()) + 1, vg.shape[0])
            vec, lam = _dense_fiedler(dense_laplacian_np(g))
            results[i] = FiedlerResult(vec, lam, 0.0, 0, "dense")
        else:
            solve_ix.append(i)
    if not solve_ix:
        return results

    if method == "lanczos":
        sizes = [vert_gids[i].shape[0] for i in solve_ix]
        offs, N, n_seg, seg, mask = _pack_layout(sizes, pack_slots, pack_segs)
        op = _packed_gs_laplacian([vert_gids[i] for i in solve_ix], offs, N)
        b0 = _packed_b0(sizes, offs, N, [seeds[i] for i in solve_ix],
                        [warms[i] for i in solve_ix])
        packed = _solve_packed_lanczos(
            op, offs, N, n_seg, seg, mask, b0, sizes, tol, window, max_restarts
        )
        for r, i in enumerate(solve_ix):
            results[i] = packed[r]
        return results

    if method != "inverse":
        raise ValueError(f"unknown fiedler method: {method}")
    _solve_inverse_buckets(
        results, solve_ix, lambda i: vert_gids[i].shape[0],
        lambda i: (next_pow2(vert_gids[i].shape[0]),),
        lambda ix, key, b_pad: _padded_gs_laplacian_batched(
            [vert_gids[i] for i in ix], key[0], b_pad
        ),
        seeds, warms, tol,
    )
    return results


# ---------------------------------------------------------------------------
# Degenerate Fiedler pairs (paper §9 future work, implemented here)
# ---------------------------------------------------------------------------

def fiedler_pair_from_graph(
    graph: Graph,
    *,
    seed: int = 0,
    tol: float = 1e-4,
    window: int = 40,
    max_restarts: int = 60,
) -> tuple[np.ndarray, np.ndarray, float, float]:
    """(y₂, y₃, λ₂, λ₃): the two smallest nontrivial eigenpairs.

    Paper §9: on topologically-checkerboard graphs λ₂ has multiplicity 2
    and single-vector Lanczos returns an arbitrary member of the eigenspace
    whose cut quality varies (45° cuts expose ≈2N faces vs N).  We find the
    second vector by SPECTRAL DEFLATION: run Lanczos again on
    `L' = L + σ·y₂y₂ᵀ` (σ > λ_max pushes y₂'s eigenvalue out of the way),
    which needs no changes to the Lanczos kernel itself.
    """
    res1 = fiedler_from_graph(graph, method="lanczos", seed=seed, tol=tol,
                              window=window, max_restarts=max_restarts)
    y1 = res1.vector / max(np.linalg.norm(res1.vector), 1e-30)

    n = graph.n
    n_pad = next_pow2(n)
    width = int(graph.degrees.max()) if graph.nnz else 1
    op = _padded_ell_laplacian(graph, n_pad, next_pow2(max(width, 2)))
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    y1p = jnp.asarray(np.pad(y1.astype(np.float32), (0, n_pad - n)))
    # Gershgorin bound on λ_max; σ above it exiles y₂'s eigenvalue
    sigma = 4.0 * float(np.max(np.asarray(op.diag))) + 1.0

    def deflated(x):
        return op.apply(x) + sigma * y1p * jnp.vdot(y1p, x)

    y, info = lanczos_fiedler(
        deflated, n_pad, mask=mask, key=jax.random.PRNGKey(seed + 1),
        window=window, max_restarts=max_restarts, tol=tol,
    )
    y2 = np.asarray(y[:n])
    y2 = y2 - y1 * float(y1 @ y2)          # exact orthogonality polish
    y2 /= max(np.linalg.norm(y2), 1e-30)
    return y1, y2, res1.eigenvalue, info.eigenvalue


def best_cut_in_pair(
    graph: Graph,
    y1: np.ndarray,
    y2: np.ndarray,
    *,
    n_theta: int = 16,
    weights: np.ndarray | None = None,
) -> tuple[np.ndarray, float, float]:
    """Paper §9: sweep θ over span{y₂, y₃} and keep the balanced bisection
    with the minimum ω-cut.  Returns (fiedler-like vector, θ, cut)."""
    w = np.ones(graph.n) if weights is None else np.asarray(weights, np.float64)
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    best = (None, 0.0, np.inf)
    for theta in np.linspace(0.0, np.pi, n_theta, endpoint=False):
        v = np.cos(theta) * y1 + np.sin(theta) * y2
        order = np.argsort(v, kind="stable")
        half = np.zeros(graph.n, dtype=bool)
        cw = np.cumsum(w[order])
        k = int(np.searchsorted(cw - w[order] / 2, cw[-1] / 2)) + 1
        half[order[:k]] = True
        cut = float(ew[half[rows] != half[cols]].sum() / 2.0)
        if cut < best[2]:
            best = (v, float(theta), cut)
    return best
