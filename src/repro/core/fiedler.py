"""Fiedler-vector solver facade: picks Lanczos or inverse iteration.

Adds the practical glue the RSB driver needs:
  * operator construction from a mesh (gather-scatter) or a graph (ELL),
  * power-of-two bucketing/padding so the recursion reuses compiled solvers
    (pad entries are fully decoupled: dummy gids / zero rows — the self-term
    cancellation makes `L` act as 0 on them),
  * a dense NumPy path for tiny subproblems (recursion tail),
  * optional geometric warm start (beyond-paper: seed with the coordinate
    along the dominant axis instead of noise — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amg import amg_setup
from repro.core.gather_scatter import GSLaplacian, gs_setup, _build
from repro.core.inverse_iteration import inverse_iteration
from repro.core.laplacian import EllLaplacian, dense_laplacian_np, ell_laplacian
from repro.core.lanczos import lanczos_fiedler
from repro.mesh.graphs import Graph, csr_to_ell

_DENSE_CUTOFF = 192


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@dataclasses.dataclass
class FiedlerResult:
    vector: np.ndarray     # (n,) float — Fiedler components (real entries only)
    eigenvalue: float
    residual: float
    iterations: int        # restarts (lanczos) or outer iters (inverse)
    method: str


def _padded_gs_laplacian(vert_gid: np.ndarray, n_pad: int) -> GSLaplacian:
    """Gather-scatter Laplacian padded to n_pad elements (decoupled tail)."""
    E, K = vert_gid.shape
    uniq, inv = np.unique(vert_gid, return_inverse=True)
    ng = uniq.size
    gid = np.empty((n_pad, K), dtype=np.int64)
    gid[:E] = inv.reshape(E, K)
    if n_pad > E:
        # one fresh dummy id per padded slot — no coupling, self-cancelling
        gid[E:] = (ng + np.arange((n_pad - E) * K)).reshape(n_pad - E, K)
    handle_gid = jnp.asarray(gid.astype(np.int32))
    from repro.core.gather_scatter import GSHandle

    h = GSHandle(gid=handle_gid, n_global=int(gid.max()) + 1)
    return _build([(1.0, h)], n_pad)


def _padded_ell_laplacian(graph: Graph, n_pad: int, width_pad: int) -> EllLaplacian:
    cols, vals = csr_to_ell(graph, max_row=None)
    n, w = cols.shape
    if width_pad < w:
        raise ValueError("width_pad below max degree")
    C = np.tile(np.arange(n_pad, dtype=np.int64)[:, None], (1, width_pad))
    V = np.zeros((n_pad, width_pad), dtype=np.float64)
    C[:n, :w] = cols
    V[:n, :w] = vals
    deg = np.zeros(n_pad, dtype=np.float64)
    np.add.at(deg, graph.rows, graph.weights)
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(deg.astype(np.float32)),
        n=n_pad,
    )


def _dense_fiedler(L: np.ndarray) -> tuple[np.ndarray, float]:
    w, v = np.linalg.eigh(L)
    return v[:, 1], float(w[1])


def fiedler_from_graph(
    graph: Graph,
    *,
    method: str = "lanczos",
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
    use_kernel: bool = False,
) -> FiedlerResult:
    """Fiedler vector of an assembled graph Laplacian."""
    n = graph.n
    if n <= _DENSE_CUTOFF:
        vec, lam = _dense_fiedler(dense_laplacian_np(graph))
        return FiedlerResult(vec, lam, 0.0, 0, "dense")

    n_pad = next_pow2(n) if pad else n
    width = int(graph.degrees.max()) if graph.nnz else 1
    width_pad = next_pow2(max(width, 2)) if pad else width
    op = _padded_ell_laplacian(graph, n_pad, width_pad)
    if use_kernel:
        op = dataclasses.replace(op, use_kernel=True)
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    b0 = None
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - n)))

    if method == "lanczos":
        y, info = lanczos_fiedler(
            op.apply, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
            window=window, max_restarts=max_restarts, tol=tol,
        )
        iters = info.restarts
        lam, res = info.eigenvalue, info.residual
    elif method == "inverse":
        pre = amg_setup(graph, order=order)
        # AMG hierarchy is sized to the real graph; wrap to ignore padding.
        def precond(r):
            u = pre(r[:n])
            return jnp.pad(u, (0, n_pad - n))

        y, info = inverse_iteration(
            op.apply, n_pad, precond=precond, mask=mask,
            key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
        )
        iters = info.outer_iters
        lam, res = info.eigenvalue, info.residual
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    return FiedlerResult(np.asarray(y[:n]), lam, res, iters, method)


def fiedler_from_mesh(
    vert_gid: np.ndarray,
    *,
    method: str = "lanczos",
    graph_for_amg: Graph | None = None,
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
) -> FiedlerResult:
    """Fiedler vector via the matrix-free gather-scatter Laplacian (paper §5).

    `graph_for_amg` (the assembled dual graph) is only needed for
    method="inverse" — the AMG hierarchy requires assembled coarse levels
    (paper §7), while Lanczos runs fully matrix-free.
    """
    E = vert_gid.shape[0]
    if E <= _DENSE_CUTOFF:
        from repro.mesh.graphs import dual_graph_from_incidence

        g = dual_graph_from_incidence(vert_gid, int(vert_gid.max()) + 1, E)
        vec, lam = _dense_fiedler(dense_laplacian_np(g))
        return FiedlerResult(vec, lam, 0.0, 0, "dense")

    n_pad = next_pow2(E) if pad else E
    op = _padded_gs_laplacian(vert_gid, n_pad)
    mask = jnp.asarray((np.arange(n_pad) < E).astype(np.float32))
    b0 = None
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - E)))

    if method == "lanczos":
        y, info = lanczos_fiedler(
            op.apply, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
            window=window, max_restarts=max_restarts, tol=tol,
        )
        iters, lam, res = info.restarts, info.eigenvalue, info.residual
    elif method == "inverse":
        if graph_for_amg is None:
            raise ValueError("inverse iteration needs the assembled dual graph for AMG")
        pre = amg_setup(graph_for_amg, order=order)

        def precond(r):
            u = pre(r[:E])
            return jnp.pad(u, (0, n_pad - E))

        y, info = inverse_iteration(
            op.apply, n_pad, precond=precond, mask=mask,
            key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
        )
        iters, lam, res = info.outer_iters, info.eigenvalue, info.residual
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    return FiedlerResult(np.asarray(y[:E]), lam, res, iters, method)


# ---------------------------------------------------------------------------
# Degenerate Fiedler pairs (paper §9 future work, implemented here)
# ---------------------------------------------------------------------------

def fiedler_pair_from_graph(
    graph: Graph,
    *,
    seed: int = 0,
    tol: float = 1e-4,
    window: int = 40,
    max_restarts: int = 60,
) -> tuple[np.ndarray, np.ndarray, float, float]:
    """(y₂, y₃, λ₂, λ₃): the two smallest nontrivial eigenpairs.

    Paper §9: on topologically-checkerboard graphs λ₂ has multiplicity 2
    and single-vector Lanczos returns an arbitrary member of the eigenspace
    whose cut quality varies (45° cuts expose ≈2N faces vs N).  We find the
    second vector by SPECTRAL DEFLATION: run Lanczos again on
    `L' = L + σ·y₂y₂ᵀ` (σ > λ_max pushes y₂'s eigenvalue out of the way),
    which needs no changes to the Lanczos kernel itself.
    """
    res1 = fiedler_from_graph(graph, method="lanczos", seed=seed, tol=tol,
                              window=window, max_restarts=max_restarts)
    y1 = res1.vector / max(np.linalg.norm(res1.vector), 1e-30)

    n = graph.n
    n_pad = next_pow2(n)
    width = int(graph.degrees.max()) if graph.nnz else 1
    op = _padded_ell_laplacian(graph, n_pad, next_pow2(max(width, 2)))
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    y1p = jnp.asarray(np.pad(y1.astype(np.float32), (0, n_pad - n)))
    # Gershgorin bound on λ_max; σ above it exiles y₂'s eigenvalue
    sigma = 4.0 * float(np.max(np.asarray(op.diag))) + 1.0

    def deflated(x):
        return op.apply(x) + sigma * y1p * jnp.vdot(y1p, x)

    y, info = lanczos_fiedler(
        deflated, n_pad, mask=mask, key=jax.random.PRNGKey(seed + 1),
        window=window, max_restarts=max_restarts, tol=tol,
    )
    y2 = np.asarray(y[:n])
    y2 = y2 - y1 * float(y1 @ y2)          # exact orthogonality polish
    y2 /= max(np.linalg.norm(y2), 1e-30)
    return y1, y2, res1.eigenvalue, info.eigenvalue


def best_cut_in_pair(
    graph: Graph,
    y1: np.ndarray,
    y2: np.ndarray,
    *,
    n_theta: int = 16,
    weights: np.ndarray | None = None,
) -> tuple[np.ndarray, float, float]:
    """Paper §9: sweep θ over span{y₂, y₃} and keep the balanced bisection
    with the minimum ω-cut.  Returns (fiedler-like vector, θ, cut)."""
    w = np.ones(graph.n) if weights is None else np.asarray(weights, np.float64)
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    best = (None, 0.0, np.inf)
    for theta in np.linspace(0.0, np.pi, n_theta, endpoint=False):
        v = np.cos(theta) * y1 + np.sin(theta) * y2
        order = np.argsort(v, kind="stable")
        half = np.zeros(graph.n, dtype=bool)
        cw = np.cumsum(w[order])
        k = int(np.searchsorted(cw - w[order] / 2, cw[-1] / 2)) + 1
        half[order[:k]] = True
        cut = float(ew[half[rows] != half[cols]].sum() / 2.0)
        if cut < best[2]:
            best = (v, float(theta), cut)
    return best
