"""Fiedler-vector solver facade: picks Lanczos or inverse iteration.

Adds the practical glue the RSB driver needs:
  * operator construction from a mesh (gather-scatter) or a graph (ELL),
  * power-of-two bucketing/padding so the recursion reuses compiled solvers
    (pad entries are fully decoupled: dummy gids / zero rows — the self-term
    cancellation makes `L` act as 0 on them),
  * a dense NumPy path for tiny subproblems (recursion tail),
  * optional geometric warm start (beyond-paper: seed with the coordinate
    along the dominant axis instead of noise — see EXPERIMENTS.md §Perf),
  * **multilevel (coarse-to-fine) warm starts** (`multilevel_warm_start`,
    on by default): a Galerkin hierarchy per subproblem (the `amg_setup`
    pairwise aggregation), a dense Fiedler solve on the coarsest graph, and
    a cascadic prolongation (one Jacobi-PCG inverse-iteration step per
    level, host NumPy) whose output seeds the device solve — the fine-level
    Lanczos then only *refines*, so callers can cap it at a few restarts,
  * **batched entry points** (`fiedler_from_graph_batched`,
    `fiedler_from_mesh_batched`): solve a whole RSB tree level at once.
    Subproblems are grouped into (n_pad, width_pad) **shape buckets**
    (power-of-two padded, batch padded to a power of two with fully-masked
    dummy rows), each bucket runs one vmapped solve whose compiled trace is
    shared by every bucket of the same shape for the life of the process.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.amg import amg_setup, amg_setup_batched, coarsen_graph
from repro.core.gather_scatter import GSHandle, GSLaplacian, _build
from repro.core.inverse_iteration import inverse_iteration, inverse_iteration_batched
from repro.core.lanczos import lanczos_fiedler, lanczos_fiedler_batched
from repro.core.laplacian import (
    EllLaplacian,
    dense_laplacian_np,
    ell_laplacian_batched,
    fill_ell_block as _fill_ell_block,
)
from repro.mesh.graphs import Graph, dual_graph_from_incidence
from repro.obs import jaxprof

_DENSE_CUTOFF = 192


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@dataclasses.dataclass
class FiedlerResult:
    vector: np.ndarray     # (n,) float — Fiedler components (real entries only)
    eigenvalue: float
    residual: float
    iterations: int        # restarts (lanczos) or outer iters (inverse)
    method: str
    levels: int = 0        # multilevel warm-start hierarchy depth (0 = none)
    breakdown: bool = False  # solver hit a non-finite iterate; stale (λ, res)


def _emit_fiedler_metrics(results) -> None:
    """Emit solver counters/gauges for completed solves into the active
    obs span (no-op outside a trace — counter_add early-outs)."""
    for r in results:
        if r is None:
            continue
        obs.counter_add("fiedler_solves")
        if r.method == "lanczos":
            obs.counter_add("lanczos_restarts", r.iterations)
        elif r.method == "inverse":
            obs.counter_add("inverse_outer_iters", r.iterations)
        obs.gauge_max("residual_max", float(r.residual))
        if r.levels:
            obs.gauge_max("multilevel_levels", r.levels)


# ---------------------------------------------------------------------------
# Multilevel (coarse-to-fine) warm starts — host NumPy, no compiled traces
# ---------------------------------------------------------------------------

def _lap_matvec_np(graph: Graph, deg: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Host Laplacian matvec L x = deg ⊙ x − A x over the COO view."""
    ax = np.bincount(
        graph.rows, weights=graph.weights * x[graph.indices], minlength=graph.n
    )
    return deg * x - ax


def _cg_refine_np(graph: Graph, deg: np.ndarray, inv_d: np.ndarray,
                  b: np.ndarray, iters: int) -> np.ndarray:
    """One cascadic inverse-iteration step: ≈solve L x = b with `iters`
    Jacobi-PCG steps, x₀ = b (host NumPy; every vector stays ⊥ 1)."""
    x = b.copy()
    r = b - _lap_matvec_np(graph, deg, x)
    r -= r.mean()
    z = inv_d * r
    z -= z.mean()
    p = z.copy()
    rz = r @ z
    for _ in range(iters):
        w = _lap_matvec_np(graph, deg, p)
        pw = p @ w
        if abs(pw) < 1e-30:
            break
        a = rz / pw
        x += a * p
        r -= a * w
        r -= r.mean()
        z = inv_d * r
        z -= z.mean()
        rz_new = r @ z
        if rz_new < 1e-30:
            break
        p = z + (rz_new / rz) * p
        rz = rz_new
    x -= x.mean()
    return x


def _rayleigh_ritz_pair_np(graph: Graph, deg: np.ndarray,
                           V: np.ndarray) -> np.ndarray | None:
    """Rayleigh–Ritz over span(V) (V: (n, k) candidates, k small): project
    out constants, orthonormalize, rotate to the L-eigenbasis of the
    subspace, columns sorted by ascending Ritz value.  None on breakdown."""
    V = V - V.mean(axis=0, keepdims=True)
    Q, _ = np.linalg.qr(V)
    W = np.stack([_lap_matvec_np(graph, deg, Q[:, j]) for j in range(Q.shape[1])], 1)
    G = Q.T @ W
    G = 0.5 * (G + G.T)
    if not np.isfinite(G).all():
        return None
    w, S = np.linalg.eigh(G)
    return Q @ S[:, np.argsort(w)]


def multilevel_warm_start(
    graph: Graph,
    *,
    coarse_cutoff: int = _DENSE_CUTOFF,
    refine_iters: int = 6,
) -> tuple[np.ndarray | None, int]:
    """Cascadic coarse-to-fine Fiedler warm start (returns (warm, n_levels)).

    Builds the same pairwise Galerkin hierarchy as `amg_setup` (consecutive
    nodes aggregate — callers feed RCB-ordered graphs, as the RSB engines
    do after the geometric pre-pass), solves the coarsest eigenproblem
    densely, then prolongs level by level with one Jacobi-PCG
    inverse-iteration step per candidate and level.

    A **block of two** candidates (y₂, y₃) rides the whole cascade with a
    per-level 2×2 Rayleigh–Ritz rotation: pairwise aggregation can shrink
    one graph axis faster than another, swapping the eigenvalue order
    between levels (a 24×28 grid coarsens toward 24×14, so the coarse
    Fiedler vector cuts the axis the FINE Fiedler vector does not) — a
    single-vector cascade would then hand the device solve an accurate
    approximation of the WRONG eigenvector, which satisfies the residual
    stopping test at λ₃.  Tracking the pair and re-sorting by fine-level
    Rayleigh quotient keeps the warm start on y₂.

    Everything runs on the host: the warm start adds NO compiled traces,
    and the device solve it seeds only needs a few refinement restarts
    (the RSB engines cap it at `fine_restarts`).  Returns (None, 0) for
    graphs at or below `coarse_cutoff` — those take the dense path
    outright — and on numerical breakdown (caller falls back to noise).
    """
    if graph.n <= coarse_cutoff:
        return None, 0
    levels: list[Graph] = [graph]
    aggs: list[np.ndarray] = []
    while levels[-1].n > coarse_cutoff:
        g = levels[-1]
        agg = np.arange(g.n, dtype=np.int64) // 2
        levels.append(coarsen_graph(g, agg, (g.n + 1) // 2))
        aggs.append(agg)
    w, v = np.linalg.eigh(dense_laplacian_np(levels[-1]))
    V = v[:, 1:3] if v.shape[1] >= 3 else v[:, 1:2]   # (n_c, ≤2) candidates
    for agg, g in zip(reversed(aggs), reversed(levels[:-1])):
        V = V[agg]                           # piecewise-constant prolongation
        deg = np.zeros(g.n)
        np.add.at(deg, g.rows, g.weights)
        inv_d = np.where(deg > 0, 1.0 / np.maximum(deg, 1e-30), 0.0)
        cols = []
        for j in range(V.shape[1]):
            c = V[:, j] - V[:, j].mean()
            nrm = np.linalg.norm(c)
            if not np.isfinite(nrm) or nrm < 1e-30:
                return None, 0               # degenerate level: fall back
            cols.append(_cg_refine_np(g, deg, inv_d, c / nrm, refine_iters))
        V = _rayleigh_ritz_pair_np(g, deg, np.stack(cols, 1))
        if V is None:
            return None, 0
    vec = V[:, 0]
    if not np.isfinite(vec).all():
        return None, 0
    return vec.astype(np.float32), len(aggs)


_INVERSE_NOISE_BLEND = 0.3


def _blend_noise(warm: np.ndarray, seed: int) -> np.ndarray:
    """Mix a deterministic noise floor into a multilevel warm start.

    Single-vector inverse iteration amplifies only the eigencomponents its
    start vector contains: a prolonged coarse Fiedler vector that lands
    (near-)orthogonal to y₂ — near-degenerate pairs, paper §9 — would trap
    the iteration on the wrong eigenvector.  Lanczos is immune (it builds a
    Krylov *subspace*), so only the inverse paths blend."""
    z = _noise_b0(seed, warm.shape[0])
    nw, nz = np.linalg.norm(warm), np.linalg.norm(z)
    if nw < 1e-30 or nz < 1e-30:
        return warm
    return (warm / nw + _INVERSE_NOISE_BLEND * z / nz).astype(np.float32)


def _graph_from_vert_gid(vert_gid: np.ndarray) -> Graph:
    """Assembled dual graph of one sub-mesh (compacted vertex id space)."""
    uniq, inv = np.unique(vert_gid, return_inverse=True)
    return dual_graph_from_incidence(
        inv.reshape(vert_gid.shape), uniq.size, vert_gid.shape[0]
    )


def _noise_b0(seed: int, n: int) -> np.ndarray:
    """Deterministic start-vector noise, generated on the host: identical
    between the unbatched and batched entry points (batch-of-one parity)
    and free of the threefry compile a first `jax.random.normal` costs."""
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _gs_laplacian_from_np(gid: np.ndarray, n_global: int, n: int) -> GSLaplacian:
    """GSLaplacian with host-computed degrees (aw_apply(1) ≡ per-slot sum of
    gid multiplicities) — avoids `_build`'s eager JAX dispatch on the hot
    setup path.  gid: (n, K) or (B, n, K); per-problem id spaces for 3-D."""
    K = gid.shape[-1]
    if gid.ndim == 3:
        deg_full = np.stack([
            np.bincount(g.ravel(), minlength=n_global)[g].sum(-1) for g in gid
        ])
    else:
        deg_full = np.bincount(gid.ravel(), minlength=n_global)[gid].sum(-1)
    h = GSHandle(gid=jnp.asarray(gid.astype(np.int32)), n_global=n_global)
    return GSLaplacian(
        terms=((1.0, h),), n=n,
        degree_full=jnp.asarray(deg_full.astype(np.float32)),
        diag=jnp.asarray((deg_full - K).astype(np.float32)),
    )


def _fill_gs_block(vert_gid: np.ndarray, gid_block: np.ndarray,
                   base: int) -> int:
    """Compact one sub-mesh's gids into gid_block starting at id `base`;
    rows past E get one fresh singleton id per slot (no coupling,
    self-cancelling).  Returns the next unused id."""
    E, K = vert_gid.shape
    uniq, inv = np.unique(vert_gid, return_inverse=True)
    gid_block[:E] = inv.reshape(E, K) + base
    base += uniq.size
    n_rows = gid_block.shape[0]
    if n_rows > E:
        pad = (n_rows - E) * K
        gid_block[E:] = (base + np.arange(pad)).reshape(-1, K)
        base += pad
    return base


def _padded_gs_laplacian(vert_gid: np.ndarray, n_pad: int) -> GSLaplacian:
    """Gather-scatter Laplacian padded to n_pad elements (decoupled tail)."""
    gid = np.empty((n_pad, vert_gid.shape[1]), dtype=np.int64)
    ng = _fill_gs_block(vert_gid, gid, 0)
    h = GSHandle(gid=jnp.asarray(gid.astype(np.int32)), n_global=ng)
    return _build([(1.0, h)], n_pad)


def _padded_ell_laplacian(graph: Graph, n_pad: int, width_pad: int) -> EllLaplacian:
    C = np.tile(np.arange(n_pad, dtype=np.int64)[:, None], (1, width_pad))
    V = np.zeros((n_pad, width_pad), dtype=np.float64)
    D = np.zeros(n_pad, dtype=np.float64)
    _fill_ell_block(graph, C, V, D)
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=n_pad,
    )


def _dense_fiedler(L: np.ndarray) -> tuple[np.ndarray, float]:
    w, v = np.linalg.eigh(L)
    return v[:, 1], float(w[1])


def fiedler_from_graph(
    graph: Graph,
    *,
    method: str = "lanczos",
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
    use_kernel: bool = False,
    multilevel: bool = True,
) -> FiedlerResult:
    """Fiedler vector of an assembled graph Laplacian.

    `use_kernel=True` routes the ELL matvec through the Pallas `ell_spmv`
    kernel (interpret mode off-TPU).  `multilevel=True` (default) seeds the
    solve with a cascadic coarse-to-fine warm start (`multilevel_warm_start`)
    when no explicit `warm` vector is given — the iterative solve then only
    refines the prolonged coarse Fiedler vector.
    """
    n = graph.n
    if n <= _DENSE_CUTOFF:
        vec, lam = _dense_fiedler(dense_laplacian_np(graph))
        res = FiedlerResult(vec, lam, 0.0, 0, "dense")
        _emit_fiedler_metrics([res])
        return res

    ml_levels = 0
    if warm is None and multilevel:
        warm, ml_levels = multilevel_warm_start(graph)
        if warm is not None and method == "inverse":
            warm = _blend_noise(warm, seed)

    n_pad = next_pow2(n) if pad else n
    width = int(graph.degrees.max()) if graph.nnz else 1
    width_pad = next_pow2(max(width, 2)) if pad else width
    op = _padded_ell_laplacian(graph, n_pad, width_pad)
    if use_kernel:
        op = dataclasses.replace(op, use_kernel=True)
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - n)))
    else:
        b0 = jnp.asarray(_noise_b0(seed, n_pad))

    if method == "lanczos":
        # Pass the operator dataclass itself (a pytree): the window trace
        # is shared across same-shape operators instead of per instance.
        with jaxprof.annotate("fiedler:lanczos"):
            y, info = lanczos_fiedler(
                op, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
                window=window, max_restarts=max_restarts, tol=tol,
            )
        iters = info.restarts
        lam, res = info.eigenvalue, info.residual
        broke = info.breakdown
    elif method == "inverse":
        pre = amg_setup(graph, order=order)
        ml_levels = max(ml_levels, len(pre.ops))
        obs.gauge_max("amg_levels", len(pre.ops))

        # AMG hierarchy is sized to the real graph; wrap to ignore padding.
        def precond(r):
            u = pre(r[:n])
            return jnp.pad(u, (0, n_pad - n))

        with jaxprof.annotate("fiedler:inverse"):
            y, info = inverse_iteration(
                op.apply, n_pad, precond=precond, mask=mask,
                key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
            )
        iters = info.outer_iters
        lam, res = info.eigenvalue, info.residual
        broke = info.breakdown
        obs.counter_add("cg_inner_iters", float(np.sum(info.inner_iters)))
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    out = FiedlerResult(np.asarray(y[:n]), lam, res, iters, method,
                        levels=ml_levels, breakdown=broke)
    _emit_fiedler_metrics([out])
    return out


def fiedler_from_mesh(
    vert_gid: np.ndarray,
    *,
    method: str = "lanczos",
    graph_for_amg: Graph | None = None,
    order: np.ndarray | None = None,
    seed: int = 0,
    warm: np.ndarray | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pad: bool = True,
    multilevel: bool = True,
) -> FiedlerResult:
    """Fiedler vector via the matrix-free gather-scatter Laplacian (paper §5).

    `graph_for_amg` (the assembled dual graph) is only needed for
    method="inverse" — the AMG hierarchy requires assembled coarse levels
    (paper §7), while Lanczos runs fully matrix-free.  `multilevel=True`
    (default) assembles the dual graph on the host to build the cascadic
    coarse-to-fine warm start when no `warm` vector is given; the device
    solve itself stays matrix-free.
    """
    E = vert_gid.shape[0]
    if E <= _DENSE_CUTOFF:
        g = dual_graph_from_incidence(vert_gid, int(vert_gid.max()) + 1, E)
        vec, lam = _dense_fiedler(dense_laplacian_np(g))
        res = FiedlerResult(vec, lam, 0.0, 0, "dense")
        _emit_fiedler_metrics([res])
        return res

    ml_levels = 0
    if warm is None and multilevel:
        g_ml = graph_for_amg
        if g_ml is None:
            g_ml = _graph_from_vert_gid(np.asarray(vert_gid))
        warm, ml_levels = multilevel_warm_start(g_ml)
        if warm is not None and method == "inverse":
            warm = _blend_noise(warm, seed)

    n_pad = next_pow2(E) if pad else E
    op = _padded_gs_laplacian(vert_gid, n_pad)
    mask = jnp.asarray((np.arange(n_pad) < E).astype(np.float32))
    if warm is not None:
        b0 = jnp.asarray(np.pad(warm.astype(np.float32), (0, n_pad - E)))
    else:
        b0 = jnp.asarray(_noise_b0(seed, n_pad))

    if method == "lanczos":
        with jaxprof.annotate("fiedler:lanczos"):
            y, info = lanczos_fiedler(
                op, n_pad, mask=mask, key=jax.random.PRNGKey(seed), b0=b0,
                window=window, max_restarts=max_restarts, tol=tol,
            )
        iters, lam, res = info.restarts, info.eigenvalue, info.residual
        broke = info.breakdown
    elif method == "inverse":
        if graph_for_amg is None:
            raise ValueError("inverse iteration needs the assembled dual graph for AMG")
        pre = amg_setup(graph_for_amg, order=order)
        ml_levels = max(ml_levels, len(pre.ops))
        obs.gauge_max("amg_levels", len(pre.ops))

        def precond(r):
            u = pre(r[:E])
            return jnp.pad(u, (0, n_pad - E))

        with jaxprof.annotate("fiedler:inverse"):
            y, info = inverse_iteration(
                op.apply, n_pad, precond=precond, mask=mask,
                key=jax.random.PRNGKey(seed), b0=b0, tol=tol,
            )
        iters, lam, res = info.outer_iters, info.eigenvalue, info.residual
        broke = info.breakdown
        obs.counter_add("cg_inner_iters", float(np.sum(info.inner_iters)))
    else:
        raise ValueError(f"unknown fiedler method: {method}")
    out = FiedlerResult(np.asarray(y[:E]), lam, res, iters, method,
                        levels=ml_levels, breakdown=broke)
    _emit_fiedler_metrics([out])
    return out


# ---------------------------------------------------------------------------
# Batched (level-synchronous) entry points
# ---------------------------------------------------------------------------

_padded_ell_laplacian_batched = ell_laplacian_batched


def _padded_gs_laplacian_batched(
    vert_gids: list, n_pad: int, b_pad: int
) -> GSLaplacian:
    """Stack B gather-scatter Laplacians into one (b_pad, n_pad, K) handle.

    Each subproblem's gids are compacted independently (per-problem id
    space); padded element slots get fresh singleton ids (decoupled,
    self-cancelling).  `n_global` is a shared power-of-two upper bound so
    every same-shape bucket reuses one compiled trace."""
    K = vert_gids[0].shape[1]
    gid = np.empty((b_pad, n_pad, K), dtype=np.int64)
    need = 2
    for b, vg in enumerate(vert_gids):
        need = max(need, _fill_gs_block(vg, gid[b], 0))
    ng = next_pow2(need)
    for b in range(len(vert_gids), b_pad):  # batch-padding dummy problems
        gid[b] = (np.arange(n_pad * K, dtype=np.int64) % ng).reshape(n_pad, K)
    return _gs_laplacian_from_np(gid, ng, n_pad)


def _batched_b0(sizes, seeds, warms, n_pad: int, b_pad: int) -> jax.Array:
    """Per-problem start vectors: padded warm starts where given, otherwise
    seeded noise; zero rows for batch-padding dummies."""
    rows = []
    for sz, sd, warm in zip(sizes, seeds, warms):
        if warm is not None:
            w = np.asarray(warm, dtype=np.float32)
            rows.append(np.pad(w, (0, n_pad - sz)))
        else:
            rows.append(_noise_b0(sd, n_pad))
    for _ in range(b_pad - len(rows)):
        rows.append(np.zeros(n_pad, dtype=np.float32))
    return jnp.asarray(np.stack(rows))


def _normalize_batch_args(B, seeds, warms):
    seeds = list(range(B)) if seeds is None else list(seeds)
    warms = [None] * B if warms is None else list(warms)
    if len(seeds) != B or len(warms) != B:
        raise ValueError("seeds/warms must match the batch length")
    return seeds, warms


# -- packed layout (one flat vector; the Lanczos single-trace fast path) ----

def _pack_layout(sizes, pack_slots=None, pack_segs=None):
    """Pack B subproblems into one flat vector of power-of-two blocks.

    Returns (offs, N, n_seg, seg, mask): problem b owns slots
    [offs[b], offs[b+1]) with its first sizes[b] slots real (mask 1).
    `pack_slots`/`pack_segs` pin N / n_seg to run-wide values so every tree
    level of an RSB run solves in ONE compiled trace (a level's subproblems
    partition the root set, so their padded blocks always fit the root's
    padded size); they are only overridden upward if a layout overflows.
    """
    pads = [next_pow2(max(s, 2)) for s in sizes]
    offs = np.concatenate([[0], np.cumsum(pads)]).astype(np.int64)
    total = int(offs[-1])
    N = next_pow2(total)
    if pack_slots is not None:
        N = max(N, int(pack_slots))
    n_seg = next_pow2(len(sizes))
    if pack_segs is not None:
        n_seg = max(n_seg, int(pack_segs))
    seg = np.zeros(N, dtype=np.int32)
    mask = np.zeros(N, dtype=np.float32)
    for b, s in enumerate(sizes):
        seg[offs[b]:offs[b + 1]] = b
        mask[offs[b]:offs[b] + s] = 1.0
    # trailing slots: seg 0, mask 0, zero operator rows — fully inert
    return offs, N, n_seg, seg, mask


def _packed_ell_laplacian(graphs: list, offs, N: int, width_pad: int) -> EllLaplacian:
    """Block-diagonal ELL Laplacian over the packed slots (plain unbatched
    `EllLaplacian` of size N — each problem's cols are offset into its own
    block, so there is no cross-problem coupling)."""
    C = np.tile(np.arange(N, dtype=np.int64)[:, None], (1, width_pad))
    V = np.zeros((N, width_pad), dtype=np.float64)
    D = np.zeros(N, dtype=np.float64)
    for b, g in enumerate(graphs):
        o, o_next = int(offs[b]), int(offs[b + 1])
        _fill_ell_block(g, C[o:o_next], V[o:o_next], D[o:o_next], col_offset=o)
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=N,
    )


def _packed_gs_laplacian(vert_gids: list, offs, N: int) -> GSLaplacian:
    """Block-diagonal gather-scatter Laplacian over the packed slots: each
    problem's compacted gids live in a disjoint range of one shared id
    space; padding slots get fresh singleton ids (self-cancelling).
    `n_global` is the shape-stable bound next_pow2(N·K)."""
    K = vert_gids[0].shape[1]
    gid = np.empty((N, K), dtype=np.int64)
    base = 0
    for b, vg in enumerate(vert_gids):
        o, o_next = int(offs[b]), int(offs[b + 1])
        base = _fill_gs_block(vg, gid[o:o_next], base)
    tail = int(offs[-1])
    if N > tail:
        gid[tail:] = (base + np.arange((N - tail) * K)).reshape(-1, K)
    return _gs_laplacian_from_np(gid, next_pow2(N * K), N)


def _packed_b0(sizes, offs, N: int, seeds, warms) -> jax.Array:
    out = np.zeros(N, dtype=np.float32)
    for b, s in enumerate(sizes):
        o, o_next = int(offs[b]), int(offs[b + 1])
        if warms[b] is not None:
            out[o:o + s] = np.asarray(warms[b], dtype=np.float32)
        else:
            out[o:o_next] = _noise_b0(seeds[b], o_next - o)
    return jnp.asarray(out)


def _solve_inverse_buckets(results, solve_ix, size_of, bucket_key, build_op,
                           seeds, warms, tol, *, graph_of=None,
                           precond="jacobi"):
    """Shared method="inverse" tail for both batched entry points: group
    problems into shape buckets, run the leading-batch-dim preconditioned
    solve per bucket, unpack FiedlerResults in place.

    precond="jacobi" builds the preconditioner from each operator's own
    diagonal; precond="amg" builds one packed `BatchedAMG` V-cycle per
    bucket from the assembled graphs (`graph_of(i)` must be given — the
    graph path hands over the input graphs, the mesh path assembles each
    sub-mesh's dual graph on the host, exactly like the unbatched path's
    `graph_for_amg`)."""
    if precond not in ("jacobi", "amg"):
        raise ValueError(f"unknown preconditioner: {precond}")
    if precond == "amg" and graph_of is None:
        raise ValueError("precond='amg' needs assembled graphs")
    buckets: dict = {}
    for i in solve_ix:
        buckets.setdefault(bucket_key(i), []).append(i)
    for key, ix in sorted(buckets.items()):
        n_pad = key[0]
        b_pad = next_pow2(len(ix))
        op = build_op(ix, key, b_pad)
        pre = None
        pre_levels = 0
        if precond == "amg":
            pre = amg_setup_batched([graph_of(i) for i in ix], n_pad, b_pad)
            pre_levels = len(pre.ops)
            obs.gauge_max("amg_levels", pre_levels)
        mask = np.zeros((b_pad, n_pad), dtype=np.float32)
        for r, i in enumerate(ix):
            mask[r, : size_of(i)] = 1.0
        b0 = _batched_b0(
            [size_of(i) for i in ix], [seeds[i] for i in ix],
            [warms[i] for i in ix], n_pad, b_pad,
        )
        with jaxprof.annotate(f"fiedler:inverse_batched:n{n_pad}xb{b_pad}"):
            Y, info = inverse_iteration_batched(
                op, n_pad, mask=jnp.asarray(mask), b0=b0, tol=tol, precond=pre
            )
        obs.counter_add(
            "cg_inner_iters",
            float(sum(np.asarray(c).sum() for c in info.inner_iters)))
        Yh = np.asarray(Y)
        for r, i in enumerate(ix):
            results[i] = FiedlerResult(
                Yh[r, : size_of(i)], float(info.eigenvalue[r]),
                float(info.residual[r]), int(info.outer_iters[r]), "inverse",
                levels=pre_levels,
                breakdown=bool(info.breakdown[r])
                if info.breakdown is not None else False,
            )


def _solve_packed_lanczos(op, offs, N, n_seg, seg, mask, b0, sizes,
                          tol, window, max_restarts):
    with jaxprof.annotate(f"fiedler:lanczos_packed:N{N}"):
        Y, info = lanczos_fiedler_batched(
            op, N, seg=jnp.asarray(seg), n_seg=n_seg, mask=jnp.asarray(mask),
            b0=b0, window=window, max_restarts=max_restarts, tol=tol,
        )
    Yh = np.asarray(Y)
    return [
        FiedlerResult(
            Yh[int(offs[b]):int(offs[b]) + s], float(info.eigenvalue[b]),
            float(info.residual[b]), int(info.restarts[b]), "lanczos",
            breakdown=bool(info.breakdown[b])
            if info.breakdown is not None else False,
        )
        for b, s in enumerate(sizes)
    ]


def fiedler_from_graph_batched(
    graphs: list,
    *,
    method: str = "lanczos",
    seeds: list | None = None,
    warms: list | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pack_slots: int | None = None,
    pack_segs: int | None = None,
    width_pad: int | None = None,
    use_kernel: bool = False,
    multilevel: bool = True,
    precond: str = "jacobi",
) -> list:
    """Fiedler vectors of B independent graphs in one batched solve.

    Returns FiedlerResults aligned with the input order; problems at or
    below the dense cutoff take the same dense path as the unbatched entry
    point (exact parity on a batch of one).

    method="lanczos" packs all subproblems into one flat block-diagonal
    solve whose trace is keyed by (pack_slots, pack_segs, width_pad,
    window) — the RSB engine pins those to run-wide values so one trace
    serves the whole run.  method="inverse" runs batched flexcg over
    leading-batch-dim operators bucketed by (n_pad, width_pad), with
    `precond="jacobi"` (the operator's own diagonal) or `precond="amg"`
    (one packed `BatchedAMG` V-cycle per bucket — paper §7's
    preconditioner, batched).  `use_kernel=True` routes BOTH layouts
    through the Pallas `ell_spmv` kernel: the packed 2-D operator uses the
    flat kernel and the 3-D leading-batch-dim operators use the batched
    grid variant.  `multilevel=True` (default) fills every missing `warms`
    entry with the cascadic coarse-to-fine warm start of
    :func:`multilevel_warm_start`.
    """
    B = len(graphs)
    seeds, warms = _normalize_batch_args(B, seeds, warms)
    results: list = [None] * B
    solve_ix = []
    for i, g in enumerate(graphs):
        if g.n <= _DENSE_CUTOFF:
            vec, lam = _dense_fiedler(dense_laplacian_np(g))
            results[i] = FiedlerResult(vec, lam, 0.0, 0, "dense")
        else:
            solve_ix.append(i)
    if not solve_ix:
        _emit_fiedler_metrics(results)
        return results

    ml_levels = {i: 0 for i in solve_ix}
    if multilevel:
        for i in solve_ix:
            if warms[i] is None:
                warms[i], ml_levels[i] = multilevel_warm_start(graphs[i])
                if warms[i] is not None and method == "inverse":
                    warms[i] = _blend_noise(warms[i], seeds[i])

    if method == "lanczos":
        sizes = [graphs[i].n for i in solve_ix]
        offs, N, n_seg, seg, mask = _pack_layout(sizes, pack_slots, pack_segs)
        width = max(
            int(graphs[i].degrees.max()) if graphs[i].nnz else 1
            for i in solve_ix
        )
        width = next_pow2(max(width, 2))
        if width_pad is not None:
            width = max(width, int(width_pad))
        op = _packed_ell_laplacian([graphs[i] for i in solve_ix], offs, N, width)
        if use_kernel:
            op = dataclasses.replace(op, use_kernel=True)
        b0 = _packed_b0(sizes, offs, N, [seeds[i] for i in solve_ix],
                        [warms[i] for i in solve_ix])
        packed = _solve_packed_lanczos(
            op, offs, N, n_seg, seg, mask, b0, sizes, tol, window, max_restarts
        )
        for r, i in enumerate(solve_ix):
            results[i] = packed[r]
            results[i].levels = ml_levels[i]
        _emit_fiedler_metrics(results)
        return results

    if method != "inverse":
        raise ValueError(f"unknown fiedler method: {method}")

    def bucket_key(i):
        g = graphs[i]
        width = int(g.degrees.max()) if g.nnz else 1
        return (next_pow2(g.n), next_pow2(max(width, 2)))

    def build_op(ix, key, b_pad):
        op = _padded_ell_laplacian_batched(
            [graphs[i] for i in ix], key[0], key[1], b_pad
        )
        if use_kernel:
            op = dataclasses.replace(op, use_kernel=True)
        return op

    _solve_inverse_buckets(
        results, solve_ix, lambda i: graphs[i].n, bucket_key, build_op,
        seeds, warms, tol, graph_of=lambda i: graphs[i], precond=precond,
    )
    for i in solve_ix:  # deepest hierarchy used: warm start or AMG ladder
        results[i].levels = max(results[i].levels, ml_levels[i])
    _emit_fiedler_metrics(results)
    return results


def fiedler_from_mesh_batched(
    vert_gids: list,
    *,
    method: str = "lanczos",
    seeds: list | None = None,
    warms: list | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    pack_slots: int | None = None,
    pack_segs: int | None = None,
    multilevel: bool = True,
    precond: str = "jacobi",
    graphs: list | None = None,
) -> list:
    """Matrix-free batched analogue of :func:`fiedler_from_mesh`: B element
    sub-meshes (their (E, K) global-id tables) per call.  method="lanczos"
    packs every sub-mesh into one flat gather-scatter solve (one trace per
    run when pack_slots/pack_segs are pinned); method="inverse" uses the
    leading-batch-dim path with `precond="jacobi"` or `precond="amg"` (a
    packed `BatchedAMG` V-cycle over the assembled dual graphs — the fine
    operator stays matrix-free gather-scatter, exactly like the unbatched
    path's `graph_for_amg`).  `multilevel=True` (default) fills missing
    `warms` entries with the cascadic coarse-to-fine warm start.

    `graphs` optionally supplies each sub-mesh's assembled dual graph (the
    batched `graph_for_amg` analogue): the RSB mesh engine extracts all of
    a level's subgraphs in one vectorized pass, which is much cheaper than
    re-assembling every problem here from its gid table.  Entries may be
    None; anything missing is assembled on demand."""
    B = len(vert_gids)
    seeds, warms = _normalize_batch_args(B, seeds, warms)
    graphs = [None] * B if graphs is None else list(graphs)
    if len(graphs) != B:
        raise ValueError("graphs must match the batch length")

    def graph_of(i):
        if graphs[i] is None:
            graphs[i] = _graph_from_vert_gid(np.asarray(vert_gids[i]))
        return graphs[i]

    results: list = [None] * B
    solve_ix = []
    for i, vg in enumerate(vert_gids):
        if vg.shape[0] <= _DENSE_CUTOFF:
            vec, lam = _dense_fiedler(dense_laplacian_np(graph_of(i)))
            results[i] = FiedlerResult(vec, lam, 0.0, 0, "dense")
        else:
            solve_ix.append(i)
    if not solve_ix:
        _emit_fiedler_metrics(results)
        return results

    ml_levels = {i: 0 for i in solve_ix}

    if multilevel:
        for i in solve_ix:
            if warms[i] is None:
                warms[i], ml_levels[i] = multilevel_warm_start(graph_of(i))
                if warms[i] is not None and method == "inverse":
                    warms[i] = _blend_noise(warms[i], seeds[i])

    if method == "lanczos":
        sizes = [vert_gids[i].shape[0] for i in solve_ix]
        offs, N, n_seg, seg, mask = _pack_layout(sizes, pack_slots, pack_segs)
        op = _packed_gs_laplacian([vert_gids[i] for i in solve_ix], offs, N)
        b0 = _packed_b0(sizes, offs, N, [seeds[i] for i in solve_ix],
                        [warms[i] for i in solve_ix])
        packed = _solve_packed_lanczos(
            op, offs, N, n_seg, seg, mask, b0, sizes, tol, window, max_restarts
        )
        for r, i in enumerate(solve_ix):
            results[i] = packed[r]
            results[i].levels = ml_levels[i]
        _emit_fiedler_metrics(results)
        return results

    if method != "inverse":
        raise ValueError(f"unknown fiedler method: {method}")
    _solve_inverse_buckets(
        results, solve_ix, lambda i: vert_gids[i].shape[0],
        lambda i: (next_pow2(vert_gids[i].shape[0]),),
        lambda ix, key, b_pad: _padded_gs_laplacian_batched(
            [vert_gids[i] for i in ix], key[0], b_pad
        ),
        seeds, warms, tol, graph_of=graph_of, precond=precond,
    )
    for i in solve_ix:  # deepest hierarchy used: warm start or AMG ladder
        results[i].levels = max(results[i].levels, ml_levels[i])
    _emit_fiedler_metrics(results)
    return results


# ---------------------------------------------------------------------------
# Degenerate Fiedler pairs (paper §9 future work, implemented here)
# ---------------------------------------------------------------------------

def fiedler_pair_from_graph(
    graph: Graph,
    *,
    seed: int = 0,
    tol: float = 1e-4,
    window: int = 40,
    max_restarts: int = 60,
) -> tuple[np.ndarray, np.ndarray, float, float]:
    """(y₂, y₃, λ₂, λ₃): the two smallest nontrivial eigenpairs.

    Paper §9: on topologically-checkerboard graphs λ₂ has multiplicity 2
    and single-vector Lanczos returns an arbitrary member of the eigenspace
    whose cut quality varies (45° cuts expose ≈2N faces vs N).  We find the
    second vector by SPECTRAL DEFLATION: run Lanczos again on
    `L' = L + σ·y₂y₂ᵀ` (σ > λ_max pushes y₂'s eigenvalue out of the way),
    which needs no changes to the Lanczos kernel itself.
    """
    res1 = fiedler_from_graph(graph, method="lanczos", seed=seed, tol=tol,
                              window=window, max_restarts=max_restarts)
    y1 = res1.vector / max(np.linalg.norm(res1.vector), 1e-30)

    n = graph.n
    n_pad = next_pow2(n)
    width = int(graph.degrees.max()) if graph.nnz else 1
    op = _padded_ell_laplacian(graph, n_pad, next_pow2(max(width, 2)))
    mask = jnp.asarray((np.arange(n_pad) < n).astype(np.float32))
    y1p = jnp.asarray(np.pad(y1.astype(np.float32), (0, n_pad - n)))
    # Gershgorin bound on λ_max; σ above it exiles y₂'s eigenvalue
    sigma = 4.0 * float(np.max(np.asarray(op.diag))) + 1.0

    def deflated(x):
        return op.apply(x) + sigma * y1p * jnp.vdot(y1p, x)

    y, info = lanczos_fiedler(
        deflated, n_pad, mask=mask, key=jax.random.PRNGKey(seed + 1),
        window=window, max_restarts=max_restarts, tol=tol,
    )
    y2 = np.asarray(y[:n])
    y2 = y2 - y1 * float(y1 @ y2)          # exact orthogonality polish
    y2 /= max(np.linalg.norm(y2), 1e-30)
    return y1, y2, res1.eigenvalue, info.eigenvalue


def best_cut_in_pair(
    graph: Graph,
    y1: np.ndarray,
    y2: np.ndarray,
    *,
    n_theta: int = 16,
    weights: np.ndarray | None = None,
) -> tuple[np.ndarray, float, float]:
    """Paper §9: sweep θ over span{y₂, y₃} and keep the balanced bisection
    with the minimum ω-cut.  Returns (fiedler-like vector, θ, cut)."""
    w = np.ones(graph.n) if weights is None else np.asarray(weights, np.float64)
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    best = (None, 0.0, np.inf)
    for theta in np.linspace(0.0, np.pi, n_theta, endpoint=False):
        v = np.cos(theta) * y1 + np.sin(theta) * y2
        order = np.argsort(v, kind="stable")
        half = np.zeros(graph.n, dtype=bool)
        cw = np.cumsum(w[order])
        k = int(np.searchsorted(cw - w[order] / 2, cw[-1] / 2)) + 1
        half[order[:k]] = True
        cut = float(ew[half[rows] != half[cols]].sum() / 2.0)
        if cut < best[2]:
            best = (v, float(theta), cut)
    return best
