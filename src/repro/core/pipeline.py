"""Composable partition pipeline: pre → bisect → post.

parRSB's quality claims rest on a *pipeline*, not on raw bisection labels:
geometric pre-partitioning, spectral bisection on the dual graph, then
post-processing that repairs disconnected parts and smooths boundaries.
This module turns that shape into the front door of the partitioning
stack:

* :class:`PartitionPipeline` — three stage slots.
  - ``pre``    ∈ {"rcb", "rib", "sfc", "none"}.  For spectral bisect
    stages, "rcb"/"rib" select the *per-level* geometric reordering the
    RSB drivers apply at every tree node (paper §8 — threaded through as
    the drivers' ``pre=``, because the reorder must follow the recursion);
    "sfc" applies ONE global space-filling-curve permutation up front (the
    ordering bootstrap for the order-following multilevel hierarchy).
    Geometric bisect stages are their own geometry and ignore ``pre``.
  - ``bisect`` ∈ {"rsb-batched", "rsb-recursive", "multilevel", "rcb",
    "rib", "sfc", "random"} — a registered stage producing the labels (the
    geometric partitioners are ordinary stages here, not special cases;
    "multilevel" is the METIS-style coarsen→partition→prolong+refine
    V-cycle in :mod:`repro.core.multilevel` — no eigensolves on the fine
    graph, the raw-speed engine at scale).
  - ``post``   — an ordered tuple of registered refiners, by default
    ``("repair", "refine")``: connected-component repair then greedy
    weighted FM boundary sweeps (:mod:`repro.core.refine`), both
    cut-non-increasing.  ``("repair", "kway")`` swaps the greedy sweeps
    for the hill-climbing k-way FM (:mod:`repro.core.kway` — negative-gain
    prefixes, rollback to the best prefix).  The "refine"/"kway" stages
    close with a repair pass so the zero-disconnected-parts invariant
    survives articulation moves.  One balance corridor — computed from the
    part weights the chain starts with — governs the whole chain
    (:func:`run_post_stages`).

* :class:`PartitionContext` — what flows through the stages: the
  mesh/graph, coords, weights, the evolving ``parts``, the
  :class:`~repro.core.rsb.RSBReport` (whose ``post`` section the post
  stages fill in), and one :class:`StageRecord` per stage with wall-clock
  and stage-specific info.  Consumers that want more than labels
  (``plan_halo_sharding``, the benchmark tables, the smoke gate) take the
  context itself.

* :func:`partition` — the compatibility front door `rsb.partition`
  forwards to.  It builds a pipeline from the classic keyword surface
  (``partitioner=``, ``engine=``, plus the new ``refine=`` escape hatch,
  default on for RSB) and returns only the label array.  Stage kwargs are
  routed explicitly and unknown keys raise — ``sfc_parts`` no longer
  silently drops ``curve``/``bits``.

Adding a quality optimization is now "register a stage", not "grow the
driver": see ``register_post_stage`` and the README's stage contract.
"""

from __future__ import annotations

import contextlib
import dataclasses
import inspect
import os

import numpy as np

from repro import obs
from repro.core.kway import kway_stage
from repro.core.refine import (
    PostStats,
    balance_corridor,
    refine_stage,
    repair_components,
)
from repro.core.rsb import RSBReport, rsb_partition_graph, rsb_partition_mesh
from repro.guard import chaos
from repro.guard.errors import GuardReport
from repro.guard.policy import GuardPolicy, check_output, enforce_output
from repro.guard.validate import (
    component_labels,
    pack_components,
    proportional_budgets,
    validate_graph,
    validate_mesh,
    validate_nparts,
)
from repro.mesh.graphs import Graph, dual_graph_from_incidence


@dataclasses.dataclass
class StageRecord:
    """One executed stage: where the wall-clock went and what it did."""

    kind: str          # "pre" | "bisect" | "post"
    name: str
    seconds: float
    info: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "name": self.name,
                "seconds": self.seconds, **self.info}

    @classmethod
    def from_span(cls, span, kind: str, name: str, info: dict | None = None):
        """Derive the record from a completed obs span (single source of
        wall-clock truth when tracing is active)."""
        return cls(kind=kind, name=name, seconds=span.seconds,
                   info=dict(info or {}))


@dataclasses.dataclass
class PartitionContext:
    """State threaded through the pipeline stages."""

    nparts: int
    mesh: object | None = None          # HexMesh input (None for graphs)
    graph: Graph | None = None          # dual graph (built lazily for meshes)
    coords: np.ndarray | None = None
    weights: np.ndarray | None = None
    parts: np.ndarray | None = None     # current labels (post stages mutate)
    parts_raw: np.ndarray | None = None  # bisect output, before any post stage
    report: RSBReport | None = None
    stages: list = dataclasses.field(default_factory=list)  # [StageRecord]
    trace: object | None = None          # obs.Span root (None: REPRO_OBS=off)
    config: dict = dataclasses.field(default_factory=dict)  # pipeline shape

    @property
    def n(self) -> int:
        return self.mesh.nelems if self.mesh is not None else self.graph.n

    def require_graph(self) -> Graph:
        """The dual graph — assembled on first use for mesh inputs."""
        if self.graph is None:
            m = self.mesh
            self.graph = dual_graph_from_incidence(m.vert_gid, m.n_vert,
                                                   m.nelems)
        return self.graph

    def stage_seconds(self, kind: str | None = None) -> float:
        return sum(s.seconds for s in self.stages
                   if kind is None or s.kind == kind)

    @property
    def seconds(self) -> float:
        return self.stage_seconds()

    def stats(self) -> dict:
        """JSON-able run summary (benchmark rows, experiment records)."""
        out = {
            "nparts": self.nparts,
            "n": self.n,
            "seconds": self.seconds,
            "stages": [s.to_dict() for s in self.stages],
        }
        if self.report is not None and self.report.post is not None:
            out["post"] = self.report.post.row()
        return out

    def export_manifest(self, path: str | None = None, *,
                        name: str = "partition",
                        runs_dir: str = "runs") -> str | None:
        """Write this run's JSONL manifest (span tree + counters + config
        + git SHA).  Returns the path, or None when no trace was recorded
        (``REPRO_OBS=off``)."""
        if self.trace is None:
            return None
        if path is None:
            path = obs.run_path(runs_dir, name)
        return obs.write_manifest(self.trace, path, name=name,
                                  config=self.config)

    def export_trace_events(self, path: str) -> str | None:
        """Write the Chrome/Perfetto ``trace_event`` JSON for this run;
        None when no trace was recorded."""
        if self.trace is None:
            return None
        return obs.write_trace_events(self.trace, path)


# ---------------------------------------------------------------------------
# Stage registries
# ---------------------------------------------------------------------------

PRE_STAGES = ("rcb", "rib", "sfc", "none")

_BISECT_STAGES: dict = {}
_POST_STAGES: dict = {}


def register_bisect_stage(name: str, fn) -> None:
    """Register ``fn(ctx, pre, **kw) -> (parts, RSBReport | None)``.

    ``pre`` is the pipeline's pre-stage hint ("rcb"/"rib"/None) for stages
    that thread a per-level reordering; geometric stages may ignore it.
    """
    _BISECT_STAGES[name] = fn


def register_post_stage(name: str, fn) -> None:
    """Register ``fn(graph, parts, nparts, *, weights=None, ...) ->
    (parts, PostStats)``.  The stage must be cut-non-increasing and must
    not change the label domain ``0..nparts-1``.  The pipeline's
    ``post_kw`` is filtered against the stage's signature (declare the
    keywords you consume — e.g. "repair" takes ``balance_tol`` but not
    ``sweeps``; a ``**kw`` catch-all receives everything)."""
    _POST_STAGES[name] = fn


def bisect_stage_names() -> tuple:
    return tuple(sorted(_BISECT_STAGES))


def post_stage_names() -> tuple:
    return tuple(sorted(_POST_STAGES))


def _rsb_stage(engine):
    def stage(ctx: PartitionContext, pre, **kw):
        if ctx.mesh is not None:
            if engine == "batched":
                # The batched mesh driver only assembles the dual graph and
                # delegates to the graph driver; assembling through the
                # context instead builds the graph ONCE per run — the post
                # stages (and any metrics consumer) reuse it.
                laplacian = kw.pop("laplacian", "weighted")
                if laplacian not in ("weighted", "unweighted"):
                    raise ValueError(laplacian)
                return rsb_partition_graph(
                    ctx.require_graph(), ctx.nparts, coords=ctx.coords,
                    weights=ctx.weights, pre=pre, engine=engine, **kw)
            # The recursive mesh driver reads coords/weights off the mesh;
            # honor caller overrides by handing it an overridden copy so
            # both engines balance the same weights.
            mesh = ctx.mesh
            if ctx.coords is not mesh.coords or ctx.weights is not mesh.weights:
                mesh = dataclasses.replace(
                    mesh, coords=np.asarray(ctx.coords, np.float64),
                    weights=np.asarray(ctx.weights, np.float64))
            return rsb_partition_mesh(mesh, ctx.nparts, pre=pre,
                                      engine=engine, **kw)
        return rsb_partition_graph(ctx.require_graph(), ctx.nparts,
                                   coords=ctx.coords, weights=ctx.weights,
                                   pre=pre, engine=engine, **kw)
    return stage


def _geometric_stage(fn):
    def stage(ctx: PartitionContext, pre, **kw):
        if ctx.coords is None:
            raise ValueError("geometric bisect stages need coords")
        return fn(ctx.coords, ctx.nparts, ctx.weights, **kw), None
    return stage


def _random_stage(ctx: PartitionContext, pre, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    return rng.permutation(np.arange(ctx.n) % ctx.nparts), None


def _multilevel_stage(ctx: PartitionContext, pre, **kw):
    """METIS-style multilevel k-way V-cycle (repro.core.multilevel):
    coarsen → partition-coarsest → prolong+refine.  Purely combinatorial —
    the ``pre`` reorder hint is irrelevant (matching is order-free)."""
    from repro.core.multilevel import multilevel_partition

    return multilevel_partition(ctx.require_graph(), ctx.nparts,
                                weights=ctx.weights, **kw)


def _stage_kw(fn, post_kw: dict) -> dict:
    """Filter ``post_kw`` to the keywords ``fn``'s signature accepts
    (everything passes through a ``**kw`` catch-all)."""
    params = inspect.signature(fn).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return dict(post_kw)
    return {k: v for k, v in post_kw.items() if k in params}


def _refine_sharded_stage(graph, parts, nparts, *, weights=None, sweeps=4,
                          balance_tol=0.05, corridor=None, backend="auto",
                          guard=None):
    """Device-resident sharded boundary refinement (repro.dist).  The
    signature mirrors dist.refine_sharded.refine_sharded_stage so
    ``_stage_kw`` filters correctly; the import is lazy because the dist
    layer imports this module's PartitionContext."""
    from repro.dist.refine_sharded import refine_sharded_stage
    return refine_sharded_stage(graph, parts, nparts, weights=weights,
                                sweeps=sweeps, balance_tol=balance_tol,
                                corridor=corridor, backend=backend,
                                guard=guard)


def _kway_sharded_stage(graph, parts, nparts, *, weights=None, sweeps=4,
                        passes=2, balance_tol=0.05, corridor=None,
                        backend="auto", guard=None):
    """Sharded sweeps + host boundary k-way polish (repro.dist)."""
    from repro.dist.refine_sharded import kway_sharded_stage
    return kway_sharded_stage(graph, parts, nparts, weights=weights,
                              sweeps=sweeps, passes=passes,
                              balance_tol=balance_tol, corridor=corridor,
                              backend=backend, guard=guard)


def _register_builtin_stages() -> None:
    from repro.core.rcb import rcb_parts, rib_parts
    from repro.core.sfc import sfc_parts

    register_bisect_stage("rsb-batched", _rsb_stage("batched"))
    register_bisect_stage("rsb-recursive", _rsb_stage("recursive"))
    register_bisect_stage("rcb", _geometric_stage(
        lambda c, p, w, **kw: rcb_parts(c, p, w, **kw)))
    register_bisect_stage("rib", _geometric_stage(
        lambda c, p, w, **kw: rib_parts(c, p, w, **kw)))
    register_bisect_stage("sfc", _geometric_stage(
        lambda c, p, w, **kw: sfc_parts(c, p, w, **kw)))
    register_bisect_stage("random", _random_stage)
    register_bisect_stage("multilevel", _multilevel_stage)
    # The refine.py/kway.py functions ARE the stages (their signatures
    # declare the keywords each consumes; refine_stage and kway_stage close
    # with a repair pass so the zero-disconnected invariant survives FM
    # articulation moves).
    register_post_stage("repair", repair_components)
    register_post_stage("refine", refine_stage)
    register_post_stage("kway", kway_stage)
    register_post_stage("refine-sharded", _refine_sharded_stage)
    register_post_stage("kway-sharded", _kway_sharded_stage)


_register_builtin_stages()


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------

def _make_context(obj, nparts, coords, weights) -> PartitionContext:
    is_mesh = hasattr(obj, "vert_gid")
    if is_mesh:
        c = obj.coords if coords is None else coords
        w = obj.weights if weights is None else weights
        return PartitionContext(nparts=nparts, mesh=obj, coords=c, weights=w)
    return PartitionContext(nparts=nparts, graph=obj, coords=coords,
                            weights=weights)


def _permuted_input(ctx: PartitionContext, order: np.ndarray):
    """A new context whose input is reordered by ``order`` (pre="sfc"),
    carrying any caller coords/weights overrides along."""
    if ctx.mesh is not None:
        mesh = ctx.mesh.take(order)
        if (ctx.coords is not ctx.mesh.coords
                or ctx.weights is not ctx.mesh.weights):
            mesh = dataclasses.replace(
                mesh, coords=np.asarray(ctx.coords, np.float64)[order],
                weights=np.asarray(ctx.weights, np.float64)[order])
        return PartitionContext(nparts=ctx.nparts, mesh=mesh,
                                coords=mesh.coords, weights=mesh.weights)
    return PartitionContext(
        nparts=ctx.nparts, graph=ctx.graph.sub(order),
        coords=None if ctx.coords is None else ctx.coords[order],
        weights=None if ctx.weights is None else ctx.weights[order],
    )


def _subset_context(ctx: PartitionContext, idx: np.ndarray,
                    nparts: int) -> PartitionContext:
    """A sub-context over the nodes in ``idx`` (one connected component),
    renumbered contiguously — what the per-component bisect runs on."""
    if ctx.mesh is not None:
        mesh = ctx.mesh.take(idx)
        return PartitionContext(nparts=nparts, mesh=mesh,
                                coords=mesh.coords, weights=mesh.weights)
    return PartitionContext(
        nparts=nparts, graph=ctx.require_graph().sub(idx),
        coords=None if ctx.coords is None else ctx.coords[idx],
        weights=None if ctx.weights is None else ctx.weights[idx],
    )


def _guard_enabled(flag: bool | None) -> bool:
    """Resolve the pipeline guard switch: an explicit ``guard=`` wins;
    otherwise ``REPRO_GUARD`` (default on; off/0/false/no disable)."""
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_GUARD", "on").strip().lower()
    return env not in ("off", "0", "false", "no")


def _merge_guard(dst: GuardReport, src) -> None:
    """Fold one bisect stage's GuardReport into the pipeline-wide one
    (the RSB drivers create their own per-stage report)."""
    if src is None or src is dst:
        return
    dst.validated |= src.validated
    dst.sanitized |= src.sanitized
    dst.issues.extend(src.issues)
    dst.components = max(dst.components, src.components)
    dst.retries += src.retries
    dst.fallbacks += src.fallbacks
    dst.sanitize_fixes += src.sanitize_fixes
    dst.deadline_expired |= src.deadline_expired
    dst.degraded.extend(src.degraded)


def run_post_stages(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    post: tuple,
    *,
    weights: np.ndarray | None = None,
    post_kw: dict | None = None,
) -> tuple[np.ndarray, PostStats, list]:
    """Run an ordered chain of registered post stages over ``parts``.

    The balance corridor is computed ONCE here — from the part weights the
    chain starts with — and threaded through every stage, so a
    cap-exceeding forced move in one stage cannot widen the corridor for
    the stages after it (callers may pre-seed ``post_kw["corridor"]`` to
    pin an even earlier reference).  Returns the refined labels, the
    aggregated :class:`PostStats`, and one :class:`StageRecord` per stage.

    This is what :meth:`PartitionPipeline.run` executes after the bisect
    stage; benchmarks call it directly on a context's ``parts_raw`` to
    compare post chains (e.g. greedy vs k-way) from ONE bisection solve.
    """
    post_kw = dict(post_kw or {})
    parts = np.asarray(parts, dtype=np.int64)
    if post_kw.get("corridor") is None:
        post_kw["corridor"] = balance_corridor(
            parts, nparts, weights, post_kw.get("balance_tol", 0.05))
    corridor = post_kw["corridor"]
    agg = PostStats(corridor=tuple(corridor))
    records = []
    for i, name in enumerate(post):
        fn = _POST_STAGES[name]
        with obs.timed(f"post:{name}") as t:
            parts, stats = fn(graph, parts, nparts, weights=weights,
                              **_stage_kw(fn, post_kw))
        dt = t.seconds
        parts = np.asarray(parts, dtype=np.int64)
        agg.stages.append(name)
        agg.fragments_repaired += stats.fragments_repaired
        agg.forced_moves += stats.forced_moves
        # final state, not a sum: a later repair can clear earlier
        # stages' leftovers
        agg.unrepaired_fragments = stats.unrepaired_fragments
        agg.moves_applied += stats.moves_applied
        agg.sweeps.extend(stats.sweeps)
        if stats.kway is not None:
            agg.kway = stats.kway
        agg.seconds += dt
        records.append(StageRecord(
            kind="post", name=name, seconds=dt,
            info={"cut_before": stats.cut_before,
                  "cut_after": stats.cut_after,
                  "fragments": stats.fragments_repaired,
                  "moves": stats.moves_applied,
                  "corridor": tuple(stats.corridor)
                  if stats.corridor else None},
        ))
        if i == 0:
            agg.cut_before = stats.cut_before
        agg.cut_after = stats.cut_after
    return parts, agg, records


@dataclasses.dataclass
class PartitionPipeline:
    """pre → bisect → post, each slot a registered stage (module docstring).

    ``bisect_kw`` goes to the bisect stage verbatim; ``post_kw`` to every
    post stage, filtered against each stage's signature (the built-ins
    share the ``balance_tol`` surface; ``sweeps`` is declared — and hence
    received — by "refine" only).

    ``guard`` switches the fault-tolerance envelope (:mod:`repro.guard`):
    validation front door before ``pre``, per-component dispatch for
    disconnected inputs, a :class:`~repro.guard.policy.SolverGuard` around
    every spectral solve, and the output-invariant finalizer after
    ``post``.  ``None`` defers to ``REPRO_GUARD`` (default on).
    ``guard_kw`` parameterizes the :class:`~repro.guard.policy.GuardPolicy`
    (``sanitize``, ``max_retries``, ``switch_method``, ``deadline``,
    ``balance_tol``) plus the chaos overlay (``chaos`` — fault-site tuple —
    ``chaos_seed``, ``chaos_rate``).  A healthy guarded run returns labels
    bit-identical to ``guard=False``: the guard only *mutates* on failure.
    """

    pre: str = "rcb"
    bisect: str = "rsb-batched"
    post: tuple = ("repair", "refine")
    bisect_kw: dict = dataclasses.field(default_factory=dict)
    post_kw: dict = dataclasses.field(default_factory=dict)
    guard: bool | None = None
    guard_kw: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.pre not in PRE_STAGES:
            raise ValueError(
                f"unknown pre stage: {self.pre!r} (have {PRE_STAGES})")
        if self.bisect not in _BISECT_STAGES:
            raise ValueError(
                f"unknown bisect stage: {self.bisect!r} "
                f"(have {bisect_stage_names()})")
        self.post = tuple(self.post)
        for name in self.post:
            if name not in _POST_STAGES:
                raise ValueError(
                    f"unknown post stage: {name!r} "
                    f"(have {post_stage_names()})")

    def run(self, obj, nparts: int, *, coords: np.ndarray | None = None,
            weights: np.ndarray | None = None) -> PartitionContext:
        """Partition a HexMesh or Graph; returns the full context.

        When tracing is on (``REPRO_OBS`` unset/on) the whole run happens
        inside one ``partition`` root span — ``ctx.trace`` — with one
        child span per stage; ``ctx.export_manifest()`` serializes it, and
        setting ``REPRO_OBS_DIR`` writes a manifest there automatically.
        """
        ctx = _make_context(obj, nparts, coords, weights)
        spectral = self.bisect.startswith("rsb")
        guard_on = _guard_enabled(self.guard)
        ctx.config = {"pre": self.pre, "bisect": self.bisect,
                      "post": list(self.post), "nparts": nparts, "n": ctx.n,
                      "guard": guard_on}

        root = obs.trace("partition", nparts=nparts, n=ctx.n,
                         pre=self.pre, bisect=self.bisect,
                         post=",".join(self.post), guard=guard_on)
        with root:
            if guard_on:
                self._run_guarded(ctx, nparts, spectral)
            else:
                self._run_stages(ctx, nparts, spectral)
        if isinstance(root, obs.Span):
            ctx.trace = root
            out_dir = os.environ.get("REPRO_OBS_DIR")
            if out_dir:
                ctx.export_manifest(runs_dir=out_dir)
        return ctx

    # -- the guarded path: validate → (components?) → stages → finalize --

    def _run_guarded(self, ctx: PartitionContext, nparts: int,
                     spectral: bool) -> None:
        policy = GuardPolicy.from_kw(self.guard_kw)
        greport = GuardReport()
        sites = tuple(self.guard_kw.get("chaos") or ())
        overlay = (chaos.overlay(
            sites, seed=int(self.guard_kw.get("chaos_seed", 0)),
            rate=float(self.guard_kw.get("chaos_rate", 1.0)))
            if sites else contextlib.nullcontext())
        with overlay:
            ncomp, comp = self._validate_input(ctx, nparts, policy, greport)
            if ncomp > 1:
                self._run_components(ctx, nparts, spectral, policy,
                                     greport, comp, ncomp)
            else:
                self._run_stages(ctx, nparts, spectral, policy=policy,
                                 greport=greport)
            self._finalize(ctx, nparts, policy, greport, ncomp)

    def _validate_input(self, ctx: PartitionContext, nparts: int,
                        policy: GuardPolicy, greport: GuardReport):
        """``guard:validate`` — the implicit first stage: typed
        :class:`GuardError` in strict mode, recorded repairs in sanitize
        mode, plus component detection (disconnected inputs are handled
        downstream, never rejected here)."""
        with obs.timed("guard:validate") as t:
            validate_nparts(nparts, ctx.n)
            if ctx.mesh is not None:
                mesh = ctx.mesh
                if (ctx.coords is not mesh.coords
                        or ctx.weights is not mesh.weights):
                    mesh = dataclasses.replace(
                        mesh, coords=np.asarray(ctx.coords, np.float64),
                        weights=np.asarray(ctx.weights, np.float64))
                mesh = validate_mesh(mesh, nparts=nparts,
                                     sanitize=policy.sanitize,
                                     report=greport)
                ctx.mesh = mesh
                ctx.coords, ctx.weights = mesh.coords, mesh.weights
            else:
                g, c, w = validate_graph(
                    ctx.graph, coords=ctx.coords, weights=ctx.weights,
                    nparts=nparts, sanitize=policy.sanitize, report=greport)
                ctx.graph, ctx.coords, ctx.weights = g, c, w
            comp, ncomp = component_labels(ctx.require_graph())
            greport.components = max(greport.components, ncomp)
            if greport.sanitize_fixes:
                obs.counter_add("guard_sanitize_fixes",
                                greport.sanitize_fixes)
        ctx.config["components"] = ncomp
        ctx.stages.append(StageRecord(
            kind="guard", name="validate", seconds=t.seconds,
            info={"issues": len(greport.issues),
                  "fixes": greport.sanitize_fixes,
                  "components": ncomp},
        ))
        return ncomp, comp

    def _run_components(self, ctx: PartitionContext, nparts: int,
                        spectral: bool, policy: GuardPolicy,
                        greport: GuardReport, comp: np.ndarray,
                        ncomp: int) -> None:
        """Partition a disconnected input component by component.

        ``ncomp <= nparts``: largest-remainder part budgets per component,
        each component run through pre+bisect with its own budget; the
        post chain then runs ONCE over the full graph (no edge crosses
        components, so refinement can never merge them back).
        ``ncomp > nparts``: whole components are packed onto parts
        (greedy heaviest-first) — no bisection can improve on that without
        splitting a component across parts it shares no edge with.
        """
        w = np.ones(ctx.n) if ctx.weights is None else \
            np.asarray(ctx.weights, np.float64)
        comp_w = np.bincount(comp, weights=w, minlength=ncomp)
        with obs.timed(f"pre:{self.pre}") as t_pre:
            pass        # pre runs inside each component's sub-pipeline
        ctx.stages.append(StageRecord(
            kind="pre", name=self.pre, seconds=t_pre.seconds,
            info={"mode": "per-component", "components": ncomp}))

        parts = np.zeros(ctx.n, dtype=np.int64)
        merged = RSBReport(records=[], seconds=0.0, engine="-", pre=self.pre)
        with obs.timed(f"bisect:{self.bisect}") as t_bisect:
            if ncomp > nparts:
                parts = pack_components(comp_w, nparts)[comp]
                merged.engine = "pack-components"
                greport.degrade(f"input:packed-{ncomp}-components")
            else:
                budgets = proportional_budgets(comp_w, nparts)
                offset = 0
                for c in range(ncomp):
                    idx = np.flatnonzero(comp == c)
                    k = int(budgets[c])
                    if k <= 1 or idx.size <= 1:
                        parts[idx] = offset
                    else:
                        sub = _subset_context(ctx, idx, k)
                        self._run_stages(sub, k, spectral, policy=policy,
                                         greport=greport, with_post=False)
                        parts[idx] = offset + np.asarray(sub.parts,
                                                         np.int64)
                        for s in sub.stages:
                            s.info["component"] = c
                        ctx.stages.extend(sub.stages)
                        merged.records.extend(sub.report.records)
                        merged.engine = sub.report.engine
                    offset += k
        merged.seconds = t_bisect.seconds
        ctx.parts = parts
        ctx.parts_raw = parts.copy()
        ctx.report = merged
        ctx.stages.append(StageRecord(
            kind="bisect", name=self.bisect, seconds=t_bisect.seconds,
            info={"mode": ("pack" if ncomp > nparts else "per-component"),
                  "components": ncomp,
                  "iterations": merged.total_iterations}))

        if self.post:
            parts, agg, records = run_post_stages(
                ctx.require_graph(), ctx.parts, nparts, self.post,
                weights=ctx.weights, post_kw=self.post_kw)
            ctx.parts = parts
            ctx.stages.extend(records)
            merged.post = agg

    def _finalize(self, ctx: PartitionContext, nparts: int,
                  policy: GuardPolicy, greport: GuardReport,
                  ncomp: int) -> None:
        """``guard:finalize`` — the output-invariant closer.  Checks every
        run; *mutates* only when labels are structurally invalid or a
        degraded solve path left problems behind, so a healthy guarded run
        returns bit-identical labels to ``guard=False``."""
        with obs.timed("guard:finalize") as t:
            graph = ctx.require_graph()
            expected = max(0, ncomp - nparts)
            problems = check_output(
                graph, ctx.parts, nparts, weights=ctx.weights,
                balance_tol=policy.balance_tol,
                expected_disconnected=expected)
            structural = any(p.startswith("labels") for p in problems)
            degraded = bool(greport.fallbacks or greport.deadline_expired)
            enforced = False
            if structural or (problems and degraded):
                ctx.parts = enforce_output(
                    graph, ctx.parts, nparts, weights=ctx.weights,
                    balance_tol=policy.balance_tol, report=greport)
                enforced = True
                problems = check_output(
                    graph, ctx.parts, nparts, weights=ctx.weights,
                    balance_tol=policy.balance_tol,
                    expected_disconnected=expected)
        ctx.stages.append(StageRecord(
            kind="guard", name="finalize", seconds=t.seconds,
            info={"problems": list(problems), "enforced": enforced,
                  "retries": greport.retries,
                  "fallbacks": greport.fallbacks},
        ))
        if ctx.report is not None:
            ctx.report.guard = greport

    def _run_stages(self, ctx: PartitionContext, nparts: int,
                    spectral: bool, *, policy: GuardPolicy | None = None,
                    greport: GuardReport | None = None,
                    with_post: bool = True) -> None:
        # --- pre: reorder hint (rcb/rib) or one-shot permutation (sfc)
        with obs.timed(f"pre:{self.pre}") as t_pre:
            hint, order = None, None
            run_ctx = ctx
            if spectral and self.pre in ("rcb", "rib"):
                hint = self.pre  # per-level reorder, applied inside driver
            elif spectral and self.pre == "sfc":
                if ctx.coords is not None:
                    from repro.core.sfc import sfc_order

                    order = sfc_order(ctx.coords)
                    run_ctx = _permuted_input(ctx, order)
        ctx.stages.append(StageRecord(
            kind="pre", name=self.pre, seconds=t_pre.seconds,
            info={"mode": ("per-level" if hint else
                           "permute" if order is not None else "noop")},
        ))

        # --- bisect
        bkw = dict(self.bisect_kw)
        if policy is not None and spectral:
            bkw.setdefault("guard", policy)
        with obs.timed(f"bisect:{self.bisect}") as t_bisect:
            parts, report = _BISECT_STAGES[self.bisect](run_ctx, hint, **bkw)
        dt = t_bisect.seconds
        if order is not None:   # map labels back to the caller's order
            unperm = np.empty_like(parts)
            unperm[order] = parts
            parts = unperm
            if ctx.graph is None and run_ctx.graph is not None:
                # The bisect stage assembled the permuted dual graph; one
                # cheap CSR relabel recovers the caller-order graph, so the
                # post stages don't pay a second incidence-table assembly.
                ctx.graph = run_ctx.graph.sub(np.argsort(order))
        if report is None:
            report = RSBReport(records=[], seconds=dt, engine="-",
                               pre=self.pre)
        if greport is not None:
            _merge_guard(greport, report.guard)
        ctx.parts = np.asarray(parts, dtype=np.int64)
        ctx.parts_raw = ctx.parts.copy()
        ctx.report = report
        ctx.stages.append(StageRecord(
            kind="bisect", name=self.bisect, seconds=dt,
            info={"iterations": report.total_iterations},
        ))

        # --- post (one corridor per chain, fixed from the bisection's
        # part weights — see run_post_stages)
        if self.post and with_post:
            post_kw = dict(self.post_kw)
            if policy is not None and "guard" not in post_kw:
                # Stages that declare a ``guard`` keyword (the sharded
                # refinement pair) get the stage-deadline envelope; the
                # host stages simply never see it (_stage_kw filters).
                from repro.guard.policy import SolverGuard
                post_kw["guard"] = SolverGuard(
                    policy, seed=0, method="post", report=greport)
            parts, agg, records = run_post_stages(
                ctx.require_graph(), ctx.parts, nparts, self.post,
                weights=ctx.weights, post_kw=post_kw)
            ctx.parts = parts
            ctx.stages.extend(records)
            report.post = agg


# ---------------------------------------------------------------------------
# Front door (the classic keyword surface, now a pipeline builder)
# ---------------------------------------------------------------------------

_ENGINE_TO_BISECT = {"batched": "rsb-batched", "recursive": "rsb-recursive"}

# Explicit per-stage keyword routing: the old front door forwarded **kw
# blindly, silently dropping sfc's curve/bits and rcb/rib's everything.
_RSB_KW = {"method", "pre", "tol", "window", "max_restarts", "seed",
           "warm_start", "multilevel", "fine_restarts", "precond"}
_RSB_MESH_KW = _RSB_KW | {"laplacian"}
_RSB_GRAPH_KW = _RSB_KW | {"use_kernel"}
_GEOM_KW = {"rcb": set(), "rib": set(), "sfc": {"curve", "bits"},
            "random": {"seed"}}
_ML_KW = {"coarse_factor", "coarse_solver", "refine_passes", "stall",
          "coarse_passes", "seed", "max_levels", "min_coarsen_ratio"}

_REFINE_SPECS = {
    "none": (), "repair": ("repair",), "refine": ("refine",),
    "repair+refine": ("repair", "refine"),
    # Hill-climbing k-way FM (repro.core.kway): negative-gain prefixes with
    # rollback to the best prefix.  Greedy "repair+refine" stays the
    # default until the bench gate proves k-way ≥ greedy across suites.
    "kway": ("kway",), "repair+kway": ("repair", "kway"),
    # Device-resident sharded refinement (repro.dist.refine_sharded): one
    # boundary-label all_gather per sweep, Pallas segment-sum gain tables.
    "refine-sharded": ("refine-sharded",),
    "repair+refine-sharded": ("repair", "refine-sharded"),
    "kway-sharded": ("kway-sharded",),
    "repair+kway-sharded": ("repair", "kway-sharded"),
}


def parse_refine(refine) -> tuple:
    """``refine=`` spec → post-stage tuple ("none" is the escape hatch)."""
    if refine is None:
        return _REFINE_SPECS["repair+refine"]
    if isinstance(refine, str):
        try:
            return _REFINE_SPECS[refine]
        except KeyError:
            raise ValueError(
                f"unknown refine spec: {refine!r} "
                f"(have {tuple(_REFINE_SPECS)} or a stage tuple)") from None
    return tuple(refine)


def _check_kw(kw: dict, allowed: set, who: str) -> None:
    unknown = set(kw) - allowed
    if unknown:
        raise TypeError(
            f"unknown keyword(s) for partitioner {who!r}: "
            f"{sorted(unknown)} (allowed: {sorted(allowed)})")


def partition(
    obj,
    nparts: int,
    *,
    partitioner: str = "rsb",
    coords: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    engine: str = "batched",
    refine: str | tuple | None = None,
    refine_sweeps: int = 4,
    balance_tol: float = 0.05,
    guard: bool | None = None,
    guard_kw: dict | None = None,
    **kw,
) -> np.ndarray:
    """Uniform front door: partitioner ∈ {rsb, rsb_inverse, multilevel,
    rcb, rib, sfc, random}, built as a :class:`PartitionPipeline` run.

    ``refine`` selects the post stages: "repair+refine" (the default for
    the RSB family — parRSB ships repaired/smoothed labels, not raw
    bisections), "repair+kway" (hill-climbing k-way FM), "repair",
    "refine", "kway", "none", or an explicit stage tuple.
    Geometric/random baselines default to "none" so they stay raw
    comparison points; pass ``refine=`` explicitly to post-process them.
    ``refine_sweeps``/``balance_tol`` parameterize the post stages.

    ``engine`` selects the RSB driver ("batched"/"recursive"); remaining
    keywords are routed to the selected stage and unknown keys raise.
    ``guard``/``guard_kw`` switch and parameterize the fault-tolerance
    envelope (validation, solver escalation, output finalizer — see
    :class:`PartitionPipeline`); the default defers to ``REPRO_GUARD``.
    Use :meth:`PartitionPipeline.run` directly to get the full context
    (report with post section, per-stage timings) instead of labels only.
    """
    is_mesh = hasattr(obj, "vert_gid")
    post_kw = dict(sweeps=refine_sweeps, balance_tol=balance_tol)
    gkw = dict(guard=guard, guard_kw=dict(guard_kw or {}))

    if partitioner in ("rsb", "rsb_lanczos", "rsb_inverse"):
        if engine not in _ENGINE_TO_BISECT:
            raise ValueError(f"unknown engine: {engine}")
        if partitioner == "rsb_inverse":
            kw["method"] = "inverse"
        _check_kw(kw, _RSB_MESH_KW if is_mesh else _RSB_GRAPH_KW, partitioner)
        pre = kw.pop("pre", "rcb")
        pipe = PartitionPipeline(
            pre=pre or "none", bisect=_ENGINE_TO_BISECT[engine],
            post=parse_refine(refine), bisect_kw=kw, post_kw=post_kw, **gkw,
        )
    elif partitioner == "multilevel":
        # The V-cycle's default post chain is repair+kway: its bisect cost
        # is so small that the deeper hill-climbing chain is free by
        # comparison, and the V-cycle's own per-level sweeps are bounded
        # (boundary-only, stall-capped) rather than exhaustive.
        _check_kw(kw, _ML_KW, partitioner)
        pipe = PartitionPipeline(
            pre="none", bisect="multilevel",
            post=parse_refine("repair+kway" if refine is None else refine),
            bisect_kw=dict(balance_tol=balance_tol, **kw), post_kw=post_kw,
            **gkw,
        )
    elif partitioner in _GEOM_KW:
        _check_kw(kw, _GEOM_KW[partitioner], partitioner)
        pipe = PartitionPipeline(
            pre="none", bisect=partitioner,
            post=parse_refine("none" if refine is None else refine),
            bisect_kw=kw, post_kw=post_kw, **gkw,
        )
    else:
        raise ValueError(f"unknown partitioner: {partitioner}")

    return pipe.run(obj, nparts, coords=coords, weights=weights).parts
