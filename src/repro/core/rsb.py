"""Recursive Spectral Bisection driver (paper Algorithm 1).

Host-orchestrated recursion (the bisection tree), jitted numerics per node:

  1. (optional) geometric pre-partitioning — RCB/RIB reorder of the active
     elements (paper §8: ≈2× Lanczos speedup; also seeds AMG aggregation),
  2. Fiedler vector of the active sub-mesh/sub-graph (Lanczos or
     AMG-preconditioned inverse iteration),
  3. sort by Fiedler component, split proportionally to ⌊P/2⌋ / ⌈P/2⌉
     (element weights honored — multi-material support),
  4. recurse until each part maps to a single processor.

Load-balance invariant (paper Eq. 2.6): with unit weights, part sizes
differ by at most one element at every level — asserted in tests.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.fiedler import fiedler_from_graph, fiedler_from_mesh
from repro.core.rcb import rcb_order, rib_order
from repro.mesh.graphs import Graph, dual_graph_from_incidence


@dataclasses.dataclass
class BisectionRecord:
    level: int
    size: int
    nparts: int
    method: str
    iterations: int
    eigenvalue: float
    residual: float
    seconds: float


@dataclasses.dataclass
class RSBReport:
    records: list
    seconds: float

    @property
    def total_iterations(self) -> int:
        return sum(r.iterations for r in self.records)


def _proportional_split(keys: np.ndarray, weights: np.ndarray, n_left: int,
                        n_total: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(keys, kind="stable")
    cw = np.cumsum(weights[order])
    target = cw[-1] * (n_left / n_total)
    k = int(np.searchsorted(cw, target, side="left")) + 1
    k = min(max(k, 1), keys.size - 1)
    return order[:k], order[k:]


def rsb_partition_mesh(
    mesh,
    nparts: int,
    *,
    method: str = "lanczos",
    laplacian: str = "weighted",
    pre: str | None = "rcb",
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    seed: int = 0,
    warm_start: bool = False,
) -> tuple[np.ndarray, RSBReport]:
    """Partition a HexMesh into `nparts` via RSB on its dual graph.

    warm_start=True (beyond-paper) seeds the Fiedler solve with the
    centroid coordinate along the subset's longest axis — an excellent
    initial guess on mesh-like graphs that cuts Lanczos restarts."""
    if laplacian not in ("weighted", "unweighted"):
        raise ValueError(laplacian)
    records: list[BisectionRecord] = []
    parts = np.zeros(mesh.nelems, dtype=np.int64)
    t0 = time.perf_counter()

    def rec(idx: np.ndarray, p_lo: int, p_hi: int, level: int) -> None:
        np_here = p_hi - p_lo
        if np_here <= 1 or idx.size <= 1:
            parts[idx] = p_lo
            return
        # Geometric pre-partitioning: make active data locally contiguous.
        if pre in ("rcb", "rib"):
            fn = rcb_order if pre == "rcb" else rib_order
            idx = idx[fn(mesh.coords[idx], mesh.weights[idx])]

        sub_vg = mesh.vert_gid[idx]
        graph_amg = None
        order_amg = None
        if method == "inverse":
            uniq, inv = np.unique(sub_vg, return_inverse=True)
            graph_amg = dual_graph_from_incidence(
                inv.reshape(sub_vg.shape), uniq.size, idx.size
            )
            order_amg = np.arange(idx.size)  # already RCB-ordered above
        warm = None
        if warm_start:
            c = mesh.coords[idx]
            ax = int(np.argmax(c.max(0) - c.min(0)))
            warm = (c[:, ax] - c[:, ax].mean()).astype(np.float32)
        t = time.perf_counter()
        res = fiedler_from_mesh(
            sub_vg, method=method, graph_for_amg=graph_amg, order=order_amg,
            seed=seed + level, tol=tol, window=window, max_restarts=max_restarts,
            warm=warm,
        )
        dt = time.perf_counter() - t
        records.append(BisectionRecord(
            level=level, size=int(idx.size), nparts=np_here, method=res.method,
            iterations=res.iterations, eigenvalue=res.eigenvalue,
            residual=res.residual, seconds=dt,
        ))
        n_left = np_here // 2
        lo, hi = _proportional_split(res.vector, mesh.weights[idx], n_left, np_here)
        rec(idx[lo], p_lo, p_lo + n_left, level + 1)
        rec(idx[hi], p_lo + n_left, p_hi, level + 1)

    rec(np.arange(mesh.nelems, dtype=np.int64), 0, nparts, 0)
    return parts, RSBReport(records=records, seconds=time.perf_counter() - t0)


def rsb_partition_graph(
    graph: Graph,
    nparts: int,
    *,
    coords: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    method: str = "lanczos",
    pre: str | None = None,
    tol: float = 1e-3,
    window: int = 30,
    max_restarts: int = 50,
    seed: int = 0,
    use_kernel: bool = False,
) -> tuple[np.ndarray, RSBReport]:
    """Partition a generic graph (assembled ELL Laplacian) via RSB.

    This is the entry point the framework's partition-aware GNN sharding
    uses: feed the returned `parts` to
    `repro.dist.partition_aware.plan_halo_sharding` to get the shard_map
    halo plan whose all_gather volume is proportional to this cut.
    """
    n = graph.n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    records: list[BisectionRecord] = []
    parts = np.zeros(n, dtype=np.int64)
    t0 = time.perf_counter()

    def rec(g: Graph, idx: np.ndarray, p_lo: int, p_hi: int, level: int) -> None:
        np_here = p_hi - p_lo
        if np_here <= 1 or idx.size <= 1:
            parts[idx] = p_lo
            return
        if pre in ("rcb", "rib") and coords is not None:
            fn = rcb_order if pre == "rcb" else rib_order
            perm = fn(coords[idx], w[idx])
            idx = idx[perm]
            g = g.sub(perm)
        t = time.perf_counter()
        res = fiedler_from_graph(
            g, method=method, order=None, seed=seed + level, tol=tol,
            window=window, max_restarts=max_restarts, use_kernel=use_kernel,
        )
        dt = time.perf_counter() - t
        records.append(BisectionRecord(
            level=level, size=int(idx.size), nparts=np_here, method=res.method,
            iterations=res.iterations, eigenvalue=res.eigenvalue,
            residual=res.residual, seconds=dt,
        ))
        n_left = np_here // 2
        lo, hi = _proportional_split(res.vector, w[idx], n_left, np_here)
        rec(g.sub(lo), idx[lo], p_lo, p_lo + n_left, level + 1)
        rec(g.sub(hi), idx[hi], p_lo + n_left, p_hi, level + 1)

    rec(graph, np.arange(n, dtype=np.int64), 0, nparts, 0)
    return parts, RSBReport(records=records, seconds=time.perf_counter() - t0)


def partition(
    obj,
    nparts: int,
    *,
    partitioner: str = "rsb",
    coords: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    **kw,
) -> np.ndarray:
    """Uniform front door: partitioner ∈ {rsb, rsb_inverse, rcb, rib, sfc, random}."""
    from repro.core.rcb import rcb_parts, rib_parts
    from repro.core.sfc import sfc_parts

    is_mesh = hasattr(obj, "vert_gid")
    c = obj.coords if is_mesh and coords is None else coords
    w = obj.weights if is_mesh and weights is None else weights
    n = obj.nelems if is_mesh else obj.n

    if partitioner in ("rsb", "rsb_lanczos", "rsb_inverse"):
        method = "inverse" if partitioner == "rsb_inverse" else kw.pop("method", "lanczos")
        if is_mesh:
            parts, _ = rsb_partition_mesh(obj, nparts, method=method, **kw)
        else:
            parts, _ = rsb_partition_graph(
                obj, nparts, coords=c, weights=w, method=method, **kw
            )
        return parts
    if partitioner == "rcb":
        return rcb_parts(c, nparts, w)
    if partitioner == "rib":
        return rib_parts(c, nparts, w)
    if partitioner == "sfc":
        return sfc_parts(c, nparts, w)
    if partitioner == "random":
        rng = np.random.default_rng(kw.get("seed", 0))
        return rng.permutation(np.arange(n) % nparts)
    raise ValueError(f"unknown partitioner: {partitioner}")
