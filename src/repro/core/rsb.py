"""Recursive Spectral Bisection driver (paper Algorithm 1).

Two engines share the same math:

**engine="batched"** (default) — the level-synchronous engine.  All 2^L
subdomains at level L of the bisection tree are independent (the paper
splits communicators so their Fiedler solves run concurrently; Sphynx maps
the same structure onto accelerator-batched linear algebra).  Each level:

  1. (optional) geometric pre-partitioning — RCB/RIB reorder of every
     active node's elements (paper §8: ≈2× Lanczos speedup),
  2. every active subproblem is padded into a power-of-two
     (n_pad, width_pad) **shape bucket** and the whole bucket runs ONE
     jitted, vmapped Fiedler solve — batched ELL / gather-scatter Laplacian
     applies, batched Lanczos windows (or Jacobi-preconditioned inverse
     iteration with per-element-stopping batched flexcg), per-subproblem
     masks and per-subproblem convergence flags,
  3. a proportional split per node (sort by Fiedler component, cut at
     ⌊P/2⌋ / ⌈P/2⌉ of the weight — multi-material support) emits the next
     level's subgraphs via one vectorized multi-subgraph extraction.

Because the batched operators are *pytrees* handed to jit as traced
arguments, the run compiles one trace per shape bucket — a constant number
per run — instead of one trace per tree node.  That is what turns the
hardware-saturating batched matvecs into wall-clock wins, and the level
structure is exactly what `repro.dist` needs to later shard levels across
devices.

**Multilevel acceleration** (default on): every Fiedler solve runs
coarse-to-fine.  A Galerkin hierarchy per subproblem (host-built, the
`amg_setup` pairwise aggregation over the RCB ordering) is solved densely
at the coarsest level and prolonged cascadically to seed the device solve,
which is capped at `fine_restarts` refinement restarts over a shallower
Lanczos window; method="inverse" can additionally swap the Jacobi inner
preconditioner for the packed `BatchedAMG` V-cycle (`precond="amg"`).

**engine="recursive"** — the host-side depth-first recursion (one jitted
solve per tree node), kept for parity testing and as the AMG-preconditioned
inverse-iteration reference (AMG hierarchies are per-graph host state).

Load-balance invariant (paper Eq. 2.6): with unit weights, part sizes
differ by at most one element at every level — asserted in tests for both
engines.  Per-node Lanczos start vectors are seeded deterministically from
(seed, level, p_lo) so sibling subtrees never share a start vector.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.fiedler import (
    _DENSE_CUTOFF,
    fiedler_from_graph,
    fiedler_from_graph_batched,
    fiedler_from_mesh,
    next_pow2,
)
from repro.core.rcb import rcb_order, rib_order
from repro.guard.policy import SolverGuard
from repro.mesh.graphs import Graph, dual_graph_from_incidence, extract_subgraphs

_ENGINES = ("batched", "recursive")


@dataclasses.dataclass
class BisectionRecord:
    level: int
    size: int
    nparts: int
    method: str
    iterations: int
    eigenvalue: float
    residual: float
    seconds: float
    levels: int = 0    # multilevel hierarchy depth (warm start or AMG); 0 = none
    split_seconds: float = 0.0   # this node's sort/split + child extraction
    breakdown: bool = False      # solver breakdown (or guard fallback) here

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LevelRecord:
    """One tree level of the engine: how many nodes were solved together,
    in which shape buckets, and where the time went."""

    level: int
    n_nodes: int             # nodes solved at this level
    total_size: int          # Σ elements over those nodes
    buckets: list            # [(count, n_pad)] — n_pad 0 = dense tail
    iterations: int          # Σ per-node restarts / outer iterations
    solve_seconds: float     # Fiedler solves (batched: the bucket solves)
    split_seconds: float     # sort/split + child extraction

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RSBReport:
    records: list
    seconds: float
    levels: list = dataclasses.field(default_factory=list)
    engine: str = "recursive"
    pre: str = "none"          # geometric pre-partitioning used ("rcb"/"rib")
    precond: str = "none"      # inverse-iteration preconditioner ("jacobi"/"amg")
    multilevel: bool = False   # coarse-to-fine warm starts active
    post: object = None        # refine.PostStats once pipeline post stages ran
    ml: object = None          # multilevel.MultilevelStats (V-cycle bisect)
    guard: object = None       # guard.GuardReport: what degraded and why

    @property
    def total_iterations(self) -> int:
        return sum(r.iterations for r in self.records)

    @property
    def precond_levels(self) -> int:
        """Deepest multilevel hierarchy used by any solve (warm-start
        Galerkin ladder for Lanczos, AMG ladder for inverse iteration)."""
        return max((r.levels for r in self.records), default=0)

    def to_dict(self) -> dict:
        """JSON-able form — the one the benchmark rows and run manifests
        serialize instead of re-extracting fields by hand."""
        return {
            "engine": self.engine,
            "pre": self.pre,
            "precond": self.precond,
            "multilevel": self.multilevel,
            "seconds": self.seconds,
            "total_iterations": self.total_iterations,
            "precond_levels": self.precond_levels,
            "records": [r.to_dict() for r in self.records],
            "levels": [lv.to_dict() for lv in self.levels],
            "post": self.post.to_dict() if self.post is not None else None,
            "ml": self.ml.to_dict() if self.ml is not None else None,
            "guard": self.guard.to_dict() if self.guard is not None else None,
        }


def _node_seed(seed: int, level: int, p_lo: int, attempt: int = 0) -> int:
    """Deterministic per-node seed.  `seed + level` alone would hand every
    sibling at a level the identical Lanczos start vector; mixing in p_lo
    (the node's part range origin — unique per node within a level)
    decorrelates them.  `attempt` decorrelates guard retries: every retry
    (and its warm-start noise blend) draws a fresh start vector instead of
    replaying the identical failing solve; attempt=0 leaves the seed
    bit-identical to the pre-guard hash."""
    h = (seed * 0x9E3779B1 + level * 0x85EBCA77 + p_lo * 0xC2B2AE3D
         + attempt * 0x27D4EB2F) & 0x7FFFFFFF
    return int(h)


def _guarded(sg: SolverGuard | None, res, solve_fn, *, level: int,
             p_lo: int, size: int, coords_sub=None):
    """Admit one solve through the guard (no-op when unguarded).
    ``res`` may be None when the primary solve raised."""
    if sg is None:
        return res
    res2, why = sg.admit(res, level=level, p_lo=p_lo, size=size)
    if why is None:
        return res2
    return sg.rescue(solve_fn, why, level=level, p_lo=p_lo, size=size,
                     coords=coords_sub)


def _warm_vector(c: np.ndarray) -> np.ndarray:
    """Geometric warm start: centroid coordinate along the longest axis."""
    ax = int(np.argmax(c.max(0) - c.min(0)))
    return (c[:, ax] - c[:, ax].mean()).astype(np.float32)


def _proportional_split(keys: np.ndarray, weights: np.ndarray, n_left: int,
                        n_total: int) -> tuple[np.ndarray, np.ndarray]:
    order = np.argsort(keys, kind="stable")
    cw = np.cumsum(weights[order])
    target = cw[-1] * (n_left / n_total)
    k = int(np.searchsorted(cw, target, side="left")) + 1
    k = min(max(k, 1), keys.size - 1)
    return order[:k], order[k:]


def _size_buckets(sizes: list) -> list:
    """Group node sizes into the (count, n_pad) shape buckets they solve in."""
    counts: dict = {}
    for s in sizes:
        key = 0 if s <= _DENSE_CUTOFF else next_pow2(s)
        counts[key] = counts.get(key, 0) + 1
    return sorted((c, k) for k, c in counts.items())


def _levels_from_records(records: list) -> list:
    """Aggregate per-node records into per-level records (recursive engine)."""
    by_level: dict = {}
    for r in records:
        by_level.setdefault(r.level, []).append(r)
    out = []
    for level in sorted(by_level):
        rs = by_level[level]
        out.append(LevelRecord(
            level=level,
            n_nodes=len(rs),
            total_size=sum(r.size for r in rs),
            buckets=_size_buckets([r.size for r in rs]),
            iterations=sum(r.iterations for r in rs),
            solve_seconds=sum(r.seconds for r in rs),
            split_seconds=sum(r.split_seconds for r in rs),
        ))
    return out


# ---------------------------------------------------------------------------
# Mesh drivers
# ---------------------------------------------------------------------------

def _resolve_solver_opts(window, max_restarts, multilevel, fine_restarts,
                         ordered):
    """Multilevel solves are *refinements* of the prolonged coarse Fiedler
    vector: a shallower Lanczos window (cheaper restarts AND a cheaper
    compiled trace) capped at a few restarts replaces the deep cold-start
    windows.  An explicit `window` always wins.

    The cap is only safe when the cascadic warm start is actually in play
    AND the geometric pre-ordering applied (`ordered`): pairwise
    aggregation follows the node order, so without RCB/RIB locality the
    hierarchy — and hence the warm start — is weaker, and a capped
    refinement would freeze a poorer bisection.  Unordered runs (and runs
    whose warm start comes from elsewhere — callers pass ordered=False)
    keep the multilevel seeding but solve to tolerance.  The one remaining
    capped-without-warm-start case is a per-problem
    `multilevel_warm_start` numerical-breakdown fallback to noise inside a
    packed solve (the cap is per call, not per problem) — rare enough that
    the balanced-but-coarser bisection it risks is accepted."""
    if window is None:
        window = 20 if multilevel else 30
    if multilevel and ordered and fine_restarts is not None:
        max_restarts = min(max_restarts, fine_restarts)
    return window, max_restarts


def rsb_partition_mesh(
    mesh,
    nparts: int,
    *,
    method: str = "lanczos",
    laplacian: str = "weighted",
    pre: str | None = "rcb",
    tol: float = 1e-3,
    window: int | None = None,
    max_restarts: int = 50,
    seed: int = 0,
    warm_start: bool = False,
    engine: str = "batched",
    multilevel: bool = True,
    fine_restarts: int | None = 3,
    precond: str = "jacobi",
    guard=None,
) -> tuple[np.ndarray, RSBReport]:
    """Partition a HexMesh into `nparts` via RSB on its dual graph.

    engine="batched" (default) solves every bisection of a tree level in
    one vmapped Fiedler solve per shape bucket; engine="recursive" is the
    sequential per-node reference.

    multilevel=True (default) runs every Fiedler solve coarse-to-fine: a
    Galerkin hierarchy per subproblem, a dense coarsest solve, a cascadic
    prolongation as the warm start, and the device solve capped at
    `fine_restarts` refinement restarts with a shallower default window
    (see `_resolve_solver_opts`).  `window=None` resolves to 20 under
    multilevel, 30 otherwise.

    `laplacian` is validated but currently a NO-OP: both settings
    partition the shared-vertex-weighted dual graph (the paper's ω
    weights); a genuinely unweighted operator is future work, so the
    benchmark rows labelled weighted/unweighted differ only in cache
    warmth.

    method="inverse" selects `precond`: "jacobi" (the batched default) or
    "amg" — the packed `BatchedAMG` V-cycle (paper §7) over
    leading-batch-dim operators.  The recursive engine always uses the
    per-graph host-built AMG hierarchy (the reference implementation).

    warm_start=True (beyond-paper) instead seeds the Fiedler solve with
    the centroid coordinate along the subset's longest axis; explicit warm
    starts take precedence over the multilevel ones.
    """
    if laplacian not in ("weighted", "unweighted"):
        raise ValueError(laplacian)
    if engine not in _ENGINES:
        raise ValueError(f"unknown engine: {engine}")
    window, max_restarts = _resolve_solver_opts(
        window, max_restarts, multilevel, fine_restarts,
        # warm_start=True replaces the cascadic warm start with the
        # geometric one — keep the pre-existing uncapped schedule there.
        ordered=pre in ("rcb", "rib") and not warm_start,
    )
    kw = dict(method=method, pre=pre, tol=tol, window=window,
              max_restarts=max_restarts, seed=seed, warm_start=warm_start,
              multilevel=multilevel, precond=precond, guard=guard)
    if engine == "batched":
        return _rsb_mesh_batched(mesh, nparts, **kw)
    return _rsb_mesh_recursive(mesh, nparts, **kw)


def _rsb_mesh_recursive(
    mesh, nparts, *, method, pre, tol, window, max_restarts, seed, warm_start,
    multilevel, precond, guard=None,
) -> tuple[np.ndarray, RSBReport]:
    records: list[BisectionRecord] = []
    parts = np.zeros(mesh.nelems, dtype=np.int64)
    sg = (SolverGuard(guard, seed=seed, method=method)
          if guard is not None and guard.enabled else None)

    def rec(idx: np.ndarray, p_lo: int, p_hi: int, level: int) -> None:
        np_here = p_hi - p_lo
        if np_here <= 1 or idx.size <= 1:
            parts[idx] = p_lo
            return
        # Geometric pre-partitioning: make active data locally contiguous.
        if pre in ("rcb", "rib"):
            fn = rcb_order if pre == "rcb" else rib_order
            idx = idx[fn(mesh.coords[idx], mesh.weights[idx])]

        sub_vg = mesh.vert_gid[idx]
        warm = _warm_vector(mesh.coords[idx]) if warm_start else None
        amg_cache: dict = {}

        def solve_fn(m, s, _sub_vg=sub_vg, _size=int(idx.size)):
            graph_amg = order_amg = None
            if m == "inverse":
                if "g" not in amg_cache:
                    uniq, inv = np.unique(_sub_vg, return_inverse=True)
                    amg_cache["g"] = dual_graph_from_incidence(
                        inv.reshape(_sub_vg.shape), uniq.size, _size
                    )
                graph_amg = amg_cache["g"]
                order_amg = np.arange(_size)  # already RCB-ordered above
            return fiedler_from_mesh(
                _sub_vg, method=m, graph_for_amg=graph_amg, order=order_amg,
                seed=s, tol=tol, window=window,
                max_restarts=max_restarts, warm=warm, multilevel=multilevel,
            )

        with obs.timed("solve", level=level, n=int(idx.size)) as t_solve:
            if sg is None:
                res = solve_fn(method, _node_seed(seed, level, p_lo))
            else:
                res = None
                if not sg.expired():  # past the stage deadline: skip straight
                    try:              # to the fallback rung inside rescue
                        res = solve_fn(method, _node_seed(seed, level, p_lo))
                    except Exception:
                        res = None
                res = _guarded(sg, res, solve_fn, level=level, p_lo=p_lo,
                               size=int(idx.size),
                               coords_sub=mesh.coords[idx])
        n_left = np_here // 2
        with obs.timed("split", level=level) as t_split:
            lo, hi = _proportional_split(
                res.vector, mesh.weights[idx], n_left, np_here)
            idx_lo, idx_hi = idx[lo], idx[hi]
        records.append(BisectionRecord(
            level=level, size=int(idx.size), nparts=np_here, method=res.method,
            iterations=res.iterations, eigenvalue=res.eigenvalue,
            residual=res.residual, seconds=t_solve.seconds, levels=res.levels,
            split_seconds=t_split.seconds, breakdown=res.breakdown,
        ))
        rec(idx_lo, p_lo, p_lo + n_left, level + 1)
        rec(idx_hi, p_lo + n_left, p_hi, level + 1)

    with obs.timed("engine", engine="recursive") as t_total:
        rec(np.arange(mesh.nelems, dtype=np.int64), 0, nparts, 0)
    return parts, RSBReport(
        records=records, seconds=t_total.seconds,
        levels=_levels_from_records(records), engine="recursive",
        pre=pre or "none", precond="amg" if method == "inverse" else "none",
        multilevel=multilevel, guard=sg.report if sg is not None else None,
    )


def _rsb_mesh_batched(
    mesh, nparts, *, method, pre, tol, window, max_restarts, seed, warm_start,
    multilevel, precond, guard=None,
) -> tuple[np.ndarray, RSBReport]:
    """Level-synchronous mesh driver: delegate to the graph engine on the
    assembled dual graph.

    The multilevel pipeline (coarse-to-fine warm starts, batched AMG,
    dense tails) runs on assembled graphs, and the engine keeps every
    level's subgraphs current with one vectorized multi-subgraph
    extraction — so the assembled ELL operators come for free, their
    packed solve shares ONE compiled trace with every graph-path run of
    the same shape, and their matvecs are ~2× cheaper than the packed
    gather-scatter form on small subproblems.  The matrix-free
    gather-scatter solve (paper §5) remains the recursive mesh engine's
    and `fiedler_from_mesh_batched`'s path."""
    graph = dual_graph_from_incidence(mesh.vert_gid, mesh.n_vert, mesh.nelems)
    return _rsb_graph_batched(
        graph, nparts, coords=mesh.coords, weights=mesh.weights,
        method=method, pre=pre, tol=tol, window=window,
        max_restarts=max_restarts, seed=seed, warm_start=warm_start,
        use_kernel=False, multilevel=multilevel, precond=precond,
        guard=guard,
    )


# ---------------------------------------------------------------------------
# Graph drivers
# ---------------------------------------------------------------------------

def rsb_partition_graph(
    graph: Graph,
    nparts: int,
    *,
    coords: np.ndarray | None = None,
    weights: np.ndarray | None = None,
    method: str = "lanczos",
    pre: str | None = "rcb",
    tol: float = 1e-3,
    window: int | None = None,
    max_restarts: int = 50,
    seed: int = 0,
    warm_start: bool = False,
    use_kernel: bool = False,
    engine: str = "batched",
    multilevel: bool = True,
    fine_restarts: int | None = 3,
    precond: str = "jacobi",
    guard=None,
) -> tuple[np.ndarray, RSBReport]:
    """Partition a generic graph (assembled ELL Laplacian) via RSB.

    `pre` selects the GEOMETRIC pre-partitioning pass ("rcb"/"rib"/None —
    paper §8), not a preconditioner; it defaults to "rcb" to match the
    mesh path and is a no-op when `coords` is not given.  The
    inverse-iteration preconditioner is `precond` ("jacobi" or "amg"),
    and `multilevel`/`fine_restarts`/`window` control the coarse-to-fine
    solver schedule exactly as in :func:`rsb_partition_mesh`.

    `use_kernel=True` routes every assembled ELL matvec through the Pallas
    `ell_spmv` kernel — both the packed 2-D Lanczos operator and the 3-D
    leading-batch-dim inverse-iteration operators (the batched grid
    kernel variant).

    This is the entry point the framework's partition-aware GNN sharding
    uses: feed the returned `parts` to
    `repro.dist.partition_aware.plan_halo_sharding` to get the shard_map
    halo plan whose all_gather volume is proportional to this cut.

    warm_start=True seeds each node's Fiedler solve from `coords` (the
    centroid coordinate along the subset's longest axis); it is a no-op
    without coords, and it takes precedence over the multilevel warm start.
    """
    if engine not in _ENGINES:
        raise ValueError(f"unknown engine: {engine}")
    window, max_restarts = _resolve_solver_opts(
        window, max_restarts, multilevel, fine_restarts,
        ordered=(pre in ("rcb", "rib") and coords is not None
                 and not warm_start),
    )
    kw = dict(coords=coords, weights=weights, method=method, pre=pre, tol=tol,
              window=window, max_restarts=max_restarts, seed=seed,
              warm_start=warm_start, use_kernel=use_kernel,
              multilevel=multilevel, precond=precond, guard=guard)
    if engine == "batched":
        return _rsb_graph_batched(graph, nparts, **kw)
    return _rsb_graph_recursive(graph, nparts, **kw)


def _rsb_graph_recursive(
    graph, nparts, *, coords, weights, method, pre, tol, window, max_restarts,
    seed, warm_start, use_kernel, multilevel, precond, guard=None,
) -> tuple[np.ndarray, RSBReport]:
    n = graph.n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    records: list[BisectionRecord] = []
    parts = np.zeros(n, dtype=np.int64)
    sg = (SolverGuard(guard, seed=seed, method=method)
          if guard is not None and guard.enabled else None)

    def rec(g: Graph, idx: np.ndarray, p_lo: int, p_hi: int, level: int) -> None:
        np_here = p_hi - p_lo
        if np_here <= 1 or idx.size <= 1:
            parts[idx] = p_lo
            return
        if pre in ("rcb", "rib") and coords is not None:
            fn = rcb_order if pre == "rcb" else rib_order
            perm = fn(coords[idx], w[idx])
            idx = idx[perm]
            g = g.sub(perm)
        warm = None
        if warm_start and coords is not None:
            warm = _warm_vector(coords[idx])

        def solve_fn(m, s, _g=g):
            return fiedler_from_graph(
                _g, method=m, order=None, seed=s,
                warm=warm, tol=tol, window=window, max_restarts=max_restarts,
                use_kernel=use_kernel, multilevel=multilevel,
            )

        with obs.timed("solve", level=level, n=int(idx.size)) as t_solve:
            if sg is None:
                res = solve_fn(method, _node_seed(seed, level, p_lo))
            else:
                res = None
                if not sg.expired():  # past the stage deadline: skip straight
                    try:              # to the fallback rung inside rescue
                        res = solve_fn(method, _node_seed(seed, level, p_lo))
                    except Exception:
                        res = None
                res = _guarded(
                    sg, res, solve_fn, level=level, p_lo=p_lo,
                    size=int(idx.size),
                    coords_sub=coords[idx] if coords is not None else None)
        n_left = np_here // 2
        with obs.timed("split", level=level) as t_split:
            lo, hi = _proportional_split(res.vector, w[idx], n_left, np_here)
            g_lo, g_hi = g.sub(lo), g.sub(hi)
            idx_lo, idx_hi = idx[lo], idx[hi]
        records.append(BisectionRecord(
            level=level, size=int(idx.size), nparts=np_here, method=res.method,
            iterations=res.iterations, eigenvalue=res.eigenvalue,
            residual=res.residual, seconds=t_solve.seconds, levels=res.levels,
            split_seconds=t_split.seconds, breakdown=res.breakdown,
        ))
        rec(g_lo, idx_lo, p_lo, p_lo + n_left, level + 1)
        rec(g_hi, idx_hi, p_lo + n_left, p_hi, level + 1)

    with obs.timed("engine", engine="recursive") as t_total:
        rec(graph, np.arange(n, dtype=np.int64), 0, nparts, 0)
    return parts, RSBReport(
        records=records, seconds=t_total.seconds,
        levels=_levels_from_records(records), engine="recursive",
        pre=pre or "none", precond="amg" if method == "inverse" else "none",
        multilevel=multilevel, guard=sg.report if sg is not None else None,
    )


def _rsb_graph_batched(
    graph, nparts, *, coords, weights, method, pre, tol, window, max_restarts,
    seed, warm_start, use_kernel, multilevel, precond, guard=None,
) -> tuple[np.ndarray, RSBReport]:
    n = graph.n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    records: list[BisectionRecord] = []
    levels: list[LevelRecord] = []
    parts = np.zeros(n, dtype=np.int64)
    sg = (SolverGuard(guard, seed=seed, method=method)
          if guard is not None and guard.enabled else None)
    with obs.timed("engine", engine="batched") as t_total:
        # Run-wide shape-bucket pins (see _rsb_mesh_batched): subgraph degrees
        # never exceed the root's, so the root ELL width bounds every level.
        pack_slots = next_pow2(max(n, 2))
        pack_segs = next_pow2(max(nparts, 1))
        root_width = int(graph.degrees.max()) if graph.nnz else 1
        width_pad = next_pow2(max(root_width, 2))

        active = [(graph, np.arange(n, dtype=np.int64), 0, nparts)]
        level = 0
        while active:
            solve_nodes = []
            for g, idx, p_lo, p_hi in active:
                if p_hi - p_lo <= 1 or idx.size <= 1:
                    parts[idx] = p_lo
                    continue
                if pre in ("rcb", "rib") and coords is not None:
                    fn = rcb_order if pre == "rcb" else rib_order
                    perm = fn(coords[idx], w[idx])
                    idx = idx[perm]
                    g = g.sub(perm)
                solve_nodes.append((g, idx, p_lo, p_hi))
            if not solve_nodes:
                break

            with obs.span(f"level:{level}", nodes=len(solve_nodes)):
                with obs.timed("solve", level=level) as t_solve:
                    if sg is not None and sg.expired():
                        # Past the stage deadline: skip the level solve and
                        # let every node take the fallback rung below.
                        results = [None] * len(solve_nodes)
                    else:
                        results = fiedler_from_graph_batched(
                            [g for g, _, _, _ in solve_nodes],
                            method=method,
                            seeds=[_node_seed(seed, level, p_lo)
                                   for _, _, p_lo, _ in solve_nodes],
                            warms=[
                                _warm_vector(coords[idx])
                                if warm_start and coords is not None else None
                                for _, idx, _, _ in solve_nodes
                            ],
                            tol=tol, window=window, max_restarts=max_restarts,
                            pack_slots=pack_slots, pack_segs=pack_segs,
                            width_pad=width_pad, use_kernel=use_kernel,
                            multilevel=multilevel, precond=precond,
                        )
                if sg is not None:
                    # Re-admit every node's result; failed ones re-solve
                    # individually through the escalation ladder.
                    rescued = []
                    for (g, idx, p_lo, p_hi), res in zip(solve_nodes,
                                                         results):
                        def solve_fn(m, s, _g=g):
                            return fiedler_from_graph(
                                _g, method=m, order=None, seed=s, tol=tol,
                                window=window, max_restarts=max_restarts,
                                use_kernel=use_kernel, multilevel=multilevel,
                            )
                        rescued.append(_guarded(
                            sg, res, solve_fn, level=level, p_lo=p_lo,
                            size=int(idx.size),
                            coords_sub=coords[idx]
                            if coords is not None else None))
                    results = rescued
                with obs.timed("split", level=level) as t_split:
                    next_active = []
                    for (g, idx, p_lo, p_hi), res in zip(solve_nodes, results):
                        np_here = p_hi - p_lo
                        records.append(BisectionRecord(
                            level=level, size=int(idx.size), nparts=np_here,
                            method=res.method, iterations=res.iterations,
                            eigenvalue=res.eigenvalue, residual=res.residual,
                            seconds=t_solve.seconds / len(solve_nodes),
                            levels=res.levels, breakdown=res.breakdown,
                        ))
                        n_left = np_here // 2
                        lo, hi = _proportional_split(
                            res.vector, w[idx], n_left, np_here)
                        g_lo, g_hi = extract_subgraphs(g, [lo, hi])
                        next_active.append((g_lo, idx[lo], p_lo, p_lo + n_left))
                        next_active.append((g_hi, idx[hi], p_lo + n_left, p_hi))
            levels.append(LevelRecord(
                level=level,
                n_nodes=len(solve_nodes),
                total_size=sum(int(idx.size) for _, idx, _, _ in solve_nodes),
                buckets=_size_buckets(
                    [int(idx.size) for _, idx, _, _ in solve_nodes]
                ),
                iterations=sum(r.iterations for r in results),
                solve_seconds=t_solve.seconds,
                split_seconds=t_split.seconds,
            ))
            # Per-node split cost isn't separable in the level-synchronous
            # engine; attribute the level's split evenly so engine comparisons
            # on summed split_seconds stay apples-to-apples.
            for r in records[-len(solve_nodes):]:
                r.split_seconds = t_split.seconds / len(solve_nodes)
            active = next_active
            level += 1

    return parts, RSBReport(
        records=records, seconds=t_total.seconds,
        levels=levels, engine="batched", pre=pre or "none",
        precond=precond if method == "inverse" else "none",
        multilevel=multilevel, guard=sg.report if sg is not None else None,
    )


def partition(obj, nparts: int, **kw) -> np.ndarray:
    """Uniform front door: partitioner ∈ {rsb, rsb_inverse, rcb, rib, sfc,
    random}.  Compatibility wrapper over the composable stage pipeline —
    see :func:`repro.core.pipeline.partition` for the full surface
    (``refine=`` post stages, explicit per-stage kwarg routing) and
    :class:`repro.core.pipeline.PartitionPipeline` for report + timings.
    """
    from repro.core.pipeline import partition as _pipeline_partition

    return _pipeline_partition(obj, nparts, **kw)
