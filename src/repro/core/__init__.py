"""parRSB core: the paper's contribution as a composable JAX module."""

from repro.core.amg import (
    AMG,
    BatchedAMG,
    amg_setup,
    amg_setup_batched,
    coarsen_graph,
    heavy_edge_matching,
)
from repro.core.fiedler import (
    FiedlerResult,
    best_cut_in_pair,
    fiedler_from_graph,
    fiedler_from_graph_batched,
    fiedler_from_mesh,
    fiedler_from_mesh_batched,
    fiedler_pair_from_graph,
    multilevel_warm_start,
)
from repro.core.flexcg import CGResult, flexcg
from repro.core.gather_scatter import (
    GSHandle,
    GSLaplacian,
    aw_apply,
    gs_apply,
    gs_setup,
    unweighted_laplacian,
    weighted_laplacian,
)
from repro.core.inverse_iteration import (
    BatchedInverseIterInfo,
    InverseIterInfo,
    inverse_iteration,
    inverse_iteration_batched,
)
from repro.core.kway import (
    KwayPassRecord,
    KwayStats,
    kway_fm,
    kway_fm_boundary,
    kway_stage,
)
from repro.core.lanczos import (
    BatchedLanczosInfo,
    LanczosInfo,
    lanczos_fiedler,
    lanczos_fiedler_batched,
)
from repro.core.laplacian import (
    EllLaplacian,
    dense_laplacian_np,
    ell_laplacian,
    ell_laplacian_batched,
    fiedler_oracle_np,
)
from repro.core.metrics import (
    PartitionMetrics,
    comm_time_model,
    m2_words,
    partition_metrics,
)
from repro.core.multilevel import (
    MLLevel,
    MultilevelStats,
    multilevel_partition,
)
from repro.core.pipeline import (
    PartitionContext,
    PartitionPipeline,
    StageRecord,
    parse_refine,
    partition,
    register_bisect_stage,
    register_post_stage,
    run_post_stages,
)
from repro.core.rcb import rcb_order, rcb_parts, rib_order, rib_parts
from repro.core.refine import (
    PostStats,
    SweepRecord,
    balance_corridor,
    edge_cut,
    refine_boundary,
    refine_stage,
    repair_components,
    repair_refine,
)
from repro.core.rsb import (
    BisectionRecord,
    LevelRecord,
    RSBReport,
    rsb_partition_graph,
    rsb_partition_mesh,
)
from repro.core.sfc import hilbert_index, morton_index, sfc_order, sfc_parts
