"""parRSB core: the paper's contribution as a composable JAX module."""

from repro.core.gather_scatter import (
    GSHandle,
    GSLaplacian,
    gs_setup,
    gs_apply,
    aw_apply,
    weighted_laplacian,
    unweighted_laplacian,
)
from repro.core.laplacian import (
    EllLaplacian,
    ell_laplacian,
    ell_laplacian_batched,
    dense_laplacian_np,
    fiedler_oracle_np,
)
from repro.core.lanczos import (lanczos_fiedler, lanczos_fiedler_batched,
                                LanczosInfo, BatchedLanczosInfo)
from repro.core.flexcg import flexcg, CGResult
from repro.core.inverse_iteration import (inverse_iteration,
                                          inverse_iteration_batched,
                                          InverseIterInfo,
                                          BatchedInverseIterInfo)
from repro.core.amg import (AMG, BatchedAMG, amg_setup, amg_setup_batched,
                            coarsen_graph, heavy_edge_matching)
from repro.core.rcb import rcb_order, rib_order, rcb_parts, rib_parts
from repro.core.sfc import sfc_parts, sfc_order, hilbert_index, morton_index
from repro.core.fiedler import (fiedler_from_graph, fiedler_from_mesh, FiedlerResult,
                                fiedler_from_graph_batched, fiedler_from_mesh_batched,
                                fiedler_pair_from_graph, best_cut_in_pair,
                                multilevel_warm_start)
from repro.core.rsb import (
    rsb_partition_mesh,
    rsb_partition_graph,
    RSBReport,
    LevelRecord,
    BisectionRecord,
)
from repro.core.refine import (
    PostStats,
    SweepRecord,
    balance_corridor,
    edge_cut,
    refine_boundary,
    refine_stage,
    repair_components,
    repair_refine,
)
from repro.core.kway import (
    KwayPassRecord,
    KwayStats,
    kway_fm,
    kway_fm_boundary,
    kway_stage,
)
from repro.core.multilevel import (
    MLLevel,
    MultilevelStats,
    multilevel_partition,
)
from repro.core.pipeline import (
    PartitionContext,
    PartitionPipeline,
    StageRecord,
    partition,
    parse_refine,
    register_bisect_stage,
    register_post_stage,
    run_post_stages,
)
from repro.core.metrics import partition_metrics, PartitionMetrics, comm_time_model, m2_words
