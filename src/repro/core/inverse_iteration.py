"""Inverse power iteration for the Fiedler vector (paper Algorithm 2 + §7).

Outer loop: orthogonalize b against 1, normalize, solve `L y = b` with
AMG-preconditioned flexcg, set b ← y.  Two parRSB augmentations reproduced:

* **Augmented projection**: the initial guess for each inner solve is the
  L-orthogonal projection of b onto the span of the previous outer iterates
  (a small Gram solve) — the "approximate Krylov-subspace projection of the
  inverse iterates" of the paper.  This typically cuts inner iterations by
  2–4× after the first few outer steps.
* **Single-iteration stop**: once flexcg (whose first direction is
  unpreconditioned) returns in one iteration, the Krylov space is invariant
  → b is an eigenvector → stop the outer loop.

The outer loop is a host loop (a handful of iterations, paper reports ~6);
each inner solve is a single jitted while_loop.

**Batched variant** (`inverse_iteration_batched`): B subproblems (one RSB
tree level) share a single jitted, per-element-masked flexcg inner solve.
The preconditioner is either Jacobi taken from the operator's own `diag`
(the paper's smoother, the default) or a packed `BatchedAMG` V-cycle
(`repro.core.amg.amg_setup_batched`) passed as a traced pytree argument —
level ladders padded to shared power-of-two sizes, so one compiled trace
serves every bucket of the same shape.  Both of the paper's outer-loop
refinements survive batching: the augmented Krylov projection becomes a
batched Gram solve, and the single-inner-iteration stopping signal is
tracked per subproblem.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexcg import CGResult, _project_out_ones, flexcg
from repro.guard import chaos


@dataclasses.dataclass
class InverseIterInfo:
    outer_iters: int
    inner_iters: list
    eigenvalue: float
    residual: float
    breakdown: bool = False    # hit a non-finite iterate; λ/res are stale


@dataclasses.dataclass
class BatchedInverseIterInfo:
    outer_iters: np.ndarray    # (B,) outer iteration count at convergence
    inner_iters: list          # per outer step: (B,) inner-iteration counts
    eigenvalue: np.ndarray     # (B,)
    residual: np.ndarray       # (B,)
    converged: np.ndarray      # (B,) bool
    breakdown: np.ndarray | None = None  # (B,) bool: λ/res are stale


def _rayleigh(op, y, mask):
    Ly = op(y)
    num = jnp.sum(y * Ly)
    den = jnp.maximum(jnp.sum(y * y), 1e-30)
    lam = num / den
    res = jnp.sqrt(jnp.sum((Ly - lam * y) ** 2) / den)
    return lam, res


def inverse_iteration(
    op: Callable[[jax.Array], jax.Array],
    n: int,
    *,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    mask: jax.Array | None = None,
    key: jax.Array | None = None,
    b0: jax.Array | None = None,
    max_outer: int = 30,
    inner_tol: float = 1e-4,
    inner_maxiter: int = 200,
    tol: float = 1e-3,
    proj_window: int = 5,
) -> tuple[jax.Array, InverseIterInfo]:
    """Return (y₂ approximation, info)."""
    mask = jnp.ones((n,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    if b0 is None:
        key = jax.random.PRNGKey(0) if key is None else key
        b = jax.random.normal(key, (n,), jnp.float32)
    else:
        b = b0.astype(jnp.float32)
    b = _project_out_ones(b, mask)
    b = b / jnp.maximum(jnp.linalg.norm(b), 1e-30)

    solve = jax.jit(
        lambda bb, xx0: flexcg(
            op, bb, precond=precond, x0=xx0, mask=mask,
            tol=inner_tol, maxiter=inner_maxiter,
        )
    )
    opj = jax.jit(op)

    ys: list[jax.Array] = []     # previous iterates (projection basis)
    lys: list[jax.Array] = []    # L @ previous iterates
    inner_counts = []
    lam = jnp.asarray(0.0)
    res = jnp.asarray(jnp.inf)
    outer = 0
    breakdown = False
    for outer in range(1, max_outer + 1):
        # Augmented projection: x0 = Y (Yᵀ L Y)⁻¹ Yᵀ b.
        if ys:
            Y = jnp.stack(ys, axis=1)        # (n, m)
            W = jnp.stack(lys, axis=1)       # (n, m)
            G = Y.T @ W                      # (m, m) Gram in L-inner product
            rhs = Y.T @ b
            # Ridge scaled to the Gram (an absolute 1e-12 is below fp32
            # epsilon: near-duplicate iterates make G singular → NaN x0).
            ridge = 1e-5 * jnp.trace(G) / G.shape[0] + 1e-20
            coef = jnp.linalg.solve(G + ridge * jnp.eye(G.shape[0]), rhs)
            x0 = Y @ coef
            x0 = jnp.where(jnp.isfinite(x0).all(), x0, jnp.zeros_like(b))
        else:
            x0 = None
        result: CGResult = solve(b, x0 if x0 is not None else jnp.zeros_like(b))
        y = result.x
        inner_counts.append(int(result.iters))

        b_prev = b
        ynorm = jnp.maximum(jnp.linalg.norm(y), 1e-30)
        b = _project_out_ones(y / ynorm, mask)
        b = b / jnp.maximum(jnp.linalg.norm(b), 1e-30)
        lam, res = _rayleigh(opj, b, mask)
        if chaos.should_fire("cg_divergence", outer):
            lam = jnp.asarray(jnp.nan)
        if not (np.isfinite(float(lam)) and np.isfinite(float(res))):
            # Numerical breakdown: keep the last good iterate and stop,
            # flagging the stale Rayleigh pair for the caller.
            breakdown = True
            b = b_prev
            lam, res = _rayleigh(opj, b, mask)
            break

        ys.append(b)
        lys.append(opj(b))
        if len(ys) > proj_window:
            ys.pop(0)
            lys.pop(0)

        if float(res) <= tol * max(float(lam), 1e-12):
            break
        # Paper's stopping signal: flexcg converged in a single iteration.
        if outer > 1 and int(result.iters) <= 1:
            break

    info = InverseIterInfo(
        outer_iters=outer,
        inner_iters=inner_counts,
        eigenvalue=float(lam),
        residual=float(res),
        breakdown=breakdown,
    )
    return b, info


# ---------------------------------------------------------------------------
# Batched (level-synchronous) inverse iteration
# ---------------------------------------------------------------------------

def _rayleigh_batched(Ly, y):
    den = jnp.maximum(jnp.sum(y * y, axis=-1), 1e-30)
    lam = jnp.sum(y * Ly, axis=-1) / den
    res = jnp.sqrt(jnp.sum((Ly - lam[:, None] * y) ** 2, axis=-1) / den)
    return lam, res


@partial(jax.jit, static_argnames=("inner_tol", "inner_maxiter"))
def _batched_inner_solve(op, precond, b, x0, mask, inner_tol, inner_maxiter):
    """One inner solve + renormalization + Rayleigh quotient, all batched.

    `op` and `precond` are pytree arguments (traced → one trace per shape
    bucket and preconditioner structure).  `precond=None` falls back to
    Jacobi built from the operator's own diagonal (padding rows have
    diag 0 → identity there); a `BatchedAMG` (or any callable pytree)
    is applied as the flexible preconditioner per subproblem.
    """
    pre = precond
    if pre is None:
        inv_d = jnp.where(op.diag > 0, 1.0 / jnp.maximum(op.diag, 1e-30), 0.0)
        pre = lambda r: r * inv_d  # noqa: E731
    result = flexcg(
        op, b, precond=pre, x0=x0, mask=mask,
        tol=inner_tol, maxiter=inner_maxiter,
    )
    y = result.x
    ynorm = jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-30)
    b_new = _project_out_ones(y / ynorm, mask)
    b_new = b_new / jnp.maximum(
        jnp.linalg.norm(b_new, axis=-1, keepdims=True), 1e-30
    )
    Ly = op(b_new)
    lam, res = _rayleigh_batched(Ly, b_new)
    return b_new, lam, res, result.iters, Ly


@jax.jit
def _apply_op(op, x):
    """Module-level jitted matvec: the compile cache is shared by every
    bucket/level of a run (a per-call `jax.jit(lambda ...)` would re-trace
    each time)."""
    return op(x)


@jax.jit
def _augmented_projection(Y, W, b):
    """x0 = Y (Yᵀ L Y)⁻¹ Yᵀ b per subproblem (Y (B, n, m), W = L Y).

    The ridge is scaled to each Gram (fp32 near-duplicate iterates make G
    singular) and a non-finite solve falls back to x0 = 0 per problem."""
    G = jnp.einsum("bnm,bnk->bmk", Y, W)
    rhs = jnp.einsum("bnm,bn->bm", Y, b)
    m = G.shape[-1]
    tr = jnp.trace(G, axis1=-2, axis2=-1)
    ridge = (1e-5 * tr / m + 1e-20)[:, None, None]
    coef = jnp.linalg.solve(
        G + ridge * jnp.eye(m, dtype=G.dtype), rhs[..., None]
    )[..., 0]
    x0 = jnp.einsum("bnm,bm->bn", Y, coef)
    ok = jnp.isfinite(x0).all(axis=-1, keepdims=True)
    return jnp.where(ok, x0, 0.0)


def inverse_iteration_batched(
    op,
    n: int,
    *,
    mask: jax.Array,
    b0: jax.Array,
    precond=None,
    max_outer: int = 30,
    inner_tol: float = 1e-4,
    inner_maxiter: int = 200,
    tol: float = 1e-3,
    proj_window: int = 5,
) -> tuple[jax.Array, BatchedInverseIterInfo]:
    """B inverse-iteration Fiedler solves in lockstep.

    Returns (B (B, n) iterates, per-problem info).  An all-zero mask row is
    a batch-padding dummy that converges immediately.  `precond` is a
    callable pytree applied per subproblem inside the inner flexcg (e.g. a
    `BatchedAMG` V-cycle); None selects the Jacobi preconditioner from the
    operator's own diagonal.
    """
    B = mask.shape[0]
    b = _project_out_ones(b0.astype(jnp.float32), mask)
    b = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-30)

    ys: list[jax.Array] = []
    lys: list[jax.Array] = []
    inner_counts: list[np.ndarray] = []
    lam = np.zeros(B)
    res = np.full(B, np.inf)
    done = np.zeros(B, dtype=bool)
    breakdown = np.zeros(B, dtype=bool)
    outer_iters = np.zeros(B, dtype=np.int64)
    lb = _apply_op(op, b)  # L@b, kept in lockstep with b's freeze updates
    for outer in range(1, max_outer + 1):
        if ys:
            Y = jnp.stack(ys, axis=-1)
            W = jnp.stack(lys, axis=-1)
            x0 = _augmented_projection(Y, W, b)
        else:
            x0 = jnp.zeros_like(b)
        b_new, lam_new, res_new, iters, Ly_new = _batched_inner_solve(
            op, precond, b, x0, mask, inner_tol, inner_maxiter
        )
        iters_h = np.asarray(iters)
        inner_counts.append(iters_h)
        lam_h, res_h = np.asarray(lam_new), np.asarray(res_new)
        if chaos.should_fire("cg_divergence", outer):
            lam_h = np.full_like(lam_h, np.nan)
        finite = np.isfinite(lam_h) & np.isfinite(res_h)
        upd = ~done & finite  # a non-finite update keeps the last good state
        outer_iters[upd] = outer
        lam = np.where(upd, lam_h, lam)
        res = np.where(upd, res_h, res)
        updj = jnp.asarray(upd)[:, None]
        b = jnp.where(updj, b_new, b)
        lb = jnp.where(updj, Ly_new, lb)

        ys.append(b)
        lys.append(lb)
        if len(ys) > proj_window:
            ys.pop(0)
            lys.pop(0)

        done |= res <= tol * np.maximum(lam, 1e-12)
        # Numerical breakdown: stop on the last good iterate, but flag the
        # problem — the frozen λ/res never met tolerance and are stale.
        breakdown |= ~finite & ~done
        done |= ~finite
        # Paper's stopping signal, per subproblem: a single-iteration inner
        # solve means the Krylov space is invariant → eigenvector reached.
        if outer > 1:
            done |= finite & (iters_h <= 1)
        if done.all():
            break

    info = BatchedInverseIterInfo(
        outer_iters=outer_iters,
        inner_iters=inner_counts,
        eigenvalue=lam,
        residual=res,
        converged=done,
        breakdown=breakdown,
    )
    return b, info
