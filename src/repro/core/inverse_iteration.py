"""Inverse power iteration for the Fiedler vector (paper Algorithm 2 + §7).

Outer loop: orthogonalize b against 1, normalize, solve `L y = b` with
AMG-preconditioned flexcg, set b ← y.  Two parRSB augmentations reproduced:

* **Augmented projection**: the initial guess for each inner solve is the
  L-orthogonal projection of b onto the span of the previous outer iterates
  (a small Gram solve) — the "approximate Krylov-subspace projection of the
  inverse iterates" of the paper.  This typically cuts inner iterations by
  2–4× after the first few outer steps.
* **Single-iteration stop**: once flexcg (whose first direction is
  unpreconditioned) returns in one iteration, the Krylov space is invariant
  → b is an eigenvector → stop the outer loop.

The outer loop is a host loop (a handful of iterations, paper reports ~6);
each inner solve is a single jitted while_loop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexcg import CGResult, _project_out_ones, flexcg


@dataclasses.dataclass
class InverseIterInfo:
    outer_iters: int
    inner_iters: list
    eigenvalue: float
    residual: float


def _rayleigh(op, y, mask):
    Ly = op(y)
    num = jnp.sum(y * Ly)
    den = jnp.maximum(jnp.sum(y * y), 1e-30)
    lam = num / den
    res = jnp.sqrt(jnp.sum((Ly - lam * y) ** 2) / den)
    return lam, res


def inverse_iteration(
    op: Callable[[jax.Array], jax.Array],
    n: int,
    *,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    mask: jax.Array | None = None,
    key: jax.Array | None = None,
    b0: jax.Array | None = None,
    max_outer: int = 30,
    inner_tol: float = 1e-4,
    inner_maxiter: int = 200,
    tol: float = 1e-3,
    proj_window: int = 5,
) -> tuple[jax.Array, InverseIterInfo]:
    """Return (y₂ approximation, info)."""
    mask = jnp.ones((n,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    if b0 is None:
        key = jax.random.PRNGKey(0) if key is None else key
        b = jax.random.normal(key, (n,), jnp.float32)
    else:
        b = b0.astype(jnp.float32)
    b = _project_out_ones(b, mask)
    b = b / jnp.maximum(jnp.linalg.norm(b), 1e-30)

    solve = jax.jit(
        lambda bb, xx0: flexcg(
            op, bb, precond=precond, x0=xx0, mask=mask,
            tol=inner_tol, maxiter=inner_maxiter,
        )
    )
    opj = jax.jit(op)

    ys: list[jax.Array] = []     # previous iterates (projection basis)
    lys: list[jax.Array] = []    # L @ previous iterates
    inner_counts = []
    lam = jnp.asarray(0.0)
    res = jnp.asarray(jnp.inf)
    outer = 0
    for outer in range(1, max_outer + 1):
        # Augmented projection: x0 = Y (Yᵀ L Y)⁻¹ Yᵀ b.
        if ys:
            Y = jnp.stack(ys, axis=1)        # (n, m)
            W = jnp.stack(lys, axis=1)       # (n, m)
            G = Y.T @ W                      # (m, m) Gram in L-inner product
            rhs = Y.T @ b
            coef = jnp.linalg.solve(G + 1e-12 * jnp.eye(G.shape[0]), rhs)
            x0 = Y @ coef
        else:
            x0 = None
        result: CGResult = solve(b, x0 if x0 is not None else jnp.zeros_like(b))
        y = result.x
        inner_counts.append(int(result.iters))

        ynorm = jnp.maximum(jnp.linalg.norm(y), 1e-30)
        b = _project_out_ones(y / ynorm, mask)
        b = b / jnp.maximum(jnp.linalg.norm(b), 1e-30)
        lam, res = _rayleigh(opj, b, mask)

        ys.append(b)
        lys.append(opj(b))
        if len(ys) > proj_window:
            ys.pop(0)
            lys.pop(0)

        if float(res) <= tol * max(float(lam), 1e-12):
            break
        # Paper's stopping signal: flexcg converged in a single iteration.
        if outer > 1 and int(result.iters) <= 1:
            break

    info = InverseIterInfo(
        outer_iters=outer,
        inner_iters=inner_counts,
        eigenvalue=float(lam),
        residual=float(res),
    )
    return b, info
