"""Space-filling-curve partitioner (paper §3 related work, baseline).

Hilbert ordering via the Skilling transpose algorithm (bit-interleaved,
Gray-code corrected) plus a plain Morton (Z-order) variant.  Partition =
sort centroids by curve index, split into weight-balanced contiguous chunks.
"""

from __future__ import annotations

import numpy as np

from repro.core.rcb import _parts_from_order


def _quantize(coords: np.ndarray, bits: int) -> np.ndarray:
    c = np.asarray(coords, dtype=np.float64)
    lo, hi = c.min(0), c.max(0)
    span = np.where(hi > lo, hi - lo, 1.0)
    q = ((c - lo) / span * ((1 << bits) - 1)).astype(np.uint64)
    return q


def morton_index(coords: np.ndarray, bits: int = 16) -> np.ndarray:
    q = _quantize(coords, bits)
    out = np.zeros(q.shape[0], dtype=np.uint64)
    for b in range(bits):
        for d in range(q.shape[1]):
            out |= ((q[:, d] >> np.uint64(b)) & np.uint64(1)) << np.uint64(
                b * q.shape[1] + d
            )
    return out


def hilbert_index(coords: np.ndarray, bits: int = 16) -> np.ndarray:
    """Skilling's transpose-form Hilbert index (vectorized over points)."""
    X = _quantize(coords, bits).astype(np.uint64).copy()  # (n, d)
    n, d = X.shape
    M = np.uint64(1) << np.uint64(bits - 1)

    # Inverse undo excess work (Skilling 2004, vectorized).
    Q = M
    while Q > np.uint64(1):
        P = Q - np.uint64(1)
        for i in range(d):
            mask = (X[:, i] & Q) != 0
            # invert low bits of X[0]
            X[mask, 0] ^= P
            t = (X[:, 0] ^ X[:, i]) & P
            t = np.where(mask, np.uint64(0), t)
            X[:, 0] ^= t
            X[:, i] ^= t
        Q >>= np.uint64(1)

    # Gray decode
    for i in range(1, d):
        X[:, i] ^= X[:, i - 1]
    t = np.zeros(n, dtype=np.uint64)
    Q = M
    while Q > np.uint64(1):
        mask = (X[:, d - 1] & Q) != 0
        t ^= np.where(mask, Q - np.uint64(1), np.uint64(0))
        Q >>= np.uint64(1)
    for i in range(d):
        X[:, i] ^= t

    # Interleave transpose-form bits into a single index (MSB first).
    out = np.zeros(n, dtype=np.uint64)
    for b in range(bits - 1, -1, -1):
        for i in range(d):
            out = (out << np.uint64(1)) | ((X[:, i] >> np.uint64(b)) & np.uint64(1))
    return out


def sfc_order(coords: np.ndarray, *, curve: str = "hilbert", bits: int = 16) -> np.ndarray:
    idx = hilbert_index(coords, bits) if curve == "hilbert" else morton_index(coords, bits)
    return np.argsort(idx, kind="stable")


def sfc_parts(
    coords: np.ndarray,
    nparts: int,
    weights: np.ndarray | None = None,
    *,
    curve: str = "hilbert",
    bits: int = 16,
) -> np.ndarray:
    if curve not in ("hilbert", "morton"):
        raise ValueError(f"unknown curve: {curve!r}")
    order = sfc_order(coords, curve=curve, bits=bits)
    w = np.ones(coords.shape[0]) if weights is None else np.asarray(weights, np.float64)
    return _parts_from_order(order, w, nparts)
