"""METIS-style multilevel k-way V-cycle — the ``bisect="multilevel"`` stage.

The spectral bisect stage is ~99% of pipeline wall at bench scale
(BENCH_partition.json), and its cost is dominated by Fiedler solves on
near-fine-size graphs.  The classic route to 10–100x at scale (Karypis &
Kumar's METIS; parRSB §optimizations uses the same coarse-solve shape) is
to stop solving eigenproblems on the fine graph altogether:

1. **Coarsen** — a ladder of heavy-edge-matching aggregations
   (:func:`repro.core.amg.heavy_edge_matching`, the vectorized
   generalization of the AMG setup's order-dependent pairwise map)
   Galerkin-coarsens the graph down to ~``coarse_factor * nparts`` nodes.
   Edge AND node weights flow through :func:`~repro.core.amg.coarsen_graph`
   — node-weight totals are conserved exactly, so the coarse balance
   problem is the fine one in miniature and one corridor (computed from
   the fine totals) is valid at every level.
2. **Partition the coarsest graph directly** — dense-``eigh`` recursive
   spectral bisection (the coarsest graph is tiny) or seeded BFS k-way
   growth, polished by full :func:`~repro.core.kway.kway_fm` passes at
   coarse size, where even n·nparts work is negligible.
3. **Prolong + refine** — labels transfer by aggregate copy
   (``parts_fine = parts_coarse[agg]``), and each level runs an explicit
   balance-restoration pass (:func:`_rebalance`, driving part weights
   into the *ideal* corridor now that finer granularity makes it
   reachable) followed by a bounded *boundary-restricted* FM sweep
   (:func:`~repro.core.kway.kway_fm_boundary`, per-level ``stall`` cap),
   so per-level refinement is O(boundary), not O(n), and total V-cycle
   cost stays linear in edges.

Balance is enforced twice over.  Matching is weight-capped (no aggregate
may outweigh ``total/(coarse_factor·nparts)``), so even the coarsest
level has enough granularity for a near-balanced split; and because
prolongation copies labels — part weights are *identical* across levels —
any residual violation is repaired during uncoarsening by
:func:`_rebalance` rather than grandfathered in through corridor
widening.

Observability: one ``mlevel:N`` span per ladder level on the way down
(matching + coarsening) and again on the way up (prolong + refine), a
``coarsen`` span over the whole ladder, a ``coarsest`` span around the
direct solve, and the ``ml_levels`` / ``ml_coarsen_ratio`` /
``ml_fm_moves`` metrics.  ``mlevel:0`` is emitted even when the input is
already coarse enough to skip the ladder (the refinement sweep still
runs), so the CI drift guard can require it unconditionally.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.amg import coarsen_graph, heavy_edge_matching
from repro.core.kway import kway_fm, kway_fm_boundary
from repro.core.laplacian import dense_laplacian_np
from repro.core.refine import (
    _part_weights,
    edge_cut,
    refine_boundary,
    repair_components,
)
from repro.core.rsb import BisectionRecord, LevelRecord, RSBReport, _proportional_split
from repro.mesh.graphs import Graph

# Above this size the dense-eigh coarsest solve (O(n³)) costs more than it
# buys over seeded growth + FM polish; "spectral" falls back to "greedy".
_DENSE_SPECTRAL_MAX = 1024

_EPS = 1e-9


@dataclasses.dataclass
class MLLevel:
    """One ladder level: the coarsening step taken from it on the way down
    and the refinement sweep run on it on the way up."""

    level: int
    n: int                       # fine-side node count at this level
    n_coarse: int                # nodes after this level's aggregation
    ratio: float                 # n_coarse / n
    coarsen_seconds: float = 0.0
    refine_seconds: float = 0.0
    fm_moves: int = 0            # boundary-FM moves kept at this level
    balance_moves: int = 0       # forced rebalance moves at this level
    cut: float = 0.0             # cut after this level's refinement

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class MultilevelStats:
    """The ``ml`` section of an :class:`~repro.core.rsb.RSBReport`."""

    levels: int = 0              # coarsening-ladder depth
    n_fine: int = 0
    n_coarsest: int = 0
    coarsen_ratio: float = 1.0   # n_coarsest / n_fine
    coarse_solver: str = "spectral"   # solver actually used
    coarsen_seconds: float = 0.0
    coarsest_seconds: float = 0.0
    refine_seconds: float = 0.0
    coarse_cut: float = 0.0      # cut on the coarsest graph after FM polish
    fm_moves: int = 0            # kept moves, coarsest polish + all levels
    balance_moves: int = 0       # forced rebalance moves, all levels
    records: list = dataclasses.field(default_factory=list)  # [MLLevel]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["records"] = [r.to_dict() for r in self.records]
        return d


def _fiedler_dense(g: Graph) -> np.ndarray:
    if g.n <= 1:
        return np.zeros(g.n)
    _, vecs = np.linalg.eigh(dense_laplacian_np(g))
    return vecs[:, 1]


def _dense_spectral_parts(graph: Graph, node_w: np.ndarray,
                          nparts: int) -> np.ndarray:
    """Recursive spectral bisection with dense ``eigh`` — exact Fiedler
    vectors, affordable because the coarsest graph is ~coarse_factor·nparts
    nodes.  Splits are weight-proportional so part counts line up with the
    k-way target before the FM polish."""
    parts = np.zeros(graph.n, dtype=np.int64)

    def rec(g, w, idx, p_lo, k):
        if k <= 1 or idx.size <= 1:
            parts[idx] = p_lo
            return
        n_left = k // 2
        lo, hi = _proportional_split(_fiedler_dense(g), w, n_left, k)
        rec(g.sub(lo), w[lo], idx[lo], p_lo, n_left)
        rec(g.sub(hi), w[hi], idx[hi], p_lo + n_left, k - n_left)

    rec(graph, node_w, np.arange(graph.n, dtype=np.int64), 0, nparts)
    return parts


def _rebalance(graph: Graph, parts: np.ndarray, nparts: int,
               node_w: np.ndarray, corridor: tuple,
               max_rounds: int = 8) -> int:
    """Forced balance restoration toward ``corridor`` — the IDEAL corridor,
    not a widened one.  Per round, ONE vectorized gain table covers every
    movable boundary node (nodes of over-cap parts, plus nodes a
    under-floor part could pull in), then moves apply greedily in
    least-cut-damage order under live part weights: out of over-cap parts
    into any adjacent part with room, and into under-floor parts from any
    donor that stays above the floor.  No move creates a new violation, so
    total violation is non-increasing and the loop terminates.

    The V-cycle runs this at every uncoarsening level: violations a coarse
    level cannot fix (its nodes are too heavy) shrink a level finer where
    the same weight is spread over lighter movable nodes, instead of being
    grandfathered in by the corridor-widening convention the FM stages
    use.  Batched rounds (vs one scan per move) matter at the finest
    level, where a closing repair may strand hundreds of nodes' worth of
    excess in one part.  Mutates ``parts`` in place; returns the move
    count."""
    floor, cap = corridor
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    pw = np.bincount(parts, weights=node_w, minlength=nparts)
    pn = np.bincount(parts, minlength=nparts)
    moves = 0
    for _ in range(max_rounds):
        over = pw > cap + _EPS
        under = pw < floor - _EPS
        if not over.any() and not under.any():
            break
        pr, pc = parts[rows], parts[cols]
        push_m = over[pr] & (pc != pr)
        pull_m = under[pc] & ~under[pr] & (pc != pr)
        cand = np.unique(rows[push_m | pull_m])
        if cand.size == 0:
            break
        cidx = np.full(graph.n, -1, dtype=np.int64)
        cidx[cand] = np.arange(cand.size)
        e_sel = cidx[rows] >= 0
        conn = np.bincount(
            cidx[rows[e_sel]] * np.int64(nparts) + pc[e_sel],
            weights=ew[e_sel], minlength=cand.size * nparts,
        ).reshape(cand.size, nparts)
        ar = np.arange(cand.size)
        own_part = parts[cand]
        internal = conn[ar, own_part]
        ext = conn.copy()
        ext[ar, own_part] = -np.inf
        # order ALL candidates by the damage of their best external move;
        # rebalance is forced, so negative gains are admitted — the order
        # just spends the cheapest moves first
        order = np.argsort(-(ext[ar, ext.argmax(1)] - internal),
                           kind="stable")
        did = 0
        for k in order.tolist():
            i = int(cand[k])
            s = int(parts[i])
            wi = float(node_w[i])
            if pn[s] <= 1:
                continue
            row = conn[k]
            t = -1
            if pw[s] > cap + _EPS:
                # push: strongest-connected adjacent part with room
                for q in np.argsort(-row).tolist():
                    if q == s:
                        continue
                    if row[q] <= 0.0:
                        break
                    if pw[q] + wi <= cap + _EPS:
                        t = q
                        break
            elif pw[s] - wi >= floor - _EPS:
                # pull: an adjacent under-floor part, donor stays legal
                uq = np.flatnonzero((row > 0.0) & (pw < floor - _EPS))
                if uq.size:
                    q = int(uq[np.argmax(row[uq])])
                    if pw[q] + wi <= cap + _EPS:
                        t = q
            if t < 0:
                continue
            parts[i] = t
            pw[s] -= wi
            pw[t] += wi
            pn[s] -= 1
            pn[t] += 1
            did += 1
            if not (pw > cap + _EPS).any() and \
                    not (pw < floor - _EPS).any():
                break
        moves += did
        if did == 0:
            break
    return moves


def _bfs_order(graph: Graph) -> np.ndarray:
    """Breadth-first node order, component by component (host loop — only
    ever run on the coarsest graph)."""
    indptr, nbrs = graph.indptr, graph.indices
    seen = np.zeros(graph.n, dtype=bool)
    out: list = []
    for s in range(graph.n):
        if seen[s]:
            continue
        seen[s] = True
        frontier = [s]
        while frontier:
            out.extend(frontier)
            nxt = np.unique(np.concatenate(
                [nbrs[indptr[i]:indptr[i + 1]] for i in frontier]))
            nxt = nxt[~seen[nxt]]
            seen[nxt] = True
            frontier = nxt.tolist()
    return np.asarray(out, dtype=np.int64)


def _greedy_grow_parts(graph: Graph, node_w: np.ndarray,
                       nparts: int) -> np.ndarray:
    """Seeded k-way growth: BFS order, then contiguous cumulative-weight
    chunks of ~total/nparts each.  Crude on purpose — the coarse FM passes
    and the V-cycle refinement do the optimization; this only provides k
    connected-ish, weight-proportional seeds.  Every part gets ≥1 node."""
    order = _bfs_order(graph)
    cw = np.cumsum(node_w[order])
    targets = cw[-1] * (np.arange(1, nparts) / nparts)
    cuts = np.searchsorted(cw, targets, side="left") + 1
    parts = np.empty(graph.n, dtype=np.int64)
    prev = 0
    for p in range(nparts - 1):
        c = max(int(cuts[p]), prev + 1)
        c = min(c, graph.n - (nparts - 1 - p))
        parts[order[prev:c]] = p
        prev = c
    parts[order[prev:]] = nparts - 1
    return parts


def multilevel_partition(
    graph: Graph,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    coarse_factor: int = 8,
    coarse_solver: str = "spectral",
    refine_passes: int = 2,
    stall: int = 32,
    coarse_passes: int = 8,
    fm_below: int = 4096,
    balance_tol: float = 0.05,
    seed: int = 0,
    max_levels: int = 32,
    min_coarsen_ratio: float = 0.95,
) -> tuple[np.ndarray, RSBReport]:
    """The full V-cycle (module docstring): coarsen to
    ~``coarse_factor * nparts`` nodes, partition the coarsest graph
    directly, prolong + boundary-refine level by level.

    ``coarse_solver`` ∈ {"spectral", "greedy"}: dense-eigh recursive
    bisection (falls back to greedy above ``_DENSE_SPECTRAL_MAX`` nodes)
    or seeded BFS growth.  ``min_coarsen_ratio`` stops the ladder when
    matching stalls (a round that shrinks the graph by <5% is not worth a
    level).

    Per-level refinement is hybrid: levels with ≤ ``fm_below`` nodes run
    the hill-climbing boundary FM (``stall``/``refine_passes`` bound it;
    ``coarse_passes`` the full polish at the coarsest level) — coarse
    moves are cheap and their decisions propagate through every finer
    level — while larger levels run the *vectorized* greedy boundary
    sweeps (:func:`~repro.core.refine.refine_boundary`), which smooth the
    prolonged boundaries at a per-sweep cost of one edge scan.  That split
    is what keeps the V-cycle wall sublinear in the FM work: the Python
    heap climb never touches a fine level.

    Returns ``(parts, report)`` with ``report.engine == "multilevel"``,
    per-level :class:`BisectionRecord`/:class:`LevelRecord` rows (so
    benchmark columns work unchanged: ``iterations`` = kept FM moves,
    ``levels`` = ladder depth) and the full :class:`MultilevelStats` on
    ``report.ml``.
    """
    n = graph.n
    if nparts <= 0:
        raise ValueError(f"nparts must be positive, got {nparts}")
    if nparts > n:
        raise ValueError(f"nparts={nparts} exceeds graph size {n}")
    if coarse_solver not in ("spectral", "greedy"):
        raise ValueError(f"unknown coarse_solver: {coarse_solver!r} "
                         "(have 'spectral', 'greedy')")
    node_w = (np.ones(n) if weights is None
              else np.asarray(weights, np.float64))
    stats = MultilevelStats(n_fine=n)
    target = max(int(coarse_factor) * nparts, nparts)
    # Aggregate-weight cap: no coarse node may outweigh 1/coarse_factor of
    # a part.  Self-consistent with the node-count target (total/target is
    # exactly the mean node weight AT the target) and the balance
    # guarantee: coarsest granularity stays ~1/coarse_factor of a part, so
    # a near-ideal split exists at every level of the ladder.
    max_agg_w = node_w.sum() / target

    with obs.timed("engine", engine="multilevel") as t_all:
        # --- down: heavy-edge-matching coarsening ladder
        ladder: list = []   # (fine_graph, fine_node_w, agg) per level
        g, w = graph, node_w
        with obs.timed("coarsen") as t_down:
            lvl = 0
            while g.n > target and lvl < max_levels:
                with obs.timed(f"mlevel:{lvl}", n=int(g.n)) as t_l:
                    agg, n_c = heavy_edge_matching(
                        g, node_weights=w, max_weight=max_agg_w,
                        seed=seed + lvl, rounds=8)
                    if n_c >= min_coarsen_ratio * g.n:
                        break   # matching stalled; a level would buy nothing
                    g_c, w_c = coarsen_graph(g, agg, n_c, node_weights=w)
                ladder.append((g, w, agg))
                stats.records.append(MLLevel(
                    level=lvl, n=g.n, n_coarse=n_c, ratio=n_c / g.n,
                    coarsen_seconds=t_l.seconds))
                g, w = g_c, w_c
                lvl += 1
        stats.levels = len(ladder)
        stats.n_coarsest = g.n
        stats.coarsen_ratio = g.n / max(n, 1)
        stats.coarsen_seconds = t_down.seconds

        # One corridor anchored on the FINE totals — valid at every level
        # because coarsen_graph conserves the node-weight sum exactly.
        mean = node_w.sum() / nparts
        ideal = ((1.0 - balance_tol) * mean, (1.0 + balance_tol) * mean)

        def widened(parts_lvl, w_lvl):
            """The ideal corridor, widened (refine.py convention) to admit
            the state this level starts from — never to demand worse."""
            pw = _part_weights(parts_lvl, w_lvl, nparts)
            return (min(ideal[0], float(pw.min())),
                    max(ideal[1], float(pw.max())))

        # --- coarsest: direct partition + full k-way FM polish
        solver = coarse_solver
        if solver == "spectral" and g.n > _DENSE_SPECTRAL_MAX:
            solver = "greedy"
        stats.coarse_solver = solver
        with obs.timed("coarsest", n=int(g.n), solver=solver) as t_c:
            if solver == "spectral":
                parts = _dense_spectral_parts(g, w, nparts)
            else:
                parts = _greedy_grow_parts(g, w, nparts)
            bal = _rebalance(g, parts, nparts, w, ideal)
            parts, st_c = kway_fm(g, parts, nparts, weights=w,
                                  passes=coarse_passes,
                                  corridor=widened(parts, w))
        stats.coarsest_seconds = t_c.seconds
        stats.coarse_cut = st_c.cut_after
        stats.fm_moves += st_c.moves_applied
        stats.balance_moves += bal

        # --- up: prolong by aggregate copy, restore balance toward the
        # ideal corridor (finer granularity makes it reachable), then run
        # the bounded boundary refinement.
        if ladder:
            for lvl in range(len(ladder) - 1, -1, -1):
                g_f, w_f, agg = ladder[lvl]
                with obs.timed(f"mlevel:{lvl}", n=int(g_f.n)) as t_r:
                    parts = parts[agg]
                    bal = _rebalance(g_f, parts, nparts, w_f, ideal)
                    if g_f.n <= fm_below:
                        parts, st = kway_fm_boundary(
                            g_f, parts, nparts, weights=w_f,
                            passes=refine_passes,
                            stall=max(stall, g_f.n // 16),
                            corridor=widened(parts, w_f))
                    else:
                        parts, st = refine_boundary(
                            g_f, parts, nparts, weights=w_f,
                            sweeps=2 * refine_passes,
                            corridor=widened(parts, w_f))
                rec = stats.records[lvl]
                rec.refine_seconds = t_r.seconds
                rec.fm_moves = st.moves_applied
                rec.balance_moves = bal
                rec.cut = st.cut_after
                stats.fm_moves += st.moves_applied
                stats.balance_moves += bal
        else:
            # Degenerate ladder (input already coarse): still run one
            # bounded boundary sweep under the mlevel:0 span, keeping both
            # the refinement contract and the drift guard's span set.
            with obs.timed("mlevel:0", n=int(n)) as t_r:
                bal = _rebalance(graph, parts, nparts, node_w, ideal)
                parts, st = kway_fm_boundary(
                    graph, parts, nparts, weights=node_w,
                    passes=refine_passes, stall=stall,
                    corridor=widened(parts, node_w))
            stats.records.append(MLLevel(
                level=0, n=n, n_coarse=n, ratio=1.0,
                refine_seconds=t_r.seconds, fm_moves=st.moves_applied,
                balance_moves=bal, cut=st.cut_after))
            stats.fm_moves += st.moves_applied
            stats.balance_moves += bal
        # --- finalize: the V-cycle's own closing repair.  Per-level FM can
        # strand fragments (a part split in two by a move sequence), and a
        # downstream repair stage would heal them by moving whole fragments
        # — wrecking balance at exactly the granularity where the corridor
        # was finally reachable.  Repairing INSIDE the stage (against the
        # ideal corridor) followed by one more rebalance keeps the stage's
        # contract: connected, corridor-balanced raw labels.
        with obs.timed("finalize") as t_fin:
            parts, _rep = repair_components(graph, parts, nparts,
                                            weights=node_w, corridor=ideal)
            stats.balance_moves += _rebalance(graph, parts, nparts, node_w,
                                              ideal)
            # polish the cut damage the forced moves left behind (cheap:
            # two vectorized sweeps)
            parts, _pol = refine_boundary(graph, parts, nparts,
                                          weights=node_w, sweeps=2,
                                          corridor=widened(parts, node_w))
        stats.refine_seconds = (
            sum(r.refine_seconds for r in stats.records) + t_fin.seconds)

    obs.gauge_set("ml_levels", stats.levels)
    obs.gauge_set("ml_coarsen_ratio", stats.coarsen_ratio)
    obs.counter_add("ml_fm_moves", stats.fm_moves)
    obs.gauge_set("edge_cut", edge_cut(graph, parts))

    records = [BisectionRecord(
        level=r.level, size=r.n, nparts=nparts, method="hem+kway",
        iterations=r.fm_moves, eigenvalue=0.0, residual=0.0,
        seconds=r.refine_seconds, levels=stats.levels,
        split_seconds=r.coarsen_seconds) for r in stats.records]
    levels = [LevelRecord(
        level=r.level, n_nodes=1, total_size=r.n, buckets=[],
        iterations=r.fm_moves, solve_seconds=r.refine_seconds,
        split_seconds=r.coarsen_seconds) for r in stats.records]
    report = RSBReport(records=records, seconds=t_all.seconds,
                       levels=levels, engine="multilevel",
                       multilevel=True, ml=stats)
    return parts, report
