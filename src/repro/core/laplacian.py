"""Assembled Laplacian operators (ELL / CSR) + dense oracle.

The finest level of the paper's multigrid uses the gather-scatter Laplacian
(`repro.core.gather_scatter`); coarser levels and generic-graph inputs use an
assembled form (paper §7: "we generate L₀, L₁, L₂, … as CSR matrices").  On
TPU we store the padded **ELL** layout — static shape, row-contiguous,
VMEM-tileable — and the matvec is the Pallas `ell_spmv` kernel with a pure
jnp fallback.  Both layouts are kernel-backed: 2-D (n, w) operators use the
flat kernel, 3-D (B, n, w) leading-batch-dim operators (the level-synchronous
engine's and the batched AMG's layout) use the batched grid variant.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.mesh.graphs import Graph, csr_to_ell


@dataclasses.dataclass(frozen=True)
class EllLaplacian:
    """L x = deg ⊙ x − A x with A in padded ELL form.

    cols/vals: (n, width) — or (B, n, width) for a **batched** operator
    applying B independent Laplacians to (B, n) vectors in one shot (the
    level-synchronous RSB engine's layout).  Padding entries have val 0
    (col = row id).

    Registered as a pytree (cols/vals/diag are leaves; n/use_kernel are
    static) so a batched solve can take the operator as a *traced* jit
    argument: one compiled trace serves every operator of the same shape
    bucket instead of one trace per instance.
    """

    cols: jax.Array    # (..., n, width) int32
    vals: jax.Array    # (..., n, width) float32 — adjacency weights
    diag: jax.Array    # (..., n) float32 — Σ_j ω_ij (true Laplacian diagonal)
    n: int
    use_kernel: bool = False

    def __hash__(self):
        return id(self)

    def adj_apply(self, x: jax.Array) -> jax.Array:
        if self.cols.ndim == 3:
            if self.use_kernel:
                from repro.kernels.ell_spmv import ops as _ops

                return _ops.ell_spmv_batched(self.cols, self.vals, x)
            B = self.cols.shape[0]
            taken = jnp.take_along_axis(
                x, self.cols.reshape(B, -1), axis=-1
            ).reshape(self.cols.shape)
            return (self.vals * taken).sum(-1)
        if self.use_kernel:
            from repro.kernels.ell_spmv import ops as _ops

            return _ops.ell_spmv(self.cols, self.vals, x)
        return (self.vals * jnp.take(x, self.cols, axis=-1)).sum(-1)

    def apply(self, x: jax.Array) -> jax.Array:
        return self.diag * x - self.adj_apply(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)


jax.tree_util.register_dataclass(
    EllLaplacian,
    data_fields=("cols", "vals", "diag"),
    meta_fields=("n", "use_kernel"),
)


def fill_ell_block(graph: Graph, C: np.ndarray, V: np.ndarray, D: np.ndarray,
                   col_offset: int = 0) -> None:
    """Fill one graph's rows of a padded ELL block (C/V/D are views of the
    target rows; rows past graph.n keep self-columns and zero vals/diag,
    so L acts as 0 on them).  The single home of the padding invariants —
    the padded, batched, and packed builders all delegate here."""
    cols, vals = csr_to_ell(graph, max_row=None)
    nb, wb = cols.shape
    if wb > C.shape[1]:
        raise ValueError("width_pad below max degree")
    C[:nb, :wb] = cols + col_offset
    V[:nb, :wb] = vals
    np.add.at(D[:nb], graph.rows, graph.weights)


def ell_laplacian_batched(
    graphs: list, n_pad: int, width_pad: int, b_pad: int,
    *, use_kernel: bool = False,
) -> EllLaplacian:
    """Stack B assembled Laplacians into one (b_pad, n_pad, width_pad) ELL
    operator.  Rows past each graph's n — and whole batch-padding rows —
    have zero vals and zero diag, so L acts as 0 on them."""
    C = np.tile(
        np.arange(n_pad, dtype=np.int64)[None, :, None], (b_pad, 1, width_pad)
    )
    V = np.zeros((b_pad, n_pad, width_pad), dtype=np.float64)
    D = np.zeros((b_pad, n_pad), dtype=np.float64)
    for b, g in enumerate(graphs):
        fill_ell_block(g, C[b], V[b], D[b])
    return EllLaplacian(
        cols=jnp.asarray(C.astype(np.int32)),
        vals=jnp.asarray(V.astype(np.float32)),
        diag=jnp.asarray(D.astype(np.float32)),
        n=n_pad,
        use_kernel=use_kernel,
    )


def ell_laplacian(graph: Graph, *, use_kernel: bool = False) -> EllLaplacian:
    cols, vals = csr_to_ell(graph)
    deg = np.zeros(graph.n, dtype=np.float64)
    np.add.at(deg, graph.rows, graph.weights)
    return EllLaplacian(
        cols=jnp.asarray(cols.astype(np.int32)),
        vals=jnp.asarray(vals.astype(np.float32)),
        diag=jnp.asarray(deg.astype(np.float32)),
        n=graph.n,
        use_kernel=use_kernel,
    )


def dense_laplacian_np(graph: Graph) -> np.ndarray:
    """Dense float64 Laplacian — the test oracle."""
    A = np.zeros((graph.n, graph.n), dtype=np.float64)
    A[graph.rows, graph.indices] = graph.weights
    return np.diag(A.sum(1)) - A


def fiedler_oracle_np(graph: Graph) -> tuple[float, np.ndarray]:
    """(λ₂, y₂) by dense eigendecomposition — ground truth for small graphs."""
    L = dense_laplacian_np(graph)
    w, v = np.linalg.eigh(L)
    return float(w[1]), v[:, 1]
