"""Assembled Laplacian operators (ELL / CSR) + dense oracle.

The finest level of the paper's multigrid uses the gather-scatter Laplacian
(`repro.core.gather_scatter`); coarser levels and generic-graph inputs use an
assembled form (paper §7: "we generate L₀, L₁, L₂, … as CSR matrices").  On
TPU we store the padded **ELL** layout — static shape, row-contiguous,
VMEM-tileable — and the matvec is the Pallas `ell_spmv` kernel with a pure
jnp fallback.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.mesh.graphs import Graph, csr_to_ell


@dataclasses.dataclass(frozen=True)
class EllLaplacian:
    """L x = deg ⊙ x − A x with A in padded ELL form.

    cols/vals: (n, width) — or (B, n, width) for a **batched** operator
    applying B independent Laplacians to (B, n) vectors in one shot (the
    level-synchronous RSB engine's layout).  Padding entries have val 0
    (col = row id).

    Registered as a pytree (cols/vals/diag are leaves; n/use_kernel are
    static) so a batched solve can take the operator as a *traced* jit
    argument: one compiled trace serves every operator of the same shape
    bucket instead of one trace per instance.
    """

    cols: jax.Array    # (..., n, width) int32
    vals: jax.Array    # (..., n, width) float32 — adjacency weights
    diag: jax.Array    # (..., n) float32 — Σ_j ω_ij (true Laplacian diagonal)
    n: int
    use_kernel: bool = False

    def __hash__(self):
        return id(self)

    def adj_apply(self, x: jax.Array) -> jax.Array:
        if self.cols.ndim == 3:
            B = self.cols.shape[0]
            taken = jnp.take_along_axis(
                x, self.cols.reshape(B, -1), axis=-1
            ).reshape(self.cols.shape)
            return (self.vals * taken).sum(-1)
        if self.use_kernel:
            from repro.kernels.ell_spmv import ops as _ops

            return _ops.ell_spmv(self.cols, self.vals, x)
        return (self.vals * jnp.take(x, self.cols, axis=-1)).sum(-1)

    def apply(self, x: jax.Array) -> jax.Array:
        return self.diag * x - self.adj_apply(x)

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.apply(x)


jax.tree_util.register_dataclass(
    EllLaplacian,
    data_fields=("cols", "vals", "diag"),
    meta_fields=("n", "use_kernel"),
)


def ell_laplacian(graph: Graph, *, use_kernel: bool = False) -> EllLaplacian:
    cols, vals = csr_to_ell(graph)
    deg = np.zeros(graph.n, dtype=np.float64)
    np.add.at(deg, graph.rows, graph.weights)
    return EllLaplacian(
        cols=jnp.asarray(cols.astype(np.int32)),
        vals=jnp.asarray(vals.astype(np.float32)),
        diag=jnp.asarray(deg.astype(np.float32)),
        n=graph.n,
        use_kernel=use_kernel,
    )


def dense_laplacian_np(graph: Graph) -> np.ndarray:
    """Dense float64 Laplacian — the test oracle."""
    A = np.zeros((graph.n, graph.n), dtype=np.float64)
    A[graph.rows, graph.indices] = graph.weights
    return np.diag(A.sum(1)) - A


def fiedler_oracle_np(graph: Graph) -> tuple[float, np.ndarray]:
    """(λ₂, y₂) by dense eigendecomposition — ground truth for small graphs."""
    L = dense_laplacian_np(graph)
    w, v = np.linalg.eigh(L)
    return float(w[1]), v[:, 1]
