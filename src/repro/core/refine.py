"""Post-bisection repair + boundary refinement (the parRSB quality stage).

parRSB never ships raw bisection labels: after the spectral tree bottoms
out, a post-processing pass (paper §6; Sphynx makes the same point for GPU
spectral partitioners) repairs disconnected parts and smooths part
boundaries, recovering the cut/connectivity quality the bisection labels
leave on the table.  This module implements both passes on the assembled
dual graph, host-side NumPy, as pipeline `post` stages:

* **Connected-component repair** (:func:`repair_components`) — label the
  components of every part's induced subgraph (one vectorized
  `connected_labels` sweep over the intra-part edges), keep each part's
  heaviest component, and reassign every other fragment to the neighboring
  part with the maximum shared edge weight (ties toward the lighter part).
  A fragment has *zero* edges to the rest of its own part, so each move
  strictly decreases the cut by the shared weight — repair can only
  improve the cut, and it terminates (the cut is bounded below).  Moves
  prefer destinations that stay under the balance cap; when no sharing
  part fits, connectivity wins and the move is recorded as *forced*.

* **Greedy weighted boundary refinement** (:func:`refine_boundary`) —
  Fiduccia–Mattheyses-style single-node moves over the boundary frontier.
  Each sweep computes, fully vectorized, every boundary node's edge-weight
  connection to each part; the gain of moving node i to part q is
  ``conn[i, q] − conn[i, part[i]]``.  Positive-gain candidates are applied
  in descending gain order under two guards: (a) a node is skipped if any
  neighbor already moved this sweep (its precomputed gain would be stale),
  and (b) the move must keep both endpoint parts inside the weight-balance
  corridor ``[floor, cap]`` — when the best-connected target part would
  overflow the cap, the move falls back to the best *feasible*
  positive-gain target instead of skipping the node.  Applied gains are
  exact, so the cut is strictly non-increasing across sweeps.

The balance corridor is computed ONCE per post chain — from the part
weights the chain starts with — and threaded through every stage via the
``corridor=`` keyword (the pipeline does this; so do :func:`refine_stage`
and :func:`repair_refine` for their internal sub-passes).  Recomputing it
per stage would let a cap-exceeding forced repair move permanently widen
the cap for every later stage.  Each stage records the corridor it used in
``PostStats.corridor``.

Single-node moves can disconnect a part (moving an articulation node), so
:func:`refine_stage` — the "refine" stage the pipeline registers — closes
its FM sweeps with a repair pass: the invariant handed downstream is
**zero disconnected parts** (on a globally connected graph) at a cut no
worse than the bisection's.  :func:`repair_refine` composes the default
post pair (repair, then refine_stage) as one call for direct library use.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.mesh.graphs import Graph, connected_labels


@dataclasses.dataclass
class SweepRecord:
    """One FM sweep: moves applied and the cut on either side."""

    sweep: int
    moves: int
    cut_before: float
    cut_after: float


@dataclasses.dataclass
class PostStats:
    """The `post` section of an :class:`~repro.core.rsb.RSBReport`."""

    stages: list = dataclasses.field(default_factory=list)  # stage names run
    fragments_repaired: int = 0
    forced_moves: int = 0        # fragment moves that had to exceed the cap
    unrepaired_fragments: int = 0  # left behind when repair's round cap hit
    moves_applied: int = 0       # FM single-node moves (kway: kept moves)
    sweeps: list = dataclasses.field(default_factory=list)  # [SweepRecord]
    corridor: tuple | None = None  # (floor, cap) the stage enforced
    kway: object | None = None   # kway.KwayStats when a "kway" stage ran
    cut_before: float = 0.0
    cut_after: float = 0.0
    seconds: float = 0.0

    def row(self) -> dict:
        """JSON-able summary (benchmark rows, smoke gate)."""
        return {
            "stages": list(self.stages),
            "fragments_repaired": self.fragments_repaired,
            "forced_moves": self.forced_moves,
            "unrepaired_fragments": self.unrepaired_fragments,
            "moves_applied": self.moves_applied,
            "sweeps": [dataclasses.asdict(s) for s in self.sweeps],
            "corridor": list(self.corridor) if self.corridor else None,
            "kway": self.kway.row() if self.kway is not None else None,
            "cut_before": self.cut_before,
            "cut_after": self.cut_after,
            "seconds": self.seconds,
        }

    def to_dict(self) -> dict:
        return self.row()

    @classmethod
    def from_dict(cls, d: dict) -> "PostStats":
        """Rebuild from :meth:`to_dict` output (``kway`` comes back as its
        raw row dict — consumers read it like ``KwayStats.row()``)."""
        s = cls(stages=list(d.get("stages", [])),
                fragments_repaired=d.get("fragments_repaired", 0),
                forced_moves=d.get("forced_moves", 0),
                unrepaired_fragments=d.get("unrepaired_fragments", 0),
                moves_applied=d.get("moves_applied", 0),
                corridor=tuple(d["corridor"]) if d.get("corridor") else None,
                kway=d.get("kway"),
                cut_before=d.get("cut_before", 0.0),
                cut_after=d.get("cut_after", 0.0),
                seconds=d.get("seconds", 0.0))
        s.sweeps = [SweepRecord(**r) for r in d.get("sweeps", [])]
        return s


def edge_cut(graph: Graph, parts: np.ndarray) -> float:
    """Σ ω over cut edges, each undirected edge counted once."""
    cut = parts[graph.rows] != parts[graph.indices]
    return float(graph.weights[cut].sum() / 2.0)


def _part_weights(parts, w, nparts):
    return np.bincount(parts, weights=w, minlength=nparts)


def _balance_corridor(part_w: np.ndarray, balance_tol: float):
    """[floor, cap] weight corridor.  Widened to include the initial state,
    so a partition that already violates the tolerance is never made worse
    but is not required to be fixed here (that is the bisector's job)."""
    mean = part_w.mean()
    cap = max((1.0 + balance_tol) * mean, float(part_w.max()))
    floor = min((1.0 - balance_tol) * mean, float(part_w.min()))
    return floor, cap


def balance_corridor(
    parts: np.ndarray,
    nparts: int,
    weights: np.ndarray | None,
    balance_tol: float,
) -> tuple:
    """The (floor, cap) corridor the post chain starting at ``parts``
    enforces.  Computed once per chain and threaded through every stage via
    ``corridor=`` — see the module docstring for why it must not be
    recomputed mid-chain."""
    parts = np.asarray(parts, dtype=np.int64)
    w = np.ones(parts.size) if weights is None else np.asarray(weights,
                                                               np.float64)
    return _balance_corridor(_part_weights(parts, w, nparts), balance_tol)


def repair_components(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    max_rounds: int = 8,
) -> tuple[np.ndarray, PostStats]:
    """Reassign every disconnected fragment to its best-connected neighbor
    part.  Strictly cut-decreasing; see the module docstring for the move
    rule.  Rounds iterate because a receiving part may itself have lost its
    anchoring fragment in the same round; convergence is typically 1–2
    rounds (each round strictly decreases the cut).

    ``corridor`` is the post chain's fixed (floor, cap); when None (direct
    library call outside a chain) it is computed from the incoming labels.
    Fragments with no cut edges at all (islands of a globally disconnected
    graph) are left in place — no reassignment can connect them.
    """
    parts = np.asarray(parts, dtype=np.int64).copy()
    n = graph.n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    part_w = _part_weights(parts, w, nparts)
    if corridor is None:
        corridor = _balance_corridor(part_w, balance_tol)
    _, cap = corridor
    stats = PostStats(stages=["repair"], corridor=tuple(corridor),
                      cut_before=edge_cut(graph, parts))
    with obs.timed("repair") as t:
        deferred = 0
        for round_no in range(max_rounds):
            deferred = 0
            intra = parts[rows] == parts[cols]
            comp = connected_labels(n, rows[intra], cols[intra])
            n_comp = int(comp.max()) + 1 if n else 0
            comp_w = np.bincount(comp, weights=w, minlength=n_comp)
            # Representative node per component → its (uniform) part.
            _, reps = np.unique(comp, return_index=True)
            part_of_comp = parts[reps]
            # Keep each part's heaviest component (ties: lowest label).
            keep = np.zeros(n_comp, dtype=bool)
            order = np.lexsort((np.arange(n_comp), -comp_w, part_of_comp))
            first = np.r_[True, part_of_comp[order][1:] != part_of_comp[order][:-1]]
            keep[order[first]] = True
            frag_ids = np.flatnonzero(~keep)
            if frag_ids.size == 0:
                break
            # Shared edge weight fragment → foreign part, over cut edges whose
            # source lies in a fragment (compact fragment indexing keeps the
            # bincount at F·nparts, not n·nparts).
            fidx = -np.ones(n_comp, dtype=np.int64)
            fidx[frag_ids] = np.arange(frag_ids.size)
            cut_e = np.flatnonzero(~intra)
            fsrc = fidx[comp[rows[cut_e]]]
            sel = fsrc >= 0
            shared = np.bincount(
                fsrc[sel] * np.int64(nparts) + parts[cols[cut_e[sel]]],
                weights=ew[cut_e[sel]], minlength=frag_ids.size * nparts,
            ).reshape(frag_ids.size, nparts)

            moved_any = False
            received = np.zeros(nparts, dtype=bool)
            for k, f in enumerate(frag_ids):
                src = int(part_of_comp[f])
                if received[src]:
                    # The part just gained members; this fragment may now be
                    # connected to them, so its zero-internal-edge premise (the
                    # strict-cut-decrease argument) no longer holds.  Defer to
                    # the next round, which recomputes components.
                    deferred += 1
                    continue
                cand = np.flatnonzero(shared[k] > 0)
                if cand.size == 0:
                    continue  # island: no foreign edges to follow
                fw = comp_w[f]
                fits = cand[part_w[cand] + fw <= cap]
                pool = fits if fits.size else cand
                best_shared = shared[k, pool].max()
                ties = pool[shared[k, pool] == best_shared]
                tgt = int(ties[np.argmin(part_w[ties])])  # ties → lighter part
                if not fits.size:
                    stats.forced_moves += 1
                parts[comp == f] = tgt
                part_w[tgt] += fw
                part_w[src] -= fw
                received[tgt] = True
                stats.fragments_repaired += 1
                moved_any = True
            if not moved_any:
                break
        else:
            # Round cap hit with fragments still deferred: the contract
            # (zero disconnected parts) is broken — make it diagnosable.
            stats.unrepaired_fragments = deferred

        stats.cut_after = edge_cut(graph, parts)
    stats.seconds = t.seconds
    obs.counter_add("fragments_repaired", stats.fragments_repaired)
    obs.counter_add("forced_moves", stats.forced_moves)
    return parts, stats


def refine_boundary(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    sweeps: int = 4,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
) -> tuple[np.ndarray, PostStats]:
    """Greedy weighted FM-style boundary refinement (module docstring).

    The cut never increases: only strictly-positive-gain moves are applied,
    each under a stale-gain guard (skip if a neighbor already moved this
    sweep) and the weight-balance corridor.  A candidate whose
    best-connected target would overflow the cap falls back to the best
    *feasible* positive-gain target.
    """
    parts = np.asarray(parts, dtype=np.int64).copy()
    n = graph.n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    rows, cols, ew = graph.rows, graph.indices, graph.weights
    indptr, nbrs = graph.indptr, graph.indices
    part_w = _part_weights(parts, w, nparts)
    part_n = np.bincount(parts, minlength=nparts)
    if corridor is None:
        corridor = _balance_corridor(part_w, balance_tol)
    floor, cap = corridor
    stats = PostStats(stages=["refine"], corridor=tuple(corridor),
                      cut_before=edge_cut(graph, parts))
    with obs.timed("refine_sweeps") as t:
        for s in range(sweeps):
            pr, pc = parts[rows], parts[cols]
            cut_mask = pr != pc
            cut0 = float(ew[cut_mask].sum() / 2.0)
            bmask = np.zeros(n, dtype=bool)
            bmask[rows[cut_mask]] = True
            bnodes = np.flatnonzero(bmask)
            if bnodes.size == 0:
                break
            bidx = -np.ones(n, dtype=np.int64)
            bidx[bnodes] = np.arange(bnodes.size)
            e_sel = bidx[rows] >= 0
            conn = np.bincount(
                bidx[rows[e_sel]] * np.int64(nparts) + pc[e_sel],
                weights=ew[e_sel], minlength=bnodes.size * nparts,
            ).reshape(bnodes.size, nparts)
            own = parts[bnodes]
            ar = np.arange(bnodes.size)
            internal = conn[ar, own].copy()
            conn[ar, own] = -np.inf
            best = conn.argmax(1)
            gain = conn[ar, best] - internal
            cand = np.flatnonzero(gain > 1e-12)
            order = cand[np.argsort(-gain[cand], kind="stable")]

            moved = np.zeros(n, dtype=bool)
            applied = 0
            for k in order:
                node = int(bnodes[k])
                nb = nbrs[indptr[node]:indptr[node + 1]]
                if moved[nb].any():
                    continue  # stale gain: a neighbor changed sides this sweep
                src, wn = int(parts[node]), w[node]
                if part_w[src] - wn < floor or part_n[src] <= 1:
                    continue  # never empty or under-floor the source part
                # Best *feasible* positive-gain target: when the argmax part
                # would overflow the cap, fall back to the next-best part that
                # both improves the cut and fits the corridor.
                row = conn[k]
                pos = np.flatnonzero(row - internal[k] > 1e-12)
                fits = pos[part_w[pos] + wn <= cap]
                if fits.size == 0:
                    continue
                tgt = int(fits[np.argmax(row[fits])])
                parts[node] = tgt
                part_w[tgt] += wn
                part_w[src] -= wn
                part_n[tgt] += 1
                part_n[src] -= 1
                moved[node] = True
                applied += 1
            cut1 = edge_cut(graph, parts)
            stats.sweeps.append(SweepRecord(sweep=s, moves=applied,
                                            cut_before=cut0, cut_after=cut1))
            stats.moves_applied += applied
            if applied == 0:
                break

        stats.cut_after = edge_cut(graph, parts)
    stats.seconds = t.seconds
    obs.counter_add("refine_moves", stats.moves_applied)
    obs.counter_add("refine_sweeps", len(stats.sweeps))
    return parts, stats


def close_with_repair(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    stats: PostStats,
    *,
    weights: np.ndarray | None = None,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
) -> tuple[np.ndarray, PostStats]:
    """Close an FM stage with a repair pass and merge its accounting into
    ``stats`` — the shared tail of the "refine" and "kway" stages, so the
    two report repair activity identically."""
    parts, r = repair_components(graph, parts, nparts, weights=weights,
                                 balance_tol=balance_tol, corridor=corridor)
    stats.fragments_repaired += r.fragments_repaired
    stats.forced_moves += r.forced_moves
    stats.unrepaired_fragments = r.unrepaired_fragments
    stats.cut_after = r.cut_after
    stats.seconds += r.seconds
    return parts, stats


def refine_stage(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    sweeps: int = 4,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
) -> tuple[np.ndarray, PostStats]:
    """The pipeline's "refine" stage: FM boundary sweeps + a closing repair
    pass, so articulation moves cannot leave a disconnected part.  Both
    passes are cut-non-increasing, so the stage is too.  One corridor
    (computed here from the incoming labels unless the chain supplies it)
    governs both passes."""
    if corridor is None:
        corridor = balance_corridor(parts, nparts, weights, balance_tol)
    parts, stats = refine_boundary(graph, parts, nparts, weights=weights,
                                   sweeps=sweeps, balance_tol=balance_tol,
                                   corridor=corridor)
    return close_with_repair(graph, parts, nparts, stats, weights=weights,
                             balance_tol=balance_tol, corridor=corridor)


def repair_refine(
    graph: Graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    sweeps: int = 4,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    repair: bool = True,
    refine: bool = True,
) -> tuple[np.ndarray, PostStats]:
    """The default post pair — :func:`repair_components` then
    :func:`refine_stage` — composed as one call (exactly what the pipeline
    runs for ``post=("repair", "refine")``).  One corridor, computed from
    the incoming labels, governs the whole chain."""
    with obs.timed("repair_refine") as t_chain:
        if corridor is None:
            corridor = balance_corridor(parts, nparts, weights, balance_tol)
        stats = PostStats(corridor=tuple(corridor),
                          cut_before=edge_cut(graph, parts))
        kw = dict(weights=weights, balance_tol=balance_tol, corridor=corridor)
        if repair:
            parts, r = repair_components(graph, parts, nparts, **kw)
            stats.stages.append("repair")
            stats.fragments_repaired += r.fragments_repaired
            stats.forced_moves += r.forced_moves
            stats.unrepaired_fragments = r.unrepaired_fragments
        if refine:
            parts, f = refine_stage(graph, parts, nparts, sweeps=sweeps, **kw)
            stats.stages.append("refine")
            stats.fragments_repaired += f.fragments_repaired
            stats.forced_moves += f.forced_moves
            stats.unrepaired_fragments = f.unrepaired_fragments
            stats.moves_applied += f.moves_applied
            stats.sweeps.extend(f.sweeps)
        stats.cut_after = edge_cut(graph, parts)
    stats.seconds = t_chain.seconds
    return parts, stats
