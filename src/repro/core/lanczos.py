"""Lanczos with restarts for the Fiedler pair (paper §6).

A fixed-width Lanczos window (full reorthogonalization — necessary in fp32)
runs as one jitted `lax.scan`; the small tridiagonal Ritz problem is solved
with `jnp.linalg.eigh`; the smallest Ritz vector restarts the window.  The
constant vector is deflated explicitly at every step (paper Eq. 4.11).

Residual estimate: the classic `|β_m · s_m|` bound (last component of the
Ritz eigenvector scaled by the final off-diagonal), refined with one true
matvec at restart boundaries.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.flexcg import _project_out_ones


@dataclasses.dataclass
class LanczosInfo:
    restarts: int
    eigenvalue: float
    residual: float
    converged: bool


@partial(jax.jit, static_argnums=(0, 3))
def _lanczos_window(op, q0, mask, m):
    """One restart window: returns (Q (m,n), alpha (m,), beta (m,)).

    beta[j] is the subdiagonal linking step j to j+1 (beta[m-1] is the
    residual coupling used in the Ritz residual bound).
    """
    n = q0.shape[0]

    def step(carry, j):
        Q, q, q_prev, beta_prev = carry
        w = op(q) - beta_prev * q_prev
        alpha = jnp.sum(w * q)
        w = w - alpha * q
        # Full reorthogonalization against the window + constants (twice is
        # enough — Parlett): rows ≥ j of Q are zero so the mask is implicit.
        for _ in range(2):
            w = w - Q.T @ (Q @ w)
            w = _project_out_ones(w, mask)
        beta = jnp.linalg.norm(w)
        q_next = jnp.where(beta > 1e-12, w / jnp.maximum(beta, 1e-30), 0.0)
        Q = Q.at[j].set(q)
        return (Q, q_next, q, beta), (alpha, beta)

    Q0 = jnp.zeros((m, n), q0.dtype)
    (Q, _, _, _), (alpha, beta) = jax.lax.scan(
        step, (Q0, q0, jnp.zeros_like(q0), jnp.asarray(0.0, q0.dtype)),
        jnp.arange(m),
    )
    return Q, alpha, beta


def _tridiag_eigh(alpha: jax.Array, beta: jax.Array):
    m = alpha.shape[0]
    T = jnp.diag(alpha) + jnp.diag(beta[:-1], 1) + jnp.diag(beta[:-1], -1)
    return jnp.linalg.eigh(T)


def lanczos_fiedler(
    op: Callable[[jax.Array], jax.Array],
    n: int,
    *,
    mask: jax.Array | None = None,
    key: jax.Array | None = None,
    b0: jax.Array | None = None,
    window: int = 30,
    max_restarts: int = 50,
    tol: float = 1e-3,
) -> tuple[jax.Array, LanczosInfo]:
    """Return (y₂ approximation, info)."""
    mask = jnp.ones((n,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    if b0 is None:
        key = jax.random.PRNGKey(0) if key is None else key
        q = jax.random.normal(key, (n,), jnp.float32)
    else:
        q = b0.astype(jnp.float32)
    q = _project_out_ones(q, mask)
    q = q / jnp.maximum(jnp.linalg.norm(q), 1e-30)

    opj = jax.jit(op)
    theta = jnp.asarray(0.0)
    res = jnp.asarray(jnp.inf)
    y = q
    converged = False
    r = 0
    for r in range(1, max_restarts + 1):
        Q, alpha, beta = _lanczos_window(op, q, mask, window)
        evals, evecs = _tridiag_eigh(alpha, beta)
        s = evecs[:, 0]
        theta = evals[0]
        y = Q.T @ s
        ynorm = jnp.maximum(jnp.linalg.norm(y), 1e-30)
        y = y / ynorm
        # Cheap bound, then the true residual (one matvec).
        Ly = opj(y)
        res = jnp.linalg.norm(Ly - theta * y)
        if float(res) <= tol * max(float(theta), 1e-12):
            converged = True
            break
        q = _project_out_ones(y, mask)
        q = q / jnp.maximum(jnp.linalg.norm(q), 1e-30)

    info = LanczosInfo(
        restarts=r,
        eigenvalue=float(theta),
        residual=float(res),
        converged=converged,
    )
    return y, info
