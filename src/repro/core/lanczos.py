"""Lanczos with restarts for the Fiedler pair (paper §6).

A fixed-width Lanczos window (full reorthogonalization — necessary in fp32)
runs as one jitted `lax.scan`; the small tridiagonal Ritz problem is solved
with `jnp.linalg.eigh`; the smallest Ritz vector restarts the window.  The
constant vector is deflated explicitly at every step (paper Eq. 4.11).

Residual estimate: the classic `|β_m · s_m|` bound (last component of the
Ritz eigenvector scaled by the final off-diagonal), refined with one true
matvec at restart boundaries.

**Batched variant** (`lanczos_fiedler_batched`): runs B independent Fiedler
solves — all bisections of one RSB tree level — through a single jitted
restart step.  The subproblems are **packed** into one flat (N,) vector
(each problem owns a contiguous, zero-padded block; `seg[j]` names slot
j's problem) and every per-problem reduction (α, β, reorthogonalization
dots, constant deflation, Ritz-vector norms) becomes a one-hot
segment matmul, while the small tridiagonal Ritz problems are solved with
one vmapped `eigh` over the segment axis.  The operator is a block-diagonal
*pytree* (`EllLaplacian`/`GSLaplacian` over the packed slots) passed as a
traced argument, so the compiled trace is keyed only by
(N, n_seg, window): because a tree level's subproblems partition the root
set, every level of a run — and every run on the same mesh — reuses ONE
trace, with no padded-lane compute.  Convergence is tracked per subproblem
on the host; a converged problem's Ritz output is frozen while the
remaining segments keep iterating.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flexcg import _project_out_ones


@dataclasses.dataclass
class LanczosInfo:
    restarts: int
    eigenvalue: float
    residual: float
    converged: bool
    breakdown: bool = False  # non-finite Ritz pair: (θ, res) are unusable


@dataclasses.dataclass
class BatchedLanczosInfo:
    """Per-subproblem convergence bookkeeping for a batched solve."""

    restarts: np.ndarray     # (B,) restart count at convergence (or the cap)
    eigenvalue: np.ndarray   # (B,)
    residual: np.ndarray     # (B,)
    converged: np.ndarray    # (B,) bool
    breakdown: np.ndarray | None = None  # (B,) bool: frozen on a stale pair


def _window_body(op, q0, mask, m):
    """One restart window: returns (Q (m,n), alpha (m,), beta (m,)).

    beta[j] is the subdiagonal linking step j to j+1 (beta[m-1] is the
    residual coupling used in the Ritz residual bound).
    """
    n = q0.shape[0]

    def step(carry, j):
        Q, q, q_prev, beta_prev = carry
        w = op(q) - beta_prev * q_prev
        alpha = jnp.sum(w * q)
        w = w - alpha * q
        # Full reorthogonalization against the window + constants (twice is
        # enough — Parlett): rows ≥ j of Q are zero so the mask is implicit.
        for _ in range(2):
            w = w - Q.T @ (Q @ w)
            w = _project_out_ones(w, mask)
        beta = jnp.linalg.norm(w)
        q_next = jnp.where(beta > 1e-12, w / jnp.maximum(beta, 1e-30), 0.0)
        Q = Q.at[j].set(q)
        return (Q, q_next, q, beta), (alpha, beta)

    Q0 = jnp.zeros((m, n), q0.dtype)
    (Q, _, _, _), (alpha, beta) = jax.lax.scan(
        step, (Q0, q0, jnp.zeros_like(q0), jnp.asarray(0.0, q0.dtype)),
        jnp.arange(m),
    )
    return Q, alpha, beta


# Two jit forms of the window.  Operator dataclasses (EllLaplacian /
# GSLaplacian — registered pytrees) go in as TRACED arguments: one compiled
# trace serves every operator of the same shape, so the recursive engine no
# longer retraces per tree node.  Plain callables (e.g. the deflated
# closure in `fiedler_pair_from_graph`) fall back to the static form, one
# trace per callable identity.
_lanczos_window_pytree = partial(jax.jit, static_argnames=("m",))(_window_body)
_lanczos_window = partial(jax.jit, static_argnums=(0, 3))(_window_body)


@jax.jit
def _apply_pytree_op(op, x):
    """Module-level jitted matvec for pytree operators (shared cache)."""
    return op(x)


def _run_window(op, q, mask, m):
    if dataclasses.is_dataclass(op):
        return _lanczos_window_pytree(op, q, mask, m=m)
    return _lanczos_window(op, q, mask, m)


def _tridiag_eigh(alpha: jax.Array, beta: jax.Array):
    m = alpha.shape[0]
    T = jnp.diag(alpha) + jnp.diag(beta[:-1], 1) + jnp.diag(beta[:-1], -1)
    return jnp.linalg.eigh(T)


def lanczos_fiedler(
    op: Callable[[jax.Array], jax.Array],
    n: int,
    *,
    mask: jax.Array | None = None,
    key: jax.Array | None = None,
    b0: jax.Array | None = None,
    window: int = 30,
    max_restarts: int = 50,
    tol: float = 1e-3,
) -> tuple[jax.Array, LanczosInfo]:
    """Return (y₂ approximation, info)."""
    mask = jnp.ones((n,), jnp.float32) if mask is None else mask.astype(jnp.float32)
    if b0 is None:
        key = jax.random.PRNGKey(0) if key is None else key
        q = jax.random.normal(key, (n,), jnp.float32)
    else:
        q = b0.astype(jnp.float32)
    q = _project_out_ones(q, mask)
    q = q / jnp.maximum(jnp.linalg.norm(q), 1e-30)

    if dataclasses.is_dataclass(op):
        opj = partial(_apply_pytree_op, op)
    else:
        opj = jax.jit(op)
    theta = jnp.asarray(0.0)
    res = jnp.asarray(jnp.inf)
    y = q
    converged = False
    r = 0
    for r in range(1, max_restarts + 1):
        Q, alpha, beta = _run_window(op, q, mask, window)
        evals, evecs = _tridiag_eigh(alpha, beta)
        s = evecs[:, 0]
        theta = evals[0]
        y = Q.T @ s
        ynorm = jnp.maximum(jnp.linalg.norm(y), 1e-30)
        y = y / ynorm
        # Cheap bound, then the true residual (one matvec).
        Ly = opj(y)
        res = jnp.linalg.norm(Ly - theta * y)
        if float(res) <= tol * max(float(theta), 1e-12):
            converged = True
            break
        q = _project_out_ones(y, mask)
        q = q / jnp.maximum(jnp.linalg.norm(q), 1e-30)

    info = LanczosInfo(
        restarts=r,
        eigenvalue=float(theta),
        residual=float(res),
        converged=converged,
        breakdown=not (np.isfinite(float(theta))
                       and np.isfinite(float(res))),
    )
    return y, info


# ---------------------------------------------------------------------------
# Batched (level-synchronous, packed) Lanczos
# ---------------------------------------------------------------------------

def _seg_onehot(seg: jax.Array, n_seg: int, dtype) -> jax.Array:
    """(n_seg, N) one-hot segment matrix: per-problem reductions as matmuls
    (dense GEMMs beat scatter-adds on every backend for these sizes)."""
    return (seg[None, :] == jnp.arange(n_seg, dtype=seg.dtype)[:, None]).astype(dtype)


def _project_out_ones_seg(x, mask, seg, S):
    """Per-problem constant deflation: x ← (x − mean_mask,p(x)) · mask."""
    s = S @ (x * mask)
    c = jnp.maximum(S @ mask, 1.0)
    return (x - (s / c)[seg]) * mask


@partial(jax.jit, static_argnames=("n_seg", "window"))
def _packed_restart(op, q, mask, seg, n_seg, window):
    """One jitted restart over all packed subproblems.

    `op` is a block-diagonal pytree operator over the packed (N,) slots,
    passed as a *traced* argument — the compile cache is keyed by
    (N, n_seg, window), not by operator instance, so one trace serves every
    level of a run (and every run sharing the shape).  Empty segments
    (padding) produce θ = 0, res = 0 and read as converged immediately.
    """
    m = window
    N = q.shape[0]
    S = _seg_onehot(seg, n_seg, q.dtype)

    def step(carry, j):
        Q, q, q_prev, beta_prev = carry          # Q (m, N); beta_prev (n_seg,)
        w = op(q) - beta_prev[seg] * q_prev
        alpha = S @ (w * q)                      # (n_seg,)
        w = w - alpha[seg] * q
        # Full reorthogonalization against the window + constants (twice is
        # enough — Parlett), per problem: rows ≥ j of Q are zero so the
        # window mask is implicit.
        for _ in range(2):
            dots = (Q * w[None, :]) @ S.T        # (m, n_seg) per-problem Qᵀw
            w = w - (Q * dots[:, seg]).sum(0)
            w = _project_out_ones_seg(w, mask, seg, S)
        beta = jnp.sqrt(S @ (w * w))             # (n_seg,)
        bj = beta[seg]
        q_next = jnp.where(bj > 1e-12, w / jnp.maximum(bj, 1e-30), 0.0)
        Q = Q.at[j].set(q)
        return (Q, q_next, q, beta), (alpha, beta)

    Q0 = jnp.zeros((m, N), q.dtype)
    (Q, _, _, _), (alpha, beta) = jax.lax.scan(
        step,
        (Q0, q, jnp.zeros_like(q), jnp.zeros((n_seg,), q.dtype)),
        jnp.arange(m),
    )
    alpha_t, beta_t = alpha.T, beta.T            # (n_seg, m)

    def tridiag(a, b):
        return jnp.diag(a) + jnp.diag(b[:-1], 1) + jnp.diag(b[:-1], -1)

    T = jax.vmap(tridiag)(alpha_t, beta_t)
    evals, evecs = jnp.linalg.eigh(T)            # vmapped Ritz problems
    s = evecs[:, :, 0]                           # (n_seg, m)
    theta = evals[:, 0]                          # (n_seg,)
    y = (s.T[:, seg] * Q).sum(0)                 # per-problem Ritz vector
    ynorm = jnp.sqrt(S @ (y * y))
    y = y / jnp.maximum(ynorm, 1e-30)[seg]
    Ly = op(y)
    res = jnp.sqrt(S @ ((Ly - theta[seg] * y) ** 2))
    q_next = _project_out_ones_seg(y, mask, seg, S)
    qn = jnp.sqrt(S @ (q_next * q_next))
    q_next = q_next / jnp.maximum(qn, 1e-30)[seg]
    return y, theta, res, q_next


def lanczos_fiedler_batched(
    op,
    n: int,
    *,
    seg: jax.Array,
    n_seg: int,
    mask: jax.Array,
    b0: jax.Array,
    window: int = 30,
    max_restarts: int = 50,
    tol: float = 1e-3,
) -> tuple[jax.Array, BatchedLanczosInfo]:
    """All packed Fiedler solves in lockstep: (Y (N,), per-problem info).

    `op`: block-diagonal pytree operator over the packed (N,) slots (no
    cross-problem coupling).  `seg[j]` names slot j's subproblem id in
    [0, n_seg); `mask[j]` flags real (non-padding) slots.  An empty segment
    is a padding problem that converges on the first restart.  `b0` holds
    the packed start vectors (deterministic per-node seeds / warm starts).

    Everything outside `_packed_restart` runs on the host (NumPy): the
    start-vector projection, per-problem freezing, and convergence
    bookkeeping are cheap O(N) passes, and keeping them off the device
    means the ONLY compiled code on this path is the restart step itself.
    """
    seg_h = np.asarray(seg)
    mask_h = np.asarray(mask, dtype=np.float64)
    q_h = np.asarray(b0, dtype=np.float64)
    # Host analogue of _project_out_ones_seg + per-segment normalization.
    s = np.bincount(seg_h, weights=q_h * mask_h, minlength=n_seg)
    c = np.maximum(np.bincount(seg_h, weights=mask_h, minlength=n_seg), 1.0)
    q_h = (q_h - (s / c)[seg_h]) * mask_h
    nrm = np.sqrt(np.bincount(seg_h, weights=q_h * q_h, minlength=n_seg))
    q_h = q_h / np.maximum(nrm, 1e-30)[seg_h]
    q = jnp.asarray(q_h.astype(np.float32))

    y = q_h.astype(np.float32)
    theta = np.zeros(n_seg)
    res = np.full(n_seg, np.inf)
    done = np.zeros(n_seg, dtype=bool)
    breakdown = np.zeros(n_seg, dtype=bool)
    restarts = np.zeros(n_seg, dtype=np.int64)
    for r in range(1, max_restarts + 1):
        y_new, theta_new, res_new, q_next = _packed_restart(
            op, q, mask, seg, n_seg, window
        )
        theta_h, res_h = np.asarray(theta_new), np.asarray(res_new)
        finite = np.isfinite(theta_h) & np.isfinite(res_h)
        upd = ~done & finite  # a non-finite restart keeps the last state
        restarts[upd] = r
        theta = np.where(upd, theta_h, theta)
        res = np.where(upd, res_h, res)
        y = np.where(upd[seg_h], np.asarray(y_new), y)
        done |= res <= tol * np.maximum(theta, 1e-12)
        # Numerical breakdown: freeze the problem and flag it — its frozen
        # (θ, res) never met tolerance.
        breakdown |= ~finite & ~done
        done |= ~finite
        if done.all():
            break
        q = q_next

    info = BatchedLanczosInfo(
        restarts=restarts, eigenvalue=theta, residual=res, converged=done,
        breakdown=breakdown,
    )
    return y, info
