"""Forward-compatibility shims for older JAX (< 0.5) installs.

The repo's distributed code and tests target the modern single-controller
API surface:

  * ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
  * ``jax.set_mesh(mesh)`` as a context manager providing the ambient mesh
  * ``jax.shard_map(f, mesh=None, in_specs=..., out_specs=..., check_vma=...)``

On an old install (e.g. 0.4.x, where only ``jax.experimental.shard_map``
with ``check_rep`` exists) :func:`install` grafts equivalent names onto the
``jax`` namespace so the same source runs on both.  On a new install it is
a no-op.  ``repro/__init__.py`` calls it on import, and ``src/sitecustomize
.py`` calls it at interpreter startup for any process launched with
``PYTHONPATH=src`` (the repo's documented invocation), which covers test
subprocesses that touch ``jax.sharding.AxisType`` before importing repro.
"""

from __future__ import annotations

import enum
import functools
import inspect
import threading

_installed = False
_ambient = threading.local()


def ambient_mesh():
    """The mesh most recently entered via the shimmed ``jax.set_mesh``."""
    return getattr(_ambient, "mesh", None)


def install() -> None:
    """Idempotently install the new-API names onto old ``jax``."""
    global _installed
    if _installed:
        return

    import jax
    import jax.sharding as jshard

    if not hasattr(jshard, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jshard.AxisType = AxisType

    if (hasattr(jax, "make_mesh")
            and "axis_types" not in inspect.signature(jax.make_mesh).parameters):
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            # Only Auto is advisory; Explicit/Manual semantics don't exist
            # on old JAX, so fail loudly rather than silently diverge.
            for t in axis_types or ():
                if t is not None and getattr(t, "name", t) != "Auto":
                    raise NotImplementedError(
                        f"axis_type {t} requires a newer JAX; only "
                        "AxisType.Auto is supported by the compat shim"
                    )
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        class _SetMesh:
            """Usable both ways, like the modern API: a bare
            ``jax.set_mesh(mesh)`` call sets the ambient mesh globally;
            ``with jax.set_mesh(mesh):`` additionally scopes it (and the
            Mesh resource context) to the block."""

            def __init__(self, mesh):
                self.mesh = mesh
                self._prev = ambient_mesh()
                self._entered = False
                _ambient.mesh = mesh        # effective immediately

            def __enter__(self):
                # The Mesh context lets with_sharding_constraint accept
                # bare PartitionSpecs.
                self.mesh.__enter__()
                self._entered = True
                return self.mesh

            def __exit__(self, *exc):
                _ambient.mesh = self._prev
                if self._entered:
                    self._entered = False
                    return self.mesh.__exit__(*exc)

        jax.set_mesh = _SetMesh

    if not hasattr(jax.lax, "axis_size"):
        import jax.core as _core

        def axis_size(axis_name):
            names = (
                axis_name if isinstance(axis_name, (tuple, list)) else (axis_name,)
            )
            size = 1
            for n in names:
                size *= int(_core.axis_frame(n))  # returns the size on 0.4.x
            return size

        jax.lax.axis_size = axis_size

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, *, in_specs, out_specs, check_vma=None,
                      check_rep=None, auto=frozenset()):
            if mesh is None:
                mesh = ambient_mesh()
                if mesh is None:
                    raise ValueError(
                        "shard_map: no mesh argument and no ambient mesh — "
                        "wrap the call in `with jax.set_mesh(mesh):`"
                    )
            if check_rep is None:
                # Mirror both APIs' defaults (True) so a program that fails
                # new JAX's vma check also fails here, not first in CI.
                check_rep = bool(check_vma) if check_vma is not None else True
            return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                              check_rep=check_rep, auto=auto)

        jax.shard_map = shard_map

    _installed = True  # only latch success once every shim is applied
