"""Distributed gather-scatter collectives (paper §5 under shard_map).

The paper's matrix-free Laplacian ``L x = d ⊙ x − A_w x`` distributes
verbatim: each shard broadcasts its elements' values to their vertices
(local ``P``), sums them into the *global* vertex-id space (local
``segment_sum``), a single ``psum`` over the mesh axis completes the
``Q Qᵀ`` exchange, and a local ``take`` copies the global sums back.  The
single-device reference is :mod:`repro.core.gather_scatter`.

:func:`ring_allreduce` is the hand-rolled reference collective — a
rotate-and-accumulate ring over ``jax.lax.ppermute`` whose N−1 steps each
move one shard-sized buffer, matching ``psum`` exactly (used to validate
the compiled collective and as the substrate for overlap experiments).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dist_lap_apply_allreduce(gid: jax.Array, x_local: jax.Array,
                             deg: jax.Array, n_global: int,
                             axis_name: str) -> jax.Array:
    """One shard's slice of ``L x = d ⊙ x − A_w x`` (call inside shard_map).

    Parameters
    ----------
    gid : (E_loc, K) int — compacted global vertex ids of this shard's
        elements (a row-slice of :class:`repro.core.gather_scatter.GSHandle`
        ``.gid``).
    x_local : (E_loc,) — this shard's element values.
    deg : (E_loc,) — this shard's slice of ``L.degree_full`` (= A_w·1,
        self terms included; they cancel against ``d ⊙ x`` exactly as in
        the single-device path).
    n_global : total distinct global vertex ids.
    axis_name : mesh axis to ``psum`` over.
    """
    k = gid.shape[-1]
    flat_gid = gid.reshape(-1)
    # P: broadcast each element value to its K vertices (local).
    u = jnp.broadcast_to(x_local[..., None], x_local.shape + (k,)).reshape(-1)
    # Qᵀ (partial): sum this shard's vertex values into the global id space.
    partial = jax.ops.segment_sum(u, flat_gid, num_segments=n_global)
    # Complete Q Qᵀ with one all-reduce over the shards.
    full = jax.lax.psum(partial, axis_name)
    # Q + Pᵀ (local): copy global sums back, accumulate per element.
    aw_x = jnp.take(full, flat_gid).reshape(gid.shape).sum(axis=-1)
    return deg * x_local - aw_x


def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Sum ``x`` across the axis via an N−1-step ppermute ring.

    Equivalent to ``jax.lax.psum(x, axis_name)``; each step rotates the
    running buffer one hop and accumulates, so every link carries exactly
    one buffer per step (the bandwidth-optimal ring schedule's volume,
    without the reduce-scatter/all-gather split).
    """
    n = jax.lax.axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(_, carry):
        acc, buf = carry
        # The N−1 per-step hops ARE the ring schedule — this is the
        # documented exception to one-collective-per-sweep.
        buf = jax.lax.ppermute(buf, axis_name, perm)  # repro: ignore[DIST001]
        return acc + buf, buf

    acc, _ = jax.lax.fori_loop(1, n, body, (x, x))
    return acc
