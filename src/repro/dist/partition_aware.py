"""Partition-aware halo sharding: the partitioner's output becomes the
framework's communication plan.

A partition of the (dual) graph assigns every node to one of ``nparts``
shards.  :func:`plan_halo_sharding` turns that assignment into a
:class:`HaloPlan` — per-shard contiguous node blocks plus the incoming-edge
lists and export buffers a shard_map message-passing sweep needs.  The only
collective per sweep is one ``all_gather`` of each shard's exported
boundary values, so the wire volume per feature column is
``n_shards · halo`` words — proportional to the partition's edge cut.
That is the paper's thesis operationalized: RSB's min-cut objective *is*
the minimal-collective-volume objective of the distributed runtime.

Layout
------
* Shard ``s`` owns the nodes with ``parts == s`` in ascending global id,
  at local slots ``0 .. block_sizes[s]-1`` of a block padded to the uniform
  ``n_local = max_s block_sizes[s]`` (so the per-shard arrays stack under
  ``shard_map``).
* ``export_idx[s]`` lists the local slots of shard ``s``'s *boundary*
  nodes (nodes with at least one edge into another shard), padded to the
  uniform ``halo = max_s |boundary_s|``; ``export_mask`` marks real rows.
* A sweep gathers every shard's exports into a ``(n_shards · halo, F)``
  buffer; edge sources index the *combined* space: ``[0, n_local)`` are the
  shard's own slots, ``n_local + r·halo + j`` is export row ``j`` of shard
  ``r``.
* ``edge_{src,dst,weight,mask}[s]`` hold the incoming edges of shard
  ``s``'s nodes (dst local slot, src combined index), padded to the uniform
  ``max_edges``.  Every directed CSR entry of the graph appears exactly
  once, in its destination's shard.

All planning is host-side NumPy (the ``gs_setup`` analogue); the arrays it
produces feed jitted shard_map code here and in ``repro.models.gnn.halo``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.guard import chaos


@dataclasses.dataclass(frozen=True, eq=False)  # identity eq/hash: ndarray
class HaloPlan:                                # fields break field-wise ==
    """Host-side sharding plan produced by :func:`plan_halo_sharding`."""

    n: int                     # global node count
    n_shards: int
    n_local: int               # padded nodes per shard
    halo: int                  # padded export rows per shard (max boundary)
    max_edges: int             # padded incoming edges per shard
    block_sizes: np.ndarray    # (P,) real nodes per shard
    shard_of: np.ndarray       # (n,) owning shard of each global node
    slot_of: np.ndarray        # (n,) local slot of each global node
    export_idx: np.ndarray     # (P, halo) int64 local slots exported
    export_mask: np.ndarray    # (P, halo) float32
    edge_src: np.ndarray       # (P, max_edges) int64 combined index
    edge_dst: np.ndarray       # (P, max_edges) int64 local slot
    edge_weight: np.ndarray    # (P, max_edges) float32
    edge_mask: np.ndarray      # (P, max_edges) float32

    @property
    def collective_words_per_feature(self) -> int:
        """Rows of the per-sweep all_gather buffer — the wire volume one
        message-passing sweep moves per feature column (∝ edge cut)."""
        return self.n_shards * self.halo

    def stats(self) -> dict:
        """JSON-able plan summary (benchmark / experiment records)."""
        return {
            "n": self.n,
            "n_shards": self.n_shards,
            "n_local": self.n_local,
            "halo": self.halo,
            "max_edges": self.max_edges,
            "gather_words_per_col": self.collective_words_per_feature,
            "node_fill": round(float(self.block_sizes.sum())
                               / (self.n_shards * self.n_local), 4),
            "edge_fill": round(float(self.edge_mask.sum())
                               / (self.n_shards * self.max_edges), 4),
        }


def plan_halo_sharding(graph, parts, nparts: int | None = None,
                       *, pad_to: int = 1) -> HaloPlan:
    """Build a :class:`HaloPlan` from a node→shard assignment.

    ``parts`` is either a label array or a partition-pipeline
    :class:`~repro.core.pipeline.PartitionContext` (anything with
    ``.parts``/``.nparts``) — the pipeline's output plugs in directly, and
    its report (post-stage metrics, per-stage timings) stays attached for
    the caller.  ``nparts`` may be omitted for contexts (taken from the
    context) and label arrays (inferred as ``max+1``).

    ``parts`` need not be balanced — blocks are padded to the largest
    shard.  ``pad_to`` rounds ``n_local``/``halo``/``max_edges`` up to a
    multiple (TPU lane alignment; padding rows stay fully masked).
    Host-side NumPy; O(nnz log nnz).
    """
    if hasattr(parts, "parts"):          # PartitionContext (duck-typed)
        ctx = parts
        if ctx.parts is None:
            raise ValueError("pipeline context has no parts (run() first)")
        if nparts is None:
            nparts = ctx.nparts
        parts = ctx.parts
    parts = np.asarray(parts, dtype=np.int64)
    if nparts is None:
        nparts = int(parts.max()) + 1 if parts.size else 1
    n = graph.n
    if parts.shape != (n,):
        raise ValueError(f"parts has shape {parts.shape}, expected ({n},)")
    if parts.min() < 0 or parts.max() >= nparts:
        raise ValueError("parts out of range for nparts")
    if pad_to < 1:
        raise ValueError(f"pad_to must be >= 1, got {pad_to}")

    plan = _assemble_plan(graph, parts, nparts, pad_to)
    if chaos.should_fire("halo_truncate", n, nparts):
        plan = _truncate_exports(plan)

    # Always-on cheap self-check (O(nnz), no graph re-walk): a plan whose
    # remote edge sources are not all exported would silently read zeros in
    # every sweep.  A corrupt plan is rebuilt once with fault injection
    # muted — the repair path must not be re-corrupted.
    problems = verify_halo_plan(plan)
    if problems:
        with chaos.suppressed():
            plan = _assemble_plan(graph, parts, nparts, pad_to)
        obs.counter_add("guard_fallbacks", 1)
        rest = verify_halo_plan(plan)
        if rest:
            raise ValueError(f"halo plan invalid after rebuild: {rest}")

    # Wire volume of the plan — what the partition's edge cut costs the
    # runtime, per sweep per feature column (float32 ⇒ 4 bytes/word).
    words = plan.collective_words_per_feature
    obs.counter_add("halo_words", float(words))
    obs.counter_add("halo_bytes", 4.0 * words)
    obs.gauge_max("halo_max_degree", int(plan.halo))
    return plan


def _assemble_plan(graph, parts: np.ndarray, nparts: int,
                   pad_to: int) -> HaloPlan:
    """The O(nnz log nnz) host-side plan assembly (no validation, no
    telemetry — :func:`plan_halo_sharding` wraps it)."""
    n = graph.n

    def pad(k: int) -> int:
        return int(-(-k // pad_to) * pad_to)

    counts = np.bincount(parts, minlength=nparts)
    n_local = pad(max(1, int(counts.max())))

    # Slot assignment: ascending global id within each shard.
    order = np.argsort(parts, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot_of = np.empty(n, dtype=np.int64)
    slot_of[order] = np.arange(n, dtype=np.int64) - starts[parts[order]]

    rows, cols, w = graph.rows, graph.indices, graph.weights
    pr, pc = parts[rows], parts[cols]
    cross = pr != pc

    # Exports of shard s: its nodes referenced by any other shard, in
    # ascending global id.  (Symmetric CSR ⇒ same set as boundary nodes.)
    exp_nodes = np.unique(cols[cross]) if cross.any() else np.empty(0, np.int64)
    exp_owner = parts[exp_nodes]
    eord = np.argsort(exp_owner, kind="stable")
    exp_nodes, exp_owner = exp_nodes[eord], exp_owner[eord]
    ecounts = np.bincount(exp_owner, minlength=nparts)
    halo = pad(int(ecounts.max())) if exp_nodes.size else 0
    estarts = np.concatenate([[0], np.cumsum(ecounts)[:-1]])
    epos = np.arange(exp_nodes.size, dtype=np.int64) - estarts[exp_owner]
    expos = np.full(n, -1, dtype=np.int64)   # export position of each node
    expos[exp_nodes] = epos

    export_idx = np.zeros((nparts, halo), dtype=np.int64)
    export_mask = np.zeros((nparts, halo), dtype=np.float32)
    if exp_nodes.size:
        export_idx[exp_owner, epos] = slot_of[exp_nodes]
        export_mask[exp_owner, epos] = 1.0

    # Incoming edges, grouped by destination shard.
    edge_counts = np.bincount(pr, minlength=nparts)
    max_edges = pad(max(1, int(edge_counts.max())))
    gord = np.argsort(pr, kind="stable")
    r_s, c_s, w_s, pr_s = rows[gord], cols[gord], w[gord], pr[gord]
    gstarts = np.concatenate([[0], np.cumsum(edge_counts)[:-1]])
    gpos = np.arange(r_s.size, dtype=np.int64) - gstarts[pr_s]

    edge_src = np.zeros((nparts, max_edges), dtype=np.int64)
    edge_dst = np.zeros((nparts, max_edges), dtype=np.int64)
    edge_weight = np.zeros((nparts, max_edges), dtype=np.float32)
    edge_mask = np.zeros((nparts, max_edges), dtype=np.float32)
    if r_s.size:
        local = pr_s == parts[c_s]
        remote_pos = np.where(local, 0, expos[c_s])   # guard -1 for locals
        src_combined = np.where(
            local, slot_of[c_s], n_local + parts[c_s] * halo + remote_pos
        )
        edge_dst[pr_s, gpos] = slot_of[r_s]
        edge_src[pr_s, gpos] = src_combined
        edge_weight[pr_s, gpos] = w_s
        edge_mask[pr_s, gpos] = 1.0

    return HaloPlan(
        n=n, n_shards=nparts, n_local=n_local, halo=halo, max_edges=max_edges,
        block_sizes=counts, shard_of=parts, slot_of=slot_of,
        export_idx=export_idx, export_mask=export_mask,
        edge_src=edge_src, edge_dst=edge_dst,
        edge_weight=edge_weight, edge_mask=edge_mask,
    )


def _truncate_exports(plan: HaloPlan) -> HaloPlan:
    """``halo_truncate`` chaos: drop the last real export row of every
    shard — the classic truncated-exchange bug a rank mismatch produces."""
    mask = plan.export_mask.copy()
    for s in range(plan.n_shards):
        real = np.flatnonzero(mask[s] > 0)
        if real.size:
            mask[s, real[-1]] = 0.0
    return dataclasses.replace(plan, export_mask=mask)


def verify_halo_plan(plan: HaloPlan) -> list:
    """Cheap structural audit of a plan (empty list == valid): every real
    remote edge source must point at an in-range, mask-1 export row, and
    the shard blocks must cover exactly ``n`` nodes."""
    problems: list = []
    if int(plan.block_sizes.sum()) != plan.n:
        problems.append(
            f"block sizes sum to {int(plan.block_sizes.sum())}, "
            f"expected {plan.n}")
    src = plan.edge_src[plan.edge_mask > 0]
    remote = src >= plan.n_local
    if remote.any():
        if plan.halo <= 0:
            problems.append("remote edge sources but halo == 0")
        else:
            rj = src[remote] - plan.n_local
            r, j = rj // plan.halo, rj % plan.halo
            bad_r = (r < 0) | (r >= plan.n_shards)
            if bad_r.any():
                problems.append(
                    f"{int(bad_r.sum())} remote sources index "
                    "a shard out of range")
            missing = int((plan.export_mask[r[~bad_r], j[~bad_r]]
                           < 1.0).sum())
            if missing:
                problems.append(
                    f"{missing} remote edge sources point at "
                    "unexported (masked-out) rows")
    return problems


# ---------------------------------------------------------------------------
# Feature movement: global order ↔ plan (per-shard block) order
# ---------------------------------------------------------------------------

def scatter_features(plan: HaloPlan, x: np.ndarray) -> np.ndarray:
    """Global ``(n, ...)`` features → per-shard ``(P, n_local, ...)`` blocks
    (padding slots zero).  The element-redistribution step a solver performs
    before timestepping."""
    x = np.asarray(x)
    if x.shape[0] != plan.n:
        raise ValueError(f"x has {x.shape[0]} rows, plan expects {plan.n}")
    out = np.zeros((plan.n_shards, plan.n_local) + x.shape[1:], dtype=x.dtype)
    out[plan.shard_of, plan.slot_of] = x
    return out


def gather_features(plan: HaloPlan, blocks: np.ndarray) -> np.ndarray:
    """Inverse of :func:`scatter_features`: ``(P, n_local, ...)`` blocks →
    global ``(n, ...)`` (padding slots dropped)."""
    blocks = np.asarray(blocks)
    if blocks.shape[:2] != (plan.n_shards, plan.n_local):
        raise ValueError(
            f"blocks has leading shape {blocks.shape[:2]}, "
            f"plan expects {(plan.n_shards, plan.n_local)}"
        )
    return blocks[plan.shard_of, plan.slot_of]


# ---------------------------------------------------------------------------
# Distributed adjacency matvec (one halo exchange per sweep)
# ---------------------------------------------------------------------------

def halo_exchange(x_local: jax.Array, export_idx: jax.Array,
                  export_mask: jax.Array, axis_name: str) -> jax.Array:
    """One shard's halo exchange: gather exports from every shard and return
    the combined ``(n_local + P·halo, F)`` table edge sources index."""
    exported = jnp.take(x_local, export_idx, axis=0) * export_mask[:, None]
    buf = jax.lax.all_gather(exported, axis_name, axis=0, tiled=True)
    return jnp.concatenate([x_local, buf], axis=0)


@functools.lru_cache(maxsize=32)
def _matvec_kernel(plan: HaloPlan, mesh):
    """Jitted per-(plan, mesh) matvec: device-resident plan arrays + a
    stable function object, so repeat calls hit the compile cache instead
    of retracing and re-uploading the plan every sweep."""
    axis = mesh.axis_names[0]
    n_local = plan.n_local

    def mv(xl, esrc, edst, ew, xidx, xmask):
        xl, esrc, edst = xl[0], esrc[0], edst[0]
        ew, xidx, xmask = ew[0], xidx[0], xmask[0]
        combined = halo_exchange(xl, xidx, xmask, axis)
        contrib = jnp.take(combined, esrc, axis=0) * ew[:, None]
        return jax.ops.segment_sum(contrib, edst, num_segments=n_local)[None]

    spec = P(axis)
    fn = jax.jit(jax.shard_map(mv, mesh=mesh, in_specs=(spec,) * 6,
                               out_specs=spec, check_vma=False))
    consts = (
        jnp.asarray(plan.edge_src.astype(np.int32)),
        jnp.asarray(plan.edge_dst.astype(np.int32)),
        jnp.asarray(plan.edge_weight),
        jnp.asarray(plan.export_idx.astype(np.int32)),
        jnp.asarray(plan.export_mask),
    )
    return fn, consts


def adjacency_matvec_distributed(plan: HaloPlan, mesh, x: np.ndarray) -> np.ndarray:
    """``y = A x`` for the plan's graph, executed across ``mesh``'s first
    axis with ONE export all_gather — wire volume ∝ edge cut.

    ``x`` is host-side ``(n,)`` or ``(n, F)``; the result matches shape.
    The dense oracle is ``A[dst, src] = w`` over the symmetric CSR.
    """
    axis = mesh.axis_names[0]
    if plan.n_shards != mesh.shape[axis]:
        raise ValueError(
            f"plan has {plan.n_shards} shards but mesh axis '{axis}' has "
            f"{mesh.shape[axis]} devices"
        )
    x = np.asarray(x)
    squeeze = x.ndim == 1
    xb = scatter_features(plan, x.reshape(plan.n, -1).astype(np.float32))
    fn, consts = _matvec_kernel(plan, mesh)
    out = fn(jnp.asarray(xb), *consts)
    y = gather_features(plan, np.asarray(out))
    return y[:, 0] if squeeze else y
