"""Device-resident sharded boundary refinement over the HaloPlan.

The host post chain (``repro.core.refine``) runs FM sweeps on the fully
assembled dual graph — the one stage that cannot scale past a single
host's memory.  This module ports the refinement *gain computation* onto
the existing :class:`~repro.dist.partition_aware.HaloPlan`: each shard
owns one part's node block, keeps only its ELL-packed frontier adjacency,
and the whole sweep loop runs under ``shard_map`` with exactly **one
all_gather of boundary labels per sweep**.

Protocol (per sweep, one fused collective)
------------------------------------------
1. **Exchange** — every shard packs one row buffer:
   ``[frontier labels | pending gains | pending targets | local part
   weights | local part counts]`` and a single tiled ``all_gather``
   replicates all P buffers everywhere.  Wire volume per sweep is
   ``P · (3·halo + 2·nparts)`` words — still ∝ the edge cut, and counted
   into the ``halo_words``/``halo_bytes`` counters.
2. **Gain table** — ONE batched segment-sum kernel launch
   (:func:`repro.kernels.segment_sum.ops.connection_table_batched`)
   computes every frontier node's (boundary × nparts) connection-weight
   table from the shard-local ELL adjacency, whose columns index the
   combined ``[local | gathered halo]`` label table.
3. **Conflict resolution** — *pending* proposals (computed from last
   sweep's state and shipped inside this sweep's gather, so every shard
   sees every boundary proposal) are resolved deterministically: a
   proposal survives only if it beats every proposing neighbor on the
   ``(gain, node id)`` priority (higher gain wins; ties go to the lower
   global node id).  Survivors form an independent set — no two adjacent
   nodes ever move in the same sweep, on any shard — so each applied
   move's *fresh* gain (recomputed from this sweep's table) is exact and
   the cut is monotonically non-increasing.
4. **Corridor** — part weights/counts are globally reduced from the same
   gather, and every shard replays the *identical* admission pass over
   all gathered proposals in ``(−gain, node id)`` order against the full
   corridor slack (node weights are static and replicated, source parts
   ride the gathered labels, so the pass is deterministic and identical
   everywhere).  A shard applies only ``admitted ∩ winners`` — a subset
   of a globally feasible move set — so P shards moving concurrently can
   never overflow the cap, dip under the floor, or empty a part.
   Proposals that lose the beat-test still hold their reservation for
   one sweep (conservative, never unsafe).
5. **Propose** — fresh positive-gain proposals for the *next* sweep are
   computed from the same table (first-max target, cap-feasible only)
   and ride the next gather.

A proposal is therefore applied one sweep after it is computed; the
fresh-gain re-check in step 3 discards any proposal staled by a remote
move in between.  The sweep loop, labels, and gain tables stay on device;
the host only sees per-sweep scalars (moves, realized gain, pending).

``refine_sharded_host`` is a NumPy mirror of the exact same arithmetic
(float32 where the device math is float32), used by the bit-parity tests:
on integer-weight meshes the device and host paths produce identical
labels.  The pipeline stages (``refine-sharded``, ``kway-sharded``) wrap
the sweep loop with the guard envelope — ``plan_halo_sharding`` already
self-heals ``halo_truncate`` chaos, an expired ``SolverGuard`` deadline
or any device-path failure degrades to the host FM refiner (counted in
``guard_fallbacks``) — and close with a repair pass so the
zero-disconnected-parts invariant survives articulation moves.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.refine import (
    PostStats,
    SweepRecord,
    balance_corridor,
    close_with_repair,
    edge_cut,
    refine_boundary,
)
from repro.dist.partition_aware import HaloPlan, plan_halo_sharding, scatter_features
from repro.kernels.segment_sum.ops import connection_table_batched

EPS = 1e-6   # strict-positive-gain threshold (f32-safe)


# ---------------------------------------------------------------------------
# Frontier plan: the static per-shard arrays of the sweep loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class FrontierPlan:
    """Host-side static arrays for the sharded refinement sweep: the
    HaloPlan's export rows re-packed as per-shard ELL frontier adjacency
    plus the index maps conflict resolution needs."""

    plan: HaloPlan
    w: int                      # padded max frontier degree
    exp_slot: np.ndarray        # (P, halo) int32 local slot of export row
    exp_slot_sc: np.ndarray     # (P, halo) int32 scatter slot (pad→n_local)
    exp_mask: np.ndarray        # (P, halo) float32
    exp_w: np.ndarray           # (P, halo) float32 node weight
    exp_gid: np.ndarray         # (P, halo) int32 global node id (−1 pad)
    ell_cols: np.ndarray        # (P, halo, w) int32 combined-space neighbor
    ell_wts: np.ndarray         # (P, halo, w) float32 edge weight (0 pad)
    nbr_prow: np.ndarray        # (P, halo, w) int32 neighbor's gathered
                                #   proposal row in [0, P·halo) or −1
    node_w: np.ndarray          # (P, n_local) float32 node weights (0 pad)
    node_mask: np.ndarray       # (P, n_local) float32 1.0 on real slots

    @property
    def gather_row_words(self) -> int:
        """Words one shard contributes to the per-sweep all_gather."""
        return 3 * self.plan.halo + 2 * self.plan.n_shards


def build_frontier_plan(graph, parts, nparts: int, *,
                        weights: np.ndarray | None = None,
                        plan: HaloPlan | None = None) -> FrontierPlan:
    """Re-pack a :class:`HaloPlan`'s export rows as frontier ELL adjacency.

    Host-side NumPy, O(nnz log nnz) — the ``gs_setup`` analogue of the
    refinement sweep.  Every edge whose destination is an export row lands
    in that row's ELL slots, sorted by (shard, row, combined source) so
    the accumulation order is canonical on both device and host paths.
    """
    if plan is None:
        plan = plan_halo_sharding(graph, parts, nparts)
    n, nsh, halo, n_local = graph.n, plan.n_shards, plan.halo, plan.n_local
    w_node = (np.ones(n, np.float32) if weights is None
              else np.asarray(weights, np.float32))

    node_of = np.full((nsh, n_local), -1, np.int64)
    node_of[plan.shard_of, plan.slot_of] = np.arange(n, dtype=np.int64)
    erow_of_slot = np.full((nsh, n_local), -1, np.int64)
    msh, mro = np.nonzero(plan.export_mask > 0)
    erow_of_slot[msh, plan.export_idx[msh, mro]] = mro

    exp_gid = np.full((nsh, halo), -1, np.int32)
    exp_w = np.zeros((nsh, halo), np.float32)
    if msh.size:
        gids = node_of[msh, plan.export_idx[msh, mro]]
        exp_gid[msh, mro] = gids.astype(np.int32)
        exp_w[msh, mro] = w_node[gids]

    es, ep = np.nonzero(plan.edge_mask > 0)
    dst = plan.edge_dst[es, ep]
    src = plan.edge_src[es, ep]
    ew = plan.edge_weight[es, ep]
    row = erow_of_slot[es, dst]
    sel = row >= 0
    es, src, ew, row = es[sel], src[sel], ew[sel], row[sel]
    order = np.lexsort((src, row, es))
    es, src, ew, row = es[order], src[order], ew[order], row[order]

    key = es * np.int64(halo) + row
    cnt = np.bincount(key, minlength=nsh * halo) if key.size else \
        np.zeros(nsh * halo, np.int64)
    wmax = max(1, int(cnt.max())) if cnt.size else 1
    starts = np.concatenate([[0], np.cumsum(cnt)[:-1]])
    pos = np.arange(key.size, dtype=np.int64) - starts[key]

    ell_cols = np.zeros((nsh, halo, wmax), np.int32)
    ell_wts = np.zeros((nsh, halo, wmax), np.float32)
    nbr_prow = np.full((nsh, halo, wmax), -1, np.int32)
    if key.size:
        ell_cols[es, row, pos] = src.astype(np.int32)
        ell_wts[es, row, pos] = ew.astype(np.float32)
        local = src < n_local
        loc_row = erow_of_slot[es, np.clip(src, 0, n_local - 1)]
        prow = np.where(
            local,
            np.where(loc_row >= 0, es * np.int64(halo) + loc_row, -1),
            src - n_local,
        )
        nbr_prow[es, row, pos] = prow.astype(np.int32)

    return FrontierPlan(
        plan=plan, w=wmax,
        exp_slot=plan.export_idx.astype(np.int32),
        exp_slot_sc=np.where(plan.export_mask > 0, plan.export_idx,
                             n_local).astype(np.int32),
        exp_mask=plan.export_mask.astype(np.float32),
        exp_w=exp_w, exp_gid=exp_gid,
        ell_cols=ell_cols, ell_wts=ell_wts, nbr_prow=nbr_prow,
        node_w=scatter_features(plan, w_node).astype(np.float32),
        node_mask=scatter_features(plan, np.ones(n, np.float32)),
    )


# ---------------------------------------------------------------------------
# The device sweep (shard_map; ONE all_gather + ONE kernel launch per call)
# ---------------------------------------------------------------------------

def _global_admit(gain, tgt, src, w, valid, gid,
                  cap_room, floor_room, cnt_room):
    """The replicated corridor-admission pass: every shard runs this over
    ALL gathered proposals in (−gain, gid) order against the full global
    slack, producing the same admitted set everywhere without another
    collective.  A shard then applies ``admitted ∩ winners`` only."""
    M = gain.shape[0]
    nparts = cap_room.shape[0]
    order = jnp.argsort(gid)                   # ascending gid (stable)
    order = order[jnp.argsort(-gain[order])]   # stable ⇒ −gain, ties → gid

    def body(t, carry):
        add_u, rem_u, cnt_u, adm = carry
        i = order[t]
        ti = jnp.clip(tgt[i], 0)
        si = jnp.clip(src[i], 0)
        wi = w[i]
        fits = ((add_u[ti] + wi <= cap_room[ti])
                & (rem_u[si] + wi <= floor_room[si])
                & (cnt_u[si] + 1.0 <= cnt_room[si]))
        take = valid[i] & fits
        wadd = jnp.where(take, wi, 0.0)
        add_u = add_u.at[ti].add(wadd)
        rem_u = rem_u.at[si].add(wadd)
        cnt_u = cnt_u.at[si].add(jnp.where(take, 1.0, 0.0))
        return add_u, rem_u, cnt_u, adm.at[i].set(take)

    init = (jnp.zeros(nparts, jnp.float32), jnp.zeros(nparts, jnp.float32),
            jnp.zeros(nparts, jnp.float32), jnp.zeros(M, bool))
    *_, adm = jax.lax.fori_loop(0, M, body, init)
    return adm


def _sweep_body(gather, nparts, nsh, floor, cap, prefer,
                labels, pgain, ptgt, exp_slot, exp_slot_sc, exp_mask,
                exp_w, exp_gid, ell_cols, ell_wts, nbr_prow,
                node_w, node_mask, prow_gid, exp_w_flat):
    """One sweep on a group of G shards; ``gather`` is the collective
    (``all_gather`` under shard_map, identity when G == P)."""
    G, n_local = labels.shape
    halo = exp_slot.shape[1]
    floor = jnp.float32(floor)
    cap = jnp.float32(cap)

    # 1. pack + ONE all_gather of boundary labels (+ piggybacked proposals
    #    and part weight/count partials — same buffer, same collective).
    exp_lab = jnp.take_along_axis(labels, exp_slot, axis=1)      # (G, halo)
    pw_loc = jax.vmap(lambda l, v: jax.ops.segment_sum(
        v, l, num_segments=nparts))(labels, node_w)
    pn_loc = jax.vmap(lambda l, v: jax.ops.segment_sum(
        v, l, num_segments=nparts))(labels, node_mask)
    buf = jnp.concatenate([
        exp_lab.astype(jnp.float32), pgain, ptgt.astype(jnp.float32),
        pw_loc, pn_loc,
    ], axis=1)
    allbuf = gather(buf)                                         # (P, L)

    all_lab = allbuf[:, :halo].astype(jnp.int32).reshape(-1)     # (P·halo,)
    all_gain = allbuf[:, halo:2 * halo].reshape(-1)
    all_tgt = allbuf[:, 2 * halo:3 * halo].astype(jnp.int32).reshape(-1)
    pw = allbuf[:, 3 * halo:3 * halo + nparts].sum(axis=0)       # (nparts,)
    pn = allbuf[:, 3 * halo + nparts:].sum(axis=0)

    # 2. ONE batched segment-sum launch: the (boundary × nparts) table.
    combined = jnp.concatenate(
        [labels, jnp.broadcast_to(all_lab, (G, all_lab.size))], axis=1)
    conn = connection_table_batched(combined, ell_cols, ell_wts, nparts,
                                    prefer=prefer)               # (G,halo,np)
    own = exp_lab
    internal = jnp.take_along_axis(conn, own[..., None], axis=2)[..., 0]

    # 3. resolve pending proposals: (gain, node id) priority vs every
    #    proposing neighbor (all visible — they are all boundary rows).
    mask = exp_mask > 0
    valid = mask & (pgain > EPS) & (ptgt >= 0)
    safe = jnp.clip(nbr_prow, 0)
    nb_gain = jnp.where(nbr_prow >= 0, all_gain[safe], -jnp.inf)
    nb_tgt = jnp.where(nbr_prow >= 0, all_tgt[safe], -1)
    nb_gid = jnp.where(nbr_prow >= 0, prow_gid[safe], -1)
    nb_valid = (nbr_prow >= 0) & (nb_gain > EPS) & (nb_tgt >= 0)
    my_gain = pgain[..., None]
    my_gid = exp_gid[..., None]
    beaten = nb_valid & ((nb_gain > my_gain)
                         | ((nb_gain == my_gain) & (nb_gid < my_gid)))
    fresh = jnp.take_along_axis(
        conn, jnp.clip(ptgt, 0)[..., None], axis=2)[..., 0] - internal
    winner = valid & ~beaten.any(axis=-1) & (fresh > EPS)

    # 4. corridor on globally reduced part weights: the replicated global
    #    admission pass, then this device's shard rows of the result.
    cap_room = jnp.maximum(cap - pw, 0.0)
    floor_room = jnp.maximum(pw - floor, 0.0)
    cnt_room = jnp.floor(jnp.maximum(pn - 1.0, 0.0))
    prop_valid = (all_gain > EPS) & (all_tgt >= 0)
    adm_flat = _global_admit(all_gain, all_tgt, all_lab, exp_w_flat,
                             prop_valid, prow_gid,
                             cap_room, floor_room, cnt_room)
    d = jax.lax.axis_index("shards")
    my_adm = jax.lax.dynamic_slice_in_dim(
        adm_flat.reshape(-1, halo), d * G, G, axis=0)      # (G, halo)
    admitted = winner & my_adm
    new_val = jnp.where(admitted, ptgt, exp_lab)
    labels = jax.vmap(
        lambda l, s, v: l.at[s].set(v, mode="drop")
    )(labels, exp_slot_sc, new_val)

    # 5. fresh proposals for the next sweep (skip rows that just moved).
    iota = jnp.arange(nparts)
    conn2 = jnp.where(iota[None, None, :] == own[..., None], -jnp.inf, conn)
    conn2 = jnp.where(pw[None, None, :] + exp_w[..., None] <= cap,
                      conn2, -jnp.inf)
    best = conn2.argmax(axis=-1).astype(jnp.int32)
    bgain = jnp.take_along_axis(conn2, best[..., None], axis=2)[..., 0] \
        - internal
    src_ok = ((jnp.take(pw, own) - exp_w >= floor)
              & (jnp.take(pn, own) > 1.5))
    ok = mask & ~admitted & src_ok & (bgain > EPS) & jnp.isfinite(bgain)
    ngain = jnp.where(ok, bgain, -1.0).astype(jnp.float32)
    ntgt = jnp.where(ok, best, -1)

    moves = admitted.sum(axis=1).astype(jnp.float32)             # (G,)
    gained = jnp.where(admitted, fresh, 0.0).sum(axis=1)
    pending = ok.sum(axis=1).astype(jnp.float32)
    return labels, ngain, ntgt, moves, gained, pending


@functools.lru_cache(maxsize=16)
def _device_step(fp: FrontierPlan, nparts: int, floor: float, cap: float,
                 n_dev: int):
    """Jitted per-(plan, corridor, mesh) sweep step + device constants.
    ``n_dev`` devices each own ``P / n_dev`` shards; with one device the
    all_gather degenerates to the identity but the code path is the same."""
    mesh = jax.make_mesh((n_dev,), ("shards",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    prefer = "auto"

    def gather(buf):
        return jax.lax.all_gather(buf, "shards", axis=0, tiled=True)

    body = functools.partial(_sweep_body, gather, nparts, fp.plan.n_shards,
                             floor, cap, prefer)
    spec = P("shards")
    rep = P()
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(spec,) * 13 + (rep, rep),
        out_specs=(spec,) * 6,
        check_vma=False,
    ))
    consts = (
        jnp.asarray(fp.exp_slot), jnp.asarray(fp.exp_slot_sc),
        jnp.asarray(fp.exp_mask), jnp.asarray(fp.exp_w),
        jnp.asarray(fp.exp_gid), jnp.asarray(fp.ell_cols),
        jnp.asarray(fp.ell_wts), jnp.asarray(fp.nbr_prow),
        jnp.asarray(fp.node_w), jnp.asarray(fp.node_mask),
        jnp.asarray(fp.exp_gid.reshape(-1)),
        jnp.asarray(fp.exp_w.reshape(-1)),
    )
    return fn, consts, mesh


def _pick_devices(n_shards: int, max_devices: int | None = None) -> int:
    """Largest divisor of ``n_shards`` that fits the local device count —
    each device then owns a contiguous group of shards."""
    avail = len(jax.devices()) if max_devices is None \
        else min(max_devices, len(jax.devices()))
    for d in range(min(n_shards, avail), 0, -1):
        if n_shards % d == 0:
            return d
    return 1


# ---------------------------------------------------------------------------
# Sweep runners (device + NumPy mirror)
# ---------------------------------------------------------------------------

def run_sharded_sweeps(fp: FrontierPlan, parts: np.ndarray, nparts: int, *,
                       sweeps: int = 4, corridor: tuple,
                       backend: str = "auto",
                       max_devices: int | None = None):
    """Run the sharded sweep loop; returns ``(labels, records, info)``.

    ``sweeps`` counts gather rounds (the first round only seeds proposals,
    so moves land from round 2 on).  ``backend``: "auto"/"device" runs the
    shard_map path across ``_pick_devices`` devices; "host" runs the NumPy
    mirror.  Per sweep the loop emits ``halo_words``/``halo_bytes`` wire
    counters plus ``sharded_gathers``/``sharded_sweeps`` (always equal —
    the one-collective-per-sweep contract the smoke gate asserts).
    """
    plan = fp.plan
    parts = np.asarray(parts, dtype=np.int64)
    cut0 = _plan_cut(fp, parts)
    if plan.halo == 0 or sweeps <= 0:       # no cross-shard frontier
        return parts.copy(), [], {"moves": 0, "gathers": 0, "cut": cut0}
    if backend == "host":
        return refine_sharded_host(fp, parts, nparts, sweeps=sweeps,
                                   corridor=corridor)
    floor, cap = float(corridor[0]), float(corridor[1])
    n_dev = _pick_devices(plan.n_shards, max_devices)
    fn, consts, _mesh = _device_step(fp, nparts, floor, cap, n_dev)

    labels = jnp.asarray(scatter_features(plan, parts).astype(np.int32))
    pgain = jnp.full((plan.n_shards, plan.halo), -1.0, jnp.float32)
    ptgt = jnp.full((plan.n_shards, plan.halo), -1, jnp.int32)

    records, total_moves, gathers, cut = [], 0, 0, cut0
    words = plan.n_shards * fp.gather_row_words
    for s in range(sweeps):
        with obs.timed(f"sweep:{s}"):
            labels, pgain, ptgt, mv, gn, pend = fn(labels, pgain, ptgt,
                                                   *consts)
            mv = int(np.asarray(mv).sum())
            gn = float(np.asarray(gn).sum())
            pend = int(np.asarray(pend).sum())
            gathers += 1
            obs.counter_add("halo_words", float(words))
            obs.counter_add("halo_bytes", 4.0 * words)
            obs.counter_add("sharded_gathers", 1)
            obs.counter_add("sharded_sweeps", 1)
            obs.counter_add("sharded_moves", mv)
        records.append(SweepRecord(sweep=s, moves=mv, cut_before=cut,
                                   cut_after=cut - gn))
        cut -= gn
        total_moves += mv
        if mv == 0 and pend == 0:
            break

    blocks = np.asarray(labels, dtype=np.int64)
    out = blocks[plan.shard_of, plan.slot_of]
    return out, records, {"moves": total_moves, "gathers": gathers,
                          "cut": cut}


def _plan_cut(fp: FrontierPlan, parts: np.ndarray) -> float:
    """Edge cut from the plan's own edge lists (no global graph needed)."""
    plan = fp.plan
    sel = plan.edge_mask > 0
    es, ep = np.nonzero(sel)
    dst_g = np.full((plan.n_shards, plan.n_local), 0, np.int64)
    dst_g[plan.shard_of, plan.slot_of] = parts
    combined = _combined_labels_host(fp, parts)
    pd = dst_g[es, plan.edge_dst[es, ep]]
    ps = combined[es, plan.edge_src[es, ep]]
    return float(plan.edge_weight[es, ep][pd != ps].sum() / 2.0)


def _combined_labels_host(fp: FrontierPlan, parts: np.ndarray) -> np.ndarray:
    """(P, n_local + P·halo) combined label table, NumPy."""
    plan = fp.plan
    blocks = scatter_features(plan, parts).astype(np.int64)
    msh, mro = np.nonzero(fp.exp_mask > 0)
    halo_lab = np.zeros(plan.n_shards * plan.halo, np.int64)
    halo_lab[msh * plan.halo + mro] = blocks[msh, fp.exp_slot[msh, mro]]
    return np.concatenate(
        [blocks, np.broadcast_to(halo_lab, (plan.n_shards, halo_lab.size))],
        axis=1)


def refine_sharded_host(fp: FrontierPlan, parts: np.ndarray, nparts: int, *,
                        sweeps: int = 4, corridor: tuple):
    """NumPy mirror of the device sweep — same protocol, same float32
    arithmetic, same tie-breaks — for bit-parity tests and as the
    reference the device path is audited against."""
    plan = fp.plan
    nsh, halo, n_local = plan.n_shards, plan.halo, plan.n_local
    floor = np.float32(corridor[0])
    cap = np.float32(corridor[1])

    labels = scatter_features(plan, np.asarray(parts, np.int64))
    pgain = np.full((nsh, halo), -1.0, np.float32)
    ptgt = np.full((nsh, halo), -1, np.int32)
    mask = fp.exp_mask > 0
    cut = _plan_cut(fp, np.asarray(parts, np.int64))

    records, total_moves, gathers = [], 0, 0
    for s in range(sweeps):
        # 1. "gather": labels + proposals + part weight/count partials.
        exp_lab = np.take_along_axis(labels, fp.exp_slot.astype(np.int64),
                                     axis=1)
        pw = np.zeros(nparts, np.float32)
        pn = np.zeros(nparts, np.float32)
        for g in range(nsh):   # f32 accumulation, shard-major like device
            np.add.at(pw, labels[g], fp.node_w[g])
            np.add.at(pn, labels[g], fp.node_mask[g])
        all_lab = np.where(mask, exp_lab, 0).reshape(-1)
        all_gain = pgain.reshape(-1)
        all_tgt = ptgt.reshape(-1)
        gathers += 1

        # 2. connection table (f32; canonical ELL slot order).
        combined = np.concatenate(
            [labels, np.broadcast_to(all_lab, (nsh, all_lab.size))], axis=1)
        conn = np.zeros((nsh, halo, nparts), np.float32)
        gi, ri, ki = np.nonzero(fp.ell_wts > 0)
        lab_n = combined[gi, fp.ell_cols[gi, ri, ki]]
        np.add.at(conn, (gi, ri, lab_n), fp.ell_wts[gi, ri, ki])
        own = exp_lab
        ar_g, ar_r = np.meshgrid(np.arange(nsh), np.arange(halo),
                                 indexing="ij")
        internal = conn[ar_g, ar_r, np.where(mask, own, 0)]

        # 3. resolve pending proposals.
        valid = mask & (pgain > EPS) & (ptgt >= 0)
        safe = np.clip(fp.nbr_prow, 0, None)
        has = fp.nbr_prow >= 0
        nb_gain = np.where(has, all_gain[safe], -np.inf)
        nb_tgt = np.where(has, all_tgt[safe], -1)
        nb_gid = np.where(has, fp.exp_gid.reshape(-1)[safe], -1)
        nb_valid = has & (nb_gain > EPS) & (nb_tgt >= 0)
        beaten = (nb_valid & ((nb_gain > pgain[..., None])
                              | ((nb_gain == pgain[..., None])
                                 & (nb_gid < fp.exp_gid[..., None]))))
        fresh = conn[ar_g, ar_r, np.clip(ptgt, 0, None)] - internal
        winner = valid & ~beaten.any(axis=-1) & (fresh > EPS)

        # 4. the replicated global corridor-admission pass (identical to
        #    every shard's device-side replay), then admitted ∩ winners.
        cap_room = np.maximum(cap - pw, 0.0).astype(np.float32)
        floor_room = np.maximum(pw - floor, 0.0).astype(np.float32)
        cnt_room = np.floor(np.maximum(pn - 1.0, 0.0)).astype(np.float32)
        prop_valid = (all_gain > EPS) & (all_tgt >= 0)
        all_w = fp.exp_w.reshape(-1)
        gid_flat = fp.exp_gid.reshape(-1)
        order = np.argsort(gid_flat, kind="stable")
        order = order[np.argsort(-all_gain[order], kind="stable")]
        add_u = np.zeros(nparts, np.float32)
        rem_u = np.zeros(nparts, np.float32)
        cnt_u = np.zeros(nparts, np.float32)
        adm_flat = np.zeros(nsh * halo, bool)
        for i in order:
            if not prop_valid[i]:
                continue
            ti, si = int(all_tgt[i]), int(all_lab[i])
            wi = all_w[i]
            if (add_u[ti] + wi <= cap_room[ti]
                    and rem_u[si] + wi <= floor_room[si]
                    and cnt_u[si] + 1.0 <= cnt_room[si]):
                add_u[ti] += wi
                rem_u[si] += wi
                cnt_u[si] += 1.0
                adm_flat[i] = True
        admitted = winner & adm_flat.reshape(nsh, halo)
        moves = int(admitted.sum())
        gained = np.float32(0.0)
        for g, i in zip(*np.nonzero(admitted)):
            labels[g, fp.exp_slot[g, i]] = ptgt[g, i]
            gained += fresh[g, i]

        # 5. fresh proposals for the next sweep.
        conn2 = conn.copy()
        conn2[ar_g, ar_r, np.where(mask, own, 0)] = -np.inf
        tgt_fits = pw[None, None, :] + fp.exp_w[..., None] <= cap
        conn2 = np.where(tgt_fits, conn2, -np.inf)
        best = conn2.argmax(axis=-1).astype(np.int32)
        bgain = conn2[ar_g, ar_r, best] - internal
        src_ok = (pw[np.where(mask, own, 0)] - fp.exp_w >= floor) \
            & (pn[np.where(mask, own, 0)] > 1.5)
        ok = mask & ~admitted & src_ok & (bgain > EPS) & np.isfinite(bgain)
        pgain = np.where(ok, bgain, -1.0).astype(np.float32)
        ptgt = np.where(ok, best, -1).astype(np.int32)

        records.append(SweepRecord(sweep=s, moves=moves, cut_before=cut,
                                   cut_after=cut - float(gained)))
        cut -= float(gained)
        total_moves += moves
        if moves == 0 and not ok.any():
            break

    out = labels[plan.shard_of, plan.slot_of]
    return out, records, {"moves": total_moves, "gathers": gathers,
                          "cut": cut}


# ---------------------------------------------------------------------------
# Pipeline post stages
# ---------------------------------------------------------------------------

def _sharded_pass(graph, parts, nparts, *, weights, sweeps, corridor,
                  backend, guard, stats: PostStats):
    """Shared core of the two stages: guard envelope → sharded sweeps →
    fall back to the host FM refiner on any device-path failure."""
    parts = np.asarray(parts, dtype=np.int64)
    if guard is not None and getattr(guard, "expired", lambda: False)():
        # guard.expired() itself emits guard_deadline_expired on first trip.
        stats.stages.append("host-fallback")
        return refine_boundary(graph, parts, nparts, weights=weights,
                               sweeps=sweeps, corridor=corridor)[0], False
    try:
        fp = build_frontier_plan(graph, parts, nparts, weights=weights)
        out, records, info = run_sharded_sweeps(
            fp, parts, nparts, sweeps=sweeps, corridor=corridor,
            backend=backend)
        out = np.asarray(out, dtype=np.int64)
        if (out.shape != parts.shape or out.min() < 0
                or out.max() >= nparts):
            raise ValueError("sharded refinement produced invalid labels")
        cut_now = edge_cut(graph, out)
        if cut_now > stats.cut_before + 1e-6:
            raise ValueError(
                f"sharded refinement increased the cut "
                f"({stats.cut_before} -> {cut_now})")
        stats.sweeps.extend(records)
        stats.moves_applied += info["moves"]
        return out, True
    except Exception:
        # Guard escalation: the exchange/sweep path failed — degrade to
        # the host FM refiner rather than ship a corrupt partition.
        obs.counter_add("guard_fallbacks", 1)
        stats.stages.append("host-fallback")
        out, fstats = refine_boundary(graph, parts, nparts, weights=weights,
                                      sweeps=sweeps, corridor=corridor)
        stats.sweeps.extend(fstats.sweeps)
        stats.moves_applied += fstats.moves_applied
        return out, False


def refine_sharded_stage(
    graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    sweeps: int = 4,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    backend: str = "auto",
    guard=None,
) -> tuple[np.ndarray, PostStats]:
    """The pipeline's "refine-sharded" stage: device-resident frontier FM
    sweeps (one boundary-label all_gather per sweep) + a closing repair
    pass.  Cut-non-increasing under ONE corridor, like the host stage."""
    if corridor is None:
        corridor = balance_corridor(parts, nparts, weights, balance_tol)
    stats = PostStats(stages=["refine-sharded"], corridor=tuple(corridor),
                      cut_before=edge_cut(graph, parts))
    with obs.timed("sharded_sweeps_total") as t:
        parts, _ok = _sharded_pass(graph, parts, nparts, weights=weights,
                                   sweeps=sweeps, corridor=corridor,
                                   backend=backend, guard=guard,
                                   stats=stats)
    stats.seconds = t.seconds
    obs.counter_add("refine_moves", stats.moves_applied)
    return close_with_repair(graph, parts, nparts, stats, weights=weights,
                             balance_tol=balance_tol, corridor=corridor)


def kway_sharded_stage(
    graph,
    parts: np.ndarray,
    nparts: int,
    *,
    weights: np.ndarray | None = None,
    sweeps: int = 4,
    passes: int = 2,
    balance_tol: float = 0.05,
    corridor: tuple | None = None,
    backend: str = "auto",
    guard=None,
) -> tuple[np.ndarray, PostStats]:
    """The "kway-sharded" stage: sharded frontier sweeps for the bulk of
    the gain, then a host boundary-restricted hill-climbing k-way polish
    (the part that needs global move ordering), then the closing repair."""
    from repro.core.kway import kway_fm_boundary

    if corridor is None:
        corridor = balance_corridor(parts, nparts, weights, balance_tol)
    stats = PostStats(stages=["kway-sharded"], corridor=tuple(corridor),
                      cut_before=edge_cut(graph, parts))
    with obs.timed("sharded_sweeps_total") as t:
        parts, _ok = _sharded_pass(graph, parts, nparts, weights=weights,
                                   sweeps=sweeps, corridor=corridor,
                                   backend=backend, guard=guard,
                                   stats=stats)
    stats.seconds = t.seconds
    parts, kstats = kway_fm_boundary(graph, parts, nparts, weights=weights,
                                     passes=passes, corridor=corridor)
    stats.kway = kstats.kway
    stats.moves_applied += kstats.moves_applied
    stats.seconds += kstats.seconds
    obs.counter_add("refine_moves", stats.moves_applied)
    return close_with_repair(graph, parts, nparts, stats, weights=weights,
                             balance_tol=balance_tol, corridor=corridor)
