"""repro.dist: the distribution layer.

Three pieces, one story — the paper's partitioner output drives the
framework's communication:

* :mod:`repro.dist.partition_aware` — halo sharding plans; a partition's
  edge cut becomes the all_gather volume of each message-passing sweep.
* :mod:`repro.dist.collectives` — the distributed gather-scatter Laplacian
  (paper §5 under shard_map) and a hand-rolled ring all-reduce reference.
* :mod:`repro.dist.sharding` — logical-axis → mesh-axis PartitionSpec
  rules for the LM / GNN / recsys model families.
"""

from repro.dist.collectives import dist_lap_apply_allreduce, ring_allreduce
from repro.dist.partition_aware import (
    HaloPlan,
    adjacency_matvec_distributed,
    gather_features,
    halo_exchange,
    plan_halo_sharding,
    scatter_features,
    verify_halo_plan,
)
from repro.dist.sharding import (
    MeshRules,
    batch_specs_lm,
    cache_specs_lm,
    gnn_rules,
    lm_rules,
    param_specs_lm,
    recsys_rules,
)

__all__ = [
    "HaloPlan",
    "MeshRules",
    "adjacency_matvec_distributed",
    "batch_specs_lm",
    "cache_specs_lm",
    "dist_lap_apply_allreduce",
    "gather_features",
    "gnn_rules",
    "halo_exchange",
    "lm_rules",
    "param_specs_lm",
    "plan_halo_sharding",
    "recsys_rules",
    "ring_allreduce",
    "scatter_features",
    "verify_halo_plan",
]
