"""repro.dist: the distribution layer.

Three pieces, one story — the paper's partitioner output drives the
framework's communication:

* :mod:`repro.dist.partition_aware` — halo sharding plans; a partition's
  edge cut becomes the all_gather volume of each message-passing sweep.
* :mod:`repro.dist.collectives` — the distributed gather-scatter Laplacian
  (paper §5 under shard_map) and a hand-rolled ring all-reduce reference.
* :mod:`repro.dist.sharding` — logical-axis → mesh-axis PartitionSpec
  rules for the LM / GNN / recsys model families.
* :mod:`repro.dist.refine_sharded` — device-resident sharded boundary
  refinement over the halo plan: one boundary-label all_gather per sweep,
  Pallas segment-sum gain tables (README: "Sharded refinement").
"""

from repro.dist.collectives import dist_lap_apply_allreduce, ring_allreduce
from repro.dist.partition_aware import (
    HaloPlan,
    adjacency_matvec_distributed,
    gather_features,
    halo_exchange,
    plan_halo_sharding,
    scatter_features,
    verify_halo_plan,
)
from repro.dist.refine_sharded import (
    FrontierPlan,
    build_frontier_plan,
    kway_sharded_stage,
    refine_sharded_host,
    refine_sharded_stage,
    run_sharded_sweeps,
)
from repro.dist.sharding import (
    MeshRules,
    batch_specs_lm,
    cache_specs_lm,
    gnn_rules,
    lm_rules,
    param_specs_lm,
    recsys_rules,
)

__all__ = [
    "FrontierPlan",
    "HaloPlan",
    "MeshRules",
    "adjacency_matvec_distributed",
    "batch_specs_lm",
    "build_frontier_plan",
    "cache_specs_lm",
    "dist_lap_apply_allreduce",
    "gather_features",
    "gnn_rules",
    "halo_exchange",
    "kway_sharded_stage",
    "lm_rules",
    "param_specs_lm",
    "plan_halo_sharding",
    "recsys_rules",
    "refine_sharded_host",
    "refine_sharded_stage",
    "ring_allreduce",
    "run_sharded_sweeps",
    "scatter_features",
    "verify_halo_plan",
]
