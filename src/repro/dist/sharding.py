"""Logical-axis → mesh-axis sharding rules for every model family.

Models annotate activations and params with *logical* axis names
(``"batch"``, ``"heads"``, ``"experts"`` …) through the
:class:`repro.models.common.ShardRules` hook; this module maps them onto
the physical mesh axes (``"pod"``, ``"data"``, ``"model"``).  The mapping
is divisibility-guarded: a logical axis whose dimension does not divide the
mesh-axis size silently degrades to replicated, so one rule set serves
every config from the 1.1B dense LM to the 123B GQA model.

``launch/cells.py`` consumes the whole surface (`lm_rules`, `gnn_rules`,
`recsys_rules`, `param_specs_lm`, `cache_specs_lm`, `batch_specs_lm`);
``transformer.loss_fn`` threads a :class:`MeshRules` through every block,
including the shard_map expert-parallel MoE path.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ShardRules


class MeshRules(ShardRules):
    """Concrete ShardRules bound to a mesh and a logical→physical table.

    ``table`` maps logical names to a mesh axis (str), a tuple of mesh axes
    (sharded over their product), or None (replicated).  ``layer_specs``
    is attached by ``launch/cells.py`` so the fp32→bf16 parameter cast can
    be re-constrained to the FSDP layout (see transformer._cast_layers).
    """

    def __init__(self, mesh, table: dict):
        self.mesh = mesh
        self.table = dict(table)
        self.layer_specs = None

    @property
    def mesh_axis_names(self) -> tuple:
        return tuple(self.mesh.axis_names)

    def _axes_for(self, name):
        ent = self.table.get(name)
        if ent is None:
            return None
        if isinstance(ent, str):
            ent = (ent,)
        ent = tuple(a for a in ent if a in self.mesh.axis_names)
        return ent or None

    def spec(self, logical, shape=None) -> P:
        """PartitionSpec for a tuple of logical axis names.

        Each mesh axis is used at most once (first logical wins) and a dim
        that is not divisible by its mesh-axis product stays replicated.
        """
        used: set = set()
        dims = []
        for i, name in enumerate(logical):
            axes = self._axes_for(name) if name is not None else None
            if axes:
                axes = tuple(a for a in axes if a not in used)
            if axes and shape is not None:
                size = int(np.prod([self.mesh.shape[a] for a in axes]))
                if int(shape[i]) % size != 0:
                    axes = None
            if axes:
                used.update(axes)
                dims.append(axes[0] if len(axes) == 1 else axes)
            else:
                dims.append(None)
        return P(*dims)

    def shard(self, x: jax.Array, logical) -> jax.Array:
        spec = self.spec(logical, x.shape)
        if all(d is None for d in spec):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )


def _data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None


def lm_rules(mesh, *, seq_shard: bool = True) -> MeshRules:
    """Transformer LM rules: DP over pod/data, TP(+SP) over model.

    ``seq_shard`` shards the residual stream's sequence dim over the model
    axis between attention/FFN blocks (sequence parallelism); heads, FFN,
    vocab and experts shard over model; expert weights FSDP over data.
    """
    model = _model_axis(mesh)
    data = _data_axes(mesh)
    return MeshRules(mesh, {
        "batch": data,
        "act_seq": model if seq_shard else None,
        "seq": None,
        "heads": model,
        "kv_heads": model,
        "embed": None,
        "ffn": model,
        "vocab": model,
        "experts": model,
        "expert_ffn": None,
        "fsdp": data,
    })


def gnn_rules(mesh) -> MeshRules:
    """GNN rules: nodes/edges stripe over every mesh axis (graph DP)."""
    every = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return MeshRules(mesh, {
        "nodes": every,
        "edges": every,
        "batch": _data_axes(mesh),
    })


def recsys_rules(mesh) -> MeshRules:
    """Recsys rules: user batch over data axes, item vocab over model."""
    return MeshRules(mesh, {
        "batch": _data_axes(mesh),
        "vocab": _model_axis(mesh),
    })


# ---------------------------------------------------------------------------
# LM param / cache / batch PartitionSpecs (launch + checkpoint reshard)
# ---------------------------------------------------------------------------

_LAYER_LOGICAL = {
    "attn_norm": (None,),
    "ffn_norm": (None,),
    "wq": (None, "heads", None),
    "wk": (None, "kv_heads", None),
    "wv": (None, "kv_heads", None),
}
_FFN_LOGICAL = {
    "wi": (None, "ffn"),
    "wg": (None, "ffn"),
    "wo": ("ffn", None),
}
_MOE_LOGICAL = {
    "router": (None, None),
    "wi": ("experts", "fsdp", None),
    "wg": ("experts", "fsdp", None),
    "wo": ("experts", None, "fsdp"),
    "shared_wi": (None, "ffn"),
    "shared_wg": (None, "ffn"),
    "shared_wo": ("ffn", None),
}


def param_specs_lm(cfg, params_abs, mesh) -> dict:
    """PartitionSpec tree for an LM parameter tree (stacked layers).

    Attention/FFN/expert weights shard over "model" (tensor parallel),
    expert weights additionally FSDP over the data axes, embed/head over
    the vocab dim; everything divisibility-guarded by the actual shapes.
    """
    rules = lm_rules(mesh)

    def one(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name, parent = keys[-1], (keys[-2] if len(keys) > 1 else None)
        if name == "embed":
            logical = ("vocab", None)
        elif name == "head":
            logical = (None, "vocab")
        elif name == "final_norm":
            logical = (None,)
        elif parent == "ffn":
            logical = _FFN_LOGICAL[name]
        elif parent == "moe":
            logical = _MOE_LOGICAL[name]
        elif name in _LAYER_LOGICAL:
            logical = _LAYER_LOGICAL[name]
        elif name == "wo":
            logical = ("heads", None, None)   # attention out-projection
        else:
            logical = (None,) * (len(leaf.shape) - int(keys[0] == "layers"))
        if keys[0] == "layers":
            logical = (None,) + tuple(logical)  # leading (n_layers,) stack
        return rules.spec(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params_abs)


def cache_specs_lm(cfg, mesh) -> dict:
    """KV-cache specs: (layers, batch, seq, kv_heads, d_head)."""
    data = _data_axes(mesh)
    model = _model_axis(mesh)
    if model is not None and cfg.n_kv_heads % mesh.shape[model] != 0:
        model = None
    spec = P(None, data if data else None, None, model, None)
    return {"k": spec, "v": spec}


def batch_specs_lm(mesh) -> dict:
    """Token batch specs: batch dim over the data axes."""
    data = _data_axes(mesh)
    spec = P(data if data else None, None)
    return {"tokens": spec, "labels": spec}
