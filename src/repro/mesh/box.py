"""Structured hexahedral box meshes with global vertex/edge/face numbering.

This is the SEM mesh substrate of the paper: a mesh is a set of hex elements,
each carrying the *global ids* of its 8 vertices.  parRSB's gather-scatter
Laplacian (paper §5) needs exactly this `(E, 8)` global-id table — plus, for
the *unweighted* Laplacian, analogous `(E, 12)` edge-id and `(E, 6)` face-id
tables (paper §5, inclusion-exclusion numbering: "It turns out that it is
very easy and fast to do this numbering as we have a global numbering for
vertices already available").

Everything here is host-side NumPy; it plays the role of mesh I/O +
`gs_setup`'s id discovery.  The JAX apply path lives in `repro.core`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Local corner order: corner c = (dx, dy, dz) bits, x fastest.
_CORNERS = np.array(
    [(dx, dy, dz) for dz in (0, 1) for dy in (0, 1) for dx in (0, 1)],
    dtype=np.int64,
)  # (8, 3)

# The 12 edges of a hex as pairs of local corner indices (corner order above).
_HEX_EDGES = np.array(
    [
        (0, 1), (2, 3), (4, 5), (6, 7),  # x-aligned
        (0, 2), (1, 3), (4, 6), (5, 7),  # y-aligned
        (0, 4), (1, 5), (2, 6), (3, 7),  # z-aligned
    ],
    dtype=np.int64,
)

# The 6 faces of a hex as 4-tuples of local corner indices.
_HEX_FACES = np.array(
    [
        (0, 2, 4, 6), (1, 3, 5, 7),  # x = 0, 1
        (0, 1, 4, 5), (2, 3, 6, 7),  # y = 0, 1
        (0, 1, 2, 3), (4, 5, 6, 7),  # z = 0, 1
    ],
    dtype=np.int64,
)


@dataclasses.dataclass
class HexMesh:
    """A hex mesh in parRSB's input form: per-element global-id tables.

    Attributes
    ----------
    vert_gid : (E, 8) int64 — global vertex id of each element corner.
    edge_gid : (E, 12) int64 — global edge id of each element edge.
    face_gid : (E, 6) int64 — global face id of each element face.
    coords   : (E, 3) float64 — element centroids (for RCB/RIB/SFC).
    weights  : (E,) float64 — per-element work weight (multi-material support;
               1.0 for single-material meshes).
    """

    vert_gid: np.ndarray
    edge_gid: np.ndarray
    face_gid: np.ndarray
    coords: np.ndarray
    weights: np.ndarray
    n_vert: int
    n_edge: int
    n_face: int

    @property
    def nelems(self) -> int:
        return self.vert_gid.shape[0]

    def take(self, idx: np.ndarray) -> "HexMesh":
        """Sub-mesh of the elements in `idx` (gids renumbered contiguously)."""
        vg, nv = _renumber(self.vert_gid[idx])
        eg, ne = _renumber(self.edge_gid[idx])
        fg, nf = _renumber(self.face_gid[idx])
        return HexMesh(
            vert_gid=vg,
            edge_gid=eg,
            face_gid=fg,
            coords=self.coords[idx],
            weights=self.weights[idx],
            n_vert=nv,
            n_edge=ne,
            n_face=nf,
        )


def _renumber(gid: np.ndarray) -> tuple[np.ndarray, int]:
    uniq, inv = np.unique(gid, return_inverse=True)
    return inv.reshape(gid.shape).astype(np.int64), int(uniq.size)


def _number_tuples(keys: np.ndarray) -> tuple[np.ndarray, int]:
    """Contiguously number rows of `keys` (N, k); equal rows share an id."""
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    return inv.astype(np.int64), uniq.shape[0]


def derive_edge_face_gids(vert_gid: np.ndarray) -> tuple[np.ndarray, int, np.ndarray, int]:
    """Derive global edge/face numbering from the vertex numbering.

    This is the paper's observation: with global vertex ids in hand, an edge
    is keyed by its sorted vertex-id pair and a face by its sorted 4-tuple;
    `np.unique` over keys is the parallel numbering (host-side setup).
    """
    E = vert_gid.shape[0]
    edge_pairs = vert_gid[:, _HEX_EDGES]          # (E, 12, 2)
    edge_keys = np.sort(edge_pairs, axis=-1).reshape(E * 12, 2)
    edge_gid, n_edge = _number_tuples(edge_keys)
    face_quads = vert_gid[:, _HEX_FACES]          # (E, 6, 4)
    face_keys = np.sort(face_quads, axis=-1).reshape(E * 6, 4)
    face_gid, n_face = _number_tuples(face_keys)
    return edge_gid.reshape(E, 12), n_edge, face_gid.reshape(E, 6), n_face


def box_mesh(nx: int, ny: int, nz: int, *, lengths=(1.0, 1.0, 1.0)) -> HexMesh:
    """Structured nx × ny × nz hex box mesh (the paper's weak-scaling cube)."""
    E = nx * ny * nz
    ii, jj, kk = np.meshgrid(
        np.arange(nx, dtype=np.int64),
        np.arange(ny, dtype=np.int64),
        np.arange(nz, dtype=np.int64),
        indexing="ij",
    )
    elem_ijk = np.stack([ii.ravel(), jj.ravel(), kk.ravel()], axis=1)  # (E, 3)

    # Global vertex ids on the (nx+1)(ny+1)(nz+1) lattice.
    corner = elem_ijk[:, None, :] + _CORNERS[None, :, :]  # (E, 8, 3)
    vert_gid = (
        corner[..., 0] * ((ny + 1) * (nz + 1))
        + corner[..., 1] * (nz + 1)
        + corner[..., 2]
    )
    n_vert = (nx + 1) * (ny + 1) * (nz + 1)

    edge_gid, n_edge, face_gid, n_face = derive_edge_face_gids(vert_gid)

    h = np.array(lengths, dtype=np.float64) / np.array([nx, ny, nz], dtype=np.float64)
    coords = (elem_ijk.astype(np.float64) + 0.5) * h[None, :]

    return HexMesh(
        vert_gid=vert_gid,
        edge_gid=edge_gid,
        face_gid=face_gid,
        coords=coords,
        weights=np.ones(E, dtype=np.float64),
        n_vert=n_vert,
        n_edge=n_edge,
        n_face=n_face,
    )
