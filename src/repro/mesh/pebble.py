"""Pebble-bed-like synthetic meshes.

The paper's quality studies (Tables 1-3) use pebble-bed reactor meshes:
hex meshes around dense sphere packings — geometrically irregular, with
voids, and element sizes varying near the pebble surfaces.  We synthesize a
topologically comparable mesh by (a) starting from a structured box,
(b) carving out randomly packed spheres (removing interior elements — the
pebbles themselves are solid), and (c) smoothly warping coordinates so the
geometry is not axis-aligned (defeats RCB's axis alignment, which is exactly
the regime where spectral partitioning shines — paper §3).
"""

from __future__ import annotations

import numpy as np

from repro.mesh.box import HexMesh, box_mesh


def pebble_mesh(
    nx: int,
    ny: int,
    nz: int,
    *,
    n_pebbles: int = 8,
    pebble_radius: float = 0.12,
    warp: float = 0.1,
    seed: int = 0,
) -> HexMesh:
    """Carved + warped box mesh emulating a pebble-bed exterior mesh."""
    rng = np.random.default_rng(seed)
    mesh = box_mesh(nx, ny, nz)
    centers = rng.uniform(pebble_radius, 1.0 - pebble_radius, size=(n_pebbles, 3))

    # Remove elements whose centroid lies inside any pebble.
    d2 = ((mesh.coords[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    keep = ~(d2 < pebble_radius**2).any(axis=1)
    if not keep.any():
        raise ValueError("pebble carving removed every element; reduce radius")
    sub = mesh.take(np.flatnonzero(keep))

    # Smooth non-axis-aligned warp of centroids (partitioning uses centroids
    # only, so warping coords is sufficient to exercise RIB vs RCB).
    x, y, z = sub.coords.T
    cx = x + warp * np.sin(2 * np.pi * y) * np.cos(np.pi * z)
    cy = y + warp * np.sin(2 * np.pi * z) * np.cos(np.pi * x)
    cz = z + warp * np.sin(2 * np.pi * x) * np.cos(np.pi * y)
    sub.coords = np.stack([cx, cy, cz], axis=1)

    # Multi-material weighting (paper §3: conjugate heat transfer): elements
    # near pebble surfaces are "flow" (expensive), others "solid" (cheap).
    near = (d2[keep] < (1.8 * pebble_radius) ** 2).any(axis=1)
    sub.weights = np.where(near, 2.0, 1.0)
    return sub
