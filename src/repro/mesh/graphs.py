"""Generic graph substrate: dual graphs, CSR/ELL utilities, generators.

All construction is host-side NumPy (the `gs_setup` analogue); the arrays it
produces are consumed by jitted JAX code in `repro.core` and `repro.models`.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected weighted graph in CSR form (+ COO view).

    `indptr[i]:indptr[i+1]` slices `indices`/`weights` for row i.
    The graph is stored symmetrically: (i, j) and (j, i) both present.
    """

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (nnz,) int64 — column (neighbor) ids
    weights: np.ndarray  # (nnz,) float64 — edge weights

    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    @property
    def rows(self) -> np.ndarray:
        """COO row ids aligned with `indices` — computed once, then cached
        on the instance (not a dataclass field, so eq/asdict are
        unaffected).  Hot consumers (edge_cut, FM connection tables, the
        multilevel matching pass) call this repeatedly; the CSR arrays are
        never mutated in place, so the cache cannot go stale."""
        r = self.__dict__.get("_rows")
        if r is None:
            r = np.repeat(np.arange(self.n, dtype=np.int64),
                          np.diff(self.indptr))
            self.__dict__["_rows"] = r
        return r

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def sub(self, idx: np.ndarray) -> "Graph":
        """Node-induced subgraph, nodes renumbered to 0..len(idx)-1."""
        idx = np.asarray(idx, dtype=np.int64)
        remap = -np.ones(self.n, dtype=np.int64)
        remap[idx] = np.arange(idx.size, dtype=np.int64)
        rows = self.rows
        keep = (remap[rows] >= 0) & (remap[self.indices] >= 0)
        return build_csr(
            remap[rows[keep]], remap[self.indices[keep]], idx.size,
            weights=self.weights[keep], symmetrize=False,
        )


def extract_subgraphs(graph: Graph, groups: list) -> list:
    """Node-induced subgraphs for several **disjoint** node groups in one
    pass over the parent edge list.

    The vectorized analogue of calling `graph.sub(idx)` per group: instead
    of one O(n + nnz) remap per child, all children of an RSB tree level
    are extracted with a single label/filter/lexsort sweep.  Nodes of group
    k are renumbered 0..len(groups[k])-1 in the order given (so a
    permutation of all nodes reproduces `graph.sub(perm)`).
    """
    label = np.full(graph.n, -1, dtype=np.int64)
    loc = np.zeros(graph.n, dtype=np.int64)
    sizes = []
    for k, idx in enumerate(groups):
        idx = np.asarray(idx, dtype=np.int64)
        label[idx] = k
        loc[idx] = np.arange(idx.size, dtype=np.int64)
        sizes.append(int(idx.size))
    rows = graph.rows
    keep = (label[rows] >= 0) & (label[rows] == label[graph.indices])
    grp = label[rows[keep]]
    src = loc[rows[keep]]
    dst = loc[graph.indices[keep]]
    w = graph.weights[keep]
    order = np.lexsort((dst, src, grp))
    grp, src, dst, w = grp[order], src[order], dst[order], w[order]
    cuts = np.searchsorted(grp, np.arange(len(groups) + 1))
    out = []
    for k, nk in enumerate(sizes):
        a, b = int(cuts[k]), int(cuts[k + 1])
        indptr = np.zeros(nk + 1, dtype=np.int64)
        np.add.at(indptr, src[a:b] + 1, 1)
        out.append(
            Graph(n=nk, indptr=np.cumsum(indptr), indices=dst[a:b],
                  weights=w[a:b])
        )
    return out


def build_csr(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    *,
    weights: np.ndarray | None = None,
    symmetrize: bool = True,
    sum_duplicates: bool = True,
) -> Graph:
    """Build CSR from COO edge lists; optionally symmetrize + coalesce."""
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    w = (
        np.ones(src.size, dtype=np.float64)
        if weights is None
        else np.asarray(weights, dtype=np.float64).ravel()
    )
    mask = src != dst  # drop self-loops (the dual graph has none)
    src, dst, w = src[mask], dst[mask], w[mask]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    if sum_duplicates and src.size:
        key = src * np.int64(n) + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        first = np.r_[True, key[1:] != key[:-1]]
        seg = np.cumsum(first) - 1
        w = np.bincount(seg, weights=w, minlength=int(first.sum()))
        src, dst = src[first], dst[first]
    else:
        order = np.lexsort((dst, src))
        src, dst, w = src[order], dst[order], w[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n=n, indptr=indptr, indices=dst, weights=w)


def dual_graph_from_incidence(item_gid: np.ndarray, n_items: int, nelems: int) -> Graph:
    """Weighted dual graph from an (E, K) item-incidence table.

    Two elements are adjacent iff they share an item (vertex); the edge
    weight is the number of shared items — exactly the paper's ω (1 per
    shared vertex, so 2 for an edge, 4 for a face in a hex mesh).

    This is the *assembled* (CSR) reference; the matrix-free gather-scatter
    path never materializes it.
    """
    E, K = item_gid.shape
    elems = np.repeat(np.arange(E, dtype=np.int64), K)
    gids = item_gid.ravel()
    order = np.argsort(gids, kind="stable")
    gids_s, elems_s = gids[order], elems[order]
    starts = np.flatnonzero(np.r_[True, gids_s[1:] != gids_s[:-1]])
    counts = np.diff(np.r_[starts, gids_s.size])

    # All ordered pairs within each group (group size ≤ elements sharing a
    # vertex — bounded by mesh valence, e.g. 8 for interior box vertices).
    c2 = counts * counts
    total = int(c2.sum())
    rep_c = np.repeat(counts, c2)
    rep_s = np.repeat(starts, c2)
    off = np.arange(total, dtype=np.int64) - np.repeat(np.cumsum(c2) - c2, c2)
    src = elems_s[rep_s + off // rep_c]
    dst = elems_s[rep_s + off % rep_c]
    return build_csr(src, dst, nelems, symmetrize=False)


def dual_graph(mesh) -> Graph:
    """Weighted dual graph of a HexMesh (vertex-sharing adjacency)."""
    return dual_graph_from_incidence(mesh.vert_gid, mesh.n_vert, mesh.nelems)


def csr_to_ell(graph: Graph, *, max_row: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """CSR → padded ELL: (n, max_row) column ids + weights.

    Padding entries point at row i itself with weight 0 (harmless for the
    Laplacian matvec `d ⊙ x − A x`).  ELL is the TPU-friendly layout used by
    the Pallas SpMV kernel: static shape, contiguous rows, VMEM-tileable.
    """
    deg = graph.degrees
    width = int(deg.max()) if max_row is None else int(max_row)
    if (deg > width).any():
        raise ValueError(f"row degree {int(deg.max())} exceeds ELL width {width}")
    cols = np.tile(np.arange(graph.n, dtype=np.int64)[:, None], (1, width))
    vals = np.zeros((graph.n, width), dtype=np.float64)
    rows = graph.rows
    pos = np.arange(graph.nnz, dtype=np.int64) - graph.indptr[rows]
    cols[rows, pos] = graph.indices
    vals[rows, pos] = graph.weights
    return cols, vals


def connected_labels(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Connected-component labels 0..k-1 from a COO edge list (vectorized).

    Shiloach–Vishkin-style min-label propagation: every node adopts the
    minimum label across its edges, then labels are collapsed by pointer
    doubling; O(nnz) work per round, O(log n) rounds.  Isolated nodes get
    their own label.  This is the production path (`connected_components`
    is the per-node BFS test oracle): the repair stage and the partition
    metrics run it once per call on million-edge graphs.
    """
    label = np.arange(n, dtype=np.int64)
    src = np.asarray(src, dtype=np.int64).ravel()
    dst = np.asarray(dst, dtype=np.int64).ravel()
    while src.size:
        m = np.minimum(label[src], label[dst])
        np.minimum.at(label, src, m)
        np.minimum.at(label, dst, m)
        while True:
            nxt = label[label]
            if np.array_equal(nxt, label):
                break
            label = nxt
        if (label[src] == label[dst]).all():
            break
    _, out = np.unique(label, return_inverse=True)
    return out


def connected_components(graph: Graph) -> np.ndarray:
    """Label connected components (frontier BFS, NumPy).  Test utility."""
    label = -np.ones(graph.n, dtype=np.int64)
    comp = 0
    for seed in range(graph.n):
        if label[seed] >= 0:
            continue
        frontier = np.array([seed], dtype=np.int64)
        label[seed] = comp
        while frontier.size:
            # all neighbors of the frontier
            parts = [
                graph.indices[graph.indptr[u] : graph.indptr[u + 1]] for u in frontier
            ]
            nbrs = np.unique(np.concatenate(parts)) if parts else np.array([], np.int64)
            new = nbrs[label[nbrs] < 0]
            label[new] = comp
            frontier = new
        comp += 1
    return label


# ---------------------------------------------------------------------------
# Generators for the assigned GNN shape suite
# ---------------------------------------------------------------------------

def grid_graph_2d(nx: int, ny: int) -> Graph:
    """4-neighbor 2D lattice (checkerboard degeneracy testbed, paper §9)."""
    idx = np.arange(nx * ny, dtype=np.int64).reshape(nx, ny)
    src = np.concatenate([idx[:-1, :].ravel(), idx[:, :-1].ravel()])
    dst = np.concatenate([idx[1:, :].ravel(), idx[:, 1:].ravel()])
    return build_csr(src, dst, nx * ny)


def grid_graph_3d(nx: int, ny: int, nz: int) -> Graph:
    idx = np.arange(nx * ny * nz, dtype=np.int64).reshape(nx, ny, nz)
    src = np.concatenate([idx[:-1].ravel(), idx[:, :-1].ravel(), idx[:, :, :-1].ravel()])
    dst = np.concatenate([idx[1:].ravel(), idx[:, 1:].ravel(), idx[:, :, 1:].ravel()])
    return build_csr(src, dst, nx * ny * nz)


def rmat_graph(
    n: int,
    n_edges: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    batch: int = 1 << 22,
) -> Graph:
    """R-MAT power-law generator (Chakrabarti et al.) — OGB-scale stand-in.

    Generates `n_edges` directed samples batch-wise (memory-lean), then
    symmetrizes + coalesces.  Used for the `minibatch_lg` / `ogb_products`
    shape cells where real datasets are unavailable offline.
    """
    rng = np.random.default_rng(seed)
    levels = int(np.ceil(np.log2(max(n, 2))))
    probs = np.array([a, b, c, 1.0 - a - b - c])
    srcs, dsts = [], []
    remaining = n_edges
    while remaining > 0:
        m = min(batch, remaining)
        quad = rng.choice(4, size=(m, levels), p=probs)
        row_bit = (quad >= 2).astype(np.int64)
        col_bit = (quad % 2).astype(np.int64)
        weightv = (1 << np.arange(levels, dtype=np.int64))[::-1]
        src = row_bit @ weightv
        dst = col_bit @ weightv
        ok = (src < n) & (dst < n) & (src != dst)
        srcs.append(src[ok])
        dsts.append(dst[ok])
        remaining -= m
    return build_csr(np.concatenate(srcs), np.concatenate(dsts), n)


def radius_molecule_batch(
    n_graphs: int,
    n_nodes: int,
    n_edges: int,
    *,
    seed: int = 0,
    box: float = 4.0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched random 3D point clouds with k-NN edges (molecule shape cell).

    Returns (positions (G·V, 3), species (G·V,), edge_src, edge_dst) with
    exactly `n_edges` directed edges per graph (k-NN truncated/padded) and
    node ids offset per graph — the standard batched-small-graphs layout.
    """
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, box, size=(n_graphs, n_nodes, 3))
    species = rng.integers(0, 4, size=(n_graphs, n_nodes))
    k = max(1, int(np.ceil(n_edges / n_nodes)))
    d2 = ((pos[:, :, None, :] - pos[:, None, :, :]) ** 2).sum(-1)
    d2 += np.eye(n_nodes)[None] * 1e9
    nbr = np.argsort(d2, axis=-1)[:, :, :k]                    # (G, V, k)
    src = np.tile(np.arange(n_nodes)[None, :, None], (n_graphs, 1, k))
    src, nbr = src.reshape(n_graphs, -1), nbr.reshape(n_graphs, -1)
    src, nbr = src[:, :n_edges], nbr[:, :n_edges]
    offs = (np.arange(n_graphs, dtype=np.int64) * n_nodes)[:, None]
    return (
        pos.reshape(-1, 3),
        species.reshape(-1),
        (src + offs).ravel().astype(np.int64),
        (nbr + offs).ravel().astype(np.int64),
    )


def stencil_graph_3d(nx: int, ny: int, nz: int, *, stencil: int = 26) -> Graph:
    """26- (or 6-) neighbor 3D stencil graph — the dual graph of a box hex
    mesh, built directly from offsets (memory-lean at millions of nodes).

    At 135³ this reproduces the `ogb_products` cell scale (2.46M nodes,
    ~63M directed edges) with spatial structure — representative of
    GraphCast's icosahedral mesh (bounded degree, geometric locality).
    """
    idx = np.arange(nx * ny * nz, dtype=np.int64).reshape(nx, ny, nz)
    offs = [
        (dx, dy, dz)
        for dx in (-1, 0, 1) for dy in (-1, 0, 1) for dz in (-1, 0, 1)
        if (dx, dy, dz) != (0, 0, 0)
        and (stencil == 26 or abs(dx) + abs(dy) + abs(dz) == 1)
    ]
    srcs, dsts, ws = [], [], []
    for dx, dy, dz in offs:
        sx = slice(max(0, dx), nx + min(0, dx))
        sy = slice(max(0, dy), ny + min(0, dy))
        sz = slice(max(0, dz), nz + min(0, dz))
        tx = slice(max(0, -dx), nx + min(0, -dx))
        ty = slice(max(0, -dy), ny + min(0, -dy))
        tz = slice(max(0, -dz), nz + min(0, -dz))
        srcs.append(idx[sx, sy, sz].ravel())
        dsts.append(idx[tx, ty, tz].ravel())
        # hex-dual weights: face=4, edge=2, vertex=1 shared vertices
        order = abs(dx) + abs(dy) + abs(dz)
        w = {1: 4.0, 2: 2.0, 3: 1.0}[order]
        ws.append(np.full(srcs[-1].size, w))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = np.concatenate(ws)
    # already symmetric by construction; skip coalescing (offsets disjoint)
    order = np.lexsort((dst, src))
    src, dst, w = src[order], dst[order], w[order]
    n = nx * ny * nz
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    return Graph(n=n, indptr=np.cumsum(indptr), indices=dst, weights=w)


def grid_coords_3d(nx: int, ny: int, nz: int) -> np.ndarray:
    ii, jj, kk = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz),
                             indexing="ij")
    return np.stack([ii.ravel(), jj.ravel(), kk.ravel()], 1).astype(np.float64)
