"""Mesh + graph substrate: hex meshes, dual graphs, graph generators."""

from repro.mesh.box import HexMesh, box_mesh
from repro.mesh.graphs import (
    Graph,
    build_csr,
    connected_components,
    connected_labels,
    csr_to_ell,
    dual_graph,
    dual_graph_from_incidence,
    extract_subgraphs,
    grid_coords_3d,
    grid_graph_2d,
    grid_graph_3d,
    radius_molecule_batch,
    rmat_graph,
    stencil_graph_3d,
)
from repro.mesh.pebble import pebble_mesh
