"""Mesh + graph substrate: hex meshes, dual graphs, graph generators."""

from repro.mesh.box import HexMesh, box_mesh
from repro.mesh.pebble import pebble_mesh
from repro.mesh.graphs import (
    Graph,
    dual_graph,
    dual_graph_from_incidence,
    extract_subgraphs,
    grid_graph_2d,
    grid_graph_3d,
    rmat_graph,
    stencil_graph_3d,
    grid_coords_3d,
    radius_molecule_batch,
    build_csr,
    csr_to_ell,
    connected_components,
    connected_labels,
)
