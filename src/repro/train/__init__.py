"""Training substrate: optimizers, gradient compression, checkpointing, loop."""

from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
)
