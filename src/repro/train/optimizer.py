"""AdamW with decoupled weight decay + global-norm clipping.

Hand-rolled (no optax offline): pytree m/v moments in fp32, master params
fp32, updates cast back to param dtype.  Moment trees inherit the parameter
PartitionSpecs, so under FSDP the optimizer state is fully sharded (ZeRO).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params):
    like = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(like, params),
        "v": jax.tree_util.tree_map(like, params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        new_p = p.astype(jnp.float32) - cfg.lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
