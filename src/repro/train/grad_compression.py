"""int8 gradient compression with error feedback for the DP all-reduce.

1-byte quantization (per-tensor absmax scale) cuts DP all-reduce volume 4×
vs fp32 / 2× vs bf16.  The quantization residual is carried in an error-
feedback buffer (Seide et al. / EF-SGD), which restores convergence to the
uncompressed path asymptotically — verified in tests/test_train.py on a
quadratic and a tiny LM.

Two entry points:
  * `compress`/`decompress` + `ef_update`  — used by the pjit path (grads
    are compressed before the optimizer; the backward all-reduce itself is
    XLA-generated, so this models end-to-end compressed-DP numerics),
  * `compressed_psum` — shard_map path that REALLY transmits int8: quantize
    → psum over int32 accumulators → dequantize (collective bytes drop 4×
    in HLO; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp → (int8, scale).  Symmetric absmax, stochastic-free rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_buf):
    """Compress grads+carried error; returns (dequantized grads, new error)."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress(target)
        deq = decompress(q, s)
        return deq, target - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )


def init_error_buf(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8-over-the-wire mean across the DP axis (shard_map).

    Quantize locally, sum int8 payloads in int32 (exact), share scales via a
    tiny fp32 psum, dequantize with the max scale.  Wire bytes ≈ 1/4 of fp32.
    """
    n = jax.lax.axis_size(axis_name)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    scale_max = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(g / scale_max), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale_max / n
