"""Fault-tolerant training loop + straggler-tolerant gradient quorum.

`fit()` is the production loop skeleton: resumable (CheckpointManager),
preemption-safe (checkpoint every `ckpt_every`; an injected preemption in
tests kills the loop mid-run and `fit` resumes bit-exactly), metrics
logging, and host data prefetch (`repro.data.pipeline`).

Straggler mitigation (DESIGN.md §7): `quorum_grad_mean` averages
data-parallel gradient contributions over the *responsive* shards only —
with deterministic data sharding any dropped microbatch is re-computable,
so skipping a straggler trades one microbatch of signal for not stalling
the step.  The quorum math is unit-tested with simulated dead shards.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig):
    """Generic jitted train step: (params, opt_state, batch) → updated."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def quorum_grad_mean(grad_stack, alive: jax.Array):
    """Mean of per-shard grads over alive shards (straggler skip).

    grad_stack: pytree with leading dim n_shards; alive: (n_shards,) 0/1.
    """
    denom = jnp.maximum(alive.sum(), 1.0)

    def one(g):
        w = alive.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return (g * w).sum(0) / denom.astype(g.dtype)

    return jax.tree_util.tree_map(one, grad_stack)


@dataclasses.dataclass
class FitResult:
    params: dict
    opt_state: dict
    step: int
    losses: list


def fit(
    loss_fn: Callable,
    params,
    data_iter: Iterable,
    *,
    steps: int,
    opt_cfg: AdamWConfig = AdamWConfig(),
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    preemption_hook: Callable[[int], None] | None = None,
    log: Callable[[str], None] = print,
) -> FitResult:
    """Train with checkpoint/resume.  `preemption_hook(step)` may raise to
    simulate a node failure (tests); rerunning `fit` resumes."""
    opt_state = adamw_init(params)
    start = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start, tree, _ = restored
            params, opt_state = tree["params"], tree["opt"]
            log(f"[fit] resumed from step {start}")

    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg))
    losses = []
    t0 = time.perf_counter()
    it = iter(data_iter)
    for step in range(start, steps):
        batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % log_every == 0 or step + 1 == steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            dt = time.perf_counter() - t0
            log(f"[fit] step {step+1}/{steps} loss={loss:.4f} ({dt:.1f}s)")
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if preemption_hook is not None:
            preemption_hook(step + 1)
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state})
    return FitResult(params=params, opt_state=opt_state, step=steps, losses=losses)
