"""Fault-tolerant checkpointing: atomic save, restore, elastic reshard.

Design for 1000+ nodes (DESIGN.md §7):
  * checkpoints are written atomically (tmp file + rename) so a preemption
    mid-write never corrupts the latest checkpoint,
  * a JSON manifest records step, pytree structure and the *logical*
    PartitionSpecs — restore can therefore re-shard onto a DIFFERENT mesh
    (elastic scaling: tested 4→8 devices),
  * the manager keeps the last `keep` checkpoints and resumes from the
    newest valid one (a torn checkpoint falls back to the previous).

On a real cluster each host would write its own shard-file (orbax-style);
on this single-host container we persist full arrays — the manifest format
already carries everything needed for the per-host layout.
"""

from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(path: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Atomic save of a pytree; returns the final file path."""
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "step": int(step),
        "names": names,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "extra": extra or {},
    }
    final = os.path.join(path, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, manifest=json.dumps(manifest), **arrays)
        os.replace(tmp, final)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def load_checkpoint(file: str, like):
    """Restore into the structure of `like` (abstract or concrete pytree)."""
    with np.load(file, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        leaves = [z[f"a{i}"] for i in range(len(manifest["names"]))]
    treedef = jax.tree_util.tree_structure(like)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected {treedef.num_leaves}"
        )
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves), manifest


def reshard(tree, mesh, spec_tree):
    """Place a host pytree onto `mesh` with the given PartitionSpecs —
    the elastic-restart path (device count may differ from save time)."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree, spec_tree)


class CheckpointManager:
    """Keep-last-k manager with torn-file tolerance."""

    _PAT = re.compile(r"ckpt_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def all_steps(self) -> list:
        out = []
        for f in os.listdir(self.directory):
            m = self._PAT.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_file(self) -> str | None:
        steps = self.all_steps()
        return (
            os.path.join(self.directory, f"ckpt_{steps[-1]:08d}.npz")
            if steps
            else None
        )

    def save(self, step: int, tree, *, extra: dict | None = None) -> str:
        f = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return f

    def restore_latest(self, like):
        """Newest valid checkpoint (skipping torn files); None if none."""
        for step in reversed(self.all_steps()):
            f = os.path.join(self.directory, f"ckpt_{step:08d}.npz")
            try:
                return load_checkpoint(f, like)
            except Exception:
                continue  # torn/corrupt → try previous
        return None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            try:
                os.unlink(os.path.join(self.directory, f"ckpt_{s:08d}.npz"))
            except OSError:
                pass
