"""repro: parRSB (Recursive Spectral Bisection mesh partitioner) in JAX.

A production-oriented, multi-pod JAX framework reproducing and extending

    "parRSB: Exascale Spectral Element Mesh Partitioning"
    (Ratnayaka & Fischer, CS.DC 2026)

Layers
------
core/      the paper's contribution: gather-scatter Laplacians, Lanczos,
           inverse iteration (flexcg + aggregation-AMG), RCB/RIB/SFC
           pre-partitioners, the recursive RSB driver, quality metrics.
mesh/      hex-mesh + graph substrate (dual graphs, generators).
models/    assigned architectures (LM transformers incl. MoE, GNNs, recsys).
dist/      sharding rules, distributed gather-scatter, partition-aware
           message passing.
train/     optimizers, gradient compression, checkpointing, train loop.
kernels/   Pallas TPU kernels (ELL SpMV, embedding-bag, flash attention).
configs/   one config per assigned architecture (+ the paper's own).
launch/    production mesh, multi-pod dry-run, roofline extraction.
"""

from repro import _jax_compat as _jax_compat

_jax_compat.install()

__version__ = "1.0.0"
