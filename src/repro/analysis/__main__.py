"""``python -m repro.analysis`` — the lint gate.

With no arguments, runs the full rule catalog over the installed
``repro`` package source (``src/repro`` in a checkout).  Exit codes:
0 = clean, 1 = findings, 2 = bad invocation.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.engine import analyze_paths, findings_json
from repro.analysis.rules import all_rules


def _default_target() -> str:
    import repro
    return os.path.dirname(os.path.abspath(repro.__file__))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST contract checker: trace safety, collective "
                    "discipline, instrumentation drift, guard hygiene.")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to analyze "
                         "(default: the repro package source)")
    ap.add_argument("--root", default=None,
                    help="project root for vocabulary discovery "
                         "(obs/registry.py, guard/chaos.py, …); "
                         "defaults to the common path of the targets")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None, metavar="FILE",
                    help="also write the JSON findings report to FILE "
                         "(the CI artifact)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id:<8s} {r.name}")
            print(f"         {r.rationale}")
        return 0

    paths = args.paths or [_default_target()]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    diags = analyze_paths(paths, root=args.root, rules=rules)
    report = findings_json(diags, rules=rules)
    if args.output:
        d = os.path.dirname(args.output)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.output, "w") as f:
            f.write(report)
    if args.format == "json":
        print(report)
    else:
        for diag in diags:
            print(diag.render())
        n_files = len({d.path for d in diags})
        if diags:
            print(f"\n{len(diags)} finding(s) in {n_files} file(s)")
        else:
            print("repro.analysis: clean "
                  f"({len(rules)} rules over {', '.join(paths)})")
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
