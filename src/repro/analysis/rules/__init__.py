"""The rule catalog.  Ids are stable (suppressions reference them);
see ``src/repro/analysis/README.md`` for the full table."""

from repro.analysis.rules.collective_rules import CollectiveInLoop, UnknownAxisName
from repro.analysis.rules.determinism_rules import (
    SetIterationOrder,
    UnseededRandom,
    WallClockInTrace,
)
from repro.analysis.rules.guard_rules import GuardCodeDiscipline, UnknownChaosSite
from repro.analysis.rules.obs_rules import UndeclaredSpan, UnregisteredMetric
from repro.analysis.rules.pallas_rules import BlockSpecGridRank, KernelTriple
from repro.analysis.rules.trace_rules import HostSyncInTrace, TracedPythonBranch

_CATALOG = (
    HostSyncInTrace,
    TracedPythonBranch,
    WallClockInTrace,
    UnseededRandom,
    SetIterationOrder,
    CollectiveInLoop,
    UnknownAxisName,
    BlockSpecGridRank,
    KernelTriple,
    UndeclaredSpan,
    UnregisteredMetric,
    UnknownChaosSite,
    GuardCodeDiscipline,
)


def all_rules() -> list:
    """Fresh instances of every catalog rule (rules may carry per-run
    state for ``observe_module``/``finalize``)."""
    return [cls() for cls in _CATALOG]


def rule_ids() -> list:
    return [cls.id for cls in _CATALOG]
