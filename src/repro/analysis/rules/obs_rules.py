"""OBS rules: instrumentation drift.

Span and metric names are load-bearing: exporters label them, benchmark
tables join on them, and the CI drift guard (`expected_span_names` /
`validate_manifest`) fails when a stage span disappears.  The runtime
guard only sees names on executed paths; these rules pin every call
site: a name used anywhere in `src/` must be declared in
`repro.obs.registry` (`register(...)` for metrics, `SPAN_NAMES` /
`SPAN_PREFIXES` for spans).
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Rule, dotted, suffix

_SPAN_FNS = frozenset({"span", "timed", "trace"})
_METRIC_FNS = frozenset({"counter_add", "gauge_set", "gauge_max"})


def _obs_call(node: ast.Call, fns) -> str | None:
    """The obs entry-point name if this is a call to one, else None.
    Accepts `obs.span(...)`, `trace.span(...)`, and bare `span(...)`
    (imported from repro.obs); rejects unrelated `.trace()` methods by
    requiring a string-literal/f-string first argument."""
    sfx = suffix(dotted(node.func))
    if sfx not in fns or not node.args:
        return None
    first = node.args[0]
    if isinstance(first, ast.Constant) and isinstance(first.value, str):
        return sfx
    if isinstance(first, ast.JoinedStr):
        return sfx
    return None


def _static_prefix(js: ast.JoinedStr) -> str:
    out = []
    for part in js.values:
        if isinstance(part, ast.Constant) and isinstance(part.value, str):
            out.append(part.value)
        else:
            break
    return "".join(out)


class UndeclaredSpan(Rule):
    id = "OBS001"
    name = "undeclared-span-name"
    rationale = ("Every span name must be declared in "
                 "`obs/registry.py` (`SPAN_NAMES`/`SPAN_PREFIXES`) so the "
                 "drift guard and trace consumers share one vocabulary; "
                 "an undeclared span silently escapes the CI manifest "
                 "validation.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        if not _obs_call(node, _SPAN_FNS):
            return
        proj = ctx.project
        if not proj.span_names and not proj.span_prefixes:
            return                      # no registry in scope (fixtures)
        first = node.args[0]
        if isinstance(first, ast.Constant):
            name = first.value
            if not proj.span_declared(name):
                yield ctx.diag(self, node,
                               f"span name {name!r} is not declared in "
                               "obs/registry.py (SPAN_NAMES/SPAN_PREFIXES)")
        else:                           # f-string: the static prefix decides
            prefix = _static_prefix(first)
            if not prefix:
                yield ctx.diag(self, node,
                               "span name is fully dynamic (f-string with "
                               "no static prefix) — declare a stable "
                               "prefix in obs/registry.py")
            elif not any(prefix.startswith(p) or p.startswith(prefix)
                         for p in proj.span_prefixes):
                yield ctx.diag(self, node,
                               f"span prefix {prefix!r} is not declared in "
                               "obs/registry.py SPAN_PREFIXES")


class UnregisteredMetric(Rule):
    id = "OBS002"
    name = "unregistered-metric-name"
    rationale = ("`counter_add`/`gauge_set`/`gauge_max` names must be "
                 "registered in `obs/registry.py`: unregistered names "
                 "merge with default counter semantics and carry no "
                 "unit/description, so exporters and tables mislabel "
                 "them.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        if not _obs_call(node, _METRIC_FNS):
            return
        proj = ctx.project
        if not proj.metric_names:
            return
        first = node.args[0]
        if isinstance(first, ast.JoinedStr):
            yield ctx.diag(self, node,
                           "metric name is dynamic (f-string); metric "
                           "names must be static literals registered in "
                           "obs/registry.py")
        elif first.value not in proj.metric_names:
            yield ctx.diag(self, node,
                           f"metric {first.value!r} is not registered in "
                           "obs/registry.py — register() it with a kind "
                           "and description")
