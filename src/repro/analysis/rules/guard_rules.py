"""GRD rules: guard-code hygiene.

Chaos sites and GuardError codes are string-keyed protocols: a typo'd
site never fires (the chaos test silently tests nothing), and an
uncataloged error code cannot be branched on by callers.  Both catalogs
live in one place (`guard/chaos.py` `FAULT_SITES`, `guard/errors.py`
`KNOWN_CODES`) and every literal use must come from them.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.engine import Diagnostic, Rule, dotted, suffix

_SITE_FNS = frozenset({"should_fire", "enabled", "overlay", "configure"})
_KEBAB = re.compile(r"^[a-z0-9]+(-[a-z0-9]+)*$")


def _chaos_base(name: str | None) -> bool:
    """Only flag calls rooted at the chaos module (or bare should_fire,
    which is unambiguous) — `.enabled(`/`.configure(` are common method
    names elsewhere."""
    if not name:
        return False
    parts = name.split(".")
    if len(parts) >= 2:
        return parts[-2] == "chaos"
    return parts[0] in ("should_fire", "overlay")


class UnknownChaosSite(Rule):
    id = "GRD001"
    name = "unknown-chaos-site"
    rationale = ("A fault site name not in `chaos.FAULT_SITES` never "
                 "fires: the chaos test that references it exercises "
                 "nothing, silently.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        sites = ctx.project.fault_sites
        if not sites:
            return
        name = dotted(node.func)
        sfx = suffix(name)
        if sfx not in _SITE_FNS or not _chaos_base(name):
            return
        if not node.args:
            return
        first = node.args[0]
        if sfx in ("should_fire", "enabled"):
            cands = ([first.value]
                     if isinstance(first, ast.Constant)
                     and isinstance(first.value, str) else [])
        else:                            # overlay/configure take iterables
            cands = [n.value for n in ast.walk(first)
                     if isinstance(n, ast.Constant)
                     and isinstance(n.value, str)]
        for site in cands:
            if site not in sites:
                yield ctx.diag(self, node,
                               f"chaos site {site!r} is not in "
                               f"chaos.FAULT_SITES {sorted(sites)} — it "
                               "can never fire")


class GuardCodeDiscipline(Rule):
    id = "GRD002"
    name = "guard-code-discipline"
    rationale = ("GuardError/GuardIssue codes are the stable machine-"
                 "readable API: each literal code must be kebab-case, "
                 "cataloged in `guard/errors.py` KNOWN_CODES, and the "
                 "catalog itself must be duplicate-free.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        if suffix(dotted(node.func)) not in ("GuardError", "GuardIssue"):
            return
        if not node.args:
            return
        first = node.args[0]
        if not (isinstance(first, ast.Constant)
                and isinstance(first.value, str)):
            return
        code = first.value
        if not _KEBAB.match(code):
            yield ctx.diag(self, node,
                           f"guard code {code!r} is not a kebab-case slug")
        codes = ctx.project.guard_codes
        if codes and code not in codes:
            yield ctx.diag(self, node,
                           f"guard code {code!r} is not cataloged in "
                           "guard/errors.py KNOWN_CODES")

    def finalize(self, project):
        seen: set = set()
        for code in project.guard_code_list:
            if code in seen and project.guard_codes_path:
                yield Diagnostic(rule=self.id,
                                 path=project.guard_codes_path,
                                 line=1, col=1,
                                 message=f"KNOWN_CODES lists {code!r} more "
                                         "than once — codes must be "
                                         "unique")
            seen.add(code)
