"""TRC rules: host-sync and host-control-flow hazards in traced code.

The determinism story of the reproduction (seed-keyed guard ladder,
bit-parity host mirrors, one compiled trace per run) assumes traced
bodies are pure device programs.  A ``.item()`` or ``np.asarray`` on a
tracer either crashes at trace time or — worse, under ``io_callback``
style escapes — silently syncs the device per call; a Python ``if`` on
a traced value bakes one branch into the compiled program.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Rule, dotted, suffix

_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})
_NP_SYNC = frozenset({"asarray", "array", "copyto", "save", "savez",
                      "ascontiguousarray"})
_CASTS = frozenset({"float", "int", "bool", "complex"})
_TRACED_CALL_ROOTS = ("jnp.", "jax.numpy.", "lax.", "jax.lax.")


def _static_cast_ok(arg) -> bool:
    """Casts of static quantities (shapes, sizes, constants) are fine in
    traced code — only casting a *traced value* forces a host sync."""
    if isinstance(arg, ast.Constant):
        return True
    for n in ast.walk(arg):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "size", "dtype"):
            return True
        if isinstance(n, ast.Call) and suffix(dotted(n.func)) in ("len",
                                                                  "range"):
            return True
    return False


class HostSyncInTrace(Rule):
    id = "TRC001"
    name = "host-sync-in-traced-code"
    rationale = ("Traced/jitted bodies must never sync to host: "
                 "`.item()`, `.tolist()`, `np.asarray`, or "
                 "`float()/int()/bool()` on a traced value either fails "
                 "at trace time or serializes the device pipeline.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        if not (ctx.traced or ctx.kernel):
            return
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS):
            yield ctx.diag(self, node,
                           f"`.{node.func.attr}()` inside traced code "
                           "forces a host sync")
            return
        name = dotted(node.func)
        if name:
            parts = name.split(".")
            if (len(parts) == 2 and parts[0] in ("np", "numpy")
                    and parts[1] in _NP_SYNC):
                yield ctx.diag(self, node,
                               f"`{name}` materializes a traced value on "
                               "host inside traced code")
                return
        if (isinstance(node.func, ast.Name) and node.func.id in _CASTS
                and node.args and not _static_cast_ok(node.args[0])):
            yield ctx.diag(self, node,
                           f"`{node.func.id}()` on a (possibly traced) "
                           "value inside traced code syncs to host; cast "
                           "with `jnp.<dtype>` or hoist to the host driver")


class TracedPythonBranch(Rule):
    id = "TRC002"
    name = "python-branch-on-traced-value"
    rationale = ("Python `if`/`while`/`assert` on a traced expression "
                 "concretizes it: the branch is resolved once at trace "
                 "time, not per input — use `jnp.where`/`lax.cond`.")
    node_types = (ast.If, ast.While, ast.Assert, ast.IfExp)

    def check_node(self, node, ctx):
        if not (ctx.traced or ctx.kernel):
            return
        test = node.test
        for n in ast.walk(test):
            if isinstance(n, ast.Call):
                name = dotted(n.func) or ""
                if name.startswith(_TRACED_CALL_ROOTS):
                    kind = type(node).__name__.lower()
                    yield ctx.diag(
                        self, node,
                        f"Python `{kind}` on traced expression "
                        f"`{name}(...)` inside traced code — the branch "
                        "is frozen at trace time; use `jnp.where` / "
                        "`jax.lax.cond`")
                    return
