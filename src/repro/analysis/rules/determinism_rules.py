"""DET rules: ambient nondeterminism.

Every solver path is keyed by an explicit seed (``(seed, level, node,
attempt)`` in the guard ladder) precisely so reruns are bit-identical.
Wall-clock reads inside traced code, the legacy global NumPy RNG, and
set-iteration order are the three ways ambient state leaks back in.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Rule, dotted

_CLOCK_ROOTS = ("time.", "datetime.")
_LEGACY_NP_RANDOM = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "normal", "uniform", "standard_normal",
    "seed", "binomial", "poisson", "exponential",
})
_STDLIB_RANDOM = frozenset({
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "uniform", "sample", "gauss", "normalvariate", "betavariate",
})


class WallClockInTrace(Rule):
    id = "DET001"
    name = "wall-clock-in-traced-code"
    rationale = ("`time.*` / `datetime.*` inside traced or kernel code is "
                 "evaluated once at trace time and baked into the compiled "
                 "program — timings belong in the host driver "
                 "(`repro.obs.timed`).")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        if not (ctx.traced or ctx.kernel):
            return
        name = dotted(node.func) or ""
        if name.startswith(_CLOCK_ROOTS):
            yield ctx.diag(self, node,
                           f"`{name}()` inside traced code reads the wall "
                           "clock at trace time, not at run time")


class UnseededRandom(Rule):
    id = "DET002"
    name = "unseeded-global-rng"
    rationale = ("The legacy global `np.random.*` functions and unseeded "
                 "`default_rng()` draw from ambient process state; every "
                 "RNG in this repo must be a seeded Generator so reruns "
                 "replay bit-for-bit.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        name = dotted(node.func)
        if not name:
            return
        parts = name.split(".")
        # np.random.<legacy fn>(...)  — the module-level global RNG.
        if (len(parts) == 3 and parts[0] in ("np", "numpy")
                and parts[1] == "random"):
            if parts[2] == "default_rng":
                if not node.args and not node.keywords:
                    yield ctx.diag(self, node,
                                   "`np.random.default_rng()` without a "
                                   "seed draws entropy from the OS; pass "
                                   "an explicit seed")
            elif parts[2] in _LEGACY_NP_RANDOM:
                yield ctx.diag(self, node,
                               f"`{name}` uses the legacy *global* NumPy "
                               "RNG; use a seeded "
                               "`np.random.default_rng(seed)` Generator")
        # stdlib random.<fn>(...)
        elif (len(parts) == 2 and parts[0] == "random"
                and parts[1] in _STDLIB_RANDOM):
            yield ctx.diag(self, node,
                           f"`{name}` draws from the process-global stdlib "
                           "RNG; use a seeded `random.Random(seed)` or a "
                           "NumPy Generator")


class SetIterationOrder(Rule):
    id = "DET003"
    name = "set-iteration-order"
    rationale = ("Iterating a set directly yields hash order, which varies "
                 "across processes (PYTHONHASHSEED) — data fed to device "
                 "arrays or emitted into reports must come from "
                 "`sorted(...)` or an ordered container.")
    node_types = (ast.For, ast.comprehension)

    def _is_set_expr(self, expr) -> bool:
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return True
        if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name):
            return expr.func.id in ("set", "frozenset")
        if isinstance(expr, ast.BinOp) and isinstance(
                expr.op, (ast.BitOr, ast.BitAnd, ast.Sub)):
            return self._is_set_expr(expr.left) or self._is_set_expr(
                expr.right)
        return False

    def check_node(self, node, ctx):
        it = node.iter
        if self._is_set_expr(it):
            # comprehension nodes carry no lineno; anchor on the iterable
            yield ctx.diag(self, it,
                           "iteration over a set is hash-ordered (varies "
                           "across processes); wrap in `sorted(...)` "
                           "before the order can feed device arrays")
