"""DIST rules: collective discipline under ``shard_map``.

The sharded-refinement protocol (``dist/refine_sharded.py``) is built on
ONE fused ``all_gather`` per sweep; ``smoke_check.check_dist_refine``
verifies the count at runtime, but only on the paths a benchmark happens
to execute.  These rules check every path: a collective inside a loop
body of a shard-mapped function multiplies the per-sweep wire volume,
and an axis name that no mesh in the module declares is a typo that
XLA reports only at run time, deep inside a trace.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Rule, dotted, suffix

COLLECTIVES = frozenset({
    "all_gather", "psum", "pmean", "pmax", "pmin", "ppermute",
    "all_to_all", "pshuffle", "psum_scatter",
})
_AXIS_QUERIES = frozenset({"axis_index", "axis_size"})


def _axis_literals(node: ast.Call) -> list:
    """String axis names passed to a collective: the ``axis_name``
    keyword, or the conventional second positional argument (first for
    ``axis_index``/``axis_size``)."""
    out = []
    for kw in node.keywords:
        if kw.arg == "axis_name" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            out.append(kw.value.value)
    sfx = suffix(dotted(node.func))
    pos = 0 if sfx in _AXIS_QUERIES else 1
    if len(node.args) > pos:
        arg = node.args[pos]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            out.append(arg.value)
        elif isinstance(arg, (ast.Tuple, ast.List)):
            out.extend(e.value for e in arg.elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, str))
    return out


class CollectiveInLoop(Rule):
    id = "DIST001"
    name = "collective-inside-loop-body"
    rationale = ("Shard-mapped sweeps issue exactly one fused collective "
                 "per sweep; a collective inside a loop body (Python or "
                 "`fori_loop`/`while_loop`/`scan`) of a shard-mapped "
                 "function — or of an `axis_name`-taking protocol helper "
                 "— multiplies the wire volume per sweep.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        sfx = suffix(dotted(node.func))
        if sfx not in COLLECTIVES:
            return
        if not (ctx.shard or ctx.proto):
            return
        if ctx.loop_depth >= 1:
            yield ctx.diag(
                self, node,
                f"collective `{sfx}` at loop depth {ctx.loop_depth} inside "
                "a shard-mapped scope — the protocol is ONE fused "
                "collective per sweep; hoist it or batch the payload")


class UnknownAxisName(Rule):
    id = "DIST002"
    name = "collective-axis-name-mismatch"
    rationale = ("A collective's axis name must match an axis the module "
                 "declares (via `P(...)`/`PartitionSpec`/`Mesh`/"
                 "`make_mesh`/`axis_name=`); a mismatch is an XLA "
                 "trace-time error that surfaces far from the typo.")
    node_types = (ast.Call,)

    def check_node(self, node, ctx):
        sfx = suffix(dotted(node.func))
        if sfx not in (COLLECTIVES | _AXIS_QUERIES):
            return
        vocab = ctx.axis_vocab
        if not vocab:            # module declares no mesh: nothing to match
            return
        for name in _axis_literals(node):
            if name not in vocab:
                yield ctx.diag(
                    self, node,
                    f"collective `{sfx}` uses axis name {name!r} but this "
                    f"module only declares axes {sorted(vocab)}")
