"""PAL rules: Pallas kernel contracts.

A ``pallas_call``'s grid, BlockSpec index maps, and block shapes must
agree on rank — a mismatch compiles to garbage indexing or fails deep in
Mosaic, far from the typo.  And every kernel in ``kernels/*/`` ships as
a triple (``kernel.py`` + ``ref.py`` + ``ops.py``) whose dispatch layer
consults both, which is what the parity tests and the `prefer="auto"`
fallbacks rely on.
"""

from __future__ import annotations

import ast
import os

from repro.analysis.engine import Rule, dotted, suffix


def _tuple_len(expr, ctx):
    """Static length of a tuple/list expression, resolving one level of
    Name indirection through the enclosing scopes; None if unknown."""
    if isinstance(expr, ast.Name):
        expr = ctx.lookup(expr.id)
    if isinstance(expr, (ast.Tuple, ast.List)):
        if any(isinstance(e, ast.Starred) for e in expr.elts):
            return None
        return len(expr.elts)
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return 1                       # grid=8 is shorthand for (8,)
    return None


class BlockSpecGridRank(Rule):
    id = "PAL001"
    name = "blockspec-grid-rank-mismatch"
    rationale = ("Each BlockSpec index_map takes one argument per grid "
                 "dimension and returns one coordinate per block-shape "
                 "dimension; a rank mismatch indexes the wrong blocks.")
    node_types = (ast.Call,)

    def _check_spec(self, spec: ast.Call, grid_len, ctx):
        if len(spec.args) < 2:
            return
        shape_len = _tuple_len(spec.args[0], ctx)
        index_map = spec.args[1]
        if not isinstance(index_map, ast.Lambda):
            return
        # defaulted lambda params (`lambda h, qi, g=G: ...`) are closure
        # captures, not grid arguments — only required params count
        arity = len(index_map.args.args) - len(index_map.args.defaults)
        if grid_len is not None and arity != grid_len:
            yield ctx.diag(
                self, spec,
                f"BlockSpec index_map takes {arity} argument(s) but the "
                f"grid has {grid_len} dimension(s)")
        ret = index_map.body
        ret_len = None
        if isinstance(ret, (ast.Tuple, ast.List)):
            ret_len = len(ret.elts)
        if (ret_len is not None and shape_len is not None
                and ret_len != shape_len):
            yield ctx.diag(
                self, spec,
                f"BlockSpec index_map returns {ret_len} coordinate(s) for "
                f"a {shape_len}-dimensional block_shape")

    def check_node(self, node, ctx):
        if suffix(dotted(node.func)) != "pallas_call":
            return
        grid_len = None
        spec_exprs = []
        for kw in node.keywords:
            if kw.arg == "grid":
                grid_len = _tuple_len(kw.value, ctx)
            elif kw.arg in ("in_specs", "out_specs"):
                spec_exprs.append(kw.value)
        for expr in spec_exprs:
            for n in ast.walk(expr):
                if (isinstance(n, ast.Call)
                        and suffix(dotted(n.func)) == "BlockSpec"):
                    yield from self._check_spec(n, grid_len, ctx)


class KernelTriple(Rule):
    id = "PAL002"
    name = "kernel-triple-contract"
    rationale = ("Every `kernels/<name>/` package ships kernel.py (Pallas) "
                 "+ ref.py (jnp reference) + ops.py (dispatch); ops.py "
                 "must import both so the parity tests and runtime "
                 "fallbacks always have the reference path.")
    node_types = ()

    def __init__(self):
        self._triples: dict = {}      # dir -> {basename: (path, tree)}

    def observe_module(self, ctx):
        parts = os.path.normpath(ctx.path).split(os.sep)
        base = os.path.basename(ctx.path)
        if "kernels" not in parts or base not in ("kernel.py", "ref.py",
                                                  "ops.py"):
            return ()
        kdir = os.path.dirname(ctx.path)
        if os.path.basename(os.path.dirname(kdir)) != "kernels":
            return ()
        self._triples.setdefault(kdir, {})[base] = (ctx.path, ctx.tree)
        return ()

    def _imports_of(self, tree) -> set:
        mods: set = set()
        for n in ast.walk(tree):
            if isinstance(n, ast.ImportFrom) and n.module:
                mods.add(n.module.rsplit(".", 1)[-1])
                mods.update(a.name for a in n.names)
            elif isinstance(n, ast.Import):
                for a in n.names:
                    mods.add(a.name.rsplit(".", 1)[-1])
        return mods

    def finalize(self, project):
        for kdir in sorted(self._triples):
            seen = self._triples[kdir]
            anchor_path = next(iter(seen.values()))[0]
            for want in ("kernel.py", "ref.py", "ops.py"):
                if want not in seen and not os.path.isfile(
                        os.path.join(kdir, want)):
                    yield Diagnostic_(
                        self.id, anchor_path,
                        f"kernel package {os.path.basename(kdir)!r} is "
                        f"missing {want} — every kernel ships as a "
                        "kernel/ref/ops triple")
            if "ops.py" in seen:
                path, tree = seen["ops.py"]
                mods = self._imports_of(tree)
                for dep in ("kernel", "ref"):
                    if dep not in mods:
                        yield Diagnostic_(self.id, path,
                                          f"ops.py dispatch does not import "
                                          f"the `{dep}` module — parity "
                                          "fallback path is unreachable")


def Diagnostic_(rule_id, path, message):
    from repro.analysis.engine import Diagnostic
    return Diagnostic(rule=rule_id, path=path, line=1, col=1,
                      message=message)
