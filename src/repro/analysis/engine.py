"""Visitor framework of the static analyzer.

One AST walk per file, shared by every rule.  The walker maintains a
stack of :class:`Frame` objects so a rule inspecting a node knows the
*execution context* of the enclosing function, not just its syntax:

* ``traced``  — the body runs under a JAX trace: the function is
  decorated with (or passed to) ``jax.jit`` / ``vmap`` / ``grad`` /
  ``shard_map`` / ``pallas_call``, or it is the body callable of
  ``lax.fori_loop`` / ``while_loop`` / ``scan`` / ``cond``, or it is
  nested inside such a function.  Host-sync and wall-clock hazards only
  matter here.
* ``kernel``  — the function is a Pallas kernel (first argument of a
  ``pallas_call``).
* ``shard``   — the body runs under ``shard_map``; ``axes`` carries the
  mesh axis names recovered from the mapping call's specs.
* ``proto``   — the function takes an ``axis_name`` parameter (or is
  nested in one that does): a collective-protocol helper that is meant
  to be called under ``shard_map`` even when the mapping call is in
  another module.
* ``loop_depth`` — lexical loop nesting inside the current function;
  body callables handed to ``fori_loop``/``while_loop``/``scan`` enter
  with the *caller's* depth + 1, because that is how often they run.

Tracking is name-based and intra-module: ``fn = functools.partial(f, …)``
followed by ``shard_map(fn, …)`` marks ``f``; aliases resolve through
simple assignments in the enclosing scopes.  That is deliberately
conservative — cross-module call graphs are out of scope; rules that
need them take the ``proto`` escape hatch above.

Suppressions: a ``# repro: ignore[RULE1,RULE2]`` (or a bare
``# repro: ignore``) comment on the flagged line or the line directly
above silences the listed rules (all rules when bare) for that line.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re

# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: rule id, location, human message."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


# ---------------------------------------------------------------------------
# Project context: the vocabularies rules check names against
# ---------------------------------------------------------------------------


def _literal_strings(node) -> list:
    """Every string constant anywhere in ``node``'s subtree (source order)."""
    return [n.value for n in ast.walk(node)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)]


def _parse_assign_tuples(tree: ast.Module, names) -> dict:
    """``{name: [string literals]}`` for top-level assignments to ``names``."""
    out = {n: [] for n in names}
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name) and tgt.id in out:
                out[tgt.id] = _literal_strings(stmt.value)
    return out


class Project:
    """Repo-level vocabularies, parsed statically from their source of
    truth so the analyzer never imports the code it checks:

    * ``metric_names`` — ``register("…", …)`` literals in
      ``obs/registry.py`` (counter/gauge names).
    * ``span_names`` / ``span_prefixes`` — the ``SPAN_NAMES`` /
      ``SPAN_PREFIXES`` declarations in ``obs/registry.py``.
    * ``fault_sites`` — ``FAULT_SITES`` in ``guard/chaos.py``.
    * ``guard_codes`` — ``KNOWN_CODES`` in ``guard/errors.py`` (with
      literal duplicates preserved for the uniqueness check).
    """

    def __init__(self, root: str | None = None, *,
                 metric_names=None, span_names=None, span_prefixes=None,
                 fault_sites=None, guard_codes=None):
        self.root = root
        self.metric_names = set(metric_names or ())
        self.span_names = set(span_names or ())
        self.span_prefixes = tuple(span_prefixes or ())
        self.fault_sites = set(fault_sites or ())
        self.guard_code_list = list(guard_codes or ())
        self.guard_codes = set(self.guard_code_list)
        self.guard_codes_path = None
        if root:
            self._discover(root)

    def _find(self, root: str, rel: str):
        """Locate ``rel`` (e.g. ``obs/registry.py``) under ``root``."""
        direct = os.path.join(root, rel)
        if os.path.isfile(direct):
            return direct
        for dirpath, _dirs, files in os.walk(root):
            cand = os.path.join(dirpath, rel)
            if os.path.isfile(cand):
                return cand
        return None

    def _discover(self, root: str) -> None:
        reg = self._find(root, os.path.join("obs", "registry.py"))
        if reg:
            tree = ast.parse(open(reg).read())
            for node in ast.walk(tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id == "register"
                        and node.args
                        and isinstance(node.args[0], ast.Constant)):
                    self.metric_names.add(node.args[0].value)
            spans = _parse_assign_tuples(tree, ("SPAN_NAMES",
                                                "SPAN_PREFIXES"))
            self.span_names.update(spans["SPAN_NAMES"])
            self.span_prefixes = self.span_prefixes + tuple(
                spans["SPAN_PREFIXES"])
        chaos = self._find(root, os.path.join("guard", "chaos.py"))
        if chaos:
            tree = ast.parse(open(chaos).read())
            sites = _parse_assign_tuples(tree, ("FAULT_SITES",))
            self.fault_sites.update(sites["FAULT_SITES"])
        errors = self._find(root, os.path.join("guard", "errors.py"))
        if errors:
            tree = ast.parse(open(errors).read())
            codes = _parse_assign_tuples(tree, ("KNOWN_CODES",))
            self.guard_code_list.extend(codes["KNOWN_CODES"])
            self.guard_codes = set(self.guard_code_list)
            self.guard_codes_path = errors

    def span_declared(self, name: str) -> bool:
        if name in self.span_names:
            return True
        return any(name.startswith(p) for p in self.span_prefixes)


# ---------------------------------------------------------------------------
# Name helpers
# ---------------------------------------------------------------------------


def dotted(node) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def suffix(name: str | None) -> str | None:
    """Last dotted component (``jax.lax.psum`` → ``psum``)."""
    return name.rsplit(".", 1)[-1] if name else None


# Wrappers whose callable argument runs under a JAX trace.
TRACE_WRAPPERS = frozenset({
    "jit", "pjit", "vmap", "pmap", "grad", "value_and_grad",
    "checkpoint", "remat", "custom_jvp", "custom_vjp",
})
SHARD_WRAPPERS = frozenset({"shard_map"})
KERNEL_WRAPPERS = frozenset({"pallas_call"})
# callee suffix -> indices of callable args that become (traced) loop bodies
LOOP_BODY_ARGS = {"fori_loop": (2,), "while_loop": (0, 1), "scan": (0,)}
BRANCH_BODY_ARGS = {"cond": (1, 2), "switch": (1, 2, 3, 4, 5)}

_DEF_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


# ---------------------------------------------------------------------------
# Module index: lexical scopes + traced/shard/kernel marks
# ---------------------------------------------------------------------------


class _Scope:
    __slots__ = ("node", "parent", "assigns", "defs")

    def __init__(self, node, parent):
        self.node = node
        self.parent = parent
        self.assigns: dict = {}     # name -> value expression at this level
        self.defs: dict = {}        # name -> def node at this level

    def lookup_assign(self, name):
        s = self
        while s is not None:
            if name in s.assigns:
                return s.assigns[name]
            s = s.parent
        return None

    def lookup_def(self, name):
        s = self
        while s is not None:
            if name in s.defs:
                return s.defs[name]
            s = s.parent
        return None


@dataclasses.dataclass
class _Marks:
    traced: bool = False
    shard: bool = False
    kernel: bool = False
    loop_body: bool = False
    axes: frozenset = frozenset()

    def merge(self, other: "_Marks") -> None:
        self.traced |= other.traced
        self.shard |= other.shard
        self.kernel |= other.kernel
        self.loop_body |= other.loop_body
        self.axes |= other.axes


class ModuleIndex:
    """Pre-pass over one module: scope tree, per-def trace marks, and the
    module's mesh-axis vocabulary."""

    def __init__(self, tree: ast.Module):
        self.scope_of: dict = {}        # id(def/module node) -> _Scope
        self.marks: dict = {}           # id(def node) -> _Marks
        self.axis_vocab: set = set()
        self._calls: list = []          # (Call node, enclosing _Scope)
        self._build(tree, None)
        self._mark_decorators()
        self._mark_calls()

    # -- scope construction --------------------------------------------------

    def _build(self, node, parent: _Scope | None) -> _Scope:
        scope = _Scope(node, parent)
        self.scope_of[id(node)] = scope

        def rec(n):
            for child in ast.iter_child_nodes(n):
                if isinstance(child, _DEF_NODES):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        scope.defs[child.name] = child
                    self._build(child, scope)
                    continue
                if isinstance(child, ast.Assign) and len(child.targets) == 1:
                    tgt = child.targets[0]
                    if isinstance(tgt, ast.Name):
                        scope.assigns[tgt.id] = child.value
                if isinstance(child, ast.Call):
                    self._calls.append((child, scope))
                    self._note_axes(child)
                rec(child)

        rec(node)
        return scope

    def _note_axes(self, call: ast.Call) -> None:
        """Mesh-axis names declared by this call, if it is a spec/mesh
        constructor (``P``/``PartitionSpec``/``Mesh``/``make_mesh``) or
        carries an ``axis_name(s)=`` keyword."""
        sfx = suffix(dotted(call.func))
        if sfx in ("P", "PartitionSpec", "Mesh", "make_mesh"):
            for arg in call.args:
                self.axis_vocab.update(_literal_strings(arg))
        for kw in call.keywords:
            if kw.arg in ("axis_name", "axis_names"):
                self.axis_vocab.update(_literal_strings(kw.value))

    # -- callable resolution -------------------------------------------------

    def _resolve_callable(self, expr, scope: _Scope, depth: int = 0):
        """Candidate function nodes an expression may evaluate to:
        follows Name aliases, ``functools.partial(f, …)``, and nested
        wrapper calls (``jax.jit(f)``)."""
        if depth > 6 or expr is None:
            return
        if isinstance(expr, _DEF_NODES):
            yield expr
        elif isinstance(expr, ast.Name):
            d = scope.lookup_def(expr.id)
            if d is not None:
                yield d
            val = scope.lookup_assign(expr.id)
            if val is not None and not isinstance(val, ast.Name):
                yield from self._resolve_callable(val, scope, depth + 1)
        elif isinstance(expr, ast.Call):
            sfx = suffix(dotted(expr.func))
            if sfx == "partial" and expr.args:
                yield from self._resolve_callable(expr.args[0], scope,
                                                  depth + 1)
            elif sfx in (TRACE_WRAPPERS | SHARD_WRAPPERS) and expr.args:
                yield from self._resolve_callable(expr.args[0], scope,
                                                  depth + 1)

    def _mark(self, expr, scope: _Scope, **flags) -> None:
        for node in self._resolve_callable(expr, scope):
            m = self.marks.setdefault(id(node), _Marks())
            m.merge(_Marks(**flags))

    def _shard_axes(self, call: ast.Call, scope: _Scope) -> frozenset:
        """Axis names recoverable from a ``shard_map`` call: strings in
        its spec/mesh keywords, resolving one level of Name aliasing."""
        axes: set = set()
        exprs = [kw.value for kw in call.keywords
                 if kw.arg in ("mesh", "in_specs", "out_specs",
                               "axis_names")]
        exprs += call.args[1:]
        for e in exprs:
            axes.update(_literal_strings(e))
            for n in ast.walk(e):
                if isinstance(n, ast.Name):
                    val = scope.lookup_assign(n.id)
                    if val is not None:
                        axes.update(_literal_strings(val))
        return frozenset(axes)

    # -- marking passes ------------------------------------------------------

    def _decorator_marks(self, dec, scope: _Scope) -> _Marks | None:
        name = dotted(dec)
        if name is None and isinstance(dec, ast.Call):
            fname = suffix(dotted(dec.func))
            if fname == "partial" and dec.args:
                inner = suffix(dotted(dec.args[0]))
                if inner in TRACE_WRAPPERS:
                    return _Marks(traced=True)
                if inner in SHARD_WRAPPERS:
                    return _Marks(traced=True, shard=True,
                                  axes=self._shard_axes(dec, scope))
            elif fname in TRACE_WRAPPERS:
                return _Marks(traced=True)
            elif fname in SHARD_WRAPPERS:
                return _Marks(traced=True, shard=True,
                              axes=self._shard_axes(dec, scope))
            return None
        sfx = suffix(name)
        if sfx in TRACE_WRAPPERS:
            return _Marks(traced=True)
        if sfx in SHARD_WRAPPERS:
            return _Marks(traced=True, shard=True)
        return None

    def _mark_decorators(self) -> None:
        for scope in list(self.scope_of.values()):
            node = scope.node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                m = self._decorator_marks(dec, scope.parent or scope)
                if m is not None:
                    got = self.marks.setdefault(id(node), _Marks())
                    got.merge(m)

    def _mark_calls(self) -> None:
        for call, scope in self._calls:
            sfx = suffix(dotted(call.func))
            if sfx in SHARD_WRAPPERS and call.args:
                axes = self._shard_axes(call, scope)
                self._mark(call.args[0], scope, traced=True, shard=True,
                           axes=axes)
            elif sfx in TRACE_WRAPPERS and call.args:
                self._mark(call.args[0], scope, traced=True)
            elif sfx in KERNEL_WRAPPERS and call.args:
                self._mark(call.args[0], scope, traced=True, kernel=True)
            elif sfx in LOOP_BODY_ARGS:
                for i in LOOP_BODY_ARGS[sfx]:
                    if i < len(call.args):
                        self._mark(call.args[i], scope, traced=True,
                                   loop_body=True)
            elif sfx in BRANCH_BODY_ARGS:
                for i in BRANCH_BODY_ARGS[sfx]:
                    if i < len(call.args):
                        self._mark(call.args[i], scope, traced=True)

    def marks_for(self, node) -> _Marks:
        return self.marks.get(id(node), _Marks())


# ---------------------------------------------------------------------------
# Walk context handed to rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Frame:
    node: object
    traced: bool = False
    shard: bool = False
    kernel: bool = False
    proto: bool = False          # takes (or inherits) an axis_name param
    axes: frozenset = frozenset()
    loop_depth: int = 0


class FileContext:
    """Per-file state rules read during the walk."""

    def __init__(self, path: str, tree: ast.Module, source: str,
                 project: Project):
        self.path = path
        self.tree = tree
        self.source = source
        self.project = project
        self.index = ModuleIndex(tree)
        self.frames: list = [Frame(node=tree)]

    # -- frame properties ----------------------------------------------------

    @property
    def frame(self) -> Frame:
        return self.frames[-1]

    @property
    def traced(self) -> bool:
        return self.frame.traced

    @property
    def kernel(self) -> bool:
        return self.frame.kernel

    @property
    def shard(self) -> bool:
        return self.frame.shard

    @property
    def proto(self) -> bool:
        return self.frame.proto

    @property
    def axes(self) -> frozenset:
        return self.frame.axes

    @property
    def loop_depth(self) -> int:
        return self.frame.loop_depth

    @property
    def axis_vocab(self) -> set:
        return self.index.axis_vocab

    def lookup(self, name: str):
        """Innermost assignment expression bound to ``name`` (per-scope)."""
        for frame in reversed(self.frames):
            scope = self.index.scope_of.get(id(frame.node))
            if scope is not None:
                val = scope.lookup_assign(name)
                if val is not None:
                    return val
        return None

    def diag(self, rule: "Rule", node, message: str) -> Diagnostic:
        return Diagnostic(rule=rule.id, path=self.path,
                          line=getattr(node, "lineno", 1),
                          col=getattr(node, "col_offset", 0) + 1,
                          message=message)


class Rule:
    """Base class of the catalog (see ``rules/``).

    Subclasses set ``id``/``name``/``rationale`` and implement any of:

    * ``node_types`` + :meth:`check_node` — called for every matching AST
      node with the live :class:`FileContext`;
    * :meth:`observe_module` — called once per file after its walk, to
      accumulate cross-file state;
    * :meth:`finalize` — called once per run, after every file.
    """

    id: str = "RULE000"
    name: str = ""
    rationale: str = ""
    node_types: tuple = ()

    def check_node(self, node, ctx: FileContext):
        return ()

    def observe_module(self, ctx: FileContext):
        return ()

    def finalize(self, project: Project):
        return ()


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*ignore(?:\[\s*([A-Za-z0-9_,\s]+?)\s*\])?")


def parse_suppressions(source: str) -> dict:
    """``{line_number: set of rule ids}`` (empty set == all rules);
    a suppression covers its own line and the line below it."""
    out: dict = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = (set(r.strip() for r in m.group(1).split(",") if r.strip())
                 if m.group(1) else set())
        for ln in (i, i + 1):
            if ln in out and out[ln] and rules:
                out[ln] |= rules
            elif rules and ln not in out:
                out[ln] = set(rules)
            else:
                out[ln] = set()      # bare ignore wins: all rules
    return out


def _suppressed(diag: Diagnostic, supp: dict) -> bool:
    if diag.line not in supp:
        return False
    rules = supp[diag.line]
    return not rules or diag.rule in rules


# ---------------------------------------------------------------------------
# The walk
# ---------------------------------------------------------------------------


def _collect_params(node) -> set:
    args = node.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return set(names)


def _walk_file(ctx: FileContext, rules_by_type: dict) -> list:
    diags: list = []

    def dispatch(node):
        for rule in rules_by_type.get(type(node), ()):
            diags.extend(rule.check_node(node, ctx))

    def visit(node):
        if isinstance(node, _DEF_NODES):
            parent = ctx.frame
            marks = ctx.index.marks_for(node)
            params = _collect_params(node)
            frame = Frame(
                node=node,
                traced=parent.traced or marks.traced,
                shard=parent.shard or marks.shard,
                kernel=parent.kernel or marks.kernel,
                proto=parent.proto or "axis_name" in params,
                axes=parent.axes | marks.axes,
                loop_depth=(parent.loop_depth + 1 if marks.loop_body else 0),
            )
            ctx.frames.append(frame)
            dispatch(node)
            for child in ast.iter_child_nodes(node):
                visit(child)
            ctx.frames.pop()
            return
        loop = isinstance(node, (ast.For, ast.AsyncFor, ast.While))
        if loop:
            ctx.frame.loop_depth += 1
        dispatch(node)
        for child in ast.iter_child_nodes(node):
            visit(child)
        if loop:
            ctx.frame.loop_depth -= 1

    visit(ctx.tree)
    return diags


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _expand(paths) -> list:
    files: list = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(dirpath, n)
                             for n in sorted(names) if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    return files


def analyze_source(source: str, *, path: str = "<memory>",
                   project: Project | None = None,
                   rules=None) -> list:
    """Analyze one source string (fixtures, tests)."""
    from repro.analysis.rules import all_rules

    rules = list(rules) if rules is not None else all_rules()
    project = project or Project()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Diagnostic(rule="PARSE", path=path, line=e.lineno or 1,
                           col=(e.offset or 0) + 1,
                           message=f"syntax error: {e.msg}")]
    ctx = FileContext(path, tree, source, project)
    rules_by_type: dict = {}
    for rule in rules:
        for t in rule.node_types:
            rules_by_type.setdefault(t, []).append(rule)
    diags = _walk_file(ctx, rules_by_type)
    for rule in rules:
        diags.extend(rule.observe_module(ctx))
    supp = parse_suppressions(source)
    return [d for d in diags if not _suppressed(d, supp)]


def analyze_paths(paths, *, root: str | None = None,
                  project: Project | None = None, rules=None) -> list:
    """Run the catalog over files/directories; returns sorted findings."""
    from repro.analysis.rules import all_rules

    rules = list(rules) if rules is not None else all_rules()
    files = _expand(paths)
    if project is None:
        base = root
        if base is None and files:
            base = os.path.commonpath([os.path.abspath(f) for f in files])
            if os.path.isfile(base):
                base = os.path.dirname(base)
        project = Project(base)
    diags: list = []
    supp_by_path: dict = {}
    for f in files:
        try:
            source = open(f, encoding="utf-8").read()
        except OSError as e:
            diags.append(Diagnostic(rule="PARSE", path=f, line=1, col=1,
                                    message=f"unreadable: {e}"))
            continue
        supp_by_path[f] = parse_suppressions(source)
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            diags.append(Diagnostic(
                rule="PARSE", path=f, line=e.lineno or 1,
                col=(e.offset or 0) + 1, message=f"syntax error: {e.msg}"))
            continue
        ctx = FileContext(f, tree, source, project)
        rules_by_type: dict = {}
        for rule in rules:
            for t in rule.node_types:
                rules_by_type.setdefault(t, []).append(rule)
        diags.extend(_walk_file(ctx, rules_by_type))
        for rule in rules:
            diags.extend(rule.observe_module(ctx))
    for rule in rules:
        diags.extend(rule.finalize(project))
    diags = [d for d in diags
             if not _suppressed(d, supp_by_path.get(d.path, {}))]
    return sorted(diags, key=lambda d: (d.path, d.line, d.col, d.rule))


def findings_json(diags, *, rules=None) -> str:
    """The machine-readable report the CI job uploads as an artifact."""
    from repro.analysis.rules import all_rules

    rules = list(rules) if rules is not None else all_rules()
    counts: dict = {}
    for d in diags:
        counts[d.rule] = counts.get(d.rule, 0) + 1
    return json.dumps({
        "schema": "repro.analysis/v1",
        "findings": [d.to_dict() for d in diags],
        "counts": counts,
        "rules": [{"id": r.id, "name": r.name} for r in rules],
    }, indent=2)
