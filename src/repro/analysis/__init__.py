"""`repro.analysis` — rule-based AST static analyzer for the repo's
load-bearing conventions.

The repo has contracts that runtime checks can only enforce on the code
paths a test happens to execute: traced/jitted code must never sync to
host or consume ambient nondeterminism, sharded sweep loops must issue
exactly one collective per sweep, every span/metric name must be declared
in :mod:`repro.obs.registry`, chaos sites and guard codes must come from
their catalogs.  This package checks all of them at lint time, on every
code path:

* :mod:`repro.analysis.engine` — the visitor framework: per-file AST walk
  with scope/decorator tracking (rules know when they are inside
  ``jax.jit`` / ``shard_map`` / ``pallas_call`` / ``fori_loop`` bodies),
  ``# repro: ignore[RULE]`` suppressions, JSON + human diagnostics.
* :mod:`repro.analysis.rules` — the rule catalog (see
  ``src/repro/analysis/README.md`` for ids, rationale, and examples).
* ``python -m repro.analysis`` — the CLI; runs the full catalog over
  ``src/repro`` and exits non-zero on findings (the CI lint gate).
"""

from repro.analysis.engine import (
    Diagnostic,
    Project,
    Rule,
    analyze_paths,
    analyze_source,
)
from repro.analysis.rules import all_rules

__all__ = ["Diagnostic", "Project", "Rule", "analyze_paths",
           "analyze_source", "all_rules"]
