"""Paper Table 4 analogue: weak scaling on cube meshes, E/P held constant.

Validates C3 (neighbor counts stay in the SEM range, flat in P) and
C8 (average message size ≫ m₂ → the volume-dominated regime that motivates
spectral partitioning at exascale).
"""

from __future__ import annotations

import time

from benchmarks.bench_util import emit
from repro.core import comm_time_model, m2_words, partition_metrics, rsb_partition_mesh
from repro.mesh import box_mesh, dual_graph


def _cube_dims(nelems: int) -> tuple:
    side = round(nelems ** (1 / 3))
    return (side, side, max(1, nelems // (side * side)))


def run(e_per_p: int = 512, parts_list=(4, 8, 16), full: bool = False) -> list:
    if full:
        e_per_p, parts_list = 1000, (8, 16, 32, 64)
    rows = []
    for p in parts_list:
        dims = _cube_dims(e_per_p * p)
        mesh = box_mesh(*dims)
        graph = dual_graph(mesh)
        t0 = time.perf_counter()
        parts, report = rsb_partition_mesh(mesh, p, method="lanczos",
                                           pre="rcb", tol=1e-3)
        dt = time.perf_counter() - t0
        pm = partition_metrics(graph, parts, p, dofs_per_face=64)
        ct = comm_time_model(pm)
        rows.append({
            "P": p, "E": mesh.nelems, "seconds": dt,
            "max_nbrs": pm.max_neighbors, "avg_nbrs": pm.avg_neighbors,
            "avg_msg_words": pm.avg_message_size,
            "m2_words": ct["m2_words"], "dominated": ct["dominated_by"],
            "imbalance": pm.imbalance,
        })
        emit(
            f"weak_scaling/P={p}", dt * 1e6,
            f"E={mesh.nelems};max_nbrs={pm.max_neighbors};"
            f"avg_nbrs={pm.avg_neighbors:.1f};"
            f"avg_msg={pm.avg_message_size:.0f}w;m2={ct['m2_words']:.0f}w;"
            f"regime={ct['dominated_by']};imbalance={pm.imbalance}",
        )
    return rows


if __name__ == "__main__":
    run()
