"""Shared benchmark utilities: timing, CSV emission, and the partition
tables' common row columns (extracted once from the pipeline's own
``to_dict`` records instead of per-table by hand)."""

from __future__ import annotations

import time

import jax


def report_cols(report) -> dict:
    """Solver-provenance columns every partition table repeats: geometric
    pre-pass, preconditioner family, multilevel depth, total iterations.
    One extraction point over ``RSBReport.to_dict`` — the tables stop
    cherry-picking attributes by hand."""
    d = report.to_dict()
    return {"pre": d["pre"] or "none", "precond": d["precond"],
            "precond_levels": d["precond_levels"],
            "iters": d["total_iterations"]}


def stage_seconds(ctx) -> dict:
    """Per-stage wall seconds of a pipeline run, keyed by span name
    (``pre:rcb``, ``bisect:rsb-batched``, ``post:repair``, …).  Reads the
    run's trace when one was recorded; falls back to the StageRecords so
    the columns survive ``REPRO_OBS=off``."""
    trace = getattr(ctx, "trace", None)
    if trace is not None:
        return {c.name: c.seconds for c in trace.children}
    return {f"{s.kind}:{s.name}": s.seconds for s in ctx.stages}


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
