"""Pallas kernel microbenches (interpret on CPU; numbers are correctness-
path timings — the TPU perf story lives in the roofline analysis)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_util import emit, time_fn
from repro.kernels.ell_spmv.ops import ell_spmv
from repro.kernels.ell_spmv.ref import ell_spmv_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.segment_sum.ops import connection_table


def _segment_sum_numpy(labels, cols, wts, nparts):
    """Host-baseline table build (np.add.at scatter) — what the sharded
    refinement sweep replaces; the smoke gate asserts the op beats it."""
    B, w = cols.shape
    out = np.zeros((B, nparts), np.float32)
    ri = np.broadcast_to(np.arange(B)[:, None], (B, w))
    np.add.at(out, (ri, labels[cols]), wts)
    return out


def run(full: bool = False) -> None:
    rng = np.random.default_rng(0)

    n, w = (16384, 27) if full else (4096, 27)
    cols = jnp.asarray(rng.integers(0, n, (n, w)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, w)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    ref = jax.jit(lambda c, v, xx: ell_spmv_ref(c.T, v.T, xx))
    emit("kernels/ell_spmv_ref", time_fn(ref, cols, vals, x), f"n={n};w={w}")
    emit("kernels/ell_spmv_pallas_interpret", time_fn(ell_spmv, cols, vals, x),
         f"n={n};w={w}")

    V, d, nnz, B = (100000, 64, 8192, 1024) if full else (10000, 64, 1024, 128)
    table = jnp.asarray(rng.normal(size=(V, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, B, nnz)), jnp.int32)
    refb = jax.jit(lambda t, i, s: embedding_bag_ref(t, i, s, B))
    emit("kernels/embedding_bag_ref", time_fn(refb, table, idx, seg),
         f"V={V};d={d};nnz={nnz}")
    emit("kernels/embedding_bag_pallas_interpret",
         time_fn(lambda t, i, s: embedding_bag(t, i, s, B), table, idx, seg),
         f"V={V};d={d};nnz={nnz}")

    B, w, m, nparts = (16384, 27, 32768, 128) if full else (4096, 27, 8192, 64)
    labels_n = rng.integers(0, nparts, m)
    cols_n = rng.integers(0, m, (B, w))
    wts_n = rng.integers(1, 5, (B, w)).astype(np.float32)
    emit("kernels/segment_sum_numpy",
         time_fn(lambda: _segment_sum_numpy(labels_n, cols_n, wts_n, nparts)),
         f"B={B};w={w};nparts={nparts}")
    labels = jnp.asarray(labels_n, jnp.int32)
    cols = jnp.asarray(cols_n, jnp.int32)
    wts = jnp.asarray(wts_n)
    emit("kernels/segment_sum_op",
         time_fn(lambda l, c, v: connection_table(l, c, v, nparts),
                 labels, cols, wts),
         f"B={B};w={w};nparts={nparts}")
    emit("kernels/segment_sum_pallas_interpret",
         time_fn(lambda l, c, v: connection_table(l, c, v, nparts,
                                                  prefer="pallas"),
                 labels, cols, wts),
         f"B={B};w={w};nparts={nparts}")

    Bq, S, H, D = (2, 512, 8, 64) if full else (1, 256, 4, 64)
    q = jnp.asarray(rng.normal(size=(Bq, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bq, S, H // 2, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bq, S, H // 2, D)), jnp.float32)
    refa = jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True))
    emit("kernels/flash_attention_ref", time_fn(refa, q, k, v),
         f"B={Bq};S={S};H={H};D={D}")
    emit("kernels/flash_attention_pallas_interpret",
         time_fn(lambda a, b, c: flash_attention(a, b, c, causal=True), q, k, v),
         f"B={Bq};S={S};H={H};D={D}")


if __name__ == "__main__":
    run()
