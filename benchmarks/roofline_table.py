"""§Roofline table: aggregate runs/dryrun/*.json into the per-cell report.

Run `python -m repro.launch.dryrun --all` first (or point --dir at cached
results).  Emits one CSV row per (arch × shape × mesh) with the three
roofline terms, the dominant bottleneck, and useful-FLOP fraction.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.bench_util import emit


def load_records(dirname: str = "runs/dryrun") -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def segment_sum_row(B: int = 16384, w: int = 27, nparts: int = 128,
                    m: int = 32768, *, hbm_gbps: float = 1200.0,
                    flops_tf: float = 90.0) -> dict:
    """Analytical roofline row for the segment-sum connection-table kernel
    (dist/refine_sharded's per-sweep launch).  Memory: stream cols+wts
    (B·w int32+f32), resident labels (m int32), write the (B, nparts) f32
    table.  Compute: w fused compare+multiply+add sweeps over (B, nparts).
    The table's arithmetic intensity ~ w·nparts / (8·w + 4·nparts) flops
    per byte — memory-bound at mesh-typical w, which is why one batched
    launch per sweep (not one per shard) is the right shape."""
    bytes_moved = B * w * 8 + m * 4 + B * nparts * 4
    flops = 3 * B * w * nparts          # cmp + mul + add per (row, slot, q)
    mem_s = bytes_moved / (hbm_gbps * 1e9)
    comp_s = flops / (flops_tf * 1e12)
    dominant = "memory" if mem_s >= comp_s else "compute"
    emit(
        f"roofline/kernel/segment_sum/B{B}w{w}p{nparts}",
        max(mem_s, comp_s) * 1e6,
        f"compute={comp_s:.3e}s;memory={mem_s:.3e}s;collective=0.000e+00s;"
        f"dominant={dominant};"
        f"intensity={flops / bytes_moved:.2f}flop/B",
    )
    return {"bytes": bytes_moved, "flops": flops, "dominant": dominant}


def run(dirname: str = "runs/dryrun") -> list:
    segment_sum_row()
    recs = load_records(dirname)
    if not recs:
        print("# no dry-run records found; run `python -m repro.launch.dryrun --all`")
        return []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh', '-')}"
        if r.get("status") == "skip":
            emit(name, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            emit(name, 0.0, f"FAIL:{r.get('error', '?')[:60]}")
            continue
        rl = r["roofline"]
        bound_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(
            name,
            bound_s * 1e6,  # modeled step time = dominant roofline term
            f"compute={rl['compute_s']:.3e}s;memory={rl['memory_s']:.3e}s;"
            f"collective={rl['collective_s']:.3e}s;dominant={rl['dominant']};"
            f"useful={rl['useful_fraction']:.3f};"
            f"roofline_frac={rl['roofline_fraction']:.3f};"
            f"live_gb={r['live_bytes_per_device']/1e9:.2f};"
            f"fits16gb={r['fits_16gb']}",
        )
    return recs


if __name__ == "__main__":
    run()
