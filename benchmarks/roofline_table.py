"""§Roofline table: aggregate runs/dryrun/*.json into the per-cell report.

Run `python -m repro.launch.dryrun --all` first (or point --dir at cached
results).  Emits one CSV row per (arch × shape × mesh) with the three
roofline terms, the dominant bottleneck, and useful-FLOP fraction.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.bench_util import emit


def load_records(dirname: str = "runs/dryrun") -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run(dirname: str = "runs/dryrun") -> list:
    recs = load_records(dirname)
    if not recs:
        print("# no dry-run records found; run `python -m repro.launch.dryrun --all`")
        return []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh', '-')}"
        if r.get("status") == "skip":
            emit(name, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            emit(name, 0.0, f"FAIL:{r.get('error', '?')[:60]}")
            continue
        rl = r["roofline"]
        bound_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(
            name,
            bound_s * 1e6,  # modeled step time = dominant roofline term
            f"compute={rl['compute_s']:.3e}s;memory={rl['memory_s']:.3e}s;"
            f"collective={rl['collective_s']:.3e}s;dominant={rl['dominant']};"
            f"useful={rl['useful_fraction']:.3f};"
            f"roofline_frac={rl['roofline_fraction']:.3f};"
            f"live_gb={r['live_bytes_per_device']/1e9:.2f};"
            f"fits16gb={r['fits_16gb']}",
        )
    return recs


if __name__ == "__main__":
    run()
