"""Partitioner quality comparison (paper §3 related work + §8 evaluation):
RSB (weighted / unweighted Laplacian) vs RCB vs RIB vs Hilbert-SFC vs
random, on a warped pebble-bed mesh where geometry misleads axis-aligned
cuts.  Validates C3 (quality) and C6 (weighted ≥ unweighted on volume).
Also reports the halo size each partition induces in the framework's
partition-aware GNN sharding — the paper-technique → framework bridge.

RSB rows run the full partition pipeline (pre → bisect → repair/refine
post stage) and carry a `refine` axis: `rsb_weighted_raw` is the identical
bisection with the post stage stripped (recorded from the pipeline's
`parts_raw`, no second solve), and `rsb_weighted_kway` is the SAME
bisection refined by the hill-climbing k-way FM chain instead of the
greedy sweeps (`run_post_stages` on `parts_raw` — still no second solve),
so raw-vs-greedy-vs-kway is a pure post-stage comparison.  Every row
records `disconnected` parts and the post stage's wall clock.  The
`multilevel` row runs the METIS-style k-way V-cycle (bisect="multilevel")
under its preset repair+kway chain on the same mesh.
"""

from __future__ import annotations

import time

from benchmarks.bench_util import emit, report_cols, stage_seconds
from repro.core import PartitionPipeline, partition, partition_metrics, run_post_stages
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import dual_graph, pebble_mesh


def run(dims=(12, 12, 12), nparts=16, full: bool = False) -> list:
    if full:
        dims, nparts = (20, 20, 20), 32
    mesh = pebble_mesh(*dims, n_pebbles=5, warp=0.15, seed=1)
    graph = dual_graph(mesh)
    rows = []

    def record(name, parts, dt, engine="-", report=None, refine="none",
               post_seconds=0.0, stages=None):
        pm = partition_metrics(graph, parts, nparts)
        halo = plan_halo_sharding(graph, parts, nparts).halo
        row = {"name": name, "engine": engine, "seconds": dt,
               "refine": refine, "post_seconds": post_seconds,
               "cut": pm.edge_cut,
               "volume": pm.total_volume, "max_nbrs": pm.max_neighbors,
               "avg_nbrs": pm.avg_neighbors, "halo": halo,
               "imbalance": pm.imbalance,
               "disconnected": pm.disconnected_parts}
        if report is not None:
            # Solver provenance: geometric pre-pass, preconditioner family,
            # multilevel hierarchy depth, and total iteration count.
            cols = report_cols(report)
            row.update(cols)
        if stages is not None:
            row["stages"] = stages   # per-stage wall from the run's trace
        rows.append(row)
        extra = ""
        if report is not None:
            extra = (f";pre={cols['pre']};precond={cols['precond']};"
                     f"mlv={cols['precond_levels']};"
                     f"iters={cols['iters']}")
        emit(
            f"quality/{name}", dt * 1e6,
            f"cut={pm.edge_cut:.0f};volume={pm.total_volume:.0f};"
            f"max_nbrs={pm.max_neighbors};halo={halo};imb={pm.imbalance};"
            f"disc={pm.disconnected_parts};refine={refine}"
            + extra,
        )

    # RSB rows carry the engine comparison (level-synchronous batched
    # engine vs the recursive per-node reference) and, on the batched
    # weighted run, the refine axis (raw labels vs the full pipeline).
    for engine in ("batched", "recursive"):
        for lap in ("weighted", "unweighted"):
            pipe = PartitionPipeline(
                bisect=f"rsb-{engine}",
                bisect_kw=dict(laplacian=lap, tol=1e-3),
            )
            t0 = time.perf_counter()
            ctx = pipe.run(mesh, nparts)
            dt = time.perf_counter() - t0
            suffix = "" if engine == "batched" else "_recursive"
            record(f"rsb_{lap}{suffix}", ctx.parts, dt, engine=engine,
                   report=ctx.report, refine="repair+refine",
                   post_seconds=ctx.report.post.seconds,
                   stages=stage_seconds(ctx))
            if engine == "batched" and lap == "weighted":
                # Same bisection, post stage stripped: parts_raw is free.
                record("rsb_weighted_raw", ctx.parts_raw,
                       dt - ctx.report.post.seconds, engine=engine,
                       report=ctx.report, refine="none")
                # ... and re-refined by the k-way FM chain: the greedy-vs-
                # kway axis from ONE solve.
                t0 = time.perf_counter()
                parts_k, _, _ = run_post_stages(
                    graph, ctx.parts_raw, nparts, ("repair", "kway"),
                    weights=ctx.weights)
                k_dt = time.perf_counter() - t0
                record("rsb_weighted_kway", parts_k,
                       dt - ctx.report.post.seconds + k_dt, engine=engine,
                       report=ctx.report, refine="repair+kway",
                       post_seconds=k_dt)
    # The multilevel k-way V-cycle under its preset post chain: the
    # cross-partitioner quality row for the METIS-style engine (same mesh,
    # same nparts — directly comparable to the rsb_* rows above).
    pipe = PartitionPipeline(pre="none", bisect="multilevel",
                             post=("repair", "kway"))
    t0 = time.perf_counter()
    ctx = pipe.run(mesh, nparts)
    dt = time.perf_counter() - t0
    record("multilevel", ctx.parts, dt, engine="multilevel",
           report=ctx.report, refine="repair+kway",
           post_seconds=ctx.report.post.seconds,
           stages=stage_seconds(ctx))
    for name in ("rcb", "rib", "sfc", "random"):
        t0 = time.perf_counter()
        parts = partition(mesh, nparts, partitioner=name)
        record(name, parts, time.perf_counter() - t0)
    return rows


if __name__ == "__main__":
    run()
