"""Benchmark driver: one module per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Tables ↔ paper:
  partition_time  — Tables 1–2 (Lanczos vs inverse iteration, RCB pre-pass)
  weak_scaling    — Table 4 (cube meshes, E/P const, message-size regime)
  quality         — §8 evaluation + §3 baselines (RSB/RCB/RIB/SFC/random)
  kernels         — Pallas kernel micro-benches
  roofline        — §Roofline table from cached dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None,
                    choices=["partition_time", "weak_scaling", "quality",
                             "kernels", "roofline"])
    ap.add_argument("--dryrun-dir", default="runs/dryrun")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()

    def want(name):
        return args.only is None or args.only == name

    if want("quality"):
        from benchmarks import quality

        quality.run(full=args.full)
    if want("partition_time"):
        from benchmarks import partition_time

        partition_time.run(full=args.full)
    if want("weak_scaling"):
        from benchmarks import weak_scaling

        weak_scaling.run(full=args.full)
    if want("kernels"):
        from benchmarks import kernels

        kernels.run(full=args.full)
    if want("roofline"):
        from benchmarks import roofline_table

        roofline_table.run(args.dryrun_dir)
    print(f"# benchmarks completed in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
