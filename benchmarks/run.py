"""Benchmark driver: one module per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json PATH]

Tables ↔ paper:
  partition_time  — Tables 1–2 (Lanczos vs inverse iteration, RCB pre-pass,
                    batched vs recursive RSB engine)
  weak_scaling    — Table 4 (cube meshes, E/P const, message-size regime)
  quality         — §8 evaluation + §3 baselines (RSB/RCB/RIB/SFC/random),
                    including rsb_* rows for both engines
  kernels         — Pallas kernel micro-benches
  roofline        — §Roofline table from cached dry-run artifacts

``--json PATH`` writes the partition tables (plus an `engine_speedup`
summary row — rsb_batched vs rsb_recursive wall clock — the
`partition_time_smoke` baseline the CI gate compares against, and the
`partition_large` multilevel-vs-spectral head-to-head rows the gate's
check_multilevel reads) to PATH in the BENCH_partition.json layout.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import time


def _smoke_baseline_rows(repeats: int = 3) -> list:
    """Measure the partition_time smoke rows the way benchmarks.smoke_check
    will gate them: a FRESH process per repetition running the gate's exact
    recipe (one cold run that pays the XLA compiles, then the min-sum of
    three warm runs), keeping the repetition with the minimal summed wall
    clock.  Matching the estimator on both sides is the whole point:
    per-row minima across repetitions would bound below anything a single
    run can reach, and measuring in the warm tail of the full suite reads
    ~25-30% faster than any fresh smoke_check process — either way the
    wall gate's headroom would be spent on methodology, not regressions."""
    from benchmarks.smoke_check import _wall_rows

    code = (
        "import json, sys\n"
        "from benchmarks import partition_time\n"
        "from benchmarks.smoke_check import _wall_rows\n"
        "partition_time.run(smoke=True)\n"
        "warm = [partition_time.run(smoke=True) for _ in range(3)]\n"
        "best = min(warm,\n"
        "           key=lambda rs: sum(r['seconds'] for r in _wall_rows(rs)))\n"
        "sys.stdout.flush()\n"
        "print('ROWS=' + json.dumps(best))\n"
    )
    runs = []
    for _ in range(repeats):
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            check=True, env=dict(os.environ),
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("ROWS=")]
        runs.append(json.loads(line[-1][len("ROWS="):]))
    return min(runs,
               key=lambda rows: sum(r["seconds"] for r in _wall_rows(rows)))


def _engine_pre_table(partition_rows) -> list:
    """engine × (method, pre, precond) comparison: seconds / iters / cut.

    One line per (method, pre, precond) combination with the batched and
    recursive wall clocks side by side — the at-a-glance view of where the
    level-synchronous engine and the multilevel solver schedule pay off.
    """
    if not partition_rows:
        return []
    # One solve emits refine="none", greedy, and kway rows; the greedy
    # (repair+refine) row is the canonical full-pipeline measurement (old
    # baselines have no axis).
    canon = [r for r in partition_rows
             if r.get("refine") == "repair+refine"] or [
        r for r in partition_rows if r.get("refine", "none") != "none"]
    cells: dict = {}
    for r in canon or partition_rows:
        key = (r["method"], r["pre"], r.get("precond", "jacobi"))
        cells.setdefault(key, {})[r["engine"]] = r
    lines = ["# engine×pre comparison (seconds | iters | cut)"]
    header = (f"# {'method':<8} {'pre':<5} {'precond':<8} "
              f"{'batched':>22} {'recursive':>22} {'speedup':>8}")
    lines.append(header)
    for key in sorted(cells):
        method, pre, precond = key
        row = cells[key]

        def cell(engine):
            r = row.get(engine)
            if r is None:
                return f"{'—':>22}"
            return f"{r['seconds']:7.2f}s {r['iters']:4d}it {r['cut']:7.0f}"

        speed = "—"
        if "batched" in row and "recursive" in row and row["batched"]["seconds"]:
            speed = f"{row['recursive']['seconds'] / row['batched']['seconds']:.2f}x"
        lines.append(f"# {method:<8} {pre:<5} {precond:<8} "
                     f"{cell('batched')} {cell('recursive')} {speed:>8}")
    return lines


def _engine_speedup(quality_rows, partition_rows) -> dict:
    """rsb_batched vs rsb_recursive wall-clock, per suite.  Refine-axis
    duplicate rows (raw labels and the kway re-refinement, both re-recorded
    from the same solve) are excluded so a solve is counted once."""
    quality_rows = [r for r in quality_rows
                    if not str(r.get("name", "")).endswith(("_raw", "_kway"))]
    partition_rows = [r for r in partition_rows
                      if r.get("refine", "x") == "repair+refine"] or [
        r for r in partition_rows if r.get("refine", "x") != "none"
    ] or partition_rows
    out: dict = {}
    q_b = sum(r["seconds"] for r in quality_rows if r.get("engine") == "batched")
    q_r = sum(r["seconds"] for r in quality_rows
              if r.get("engine") == "recursive")
    if q_b and q_r:
        out["quality_rsb_batched_seconds"] = q_b
        out["quality_rsb_recursive_seconds"] = q_r
        out["quality_speedup"] = q_r / q_b
    p_b = sum(r["seconds"] for r in partition_rows
              if r.get("engine") == "batched")
    p_r = sum(r["seconds"] for r in partition_rows
              if r.get("engine") == "recursive")
    if p_b and p_r:
        out["partition_time_batched_seconds"] = p_b
        out["partition_time_recursive_seconds"] = p_r
        out["partition_time_speedup"] = p_r / p_b
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None,
                    choices=["partition", "partition_time", "weak_scaling",
                             "quality", "kernels", "roofline"])
    ap.add_argument("--dryrun-dir", default="runs/dryrun")
    ap.add_argument("--json", default=None,
                    help="write partition tables to this BENCH json path")
    args = ap.parse_args()
    if args.json and args.only not in (None, "partition"):
        # The BENCH json is the CI gate's baseline; writing it from a run
        # that skipped either partition suite would clobber it with empty
        # tables and break benchmarks.smoke_check on the next push.
        ap.error("--json requires both partition tables; drop --only or "
                 "use --only partition")

    print("name,us_per_call,derived")
    t0 = time.time()

    def want(name):
        if args.only == "partition":  # both tables the BENCH json records
            return name in ("quality", "partition_time")
        return args.only is None or args.only == name

    quality_rows: list = []
    partition_rows: list = []
    smoke_rows: list = []
    large_rows: list = []
    sharded_rows: list = []
    if want("quality"):
        from benchmarks import quality

        quality_rows = quality.run(full=args.full)
    if want("partition_time"):
        from benchmarks import partition_time

        partition_rows = partition_time.run(full=args.full)
        for line in _engine_pre_table(partition_rows):
            print(line)
        if args.json:
            # Fresh-process min-of-3, matching smoke_check's measurement
            # conditions exactly — see _smoke_baseline_rows.
            smoke_rows = _smoke_baseline_rows()
            # Large-mesh engine head-to-head behind the multilevel claim;
            # smoke_check gates these recorded rows instead of re-running
            # the ~10x mesh on every push.
            large_rows = partition_time.run_large()
            # Device-resident sharded refinement vs the host chain from
            # the same bisection — check_dist_refine gates cut parity and
            # the one-collective-per-sweep contract on these rows.
            sharded_rows = partition_time.run_sharded()
    if want("weak_scaling"):
        from benchmarks import weak_scaling

        weak_scaling.run(full=args.full)
    if want("kernels"):
        from benchmarks import kernels

        kernels.run(full=args.full)
    if want("roofline"):
        from benchmarks import roofline_table

        roofline_table.run(args.dryrun_dir)

    if args.json:
        import jax

        payload = {
            "date": time.strftime("%Y-%m-%d"),
            "host": {
                "platform": platform.platform(),
                "python": platform.python_version(),
                "jax": jax.__version__,
                "device": jax.devices()[0].platform,
            },
            "quality": quality_rows,
            "partition_time": partition_rows,
            "partition_time_smoke": smoke_rows,
            "partition_large": large_rows,
            "partition_sharded": sharded_rows,
            "engine_speedup": _engine_speedup(quality_rows, partition_rows),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# benchmarks completed in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
