import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver — compiles the OPTIMIZED variants of the three
chosen cells and extracts the same census as the baseline dry-run:

  gnn  — graphcast × ogb_products: partition-aware halo shard_map step
          (full 2.46M-node scale, RCB plan) + RSB-vs-RCB-vs-random halo
          quality study at 262k nodes (collective volume ∝ edge cut).
  moe  — deepseek-moe-16b × train_4k: shard_map expert-parallel dispatch
          (local routing + all-to-all) vs the pjit einsum baseline.
  lm   — mistral-large-123b × train_4k: Megatron-TP baseline
          (seq_shard=False) vs sequence-parallel default.

    PYTHONPATH=src python -m benchmarks.hillclimb --exp gnn --out runs/perf
"""

import argparse
import dataclasses
import json
import time

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, collective_wire_bytes


def census_of(compiled, n_dev):
    cost = compiled.cost_analysis()
    coll = collective_wire_bytes(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": coll.total_wire_bytes,
        "bytes_by_kind": {k: v for k, v in coll.per_op.items() if v},
        "counts": dict(coll.counts),
    }


def add_terms(rec):
    rec["compute_s"] = rec["flops"] / PEAK_FLOPS
    rec["memory_s"] = rec["bytes"] / HBM_BW
    rec["collective_s"] = rec["wire"] / LINK_BW
    terms = {k: rec[f"{k}_s"] for k in ("compute", "memory", "collective")}
    rec["dominant"] = max(terms, key=terms.get)
    return rec


def _compile_cell(cell, mesh):
    with jax.set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_specs,
                         out_shardings=cell.out_specs,
                         donate_argnums=cell.donate())
        return jitted.lower(*cell.abstract_args).compile()


def exp_moe(out):
    """shard_map EP dispatch for deepseek-moe-16b × train_4k (single pod)."""
    mesh = make_production_mesh(multi_pod=False)
    n_dev = 256
    result = {"exp": "moe", "variant": "shardmap-ep"}
    # exec compile (memory)
    cell = build_cell("deepseek-moe-16b", "train_4k", mesh, moe_impl="shardmap")
    c = _compile_cell(cell, mesh)
    ma = c.memory_analysis()
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    result["live_bytes_per_device"] = int(live)
    jax.clear_caches()
    # profile via layer diff
    qs = {}
    for l in (2, 4):
        pc = build_cell("deepseek-moe-16b", "train_4k", mesh, unroll=True,
                        n_layers=l, moe_impl="shardmap")
        qs[l] = census_of(_compile_cell(pc, mesh), n_dev)
        jax.clear_caches()
    L = 28
    rec = {k: qs[2][k] + (qs[4][k] - qs[2][k]) / 2 * (L - 2)
           for k in ("flops", "bytes", "wire")}
    rec["bytes_by_kind"] = {
        k: qs[2]["bytes_by_kind"].get(k, 0.0)
        + (qs[4]["bytes_by_kind"].get(k, 0.0)
           - qs[2]["bytes_by_kind"].get(k, 0.0)) / 2 * (L - 2)
        for k in set(qs[2]["bytes_by_kind"]) | set(qs[4]["bytes_by_kind"])
    }
    result.update(add_terms(rec))
    _write(out, "moe_shardmap.json", result)


def exp_lm(out):
    """Megatron-TP baseline (no SP) for mistral-large × train_4k."""
    mesh = make_production_mesh(multi_pod=False)
    n_dev = 256
    result = {"exp": "lm", "variant": "tp-baseline-no-sp"}
    cell = build_cell("mistral-large-123b", "train_4k", mesh, seq_shard=False)
    c = _compile_cell(cell, mesh)
    ma = c.memory_analysis()
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    result["live_bytes_per_device"] = int(live)
    jax.clear_caches()
    qs = {}
    for l in (2, 4):
        pc = build_cell("mistral-large-123b", "train_4k", mesh, unroll=True,
                        n_layers=l, seq_shard=False)
        qs[l] = census_of(_compile_cell(pc, mesh), n_dev)
        jax.clear_caches()
    L = 88
    rec = {k: qs[2][k] + (qs[4][k] - qs[2][k]) / 2 * (L - 2)
           for k in ("flops", "bytes", "wire")}
    result.update(add_terms(rec))
    _write(out, "lm_tp_baseline.json", result)


def _halo_cell(cfg, plan, d_feat, d_out, mesh):
    """Build the shard_map halo train step for a HaloPlan."""
    from repro.models.gnn.graphcast import init_graphcast
    from repro.models.gnn.halo import graphcast_halo_loss, make_halo_batch_abstract
    from repro.train.optimizer import AdamWConfig, abstract_opt_state, adamw_update

    axis = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    hbatch = make_halo_batch_abstract(plan, d_feat, d_out)
    params_abs = jax.eval_shape(lambda: init_graphcast(cfg, jax.random.PRNGKey(0)))
    opt_abs = abstract_opt_state(params_abs)
    bspec = jax.tree_util.tree_map(lambda _: P(axis), hbatch)
    pspec = jax.tree_util.tree_map(lambda _: P(), params_abs)

    def loss_fn(params, hb):
        fn = jax.shard_map(
            lambda p, b: graphcast_halo_loss(
                cfg, p, jax.tree_util.tree_map(lambda x: x[0], b), axis
            )[None],
            in_specs=(pspec, bspec), out_specs=P(axis), check_vma=False,
        )
        return fn(params, hb).mean()

    def step(params, opt_state, hb):
        l, grads = jax.value_and_grad(loss_fn)(params, hb)
        params, opt_state, _ = adamw_update(AdamWConfig(lr=1e-4), grads,
                                            opt_state, params)
        return params, opt_state, l

    return step, (params_abs, opt_abs, hbatch), (pspec, {"m": pspec, "v": pspec, "count": P()}, bspec)


def exp_gnn(out, *, full_side: int = 135, study_side: int = 64):
    """Partition-aware halo message passing for graphcast × ogb_products."""
    from repro.configs import get_arch
    from repro.core.rcb import rcb_parts
    from repro.dist.partition_aware import plan_halo_sharding
    from repro.mesh.graphs import grid_coords_3d, stencil_graph_3d

    mesh = make_production_mesh(multi_pod=False)
    n_dev = 256
    result = {"exp": "gnn", "variant": "halo-shardmap-rcb",
              "graph": f"stencil26 {full_side}^3"}

    t0 = time.perf_counter()
    g = stencil_graph_3d(full_side, full_side, full_side)
    coords = grid_coords_3d(full_side, full_side, full_side)
    parts = rcb_parts(coords, n_dev)
    plan = plan_halo_sharding(g, parts, n_dev, pad_to=8)
    result["plan"] = plan.stats()
    result["plan_seconds"] = round(time.perf_counter() - t0, 1)
    print("plan:", result["plan"], flush=True)

    arch = get_arch("graphcast")
    base_cfg = arch.make_config(d_in=100)
    qs = {}
    for l in (2, 4):
        cfg = dataclasses.replace(base_cfg, n_layers=l, unroll=True)
        step, abstract, specs = _halo_cell(cfg, plan, 100, base_cfg.n_vars, mesh)
        out_specs = (specs[0], specs[1], P())   # params, opt, scalar loss
        with jax.set_mesh(mesh):
            compiled = jax.jit(step, in_shardings=specs, out_shardings=out_specs,
                               donate_argnums=(0, 1)).lower(*abstract).compile()
        qs[l] = census_of(compiled, n_dev)
        if l == 2:
            ma = compiled.memory_analysis()
            # memory: exec==profile here (2-layer); scale residual storage
            result["live_bytes_per_device_2layer"] = int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            )
        jax.clear_caches()
    L = base_cfg.n_layers
    rec = {k: qs[2][k] + (qs[4][k] - qs[2][k]) / 2 * (L - 2)
           for k in ("flops", "bytes", "wire")}
    result.update(add_terms(rec))
    _write(out, "gnn_halo_rcb.json", result)

    # --- partition-quality study at reduced scale: RSB vs RCB vs random ---
    from repro.core import partition_metrics
    from repro.core.rsb import rsb_partition_graph

    gs = stencil_graph_3d(study_side, study_side, study_side)
    cs = grid_coords_3d(study_side, study_side, study_side)
    study = {"graph": f"stencil26 {study_side}^3", "n_shards": n_dev}
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    p_rsb, rep = rsb_partition_graph(gs, n_dev, coords=cs, pre="rcb", tol=1e-3)
    study["rsb_seconds"] = round(time.perf_counter() - t0, 1)
    for name, parts_s in (
        ("rsb", p_rsb),
        ("rcb", rcb_parts(cs, n_dev)),
        ("random", rng.permutation(np.arange(gs.n) % n_dev)),
    ):
        pl = plan_halo_sharding(gs, parts_s, n_dev, pad_to=8)
        pm = partition_metrics(gs, parts_s, n_dev)
        study[name] = {"halo": pl.halo, "cut": pm.edge_cut,
                       "gather_words_per_col": pl.collective_words_per_feature,
                       "max_nbrs": pm.max_neighbors}
        print(name, study[name], flush=True)
    _write(out, "gnn_partition_study.json", study)


def _write(out, name, rec):
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, name), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"wrote {name}: "
          f"{ {k: v for k, v in rec.items() if not isinstance(v, dict)} }")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=["moe", "gnn", "lm"])
    ap.add_argument("--out", default="runs/perf")
    args = ap.parse_args()
    {"moe": exp_moe, "gnn": exp_gnn, "lm": exp_lm}[args.exp](args.out)


if __name__ == "__main__":
    main()
