"""Paper Tables 1–2 analogue: Lanczos vs inverse iteration on a pebble-bed
mesh, with and without RCB pre-partitioning — for BOTH RSB engines (the
level-synchronous batched engine vs the recursive per-node reference), and
for the batched inverse path with BOTH preconditioners (Jacobi vs the
packed multilevel AMG V-cycle).

Every combination runs the full partition pipeline ONCE and emits THREE
rows: `refine="none"` (the raw bisection labels, from the pipeline's
`parts_raw` — no second solve), `refine="repair+refine"` (the default
greedy post stage), and `refine="repair+kway"` (the hill-climbing k-way FM
chain re-run on the same `parts_raw` — still no second solve).  Rows carry
`disconnected` and `post_seconds`, so the CI smoke gate can assert the
refine invariants (refined cut ≤ raw cut, kway cut ≤ greedy cut, zero
disconnected parts, bounded post wall-clock) per combination.

Validates:
  C2 — RCB pre-partitioning speeds up RSB (here: wall time on CPU AND the
       mechanism metric, gather-scatter locality — boundary/halo size),
  C4 — inverse iteration needs few outer iterations vs Lanczos restarts,
  C1 — ≤1-element imbalance throughout,
  and the engine claim: batched ≥ recursive on wall clock at equal quality
  (one compiled trace per run instead of one per tree node).

Scaled to this container: the paper's 13M-element mesh on 4872–11340 ranks
becomes a ~3–8k-element mesh on 8–32 parts; the OBSERVABLES (neighbor
counts, iteration counts, relative speedups) are the comparable quantities.

The multilevel k-way V-cycle (bisect="multilevel") joins the table as its
own engine row (method="-": no eigensolver), and `run_large` runs the
~10x-scale box-mesh head-to-head behind the multilevel headline claim —
wall clock vs rsb-batched at ≤5% cut regression (gated from the recorded
`partition_large` baseline by benchmarks.smoke_check.check_multilevel).

`smoke=True` is the CI regression config (see benchmarks/smoke_check.py):
a small mesh, batched engine, both solver families and both inverse
preconditioners — fast enough for every push.  Its edge cut AND its total
wall clock are gated against the checked-in BENCH_partition.json baseline;
rows are matched on (engine, method, pre, precond, refine).
"""

from __future__ import annotations

import time

from benchmarks.bench_util import emit, report_cols, stage_seconds
from repro.core import PartitionPipeline, partition_metrics, run_post_stages
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import box_mesh, dual_graph, pebble_mesh


def run(
    dims=(14, 14, 14),
    nparts=16,
    full: bool = False,
    smoke: bool = False,
    engines=("batched", "recursive"),
    methods=("lanczos", "inverse"),
) -> list:
    if full:
        dims, nparts = (24, 24, 24), 32
    if smoke:
        # Both solver families: inverse-iteration regressions (e.g. the
        # fp32 Gram breakdown) are invisible to a lanczos-only gate.
        dims, nparts = (10, 10, 10), 8
        engines, methods = ("batched",), ("lanczos", "inverse")
    mesh = pebble_mesh(*dims, n_pebbles=6, seed=0)
    graph = dual_graph(mesh)
    emit_prefix = "partition_time_smoke" if smoke else "partition_time"
    rows = []

    def record(parts, seconds, *, engine, method, pre, report, refine,
               post_seconds=0.0, stages=None):
        pm = partition_metrics(graph, parts, nparts, weights=mesh.weights)
        halo = plan_halo_sharding(graph, parts, nparts).halo
        cols = report_cols(report)
        row = {
            "engine": engine,
            "method": method, "pre": pre or "none",
            "precond": cols["precond"],
            "precond_levels": cols["precond_levels"],
            "refine": refine, "post_seconds": post_seconds,
            "seconds": seconds, "iters": cols["iters"],
            "levels": len(report.levels),
            "cut": pm.edge_cut,
            "max_nbrs": pm.max_neighbors,
            "avg_nbrs": pm.avg_neighbors,
            "imbalance": pm.imbalance,
            "w_imb": pm.weighted_imbalance,
            "volume": pm.total_volume,
            "halo": halo,
            "disconnected": pm.disconnected_parts,
        }
        if stages is not None:
            row["stages"] = stages   # per-stage wall from the run's trace
        rows.append(row)
        emit(
            f"{emit_prefix}/{engine}/{method}/pre={pre or 'none'}"
            f"/precond={cols['precond']}/refine={refine}",
            seconds * 1e6,
            f"E={mesh.nelems};P={nparts};"
            f"iters={cols['iters']};"
            f"mlv={cols['precond_levels']};"
            f"cut={pm.edge_cut:.0f};max_nbrs={pm.max_neighbors};"
            f"avg_nbrs={pm.avg_neighbors:.1f};"
            f"w_imb={pm.weighted_imbalance:.3f};halo={halo};"
            f"disc={pm.disconnected_parts}",
        )

    for engine in engines:
        for method in methods:
            # The batched inverse path carries the Jacobi-vs-multilevel
            # preconditioner comparison (Sphynx's point: the preconditioner,
            # not the matvec, dominates spectral-partitioner cost); the
            # recursive inverse reference is inherently AMG-preconditioned.
            if method == "inverse" and engine == "batched":
                preconds = ("jacobi", "amg")
            else:
                preconds = ("jacobi",)
            for precond in preconds:
                for pre in (None, "rcb"):
                    pipe = PartitionPipeline(
                        pre=pre or "none", bisect=f"rsb-{engine}",
                        bisect_kw=dict(method=method, tol=1e-3,
                                       precond=precond),
                    )
                    t0 = time.perf_counter()
                    ctx = pipe.run(mesh, nparts)
                    dt = time.perf_counter() - t0
                    post_dt = ctx.report.post.seconds
                    record(ctx.parts_raw, dt - post_dt, engine=engine,
                           method=method, pre=pre, report=ctx.report,
                           refine="none")
                    record(ctx.parts, dt, engine=engine, method=method,
                           pre=pre, report=ctx.report,
                           refine="repair+refine", post_seconds=post_dt,
                           stages=stage_seconds(ctx))
                    # Greedy-vs-kway axis from the SAME solve: re-run the
                    # k-way FM chain on parts_raw (no second eigensolve).
                    t1 = time.perf_counter()
                    parts_k, _, _ = run_post_stages(
                        ctx.require_graph(), ctx.parts_raw, nparts,
                        ("repair", "kway"), weights=ctx.weights)
                    k_dt = time.perf_counter() - t1
                    record(parts_k, dt - post_dt + k_dt, engine=engine,
                           method=method, pre=pre, report=ctx.report,
                           refine="repair+kway", post_seconds=k_dt)

    # The multilevel k-way V-cycle (METIS-style bisect="multilevel"): the
    # claim under test is wall clock vs the spectral engines at comparable
    # cut, so it rides in the same table.  One pipeline run under the
    # "multilevel" preset's post chain emits the raw-labels row and the
    # repair+kway row; there is no eigensolver, so method is "-".
    pipe = PartitionPipeline(pre="none", bisect="multilevel",
                             post=("repair", "kway"))
    t0 = time.perf_counter()
    ctx = pipe.run(mesh, nparts)
    dt = time.perf_counter() - t0
    post_dt = ctx.report.post.seconds
    record(ctx.parts_raw, dt - post_dt, engine="multilevel", method="-",
           pre=None, report=ctx.report, refine="none")
    record(ctx.parts, dt, engine="multilevel", method="-", pre=None,
           report=ctx.report, refine="repair+kway", post_seconds=post_dt,
           stages=stage_seconds(ctx))
    return rows


def run_sharded(dims=(10, 10, 10), nparts: int = 8) -> list:
    """Host-vs-sharded refinement head-to-head from the SAME bisection
    labels (no second eigensolve): the ``repair+refine`` host chain
    against ``repair+refine-sharded`` (device-resident sweeps, one
    boundary-label all_gather per sweep — dist/refine_sharded).

    Rows land in BENCH_partition.json under ``partition_sharded``; the CI
    gate (benchmarks.smoke_check.check_dist_refine) asserts the sharded
    cut stays within 1% of the host refined cut and that the trace
    counters certify exactly one collective per sweep
    (``sharded_gathers == sharded_sweeps``)."""
    from repro import obs

    mesh = pebble_mesh(*dims, n_pebbles=6, seed=0)
    graph = dual_graph(mesh)
    pipe = PartitionPipeline(pre="rcb", bisect="rsb-batched",
                             bisect_kw=dict(tol=1e-3), post=())
    ctx = pipe.run(mesh, nparts)
    rows = []
    # Sharded sweeps apply one conflict-free independent set per collective
    # (sweep 0 only primes proposals), so reaching host-FM quality takes
    # more sweeps than the host path takes passes — 8 is where the pebble
    # mesh converges past the greedy host cut.
    for refine, post, kw in (
            ("repair+refine", ("repair", "refine"), {}),
            ("repair+refine-sharded", ("repair", "refine-sharded"),
             {"sweeps": 8}),
            ("kway-sharded", ("kway-sharded",), {"sweeps": 8})):
        with obs.trace(f"bench:sharded/{refine}") as root:
            t0 = time.perf_counter()
            parts, _, _ = run_post_stages(
                ctx.require_graph(), ctx.parts_raw, nparts, post,
                weights=ctx.weights, post_kw=dict(kw))
            dt = time.perf_counter() - t0
        counters: dict = {}
        for s in root.walk():
            for k, v in s.counters.items():
                counters[k] = counters.get(k, 0.0) + v
        pm = partition_metrics(graph, parts, nparts, weights=mesh.weights)
        rows.append({
            "name": f"sharded/{refine}", "refine": refine,
            "n": mesh.nelems, "nparts": nparts,
            "seconds": dt, "cut": pm.edge_cut,
            "w_imb": pm.weighted_imbalance,
            "disconnected": pm.disconnected_parts,
            "sweeps": counters.get("sharded_sweeps", 0),
            "gathers": counters.get("sharded_gathers", 0),
            "moves": counters.get("sharded_moves", 0),
            "halo_words": counters.get("halo_words", 0),
            "halo_bytes": counters.get("halo_bytes", 0),
        })
        emit(f"partition_sharded/{refine}", dt * 1e6,
             f"E={mesh.nelems};P={nparts};cut={pm.edge_cut:.0f};"
             f"sweeps={counters.get('sharded_sweeps', 0):.0f};"
             f"gathers={counters.get('sharded_gathers', 0):.0f};"
             f"halo_words={counters.get('halo_words', 0):.0f};"
             f"disc={pm.disconnected_parts}")
    return rows


def run_large(side: int = 32, nparts: int = 32) -> list:
    """Large-mesh engine head-to-head (the multilevel headline claim): a
    ``side``³ box mesh — ~10x the default suite's element count — split by
    the batched spectral engine and the multilevel V-cycle under the SAME
    post chain (repair only: the k-way FM chain costs the same seconds for
    both engines at this scale and would mask the engine comparison).

    Each engine runs once cold (spectral pays its XLA compiles there) and
    once warm; the warm run is the recorded row — cuts are deterministic
    and the warm wall is the reproducible algorithmic time.  Rows land in
    BENCH_partition.json under ``partition_large``, where the CI gate
    (benchmarks.smoke_check.check_multilevel) asserts the recorded claim:
    multilevel wall ≤ half the spectral wall at ≤5% cut regression with
    zero disconnected parts."""
    mesh = box_mesh(side, side, side)
    graph = dual_graph(mesh)
    configs = (
        ("rsb-batched", dict(pre="rcb", bisect="rsb-batched",
                             bisect_kw=dict(tol=1e-3))),
        # coarse_factor=16 keeps the coarsest graph inside the dense
        # spectral solver's budget at 32 parts; fm_below=1024 keeps the
        # Python FM heap off the fine levels (vectorized sweeps there).
        ("multilevel", dict(pre="none", bisect="multilevel",
                            bisect_kw=dict(coarse_factor=16,
                                           fm_below=1024))),
    )
    rows = []
    for name, kw in configs:
        pipe = PartitionPipeline(post=("repair",), **kw)
        pipe.run(mesh, nparts)           # cold: pays the compiles
        t0 = time.perf_counter()
        ctx = pipe.run(mesh, nparts)     # warm: the recorded row
        dt = time.perf_counter() - t0
        pm = partition_metrics(graph, ctx.parts, nparts,
                               weights=mesh.weights)
        rows.append({
            "name": f"large/{name}", "bisect": name,
            "n": mesh.nelems, "nparts": nparts,
            "seconds": dt, "post_seconds": ctx.report.post.seconds,
            "cut": pm.edge_cut, "w_imb": pm.weighted_imbalance,
            "imbalance": pm.imbalance,
            "disconnected": pm.disconnected_parts,
            "stages": stage_seconds(ctx),
        })
        emit(f"partition_large/{name}", dt * 1e6,
             f"E={mesh.nelems};P={nparts};cut={pm.edge_cut:.0f};"
             f"w_imb={pm.weighted_imbalance:.3f};"
             f"disc={pm.disconnected_parts}")
    return rows


if __name__ == "__main__":
    run()
