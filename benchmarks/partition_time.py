"""Paper Tables 1–2 analogue: Lanczos vs inverse iteration on a pebble-bed
mesh, with and without RCB pre-partitioning.

Validates:
  C2 — RCB pre-partitioning speeds up RSB (here: wall time on CPU AND the
       mechanism metric, gather-scatter locality — boundary/halo size),
  C4 — inverse iteration needs few outer iterations vs Lanczos restarts,
  C1 — ≤1-element imbalance throughout.

Scaled to this container: the paper's 13M-element mesh on 4872–11340 ranks
becomes a ~3–8k-element mesh on 8–32 parts; the OBSERVABLES (neighbor
counts, iteration counts, relative speedups) are the comparable quantities.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_util import emit
from repro.core import partition_metrics, rsb_partition_mesh
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import dual_graph, pebble_mesh


def run(dims=(14, 14, 14), nparts=16, full: bool = False) -> list:
    if full:
        dims, nparts = (24, 24, 24), 32
    mesh = pebble_mesh(*dims, n_pebbles=6, seed=0)
    graph = dual_graph(mesh)
    rows = []
    for method in ("lanczos", "inverse"):
        for pre in (None, "rcb"):
            t0 = time.perf_counter()
            parts, report = rsb_partition_mesh(
                mesh, nparts, method=method, pre=pre, tol=1e-3,
            )
            dt = time.perf_counter() - t0
            pm = partition_metrics(graph, parts, nparts, weights=mesh.weights)
            halo = plan_halo_sharding(graph, parts, nparts).halo
            rows.append({
                "method": method, "pre": pre or "none",
                "seconds": dt, "iters": report.total_iterations,
                "max_nbrs": pm.max_neighbors, "avg_nbrs": pm.avg_neighbors,
                "imbalance": pm.imbalance, "w_imb": pm.weighted_imbalance,
                "volume": pm.total_volume,
                "halo": halo,
            })
            emit(
                f"partition_time/{method}/pre={pre or 'none'}",
                dt * 1e6,
                f"E={mesh.nelems};P={nparts};iters={report.total_iterations};"
                f"max_nbrs={pm.max_neighbors};avg_nbrs={pm.avg_neighbors:.1f};"
                f"w_imb={pm.weighted_imbalance:.3f};halo={halo}",
            )
    return rows


if __name__ == "__main__":
    run()
