"""CI benchmark-smoke gate: run the partition_time smoke config and fail
(exit 1) if, against the checked-in BENCH_partition.json baseline,

  * any row's RSB edge cut regresses more than 10%, or
  * the config's TOTAL wall clock regresses more than 25%.

    PYTHONPATH=src python -m benchmarks.smoke_check [--baseline PATH]

The smoke config (benchmarks/partition_time.py, smoke=True) is the batched
engine, BOTH solver families (lanczos and inverse — inverse-iteration
regressions would be invisible to a lanczos-only gate), both inverse
preconditioners (jacobi and the packed multilevel AMG), pre ∈ {none, rcb}
on a small pebble mesh — fast enough for every push.  Cut is gated per row
(quality regressions are the silent failure mode of solver refactors);
wall clock is gated on the summed config only, with generous headroom,
because per-row timings are too noisy on shared CI runners but a >25%
total blowup means iteration counts exploded or a hot path fell off its
fast route.  The wall measurement is the config's SECOND in-process run:
the first run pays the XLA compiles (which vary wildly across runners and
are warm in the checked-in baseline, whose smoke rows run at the end of
the full `benchmarks.run --json` process), the second isolates the
algorithmic time both sides can compare.  Rows are matched on
(engine, method, pre, precond).
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks import partition_time

TOLERANCE = 1.10       # per-row: fail if cut > 110% of baseline
WALL_TOLERANCE = 1.25  # total: fail if summed seconds > 125% of baseline


def _key(row) -> tuple:
    # Older baselines predate the precond column; default to jacobi.
    return (row["engine"], row["method"], row["pre"],
            row.get("precond", "jacobi"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_partition.json")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    base_rows = baseline.get("partition_time_smoke", [])
    if not base_rows:
        print(f"no partition_time_smoke baseline in {args.baseline}",
              file=sys.stderr)
        return 1

    rows = partition_time.run(smoke=True)        # cold: gates the cut
    rows_warm = partition_time.run(smoke=True)   # warm: gates the wall clock
    by_key = {_key(r): r for r in rows}
    failed = False
    for base in base_rows:
        key = _key(base)
        row = by_key.get(key)
        if row is None:
            print(f"MISSING smoke row {key}", file=sys.stderr)
            failed = True
            continue
        ratio = row["cut"] / base["cut"]
        status = "OK" if ratio <= TOLERANCE else "REGRESSION"
        print(f"{status} {key}: cut {row['cut']:.0f} vs baseline "
              f"{base['cut']:.0f} ({ratio:.3f}x)", file=sys.stderr)
        if ratio > TOLERANCE:
            failed = True

    base_wall = sum(r["seconds"] for r in base_rows)
    wall = sum(r["seconds"] for r in rows_warm)
    if base_wall > 0:
        ratio = wall / base_wall
        status = "OK" if ratio <= WALL_TOLERANCE else "REGRESSION"
        print(f"{status} wall clock: {wall:.2f}s vs baseline "
              f"{base_wall:.2f}s ({ratio:.3f}x)", file=sys.stderr)
        if ratio > WALL_TOLERANCE:
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
