"""CI benchmark-smoke gate: run the partition_time smoke config and fail
(exit 1) if the RSB edge cut regresses more than 10% against the
checked-in BENCH_partition.json baseline.

    PYTHONPATH=src python -m benchmarks.smoke_check [--baseline PATH]

The smoke config (benchmarks/partition_time.py, smoke=True) is the batched
engine, BOTH solver families (lanczos and inverse — inverse-iteration
regressions would be invisible to a lanczos-only gate), pre ∈ {none, rcb}
on a small pebble mesh — fast enough for every push.  Cut is the gated
metric (quality regressions are the silent failure mode of solver
refactors; wall clock is too noisy on shared CI runners).  Rows are
matched on (engine, method, pre).
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks import partition_time

TOLERANCE = 1.10  # fail if cut > 110% of baseline


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_partition.json")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    base_rows = baseline.get("partition_time_smoke", [])
    if not base_rows:
        print(f"no partition_time_smoke baseline in {args.baseline}",
              file=sys.stderr)
        return 1

    rows = partition_time.run(smoke=True)
    by_key = {(r["engine"], r["method"], r["pre"]): r for r in rows}
    failed = False
    for base in base_rows:
        key = (base["engine"], base["method"], base["pre"])
        row = by_key.get(key)
        if row is None:
            print(f"MISSING smoke row {key}", file=sys.stderr)
            failed = True
            continue
        ratio = row["cut"] / base["cut"]
        status = "OK" if ratio <= TOLERANCE else "REGRESSION"
        print(f"{status} {key}: cut {row['cut']:.0f} vs baseline "
              f"{base['cut']:.0f} ({ratio:.3f}x)", file=sys.stderr)
        if ratio > TOLERANCE:
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
