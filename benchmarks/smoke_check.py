"""CI benchmark-smoke gate: run the partition_time smoke config and fail
(exit 1) if, against the checked-in BENCH_partition.json baseline,

  * any row's RSB edge cut regresses more than 10%, or
  * the config's TOTAL wall clock regresses more than 25%,

or if the refine-stage invariants fail WITHIN the current run:

  * a refined row's cut exceeds its raw (refine="none") sibling's, or
  * a kway row's cut exceeds its greedy (refine="repair+refine") sibling's
    (the hill-climbing k-way FM must never lose to the greedy sweeps), or
  * a refined row reports disconnected parts, or
  * the greedy post stage's summed wall clock exceeds 15% of the summed
    total, or the kway rows' summed post stage exceeds 25% of their summed
    row totals (summed, not per row: the fastest solve's row is pure
    measurement noise at the ~100 ms post scale of this box),

or if the multilevel-engine contract fails (check_multilevel): the smoke
multilevel row's cut must stay within 5% of the BEST spectral kway cut,
and the checked-in `partition_large` baseline rows must uphold the
headline claim — multilevel wall ≤ half the spectral wall at ≤5% cut
regression with zero disconnected parts.

    PYTHONPATH=src python -m benchmarks.smoke_check [--baseline PATH]

The smoke config (benchmarks/partition_time.py, smoke=True) is the batched
engine, BOTH solver families (lanczos and inverse — inverse-iteration
regressions would be invisible to a lanczos-only gate), both inverse
preconditioners (jacobi and the packed multilevel AMG), pre ∈ {none, rcb}
on a small pebble mesh — fast enough for every push.  Each combination
emits a refine="none" row (raw bisection labels) and a refined row from
ONE solve; rows are matched on (engine, method, pre, precond, refine).
Cut is gated per row (quality regressions are the silent failure mode of
solver refactors); wall clock is gated on the summed config only, with
generous headroom, because per-row timings are too noisy on shared CI
runners but a >25% total blowup means iteration counts exploded or a hot
path fell off its fast route.  The wall measurement is the MIN of three
warm in-process runs after one cold run (the cold run pays the XLA
compiles, which vary wildly across runners; the min-of-3 warm sum is the
box's reproducible algorithmic time — single runs on this class of runner
swing ±25-40%).  The checked-in baseline is measured under IDENTICAL
conditions: `benchmarks.run --json` runs the smoke config in a fresh
subprocess (cold, then warm) three times and keeps the repetition with
the minimal summed wall, so both sides of the gate estimate the same
quantity with the same estimator and the headroom covers regressions,
not measurement noise.
The summed wall clock counts each solve once (refined rows only when the
refine axis is present).

The fault-tolerance gate (check_chaos) rides along too: every
repro.guard.chaos fault class — solver NaNs, empty sign-splits, CG
divergence, stage-deadline expiry, truncated halo plans — is injected
deterministically and must degrade into a full-coverage, connected,
corridor-balanced partition with the degradation visible in the guard
report AND the trace counters (silent absorption fails the gate).

Observability gates (repro.obs) ride on the same invocation:

  * every run writes a JSONL run manifest + a Chrome/Perfetto trace for a
    representative quality-kway pipeline run and VALIDATES the manifest —
    a missing stage span (someone deleted or renamed an `obs.timed` call)
    fails the gate: the drift guard that keeps the traces trustworthy;
  * the per-stage wall SHARES of the warm rows are gated against the
    baseline's recorded `stages` maps: any stage whose share of the row
    wall grew by more than 15 percentage points fails (a stage silently
    eating the pipeline is exactly what total-wall headroom hides).  The
    trace JSON is uploaded as a CI artifact (see .github/workflows/ci.yml)
    so a regression comes with its own flamegraph.
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks import partition_time

TOLERANCE = 1.10       # per-row: fail if cut > 110% of baseline
WALL_TOLERANCE = 1.25  # total: fail if summed seconds > 125% of baseline
POST_FRACTION = 0.15   # greedy post wall clock ≤ 15% of the summed total
KWAY_POST_FRACTION = 0.25  # summed kway post ≤ 25% of summed kway row wall
STAGE_SHARE_TOLERANCE = 0.15  # per-stage share of wall may grow ≤ 15 points
DIST_CUT_TOL = 1.01    # sharded refined cut must stay within 1% of host
MULTILEVEL_CUT_TOL = 1.05  # multilevel cut ≤ 105% of the spectral cut
MULTILEVEL_WALL_FRACTION = 0.5  # large row: ml wall ≤ half spectral wall


def _key(row) -> tuple:
    # Older baselines predate the precond/refine columns; default to the
    # values the old rows actually measured (jacobi, raw labels).
    return (row["engine"], row["method"], row["pre"],
            row.get("precond", "jacobi"), row.get("refine", "none"))


def _wall_rows(rows) -> list:
    """Rows whose seconds sum to the config's wall clock, counting each
    solve ONCE: the canonical greedy (repair+refine) rows when the refine
    axis exists — the kway rows re-measure the same solve with a different
    post chain — else any refined rows, else all."""
    greedy = [r for r in rows if r.get("refine") == "repair+refine"]
    if greedy:
        return greedy
    refined = [r for r in rows if r.get("refine", "none") != "none"]
    return refined or list(rows)


def check_refine_invariants(rows, warm_rows=None) -> list:
    """The post-stage contract, asserted within one run: refined cut never
    above raw cut, zero disconnected parts, bounded post wall clock.
    Cut/connectivity come from ``rows`` (deterministic, so the cold run is
    fine); the post-fraction check uses ``warm_rows`` — cold totals are
    dominated by XLA compiles and would make a 15%-of-total bound
    near-vacuous.  Returns failure messages (empty = pass)."""
    failures = []
    raw = {_key(r)[:4]: r for r in rows if r.get("refine", "none") == "none"}
    refined = [r for r in rows if r.get("refine", "none") != "none"]
    for r in refined:
        base = raw.get(_key(r)[:4])
        if base is not None and r["cut"] > base["cut"] + 1e-9:
            failures.append(
                f"refined cut {r['cut']:.0f} > raw {base['cut']:.0f} "
                f"for {_key(r)[:4]}")
        if r.get("disconnected", 0) != 0:
            failures.append(
                f"{r['disconnected']} disconnected part(s) after refine "
                f"for {_key(r)[:4]}")
    # k-way gate: the hill-climbing chain must never lose to the greedy
    # sweeps it is meant to supersede (same solve, same corridor).
    greedy = {_key(r)[:4]: r for r in rows
              if r.get("refine") == "repair+refine"}
    for r in (r for r in rows if r.get("refine") == "repair+kway"):
        base = greedy.get(_key(r)[:4])
        if base is not None and r["cut"] > base["cut"] + 1e-9:
            failures.append(
                f"kway cut {r['cut']:.0f} > greedy {base['cut']:.0f} "
                f"for {_key(r)[:4]}")
    timed = rows if warm_rows is None else warm_rows
    canon = [r for r in timed if r.get("refine") == "repair+refine"] or [
        r for r in timed if r.get("refine", "none") != "none"]
    total = sum(r["seconds"] for r in canon)
    post = sum(r.get("post_seconds", 0.0) for r in canon)
    if canon and total > 0 and post > POST_FRACTION * total:
        failures.append(
            f"post stage {post:.3f}s exceeds {POST_FRACTION:.0%} of "
            f"total {total:.3f}s")
    kway_rows = [r for r in timed if r.get("refine") == "repair+kway"]
    k_total = sum(r["seconds"] for r in kway_rows)
    k_post = sum(r.get("post_seconds", 0.0) for r in kway_rows)
    if kway_rows and k_total > 0 and k_post > KWAY_POST_FRACTION * k_total:
        failures.append(
            f"kway post {k_post:.3f}s exceeds {KWAY_POST_FRACTION:.0%} of "
            f"kway rows' total {k_total:.3f}s")
    return failures


def check_stage_shares(rows, base_rows) -> list:
    """Per-stage wall-share gate: for rows matched on the smoke key, no
    stage's share of that row's summed stage wall may exceed the
    baseline's share by more than STAGE_SHARE_TOLERANCE (absolute).
    Shares, not seconds — runner speed cancels out; a stage quietly
    growing from 5% to 40% of the pipeline does not.  Rows without a
    recorded ``stages`` map (pre-obs baselines) are skipped."""
    failures = []
    base_by_key = {_key(r): r for r in base_rows if r.get("stages")}
    for row in rows:
        if not row.get("stages"):
            continue
        base = base_by_key.get(_key(row))
        if base is None:
            continue
        total = sum(row["stages"].values())
        base_total = sum(base["stages"].values())
        if total <= 0 or base_total <= 0:
            continue
        for stage, secs in row["stages"].items():
            share = secs / total
            base_share = base["stages"].get(stage, 0.0) / base_total
            if share > base_share + STAGE_SHARE_TOLERANCE:
                failures.append(
                    f"stage {stage} is {share:.0%} of wall vs baseline "
                    f"{base_share:.0%} for {_key(row)}")
    return failures


def check_multilevel(rows, large_rows) -> list:
    """The multilevel bisect stage's contract.  In the current smoke run:
    the V-cycle's refined row must exist and its cut must stay within
    MULTILEVEL_CUT_TOL of the BEST batched repair+kway cut (the quality
    claim is "spectral-class cuts", so the gate compares against the
    strongest spectral configuration, not the weakest).  From the recorded
    ``partition_large`` baseline (benchmarks.run --json measures it; the
    rows are too slow to re-run on every push): the headline claim itself —
    multilevel wall ≤ MULTILEVEL_WALL_FRACTION of the spectral wall at
    ≤ MULTILEVEL_CUT_TOL cut with zero disconnected parts — so a baseline
    refresh that silently loses the speedup or the quality fails CI."""
    failures = []
    ml = [r for r in rows if r.get("engine") == "multilevel"
          and r.get("refine") == "repair+kway"]
    if not ml:
        failures.append("no multilevel repair+kway smoke row")
    batched = [r["cut"] for r in rows if r.get("engine") == "batched"
               and r.get("refine") == "repair+kway"]
    if ml and batched:
        best = min(batched)
        for r in ml:
            if r["cut"] > MULTILEVEL_CUT_TOL * best:
                failures.append(
                    f"multilevel cut {r['cut']:.0f} > "
                    f"{MULTILEVEL_CUT_TOL:.2f}x best spectral kway cut "
                    f"{best:.0f}")
    by_bisect = {r.get("bisect"): r for r in large_rows}
    sp = by_bisect.get("rsb-batched")
    mlr = by_bisect.get("multilevel")
    if sp is None or mlr is None:
        failures.append("partition_large baseline is missing an engine row "
                        "(regenerate with benchmarks.run --json)")
        return failures
    if mlr["seconds"] > MULTILEVEL_WALL_FRACTION * sp["seconds"]:
        failures.append(
            f"large-mesh multilevel wall {mlr['seconds']:.2f}s > "
            f"{MULTILEVEL_WALL_FRACTION:.0%} of spectral "
            f"{sp['seconds']:.2f}s")
    if mlr["cut"] > MULTILEVEL_CUT_TOL * sp["cut"]:
        failures.append(
            f"large-mesh multilevel cut {mlr['cut']:.0f} > "
            f"{MULTILEVEL_CUT_TOL:.2f}x spectral {sp['cut']:.0f}")
    if mlr.get("disconnected", 0) != 0:
        failures.append(
            f"large-mesh multilevel row has {mlr['disconnected']} "
            f"disconnected part(s)")
    return failures


def check_manifest(manifest_path: str, trace_path: str) -> list:
    """Write + validate a run manifest for a representative quality-kway
    pipeline run — the drift guard.  A deleted/renamed stage span, an
    empty trace, or a manifest that fails schema validation returns
    failure messages; the Perfetto trace JSON lands at ``trace_path``
    (the CI artifact)."""
    from repro import obs
    from repro.core import PartitionPipeline
    from repro.mesh import pebble_mesh

    if not obs.obs_enabled():
        return ["REPRO_OBS is off — the smoke gate needs the trace "
                "(unset REPRO_OBS or set it to 'on')"]
    mesh = pebble_mesh(8, 8, 8, n_pebbles=3, seed=0)
    ctx = PartitionPipeline(pre="rcb", bisect="rsb-batched",
                            post=("repair", "kway")).run(mesh, 8)
    if ctx.trace is None:
        return ["pipeline run recorded no trace despite REPRO_OBS=on"]
    ctx.export_manifest(manifest_path, name="smoke-quality-kway")
    ctx.export_trace_events(trace_path)
    problems = obs.validate_manifest(manifest_path)
    # Same guard for the multilevel V-cycle's spans (coarsen / coarsest /
    # mlevel:N / finalize) — a second manifest from the same small mesh.
    ml_manifest = manifest_path.replace(".jsonl", "_multilevel.jsonl")
    ml_trace = trace_path.replace(".json", "_multilevel.json")
    ctx = PartitionPipeline(pre="none", bisect="multilevel",
                            post=("repair", "kway")).run(mesh, 8)
    if ctx.trace is None:
        problems.append("multilevel run recorded no trace")
    else:
        ctx.export_manifest(ml_manifest, name="smoke-multilevel")
        ctx.export_trace_events(ml_trace)
        problems += obs.validate_manifest(ml_manifest)
    print(f"manifests {manifest_path}, {ml_manifest} "
          f"({'OK' if not problems else 'INVALID'}), "
          f"traces {trace_path}, {ml_trace}", file=sys.stderr)
    return problems


def check_dist_refine(base_sharded) -> list:
    """Device-resident sharded refinement contract (dist/refine_sharded):

    * BENCH_partition.json must carry recorded ``partition_sharded`` rows
      (host chain + both sharded chains) — a baseline refresh that drops
      the table disables this gate silently otherwise;
    * live re-run: the sharded refined cut stays within DIST_CUT_TOL of
      the host ``repair+refine`` cut from the SAME bisection labels, with
      zero disconnected parts;
    * one-collective-per-sweep: the trace counters on every sharded row
      (recorded AND live) must certify exactly one boundary-label
      all_gather per sweep (``sharded_gathers == sharded_sweeps > 0``)
      with non-zero halo traffic and ``halo_bytes == 4 * halo_words``.
    """
    failures = []
    need = {"sharded/repair+refine", "sharded/repair+refine-sharded",
            "sharded/kway-sharded"}
    have = {r.get("name") for r in base_sharded}
    if not need <= have:
        failures.append(
            f"partition_sharded baseline rows missing {sorted(need - have)}"
            " (regenerate with benchmarks.run --json)")
    rows = partition_time.run_sharded()

    def contract(r, tag):
        out = []
        sweeps, gathers = r.get("sweeps", 0), r.get("gathers", 0)
        if not sweeps or gathers != sweeps:
            out.append(f"{tag}: gathers {gathers:.0f} != sweeps "
                       f"{sweeps:.0f} — the one-collective-per-sweep "
                       "contract is broken")
        if not r.get("halo_words"):
            out.append(f"{tag}: no halo traffic counted")
        elif r.get("halo_bytes") != 4 * r["halo_words"]:
            out.append(f"{tag}: halo_bytes {r.get('halo_bytes', 0):.0f} != "
                       f"4x halo_words {r['halo_words']:.0f}")
        return out

    for src, rs in (("baseline", base_sharded), ("live", rows)):
        by = {r.get("name"): r for r in rs}
        host = by.get("sharded/repair+refine")
        for name in ("sharded/repair+refine-sharded", "sharded/kway-sharded"):
            r = by.get(name)
            if r is None or host is None:
                continue  # missing rows already reported above
            failures.extend(contract(r, f"{src} {name}"))
            if r["cut"] > DIST_CUT_TOL * host["cut"]:
                failures.append(
                    f"{src} {name}: cut {r['cut']:.0f} > "
                    f"{DIST_CUT_TOL:.2f}x host refined {host['cut']:.0f}")
            if src == "live" and r.get("disconnected", 0) != 0:
                failures.append(f"{src} {name}: {r['disconnected']} "
                                "disconnected part(s)")
    return failures


def check_chaos() -> list:
    """The fault-tolerance gate (repro.guard): every injected fault class
    must still yield a full-coverage, connected, corridor-balanced
    labeling, with the degradation visible in BOTH the guard report and
    the trace counters — a fault the guard absorbs silently is as much a
    gate failure as one it cannot absorb.  Deterministic: chaos firing is
    a pure function of the (seed-keyed) site config."""
    import numpy as np

    from repro.core import PartitionPipeline
    from repro.dist import plan_halo_sharding, verify_halo_plan
    from repro.guard import chaos
    from repro.guard.policy import count_disconnected
    from repro.mesh import pebble_mesh

    failures = []
    mesh = pebble_mesh(8, 8, 8, n_pebbles=3, seed=0)
    nparts = 8
    solver_sites = ["solver_nan", "empty_split", "cg_divergence", "deadline"]
    for site in solver_sites:
        # cg_divergence lives in the inverse-iteration outer loop; the
        # other sites corrupt any solver's result at the guard boundary.
        bkw = {"method": "inverse"} if site == "cg_divergence" else {}
        ctx = PartitionPipeline(
            pre="rcb", bisect="rsb-batched", post=("repair", "refine"),
            bisect_kw=bkw, guard=True, guard_kw={"chaos": (site,)},
        ).run(mesh, nparts)
        parts = ctx.parts
        graph = ctx.require_graph()
        tag = f"chaos[{site}]"
        if sorted(np.unique(parts)) != list(range(nparts)):
            failures.append(f"{tag}: labels do not cover 0..{nparts - 1}")
        if count_disconnected(graph, parts, nparts) != 0:
            failures.append(f"{tag}: disconnected parts in output")
        # The corridor is weighted — pebble elements carry 1..2x weights.
        w = np.asarray(mesh.weights, np.float64)
        pw = np.bincount(parts, weights=w, minlength=nparts)
        mean = w.sum() / nparts
        if pw.max() > 1.10 * mean:
            failures.append(
                f"{tag}: weighted imbalance {pw.max() / mean:.3f} > 1.10")
        gr = ctx.report.guard
        if gr is None or gr.fallbacks <= 0:
            failures.append(f"{tag}: guard report shows no fallbacks — "
                            "the fault was not exercised")
        elif ctx.trace is not None:
            traced = ctx.trace.total_counters().get("guard_fallbacks", 0)
            if int(traced) != int(gr.fallbacks):
                failures.append(
                    f"{tag}: trace counter guard_fallbacks={traced:.0f} "
                    f"!= report {gr.fallbacks}")
        if site == "deadline" and (gr is None or not gr.deadline_expired):
            failures.append(f"{tag}: deadline never marked expired")
    # halo_truncate: the plan self-check must catch the dropped export
    # rows and rebuild a plan identical to the clean one.
    ctx = PartitionPipeline(pre="rcb", bisect="rsb-batched",
                            post=("repair", "refine"), guard=True).run(
                                mesh, nparts)
    clean = plan_halo_sharding(ctx.require_graph(), ctx.parts, nparts)
    with chaos.overlay(("halo_truncate",)):
        rebuilt = plan_halo_sharding(ctx.require_graph(), ctx.parts, nparts)
    if verify_halo_plan(rebuilt):
        failures.append("chaos[halo_truncate]: rebuilt plan still invalid")
    if not np.array_equal(rebuilt.export_mask, clean.export_mask):
        failures.append("chaos[halo_truncate]: rebuilt plan differs from "
                        "the clean plan")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_partition.json")
    ap.add_argument("--manifest", default="runs/smoke_manifest.jsonl")
    ap.add_argument("--trace", default="runs/smoke_trace.json")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    base_rows = baseline.get("partition_time_smoke", [])
    if not base_rows:
        print(f"no partition_time_smoke baseline in {args.baseline}",
              file=sys.stderr)
        return 1

    rows = partition_time.run(smoke=True)        # cold: gates the cut
    # warm: min-of-3 summed wall clock (same estimator as the baseline);
    # the min-sum run's rows also feed the post-fraction invariant
    warm_runs = [partition_time.run(smoke=True) for _ in range(3)]
    warm = min(warm_runs,
               key=lambda rs: sum(r["seconds"] for r in _wall_rows(rs)))
    wall = sum(r["seconds"] for r in _wall_rows(warm))
    by_key = {_key(r): r for r in rows}
    failed = False
    for base in base_rows:
        key = _key(base)
        row = by_key.get(key)
        if row is None:
            print(f"MISSING smoke row {key}", file=sys.stderr)
            failed = True
            continue
        ratio = row["cut"] / base["cut"]
        status = "OK" if ratio <= TOLERANCE else "REGRESSION"
        print(f"{status} {key}: cut {row['cut']:.0f} vs baseline "
              f"{base['cut']:.0f} ({ratio:.3f}x)", file=sys.stderr)
        if ratio > TOLERANCE:
            failed = True

    for msg in check_refine_invariants(rows, warm):
        print(f"REFINE-GATE {msg}", file=sys.stderr)
        failed = True

    # Multilevel engine contract: smoke-run quality vs the spectral rows,
    # plus the recorded large-mesh headline claim from the baseline.
    for msg in check_multilevel(rows, baseline.get("partition_large", [])):
        print(f"MULTILEVEL-GATE {msg}", file=sys.stderr)
        failed = True

    # Per-stage wall shares: warm rows against the baseline's stage maps.
    for msg in check_stage_shares(warm, base_rows):
        print(f"STAGE-GATE {msg}", file=sys.stderr)
        failed = True

    # Observability drift guard: manifest must exist, validate, and carry
    # every stage span the recorded config implies.
    for msg in check_manifest(args.manifest, args.trace):
        print(f"OBS-GATE {msg}", file=sys.stderr)
        failed = True

    # Sharded-refinement gate: cut parity with the host chain and the
    # one-all_gather-per-sweep collective contract, on both the recorded
    # partition_sharded baseline and a live re-run.
    for msg in check_dist_refine(baseline.get("partition_sharded", [])):
        print(f"DIST-GATE {msg}", file=sys.stderr)
        failed = True

    # Fault-tolerance gate: every chaos fault class must degrade into a
    # valid partition with the degradation visible in report + counters.
    for msg in check_chaos():
        print(f"CHAOS-GATE {msg}", file=sys.stderr)
        failed = True

    base_wall = sum(r["seconds"] for r in _wall_rows(base_rows))
    if base_wall > 0:
        ratio = wall / base_wall
        status = "OK" if ratio <= WALL_TOLERANCE else "REGRESSION"
        print(f"{status} wall clock: {wall:.2f}s vs baseline "
              f"{base_wall:.2f}s ({ratio:.3f}x)", file=sys.stderr)
        if ratio > WALL_TOLERANCE:
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
