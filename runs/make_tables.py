"""Generate EXPERIMENTS.md markdown tables from dry-run/perf JSON records.

    PYTHONPATH=src python runs/make_tables.py
"""

import glob
import json
import os

ORDER_ARCH = ["deepseek-moe-16b", "qwen3-moe-30b-a3b", "mistral-large-123b",
              "tinyllama-1.1b", "command-r-35b", "mace", "nequip",
              "graphcast", "meshgraphnet", "sasrec"]
ORDER_SHAPE = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "full_graph_sm", "minibatch_lg", "ogb_products", "molecule",
               "train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def load(dirname="runs/dryrun"):
    recs = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r.get("mesh", "skip"))
        recs[key] = r
    return recs


def fmt_e(x):
    return f"{x:.2e}"


def roofline_table(recs, mesh="16x16"):
    print(f"\n### Baseline roofline — single-pod {mesh} (256 chips)\n")
    print("| arch | shape | kind | compute s | memory s | collective s | "
          "dominant | useful | live GB/dev | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ORDER_ARCH:
        for s in ORDER_SHAPE:
            r = recs.get((a, s, mesh)) or recs.get((a, s, "skip"))
            if r is None:
                continue
            if r.get("status") == "skip":
                if mesh == "16x16":
                    print(f"| {a} | {s} | — | — | — | — | SKIP | — | — | — |")
                continue
            if r.get("status") == "fail":
                print(f"| {a} | {s} | — | — | — | — | FAIL | — | — | — |")
                continue
            rl = r["roofline"]
            print(
                f"| {a} | {s} | {r['kind']} | {fmt_e(rl['compute_s'])} | "
                f"{fmt_e(rl['memory_s'])} | {fmt_e(rl['collective_s'])} | "
                f"{rl['dominant']} | {rl['useful_fraction']:.2f} | "
                f"{r['live_bytes_per_device']/1e9:.2f} | "
                f"{'✓' if r['fits_16gb'] else '✗'} |"
            )


def multipod_table(recs):
    print("\n### Multi-pod check — 2×16×16 (512 chips): compile + memory\n")
    print("| arch | shape | status | live GB/dev | collective s | dominant |")
    print("|---|---|---|---|---|---|")
    for a in ORDER_ARCH:
        for s in ORDER_SHAPE:
            r = recs.get((a, s, "2x16x16"))
            if r is None:
                continue
            if r.get("status") != "ok":
                print(f"| {a} | {s} | {r.get('status')} | — | — | — |")
                continue
            rl = r["roofline"]
            print(f"| {a} | {s} | ok | {r['live_bytes_per_device']/1e9:.2f} | "
                  f"{fmt_e(rl['collective_s'])} | {rl['dominant']} |")


def summary(recs):
    ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    skip = sum(1 for r in recs.values() if r.get("status") == "skip")
    fail = sum(1 for r in recs.values() if r.get("status") == "fail")
    print(f"\ncells: ok={ok} skip={skip} fail={fail} "
          f"(skips counted once, ok counted per mesh)")


if __name__ == "__main__":
    recs = load()
    summary(recs)
    roofline_table(recs, "16x16")
    multipod_table(recs)
