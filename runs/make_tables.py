"""Generate EXPERIMENTS.md markdown tables from dry-run/perf JSON records.

    PYTHONPATH=src python runs/make_tables.py
"""

import glob
import json
import os

ORDER_ARCH = ["deepseek-moe-16b", "qwen3-moe-30b-a3b", "mistral-large-123b",
              "tinyllama-1.1b", "command-r-35b", "mace", "nequip",
              "graphcast", "meshgraphnet", "sasrec"]
ORDER_SHAPE = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "full_graph_sm", "minibatch_lg", "ogb_products", "molecule",
               "train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def load(dirname="runs/dryrun"):
    recs = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r.get("mesh", "skip"))
        recs[key] = r
    return recs


def fmt_e(x):
    return f"{x:.2e}"


def roofline_table(recs, mesh="16x16"):
    print(f"\n### Baseline roofline — single-pod {mesh} (256 chips)\n")
    print("| arch | shape | kind | compute s | memory s | collective s | "
          "dominant | useful | live GB/dev | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ORDER_ARCH:
        for s in ORDER_SHAPE:
            r = recs.get((a, s, mesh)) or recs.get((a, s, "skip"))
            if r is None:
                continue
            if r.get("status") == "skip":
                if mesh == "16x16":
                    print(f"| {a} | {s} | — | — | — | — | SKIP | — | — | — |")
                continue
            if r.get("status") == "fail":
                print(f"| {a} | {s} | — | — | — | — | FAIL | — | — | — |")
                continue
            rl = r["roofline"]
            print(
                f"| {a} | {s} | {r['kind']} | {fmt_e(rl['compute_s'])} | "
                f"{fmt_e(rl['memory_s'])} | {fmt_e(rl['collective_s'])} | "
                f"{rl['dominant']} | {rl['useful_fraction']:.2f} | "
                f"{r['live_bytes_per_device']/1e9:.2f} | "
                f"{'✓' if r['fits_16gb'] else '✗'} |"
            )


def multipod_table(recs):
    print("\n### Multi-pod check — 2×16×16 (512 chips): compile + memory\n")
    print("| arch | shape | status | live GB/dev | collective s | dominant |")
    print("|---|---|---|---|---|---|")
    for a in ORDER_ARCH:
        for s in ORDER_SHAPE:
            r = recs.get((a, s, "2x16x16"))
            if r is None:
                continue
            if r.get("status") != "ok":
                print(f"| {a} | {s} | {r.get('status')} | — | — | — |")
                continue
            rl = r["roofline"]
            print(f"| {a} | {s} | ok | {r['live_bytes_per_device']/1e9:.2f} | "
                  f"{fmt_e(rl['collective_s'])} | {rl['dominant']} |")


def manifest_table(dirname="runs"):
    """Partition-run manifests (repro.obs JSONL, written by
    ``PartitionContext.export_manifest`` / ``REPRO_OBS_DIR``) → one summary
    row each: name, commit, wall, the top stages by wall share, and the
    solver totals the trace aggregated.  Reads the manifests through
    ``obs.load_manifest`` instead of re-parsing span lines by hand."""
    try:
        from repro.obs import load_manifest
    except ImportError:       # run without PYTHONPATH=src: skip quietly
        return
    files = sorted(glob.glob(os.path.join(dirname, "*.jsonl")))
    rows = []
    for f in files:
        try:
            header, root = load_manifest(f)
        except (ValueError, OSError):
            continue
        total = max(root.seconds, 1e-12)
        stages = sorted(((c.seconds / total, c.name) for c in root.children),
                        reverse=True)
        top = ", ".join(f"{n} {s:.0%}" for s, n in stages[:3])
        m = header.get("totals", {}).get("metrics", {})
        solves = m.get("fiedler_solves")
        iters = (m.get("lanczos_restarts", 0)
                 + m.get("inverse_outer_iters", 0))
        rows.append((header.get("created", ""), header.get("name", "?"),
                     header.get("git_sha", "?")[:9], total, top,
                     "—" if solves is None else f"{solves:.0f}",
                     f"{iters:.0f}" if iters else "—"))
    if not rows:
        return
    print("\n### Partition run manifests (runs/*.jsonl)\n")
    print("| created | run | commit | wall s | top stages (share) | "
          "solves | iters |")
    print("|---|---|---|---|---|---|---|")
    for created, name, sha, total, top, solves, iters in sorted(rows):
        print(f"| {created} | {name} | {sha} | {total:.3f} | {top} | "
              f"{solves} | {iters} |")


def summary(recs):
    ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    skip = sum(1 for r in recs.values() if r.get("status") == "skip")
    fail = sum(1 for r in recs.values() if r.get("status") == "fail")
    print(f"\ncells: ok={ok} skip={skip} fail={fail} "
          f"(skips counted once, ok counted per mesh)")


if __name__ == "__main__":
    recs = load()
    summary(recs)
    roofline_table(recs, "16x16")
    multipod_table(recs)
    manifest_table()
