"""Multi-device behaviour via subprocesses (main test process keeps 1 device).

Covers: halo message passing ≡ dense oracle, distributed gather-scatter
Laplacian ≡ single-device GS, ring all-reduce ≡ psum, int8 compressed psum,
elastic checkpoint resharding 4 → 8 devices, and RSB-partition-aware halo
volume < naive partition halo volume (the paper's framework integration).
"""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_halo_matvec_and_rsb_volume():
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.mesh.graphs import grid_graph_2d
from repro.core.rcb import rcb_parts
from repro.core.rsb import rsb_partition_graph
from repro.dist.partition_aware import plan_halo_sharding, adjacency_matvec_distributed

g = grid_graph_2d(16, 16)
coords = np.stack(np.meshgrid(np.arange(16), np.arange(16), indexing='ij'), -1)
coords = np.concatenate([coords.reshape(-1, 2), np.zeros((256, 1))], 1).astype(float)

# dense oracle
A = np.zeros((256, 256)); A[g.rows, g.indices] = g.weights
x = np.random.default_rng(0).normal(size=256)

mesh = jax.make_mesh((8,), ("shards",), axis_types=(AxisType.Auto,))
for parts in (rcb_parts(coords, 8), np.random.default_rng(1).integers(0, 8, 256)):
    # rebalance random parts to equal sizes for planning
    plan = plan_halo_sharding(g, parts, 8)
    with jax.set_mesh(mesh):
        y = adjacency_matvec_distributed(plan, mesh, x)
    assert np.abs(y - A @ x).max() < 1e-4, "halo matvec mismatch"

# RSB halo < random-partition halo (paper's min-cut objective -> less comm)
p_rsb, _ = rsb_partition_graph(g, 8, tol=1e-3)
p_rnd = np.random.default_rng(2).permutation(np.arange(256) % 8)
h_rsb = plan_halo_sharding(g, p_rsb, 8).halo
h_rnd = plan_halo_sharding(g, p_rnd, 8).halo
print("halo rsb", h_rsb, "rnd", h_rnd)
assert h_rsb < h_rnd
print("OK")
""")


def test_distributed_gs_laplacian():
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.mesh import box_mesh
from repro.core import weighted_laplacian
from repro.core.gather_scatter import gs_setup
from repro.dist.collectives import dist_lap_apply_allreduce

m = box_mesh(4, 4, 4)
L = weighted_laplacian(m.vert_gid)
x = np.random.default_rng(1).normal(size=64).astype(np.float32)
y_ref = np.asarray(L.apply(jnp.asarray(x)))
h = gs_setup(m.vert_gid)
gid = np.asarray(h.gid).reshape(8, 8, 8)
deg = np.asarray(L.degree_full).reshape(8, 8)
mesh = jax.make_mesh((8,), ("shards",), axis_types=(AxisType.Auto,))
def fn(g, xl, d):
    return dist_lap_apply_allreduce(g[0], xl[0], d[0], h.n_global, "shards")[None]
with jax.set_mesh(mesh):
    out = jax.shard_map(fn, mesh=mesh, in_specs=(P("shards"),)*3,
                        out_specs=P("shards"))(
        jnp.asarray(gid), jnp.asarray(x.reshape(8, 8)), jnp.asarray(deg))
assert np.abs(np.asarray(out).reshape(-1) - y_ref).max() < 1e-4
print("OK")
""")


def test_ring_and_compressed_allreduce():
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.dist.collectives import ring_allreduce
from repro.train.grad_compression import compressed_psum

mesh = jax.make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
xs = jnp.asarray(np.random.default_rng(0).normal(size=(8, 37)), jnp.float32)

def rfn(x):
    return ring_allreduce(x[0], "d")[None]
with jax.set_mesh(mesh):
    out = jax.shard_map(rfn, mesh=mesh, in_specs=(P("d"),), out_specs=P("d"))(xs)
ref = np.asarray(xs).sum(0)
assert np.abs(np.asarray(out) - ref[None]).max() < 1e-4, "ring != psum"

def cfn(x):
    return compressed_psum(x[0], "d")[None]
with jax.set_mesh(mesh):
    cout = jax.shard_map(cfn, mesh=mesh, in_specs=(P("d"),), out_specs=P("d"))(xs)
mean = ref / 8
# int8 quantization error bound: scale = max|x|/127 per shard
tol = np.abs(np.asarray(xs)).max() / 127 + 1e-6
assert np.abs(np.asarray(cout)[0] - mean).max() < tol, "compressed psum off"
print("OK")
""")


def test_elastic_reshard_4_to_8():
    """Save sharded on a 4-device mesh, restore onto 8 devices."""
    run_sub(r"""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.train.checkpoint import save_checkpoint, load_checkpoint, reshard

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}
mesh4 = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,),
                      devices=jax.devices()[:4])
spec = {"w": P("data", None), "b": P()}
placed = reshard(tree, mesh4, spec)
d = tempfile.mkdtemp()
f = save_checkpoint(d, 1, placed)
step, restored, _ = load_checkpoint(f, tree)
mesh8 = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
placed8 = reshard(restored, mesh8, spec)
assert placed8["w"].sharding.num_devices == 8
np.testing.assert_array_equal(np.asarray(placed8["w"]), np.asarray(tree["w"]))
print("OK")
""")


def test_compressed_dp_training_step_converges():
    """A DP train step with int8 compressed gradient exchange reaches a loss
    close to the uncompressed step (error-feedback keeps the bias bounded)."""
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.train.grad_compression import compressed_psum

mesh = jax.make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
target = np.random.default_rng(0).normal(size=16).astype(np.float32)
X = np.random.default_rng(1).normal(size=(8, 32, 16)).astype(np.float32)
y = X @ target

def local_grad(w, Xl, yl):
    r = Xl @ w - yl
    return Xl.T @ r / Xl.shape[0]

def step(w, Xl, yl, compress):
    g = local_grad(w, Xl[0], yl[0])
    g = compressed_psum(g, "d") if compress else jax.lax.pmean(g, "d")
    return (w - 0.05 * g)

for compress in (False, True):
    w = jnp.zeros(16)
    with jax.set_mesh(mesh):
        f = jax.jit(jax.shard_map(lambda w, Xl, yl: step(w, Xl, yl, compress),
                    mesh=mesh, in_specs=(P(), P("d"), P("d")), out_specs=P()),
                    static_argnums=())
        for i in range(150):
            w = f(w, jnp.asarray(X), jnp.asarray(y))
    err = float(np.abs(np.asarray(w) - target).max())
    print("compress", compress, "err", err)
    assert err < 0.05
print("OK")
""")


def test_halo_graphcast_matches_baseline():
    """Partition-aware halo GraphCast ≡ baseline GraphCast (same params)."""
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.core.rcb import rcb_parts
from repro.dist.partition_aware import plan_halo_sharding, gather_features
from repro.mesh.graphs import stencil_graph_3d, grid_coords_3d
from repro.models.gnn.common import GraphBatch
from repro.models.gnn.graphcast import GraphCastConfig, init_graphcast, graphcast_forward
from repro.models.gnn.halo import graphcast_halo_local, halo_batch_from_plan

side, P_ = 6, 8
g = stencil_graph_3d(side, side, side)
coords = grid_coords_3d(side, side, side)
parts = rcb_parts(coords, P_)
plan = plan_halo_sharding(g, parts, P_)
cfg = GraphCastConfig(n_layers=2, d_hidden=16, n_vars=4, d_in=5)
params = init_graphcast(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
feat = rng.normal(size=(g.n, 5)).astype(np.float32)
tgt = rng.normal(size=(g.n, 4)).astype(np.float32)

# baseline on the full graph
base_batch = GraphBatch(
    node_feat=jnp.asarray(feat),
    edge_src=jnp.asarray(g.indices.astype(np.int32)),
    edge_dst=jnp.asarray(g.rows.astype(np.int32)),
    node_mask=jnp.ones(g.n), edge_mask=jnp.ones(g.nnz),
)
ref = np.asarray(graphcast_forward(cfg, params, base_batch))

# halo path under shard_map
hb = halo_batch_from_plan(plan, feat, tgt)
mesh = jax.make_mesh((P_,), ("shards",), axis_types=(AxisType.Auto,))
bspec = jax.tree_util.tree_map(lambda _: P("shards"), hb)
with jax.set_mesh(mesh):
    fn = jax.shard_map(
        lambda b: graphcast_halo_local(
            cfg, params, jax.tree_util.tree_map(lambda x: x[0], b), "shards")[None],
        in_specs=(bspec,), out_specs=P("shards"), check_vma=False)
    out_blocks = np.asarray(fn(hb))
out = gather_features(plan, out_blocks)
err = np.abs(out - ref).max()
print("halo graphcast err:", err)
assert err < 2e-3, err
print("OK")
""")


def test_moe_shardmap_matches_pjit_oracle():
    """EP shard_map MoE (local dispatch + a2a) ≡ single-device moe_apply."""
    run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.models.moe import MoEConfig, init_moe, moe_apply, moe_apply_shardmap
from repro.models.common import NO_SHARD

moe = MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=16,
                capacity_factor=8.0)
d = 32
p = init_moe(moe, d, jax.random.PRNGKey(0), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
y_ref = moe_apply(moe, p, x, NO_SHARD, jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
pspec = {"router": P(), "wi": P("model", None, None), "wg": P("model", None, None),
         "wo": P("model", None, None), "shared_wi": P(None, "model"),
         "shared_wg": P(None, "model"), "shared_wo": P("model", None)}
def body(xl, pl):
    return moe_apply_shardmap(moe, pl, xl, data_axes="data",
                              model_axis="model", dtype=jnp.float32)
with jax.set_mesh(mesh):
    for spec in (P("data", None, None), P("data", "model", None)):
        f = jax.jit(jax.shard_map(body, mesh=mesh, check_vma=False,
                    in_specs=(spec, pspec), out_specs=spec))
        err = float(np.abs(np.asarray(f(x, p)) - np.asarray(y_ref)).max())
        assert err < 2e-4, (spec, err)
print("OK")
""")


def test_lm_train_step_shardmap_moe_runs():
    """A full MoE train step with impl='shardmap' executes on a 2x4 mesh."""
    run_sub(r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, PartitionSpec as P
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.dist.sharding import lm_rules

cfg = LMConfig(name="moe-sm", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
               d_head=8, d_ff=64, vocab=128, dtype=jnp.float32,
               moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=16,
                             capacity_factor=4.0, impl="shardmap"))
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
rules = lm_rules(mesh)
params = init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 128)
batch = {"tokens": toks, "labels": toks}
with jax.set_mesh(mesh):
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, rules)))(params)
assert np.isfinite(float(loss))
gn = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
assert gn > 0
# matches the pjit-impl loss on the same params/batch
cfg2 = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl="pjit"))
with jax.set_mesh(mesh):
    loss2 = jax.jit(lambda p: loss_fn(cfg2, p, batch, rules))(params)
print("losses", float(loss), float(loss2))
assert abs(float(loss) - float(loss2)) < 2e-3
print("OK")
""")
