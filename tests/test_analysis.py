"""Tests for ``repro.analysis`` — the AST contract checker.

Three layers:

* per-rule fixtures: each known-bad file under ``tests/analysis_fixtures``
  produces exactly one diagnostic, at the ``# <- RULEID`` marker line,
  and a ``# repro: ignore[RULEID]`` suppression silences it;
* self-cleanliness (tier-1): the analyzer reports zero findings over
  ``src/repro`` — the tree must stay burn-down clean;
* vocabulary consistency: the runtime drift guard's required spans are a
  subset of the statically declared span vocabulary.
"""

import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import all_rules, analyze_paths, analyze_source
from repro.analysis.engine import Project, findings_json, parse_suppressions
from repro.analysis.rules import rule_ids

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC = os.path.normpath(os.path.join(HERE, "..", "src", "repro"))

# The single-file fixtures; PAL002 needs the on-disk kernels/ tree and
# is covered separately below.
SINGLE_FILE_RULES = ("TRC001", "TRC002", "DET001", "DET002", "DET003",
                     "DIST001", "DIST002", "PAL001", "OBS001", "OBS002",
                     "GRD001", "GRD002")


@pytest.fixture(scope="module")
def project():
    """One vocabulary discovery (registry/chaos/errors parse) per module."""
    return Project(SRC)


def _fixture_source(rule: str) -> str:
    path = os.path.join(FIXTURES, f"bad_{rule.lower()}.py")
    with open(path) as f:
        return f.read()


def _marker_line(source: str, rule: str) -> int:
    for i, line in enumerate(source.splitlines(), start=1):
        if f"# <- {rule}" in line:
            return i
    raise AssertionError(f"fixture for {rule} has no marker line")


# ---------------------------------------------------------------------------
# Per-rule: fixture fires exactly once, at the marker line
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", SINGLE_FILE_RULES)
def test_rule_fires_on_fixture(rule, project):
    source = _fixture_source(rule)
    diags = analyze_source(source, project=project)
    assert len(diags) == 1, [d.render() for d in diags]
    d = diags[0]
    assert d.rule == rule
    assert d.line == _marker_line(source, rule)
    assert d.message


@pytest.mark.parametrize("rule", SINGLE_FILE_RULES)
def test_rule_suppressed_by_ignore(rule, project):
    source = _fixture_source(rule)
    lines = source.splitlines()
    lines.insert(_marker_line(source, rule) - 1, f"# repro: ignore[{rule}]")
    assert analyze_source("\n".join(lines), project=project) == []


@pytest.mark.parametrize("rule", SINGLE_FILE_RULES)
def test_bare_ignore_suppresses_any_rule(rule, project):
    source = _fixture_source(rule)
    lines = source.splitlines()
    lines.insert(_marker_line(source, rule) - 1, "# repro: ignore")
    assert analyze_source("\n".join(lines), project=project) == []


def test_wrong_rule_suppression_does_not_silence(project):
    source = _fixture_source("TRC001")
    lines = source.splitlines()
    lines.insert(_marker_line(source, "TRC001") - 1,
                 "# repro: ignore[TRC002]")
    diags = analyze_source("\n".join(lines), project=project)
    assert [d.rule for d in diags] == ["TRC001"]


# ---------------------------------------------------------------------------
# PAL002: the cross-file kernel-triple contract
# ---------------------------------------------------------------------------


def test_pal002_fires_on_ops_missing_ref_import(project):
    kdir = os.path.join(FIXTURES, "kernels")
    diags = analyze_paths([kdir], project=project)
    assert [d.rule for d in diags] == ["PAL002"]
    assert diags[0].path.endswith(os.path.join("badtriple", "ops.py"))
    assert "`ref`" in diags[0].message


def test_pal002_missing_triple_member(tmp_path, project):
    kdir = tmp_path / "kernels" / "lonely"
    kdir.mkdir(parents=True)
    (kdir / "kernel.py").write_text("def lonely_pallas(x):\n    return x\n")
    diags = analyze_paths([str(tmp_path)], project=project)
    missing = {d.message.split("missing ")[1].split(" ")[0]
               for d in diags if d.rule == "PAL002"}
    assert missing == {"ref.py", "ops.py"}


def test_pal002_suppressed_in_ops(tmp_path, project):
    src_dir = os.path.join(FIXTURES, "kernels", "badtriple")
    kdir = tmp_path / "kernels" / "badtriple"
    shutil.copytree(src_dir, kdir)
    ops = kdir / "ops.py"
    ops.write_text("# repro: ignore[PAL002]\n" + ops.read_text())
    assert analyze_paths([str(tmp_path)], project=project) == []


# ---------------------------------------------------------------------------
# Engine mechanics
# ---------------------------------------------------------------------------


def test_syntax_error_becomes_parse_diagnostic(project):
    diags = analyze_source("def f(:\n", project=project)
    assert [d.rule for d in diags] == ["PARSE"]
    assert "syntax error" in diags[0].message


def test_parse_suppressions_covers_line_and_next():
    supp = parse_suppressions(
        "x = 1\n# repro: ignore[TRC001,DET002]\ny = 2\nz = 3\n")
    assert supp[2] == {"TRC001", "DET002"}
    assert supp[3] == {"TRC001", "DET002"}
    assert 4 not in supp


def test_static_cast_of_shape_not_flagged(project):
    source = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    n = int(x.shape[0])\n"
        "    return x * n\n")
    assert analyze_source(source, project=project) == []


def test_collective_outside_loop_not_flagged(project):
    source = (
        "import jax\n"
        "def gather(buf, axis_name):\n"
        "    return jax.lax.all_gather(buf, axis_name, tiled=True)\n")
    assert analyze_source(source, project=project) == []


def test_findings_json_schema(project):
    diags = analyze_source(_fixture_source("DET002"), project=project)
    report = json.loads(findings_json(diags))
    assert report["schema"] == "repro.analysis/v1"
    assert report["counts"] == {"DET002": 1}
    assert [f["rule"] for f in report["findings"]] == ["DET002"]
    assert {r["id"] for r in report["rules"]} == set(rule_ids())


def test_rule_catalog_ids_unique_and_stable():
    ids = rule_ids()
    assert len(ids) == len(set(ids))
    assert len(all_rules()) == len(ids)
    for rid in SINGLE_FILE_RULES + ("PAL002",):
        assert rid in ids


# ---------------------------------------------------------------------------
# Tier-1 gates: src/ is clean, and the two span vocabularies agree
# ---------------------------------------------------------------------------


def test_src_tree_is_clean():
    """The burn-down contract: the shipped tree has zero findings."""
    diags = analyze_paths([SRC])
    assert diags == [], "\n".join(d.render() for d in diags)


def test_expected_spans_subset_of_declared():
    """Every span the runtime drift guard can require must come from the
    statically declared vocabulary the analyzer enforces."""
    from repro.obs.export import expected_span_names
    from repro.obs.registry import span_declared

    configs = [
        {},
        {"guard": True, "pre": "heavy-connect", "bisect": "rsb-batched",
         "post": ("refine", "repair-refine"), "components": 1},
        {"bisect": "multilevel", "components": 1},
        {"bisect": "rsb-recursive", "pre": "rcb", "components": 2},
    ]
    for config in configs:
        for name in expected_span_names(config):
            assert span_declared(name), name


def test_cli_reports_findings_and_exit_codes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(SRC)
    out_json = tmp_path / "findings.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         os.path.join(FIXTURES, "bad_det002.py"),
         "--root", SRC, "--format", "json", "--output", str(out_json)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 1, proc.stderr
    report = json.loads(proc.stdout)
    assert report["counts"] == {"DET002": 1}
    assert json.loads(out_json.read_text()) == report

    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    for rid in rule_ids():
        assert rid in proc.stdout
