"""Transformer LM: shapes, training signal, decode consistency, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch, token_batches
from repro.models.moe import MoEConfig, capacity, init_moe, moe_apply
from repro.models.transformer import (
    LMConfig,
    blocked_attention,
    chunked_attention,
    decode_step,
    forward,
    init_params,
    loss_fn,
    prefill,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

TINY = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_head=16, d_ff=128, vocab=256, dtype=jnp.float32)
TINY_MOE = LMConfig(name="tm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                    d_head=16, d_ff=128, vocab=256, dtype=jnp.float32,
                    moe=MoEConfig(n_experts=8, top_k=2, n_shared=1,
                                  d_ff_expert=32, capacity_factor=4.0))


def test_forward_shapes_no_nan():
    params = init_params(TINY, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    logits = forward(TINY, params, toks)
    assert logits.shape == (2, 16, 256)
    assert not bool(jnp.isnan(logits).any())


def test_initial_loss_near_uniform():
    params = init_params(TINY, jax.random.PRNGKey(0))
    b = lm_batch(np.random.default_rng(0), 4, 32, TINY.vocab)
    loss = float(loss_fn(TINY, params, b))
    assert abs(loss - np.log(TINY.vocab)) < 1.0


def test_loss_decreases_under_training():
    params = init_params(TINY, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    it = token_batches(8, 32, TINY.vocab, seed=1)

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(lambda pp: loss_fn(TINY, pp, b))(p)
        p, o, _ = adamw_update(cfg, g, o, p)
        return p, o, l

    losses = []
    for i, b in zip(range(30), it):
        params, opt, l = step(params, opt, b)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_decode_matches_forward():
    params = init_params(TINY, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 256)
    logits_full = forward(TINY, params, toks)
    pl, cache = prefill(TINY, params, toks[:, :8])
    np.testing.assert_allclose(
        np.asarray(pl[:, 0]), np.asarray(logits_full[:, 7]), atol=2e-4
    )
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
             for k, v in cache.items()}
    for t in range(8, 12):
        dl, cache = decode_step(TINY, params, cache, toks[:, t : t + 1],
                                jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(dl[:, 0]), np.asarray(logits_full[:, t]), atol=5e-4
        )


def test_sliding_window_masks_past():
    import dataclasses

    cfgw = dataclasses.replace(TINY, attn="sliding_window", window=4)
    params = init_params(cfgw, jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, 256)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % 256)  # differ only far past
    l1 = forward(cfgw, params, t1)
    l2 = forward(cfgw, params, t2)
    # last position only sees tokens ≥ index 8 → unchanged
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-4)


def test_moe_forward_and_grads():
    params = init_params(TINY_MOE, jax.random.PRNGKey(0))
    b = lm_batch(np.random.default_rng(1), 2, 16, TINY_MOE.vocab)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(TINY_MOE, p, b))(params)
    assert np.isfinite(float(loss))
    rnorm = float(jnp.linalg.norm(grads["layers"]["moe"]["router"]))
    assert rnorm > 0  # router receives gradient


def test_moe_matches_dense_expert_oracle():
    """With capacity ≥ tokens·top_k, sort-dispatch MoE equals the dense
    per-token expert-mixture oracle."""
    moe = MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff_expert=16,
                    capacity_factor=8.0)
    d = 32
    p = init_moe(moe, d, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    from repro.models.common import NO_SHARD

    y = moe_apply(moe, p, x, NO_SHARD, jnp.float32)

    # oracle: run every expert densely, combine by renormalized top-k gates
    xt = x.reshape(-1, d)
    gates = jax.nn.softmax(xt @ p["router"], axis=-1)
    top_w, top_e = jax.lax.top_k(gates, 2)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    outs = []
    for e in range(4):
        z = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        outs.append(z @ p["wo"][e])
    outs = jnp.stack(outs, 1)  # (T, E, d)
    ref = jnp.zeros_like(xt)
    for k in range(2):
        ref = ref + top_w[:, k : k + 1] * jnp.take_along_axis(
            outs, top_e[:, k, None, None].repeat(d, -1), 1
        )[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), np.asarray(ref),
                               atol=2e-4)


def test_moe_capacity_alignment():
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16)
    c = capacity(moe, 1000)
    assert c % 8 == 0 and c >= 1000 * 2 * 1.25 / 8


@pytest.mark.parametrize("Sq,Skv", [(16, 16), (1, 64), (32, 64)])
def test_chunked_vs_blocked_attention(Sq, Skv):
    rng = np.random.default_rng(0)
    B, H, D = 2, 4, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(Skv - Sq, Skv), (B, Sq))
    out_b = blocked_attention(q, k, v, q_pos=pos, block_q=8, block_kv=16)
    qg = q.reshape(B, Sq, H, 1, D)
    out_c = chunked_attention(qg, k, v, q_pos=pos, block_kv=16)
    np.testing.assert_allclose(
        np.asarray(out_b), np.asarray(out_c.reshape(B, Sq, H, D)), atol=2e-5
    )
