"""repro.obs contract tests: span nesting, the disabled fast path,
metric merge semantics, manifest round-trip, and REPRO_OBS=off parity
(the pipeline must be bit-for-bit identical with tracing off)."""

import json
import os

import numpy as np
import pytest

from repro import obs
from repro.core import PartitionPipeline
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import dual_graph, pebble_mesh


@pytest.fixture(autouse=True)
def _obs_on():
    """Every test starts with tracing on and an empty span stack."""
    prev = obs.set_enabled(True)
    yield
    obs.set_enabled(prev)


# ---------------------------------------------------------------------------
# Span tree: nesting, ordering, timing
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    with obs.trace("root", run=1) as root:
        with obs.span("a"):
            obs.counter_add("hits", 2)
            with obs.span("a1"):
                pass
            with obs.span("a2"):
                pass
        with obs.span("b"):
            pass
    assert [c.name for c in root.children] == ["a", "b"]
    a = root.find("a")
    assert [c.name for c in a.children] == ["a1", "a2"]
    assert a.counters == {"hits": 2.0}
    assert root.tags == {"run": 1}
    # pre-order walk
    assert [s.name for s in root.walk()] == ["root", "a", "a1", "a2", "b"]
    # children nest inside the parent's time window
    assert a.t0 >= root.t0 and a.t1 <= root.t1 + 1e-9
    assert root.seconds >= a.seconds


def test_timed_measures_inside_and_outside_traces():
    with obs.trace("root") as root:
        with obs.timed("work") as t:
            pass
        assert isinstance(t, obs.Span)
    assert root.find("work") is t
    # outside any trace: a plain timer, nothing recorded anywhere
    with obs.timed("loose") as t2:
        pass
    assert not isinstance(t2, obs.Span)
    assert t2.seconds >= 0.0


def test_exception_pops_span_stack():
    with pytest.raises(RuntimeError):
        with obs.trace("root"):
            with obs.span("inner"):
                raise RuntimeError("boom")
    assert obs.current_span() is None


# ---------------------------------------------------------------------------
# Disabled mode: the zero-allocation fast path
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop_singleton():
    with obs.disabled():
        s1 = obs.span("x")
        s2 = obs.span("y", tag=1)
        assert s1 is obs.NOOP_SPAN and s2 is obs.NOOP_SPAN
        with s1:
            pass
        assert obs.current_span() is None
        # trace/timed degrade to timers that still measure wall time
        with obs.trace("root") as t:
            pass
        assert not isinstance(t, obs.Span)
        obs.counter_add("nope")          # must not raise, must not record
        obs.gauge_set("nope", 1)
        obs.gauge_max("nope", 1)
    # span() outside any trace is also the no-op singleton (enabled mode)
    assert obs.span("loose") is obs.NOOP_SPAN


# ---------------------------------------------------------------------------
# Counter / gauge merge semantics
# ---------------------------------------------------------------------------

def test_counters_sum_over_subtree():
    with obs.trace("root") as root:
        obs.counter_add("fm_moves", 3)
        with obs.span("child"):
            obs.counter_add("fm_moves", 4)
    assert root.total_counters()["fm_moves"] == 7.0


def test_gauge_aggregation_follows_registry():
    # residual_max/amg_levels are max-gauges, edge_cut is last-write
    with obs.trace("root") as root:
        obs.gauge_max("residual_max", 0.5)
        obs.gauge_set("edge_cut", 100.0)
        with obs.span("child"):
            obs.gauge_max("residual_max", 0.2)
            obs.gauge_set("edge_cut", 80.0)
            obs.gauge_set("amg_levels", 4)
    total = root.total_counters()
    assert total["residual_max"] == 0.5      # max over subtree
    assert total["edge_cut"] == 80.0         # last write wins
    assert total["amg_levels"] == 4


def test_gauge_max_within_one_span():
    with obs.trace("root") as root:
        obs.gauge_max("residual_max", 0.1)
        obs.gauge_max("residual_max", 0.3)
        obs.gauge_max("residual_max", 0.2)
    assert root.gauges["residual_max"] == 0.3


def test_merge_metrics_unregistered_defaults():
    # unregistered counters sum; unregistered gauges default to max
    dst = {}
    obs.merge_metrics(dst, {"custom": 1.0}, kind="counter")
    obs.merge_metrics(dst, {"custom": 2.0}, kind="counter")
    assert dst["custom"] == 3.0
    g = {}
    obs.merge_metrics(g, {"g": 1.0}, kind="gauge")
    obs.merge_metrics(g, {"g": 0.5}, kind="gauge")
    assert g["g"] == 1.0


# ---------------------------------------------------------------------------
# Manifest round-trip + validation
# ---------------------------------------------------------------------------

def test_manifest_round_trip(tmp_path):
    with obs.trace("partition", nparts=4) as root:
        with obs.span("bisect:rsb-batched"):
            obs.counter_add("fiedler_solves", 3)
            obs.gauge_set("amg_levels", 2)
    path = str(tmp_path / "run.jsonl")
    config = {"pre": "none", "bisect": "rsb-batched", "post": []}
    obs.write_manifest(root, path, name="t", config=config)
    header, loaded = obs.load_manifest(path)
    assert header["schema"] == obs.SCHEMA
    assert header["config"] == config
    assert header["totals"]["metrics"]["fiedler_solves"] == 3.0
    assert [s.name for s in loaded.walk()] == [s.name for s in root.walk()]
    b = loaded.find("bisect:rsb-batched")
    assert b.counters == {"fiedler_solves": 3.0}
    assert b.gauges == {"amg_levels": 2}
    assert loaded.tags == {"nparts": 4}
    assert abs(loaded.seconds - root.seconds) < 1e-9
    # every line is valid JSON (it is a JSONL file, not a JSON file)
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_validate_manifest_flags_missing_stage_span(tmp_path):
    with obs.trace("partition") as root:
        with obs.span("pre:rcb"):
            pass
    path = str(tmp_path / "bad.jsonl")
    obs.write_manifest(root, path, name="t", config={
        "pre": "rcb", "bisect": "rsb-batched", "post": ["repair"]})
    problems = obs.validate_manifest(path)
    missing = {p.split("'")[1] for p in problems if "missing span" in p}
    assert missing == {"bisect:rsb-batched", "solve", "split", "post:repair"}


def test_expected_span_names_from_config():
    names = obs.expected_span_names(
        {"pre": "none", "bisect": "rcb", "post": ["repair", "kway"]})
    assert names == {"partition", "bisect:rcb", "post:repair", "post:kway"}


# ---------------------------------------------------------------------------
# Pipeline integration + REPRO_OBS=off parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_mesh():
    return pebble_mesh(6, 6, 6, n_pebbles=2, seed=3)


def test_pipeline_records_trace_and_manifest(small_mesh, tmp_path):
    pipe = PartitionPipeline(pre="rcb", bisect="rsb-batched",
                             post=("repair", "refine"))
    ctx = pipe.run(small_mesh, 4)
    root = ctx.trace
    assert root is not None and root.name == "partition"
    for name in obs.expected_span_names(ctx.config):
        assert root.find(name) is not None, name
    # stage spans and StageRecords agree on the wall clock
    for rec in ctx.stages:
        span = root.find(f"{rec.kind}:{rec.name}")
        assert span is not None
        assert abs(span.seconds - rec.seconds) < 0.05
    path = ctx.export_manifest(str(tmp_path / "m.jsonl"))
    assert obs.validate_manifest(path) == []
    tpath = ctx.export_trace_events(str(tmp_path / "t.json"))
    events = json.load(open(tpath))["traceEvents"]
    assert {e["name"] for e in events} >= {"partition", "solve", "split"}


def test_repro_obs_off_parity(small_mesh):
    pipe = PartitionPipeline(pre="rcb", bisect="rsb-batched",
                             post=("repair", "refine"))
    ctx_on = pipe.run(small_mesh, 4)
    with obs.disabled():
        ctx_off = pipe.run(small_mesh, 4)
    # identical labels, no trace, but every report timing still populated
    assert np.array_equal(ctx_on.parts, ctx_off.parts)
    assert ctx_off.trace is None
    assert ctx_off.report.seconds > 0
    assert ctx_off.report.post.seconds > 0
    assert all(lv.solve_seconds > 0 for lv in ctx_off.report.levels)
    assert all(s.seconds >= 0 for s in ctx_off.stages)
    assert ctx_off.stats().keys() == ctx_on.stats().keys()


def test_repro_obs_dir_auto_manifest(small_mesh, tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    PartitionPipeline(bisect="rcb", post=()).run(small_mesh, 4)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".jsonl")]
    assert len(files) == 1
    assert obs.validate_manifest(str(tmp_path / files[0])) == []


def test_recursive_engine_split_seconds(small_mesh):
    # satellite fix: the recursive path used to hardcode split_seconds=0
    pipe = PartitionPipeline(pre="rcb", bisect="rsb-recursive", post=())
    ctx = pipe.run(small_mesh, 4)
    assert all(lv.split_seconds > 0 for lv in ctx.report.levels)
    assert all(r.split_seconds > 0 for r in ctx.report.records)


def test_halo_plan_emits_wire_volume(small_mesh):
    graph = dual_graph(small_mesh)
    parts = np.arange(graph.n) % 4
    with obs.trace("root") as root:
        plan = plan_halo_sharding(graph, parts, 4)
    assert root.counters["halo_words"] == plan.collective_words_per_feature
    assert root.counters["halo_bytes"] == 4.0 * plan.collective_words_per_feature
    assert root.gauges["halo_max_degree"] == plan.halo


def test_report_to_dict_round_trip(small_mesh):
    ctx = PartitionPipeline(pre="rcb", bisect="rsb-batched").run(small_mesh, 4)
    d = ctx.report.to_dict()
    json.dumps(d)                  # fully JSON-able
    assert d["total_iterations"] == ctx.report.total_iterations
    assert d["precond_levels"] == ctx.report.precond_levels
    assert d["post"]["cut_after"] <= d["post"]["cut_before"]
    assert len(d["levels"]) == len(ctx.report.levels)


def test_percentiles_nearest_rank():
    secs = [float(i) for i in range(101)]
    p = obs.percentiles(secs)
    assert p["p50"] == 50.0
    assert p["p99"] == 99.0
    assert obs.percentiles([]) == {"p50": 0.0, "p99": 0.0}
