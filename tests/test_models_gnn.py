"""GNN models: shapes, training signal, E(3) equivariance, permutation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import gnn_full_batch, molecule_batches
from repro.mesh.graphs import radius_molecule_batch, rmat_graph
from repro.models.gnn import (
    GraphBatch,
    GraphCastConfig,
    MACEConfig,
    MGNConfig,
    NequIPConfig,
    graphcast_forward,
    graphcast_loss,
    init_graphcast,
    init_mace,
    init_mgn,
    init_nequip,
    mace_energy,
    mgn_forward,
    nequip_energy,
    sample_neighbors,
)
from repro.models.gnn.equivariant import sh_l2_np
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _random_rotation(rng):
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


def _mol_batch(positions, spec, esrc, edst, n_graphs, n_per):
    N = positions.shape[0]
    gids = np.repeat(np.arange(n_graphs), n_per).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.zeros((N, 0), jnp.float32),
        edge_src=jnp.asarray(esrc, jnp.int32),
        edge_dst=jnp.asarray(edst, jnp.int32),
        node_mask=jnp.ones(N), edge_mask=jnp.ones(len(esrc)),
        positions=jnp.asarray(positions, jnp.float32),
        species=jnp.asarray(spec, jnp.int32),
        graph_ids=jnp.asarray(gids), n_graphs=n_graphs,
    )


@pytest.fixture(scope="module")
def molecules():
    pos, spec, esrc, edst = radius_molecule_batch(4, 12, 24, seed=7)
    return pos, spec, esrc, edst


def test_gaunt_parity_selection():
    """Gaunt coefficients vanish for odd l1+l2+l3 (parity)."""
    from repro.models.gnn.equivariant import enumerate_paths

    for l1, l2, l3 in enumerate_paths():
        assert (l1 + l2 + l3) % 2 == 0
        assert abs(l1 - l2) <= l3 <= l1 + l2


def test_sh_orthonormal():
    n_t, n_p = 24, 48
    ct, wt = np.polynomial.legendre.leggauss(n_t)
    phi = (np.arange(n_p) + 0.5) * (2 * np.pi / n_p)
    st = np.sqrt(1 - ct**2)
    pts = np.stack([
        (st[:, None] * np.cos(phi)).ravel(),
        (st[:, None] * np.sin(phi)).ravel(),
        np.broadcast_to(ct[:, None], (n_t, n_p)).ravel(),
    ], -1)
    w = (wt[:, None] * (2 * np.pi / n_p) * np.ones(n_p)).ravel()
    Y = sh_l2_np(pts)
    M = np.einsum("m,mi,mj->ij", w, Y, Y)
    np.testing.assert_allclose(M, np.eye(9), atol=1e-10)


@pytest.mark.parametrize("model", ["nequip", "mace"])
def test_rotation_invariance(model, molecules):
    pos, spec, esrc, edst = molecules
    rng = np.random.default_rng(3)
    Q = _random_rotation(rng)
    if model == "nequip":
        cfg = NequIPConfig(n_layers=2, d_hidden=8)
        params = init_nequip(cfg, jax.random.PRNGKey(0))
        fn = lambda p: nequip_energy(cfg, params, _mol_batch(p, spec, esrc, edst, 4, 12))
    else:
        cfg = MACEConfig(n_layers=2, d_hidden=8)
        params = init_mace(cfg, jax.random.PRNGKey(0))
        fn = lambda p: mace_energy(cfg, params, _mol_batch(p, spec, esrc, edst, 4, 12))
    e1 = np.asarray(fn(pos))
    e2 = np.asarray(fn(pos @ Q.T))
    shift = pos + rng.normal(size=3)  # translation invariance too
    e3 = np.asarray(fn(shift))
    np.testing.assert_allclose(e1, e2, atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(e1, e3, atol=1e-3, rtol=1e-4)


def test_permutation_invariance_mgn():
    """Relabeling nodes permutes outputs consistently."""
    g = rmat_graph(40, 160, seed=5)
    batch = gnn_full_batch(g, d_feat=6, d_out=3, seed=1)
    cfg = MGNConfig(n_layers=2, d_hidden=16, d_in=6)
    params = init_mgn(cfg, jax.random.PRNGKey(0))
    out = np.asarray(mgn_forward(cfg, params, batch))

    perm = np.random.default_rng(0).permutation(g.n)
    inv = np.argsort(perm)
    pb = GraphBatch(
        node_feat=batch.node_feat[perm],
        edge_src=jnp.asarray(inv)[batch.edge_src],
        edge_dst=jnp.asarray(inv)[batch.edge_dst],
        node_mask=batch.node_mask, edge_mask=batch.edge_mask,
        targets=batch.targets[perm] if batch.targets is not None else None,
    )
    out_p = np.asarray(mgn_forward(cfg, params, pb))
    np.testing.assert_allclose(out_p, out[perm], atol=2e-4)


def test_graphcast_shapes_and_training():
    g = rmat_graph(64, 256, seed=6)
    cfg = GraphCastConfig(n_layers=2, d_hidden=16, n_vars=5, d_in=5)
    batch = gnn_full_batch(g, d_feat=5, d_out=5, seed=2)
    params = init_graphcast(cfg, jax.random.PRNGKey(0))
    out = graphcast_forward(cfg, params, batch)
    assert out.shape == (64, 5)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)

    @jax.jit
    def step(p, o):
        l, gr = jax.value_and_grad(lambda pp: graphcast_loss(cfg, pp, batch))(p)
        p, o, _ = adamw_update(ocfg, gr, o, p)
        return p, o, l

    l0 = None
    for i in range(20):
        params, opt, l = step(params, opt)
        l0 = l0 if l0 is not None else float(l)
    assert float(l) < l0


def test_edge_mask_drops_messages():
    g = rmat_graph(30, 90, seed=8)
    batch = gnn_full_batch(g, d_feat=4, d_out=3, seed=3)
    cfg = MGNConfig(n_layers=1, d_hidden=8, d_in=4)
    params = init_mgn(cfg, jax.random.PRNGKey(1))
    masked = GraphBatch(
        node_feat=batch.node_feat, edge_src=batch.edge_src,
        edge_dst=batch.edge_dst, node_mask=batch.node_mask,
        edge_mask=jnp.zeros_like(batch.edge_mask), targets=batch.targets,
    )
    out = mgn_forward(cfg, params, masked)
    # with all edges masked, nodes see no neighbors: output depends only on
    # own features → equal inputs give equal outputs
    same = GraphBatch(
        node_feat=batch.node_feat.at[:].set(batch.node_feat[0]),
        edge_src=batch.edge_src, edge_dst=batch.edge_dst,
        node_mask=batch.node_mask, edge_mask=jnp.zeros_like(batch.edge_mask),
    )
    out_same = mgn_forward(cfg, params, same)
    np.testing.assert_allclose(np.asarray(out_same - out_same[0]),
                               0.0, atol=1e-5)


def test_neighbor_sampler_validity():
    g = rmat_graph(500, 3000, seed=9)
    sub = sample_neighbors(g, np.arange(8), fanout=(4, 3))
    n = int(sub.node_mask.sum())
    m = int(sub.edge_mask.sum())
    assert n <= sub.node_ids.size and m <= sub.edge_src.size
    # every edge endpoint is a sampled node
    assert sub.edge_src[:m].max() < n and sub.edge_dst[:m].max() < n
    # edges exist in the original graph
    for i in range(min(m, 40)):
        u = sub.node_ids[sub.edge_src[i]]
        v = sub.node_ids[sub.edge_dst[i]]
        nbrs = g.indices[g.indptr[v] : g.indptr[v + 1]]
        assert u in nbrs


def test_molecule_pipeline_trains_nequip():
    cfg = NequIPConfig(n_layers=2, d_hidden=8)
    it = molecule_batches(4, 10, 20, seed=11)
    batch = next(it)
    params = init_nequip(cfg, jax.random.PRNGKey(0))
    from repro.models.gnn import nequip_loss

    l, g = jax.value_and_grad(lambda p: nequip_loss(cfg, p, batch))(params)
    assert np.isfinite(float(l))
    gn = float(
        sum(jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(g))
    )
    assert gn > 0
