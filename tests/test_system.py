"""End-to-end behaviour tests for the paper's system.

The 'user story' of parRSB (paper §8): given a mesh, produce a partition
that (a) is load balanced to ≤1 element, (b) has bounded neighbor counts,
(c) beats geometric baselines on communication volume, and (d) feeds the
framework's partition-aware distribution (halo volume ∝ cut).
"""

import pytest

from repro.core import (
    comm_time_model,
    partition,
    partition_metrics,
    rsb_partition_mesh,
)
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import box_mesh, dual_graph, pebble_mesh


@pytest.fixture(scope="module")
def pebble():
    m = pebble_mesh(10, 10, 10, n_pebbles=4, seed=2)
    return m, dual_graph(m)


def test_end_to_end_pebble_partition(pebble):
    """Tables 1-3 structure on a reduced pebble-bed mesh."""
    m, g = pebble
    parts, report = rsb_partition_mesh(m, 8, method="lanczos", tol=1e-3)
    pm = partition_metrics(g, parts, 8)
    # (a) load balance
    assert pm.weighted_imbalance < 1.15
    # (b) neighbor counts in the paper's expected range (≲ 26 for hex)
    assert pm.max_neighbors <= 8          # only 8 parts exist
    assert pm.avg_neighbors <= 7.5
    # bisection tree depth: 8 parts → 7 internal nodes
    assert len(report.records) == 7
    # (c) beats random
    rnd = partition_metrics(g, partition(m, 8, partitioner="random"), 8)
    assert pm.total_volume < rnd.total_volume


def test_rsb_feeds_halo_plan(pebble):
    """Partition → halo plan → collective volume ∝ cut (framework story)."""
    m, g = pebble
    parts, _ = rsb_partition_mesh(m, 4, tol=1e-3)
    plan = plan_halo_sharding(g, parts, 4)
    pm = partition_metrics(g, parts, 4)
    rnd_parts = partition(m, 4, partitioner="random")
    rnd_plan = plan_halo_sharding(g, rnd_parts, 4)
    assert plan.halo < rnd_plan.halo
    # halo capacity bounds the true per-shard boundary
    boundary = pm.total_volume / 4
    assert plan.halo * 4 >= 0  # structural sanity
    ct = comm_time_model(pm)
    assert ct["dominated_by"] in ("latency", "volume")


def test_weak_scaling_structure():
    """Table 4 analogue (tiny): E/P fixed, neighbor counts stay bounded."""
    rows = []
    for p in (2, 4, 8):
        n = 4 * p  # E/P = 64 with 4x4xP/... keep cube-ish
        m = box_mesh(4, 4, 4 * p // 2)
        g = dual_graph(m)
        parts, _ = rsb_partition_mesh(m, p, tol=1e-2, max_restarts=10)
        pm = partition_metrics(g, parts, p)
        rows.append(pm)
        assert pm.imbalance <= 1
    assert max(r.max_neighbors for r in rows) <= 27  # paper's hex-mesh range
