"""Post-bisection repair/refinement invariants (the pipeline quality stage).

Property tests (hypothesis) on random connected graphs: the post stage
never increases the edge cut, never leaves a disconnected part, and stays
inside the weight-balance corridor whenever no move was forced by
connectivity.  Plus hand-checkable repair semantics (fragment → max shared
weight, ties toward the lighter part) and FM balance-guard cases.
"""

import numpy as np
import pytest

from repro.core import (
    balance_corridor,
    edge_cut,
    partition_metrics,
    refine_boundary,
    repair_components,
    repair_refine,
    run_post_stages,
)
from repro.mesh import build_csr, grid_graph_2d

# Property tests run under hypothesis when the dev dependency is present
# (requirements-dev.txt); otherwise the same invariant checks run over a
# deterministic parameter grid, so the invariants are exercised either way.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without dev deps
    HAVE_HYPOTHESIS = False

SETTINGS = dict(max_examples=25, deadline=None)

_GRID = [
    (16, 0, 2, 0), (23, 9, 3, 7), (35, 20, 4, 11), (48, 31, 5, 3),
    (64, 45, 2, 19), (80, 60, 3, 23), (57, 12, 4, 29), (72, 50, 5, 31),
]


def _property(func):
    """@given when hypothesis is available, else a fixed parameter grid."""
    if HAVE_HYPOTHESIS:
        return settings(**SETTINGS)(given(
            n=st.integers(16, 80),
            extra=st.integers(0, 60),
            nparts=st.integers(2, 5),
            seed=st.integers(0, 1000),
        )(func))
    return pytest.mark.parametrize("n,extra,nparts,seed", _GRID)(func)


def random_connected_graph(n: int, extra_edges: int, seed: int):
    """Random spanning tree + extra random edges: connected by construction."""
    rng = np.random.default_rng(seed)
    attach = rng.integers(0, np.arange(1, n))  # node i attaches below i
    src = np.arange(1, n, dtype=np.int64)
    dst = attach.astype(np.int64)
    if extra_edges:
        es = rng.integers(0, n, extra_edges)
        ed = rng.integers(0, n, extra_edges)
        src = np.concatenate([src, es])
        dst = np.concatenate([dst, ed])
    w = rng.integers(1, 5, src.size).astype(np.float64)
    return build_csr(src, dst, n, weights=w)


@_property
def test_repair_refine_invariants_random_connected(n, extra, nparts, seed):
    """Cut non-increasing, zero disconnected parts, balance corridor held
    (when no connectivity-forced move occurred) — from arbitrary labels."""
    g = random_connected_graph(n, extra, seed)
    rng = np.random.default_rng(seed + 1)
    parts = rng.integers(0, nparts, n).astype(np.int64)
    # every part nonempty so the label domain is 0..nparts-1 throughout
    parts[rng.choice(n, nparts, replace=False)] = np.arange(nparts)
    w = rng.integers(1, 4, n).astype(np.float64)
    tol = 0.1
    cut0 = edge_cut(g, parts)
    part_w0 = np.bincount(parts, weights=w, minlength=nparts)

    out, stats = repair_refine(g, parts, nparts, weights=w, balance_tol=tol)

    assert stats.cut_after <= cut0 + 1e-9
    assert stats.cut_after == pytest.approx(edge_cut(g, out))
    pm = partition_metrics(g, out, nparts, weights=w)
    assert pm.disconnected_parts == 0
    assert pm.component_count == nparts
    # the balance corridor is [min(floor, initial min), max(cap, initial
    # max)]; only connectivity-forced fragment moves may step outside it
    part_w = np.bincount(out, weights=w, minlength=nparts)
    cap = max((1 + tol) * part_w0.mean(), part_w0.max())
    if stats.forced_moves == 0:
        assert part_w.max() <= cap + 1e-9
    # labels still cover 0..nparts-1
    assert set(np.unique(out)) == set(range(nparts))


@_property
def test_refine_alone_never_worsens(n, extra, nparts, seed):
    g = random_connected_graph(n, extra, seed)
    rng = np.random.default_rng(seed)
    parts = rng.integers(0, nparts, n).astype(np.int64)
    parts[rng.choice(n, nparts, replace=False)] = np.arange(nparts)
    cut0 = edge_cut(g, parts)
    out, stats = refine_boundary(g, parts, nparts)
    assert edge_cut(g, out) <= cut0 + 1e-9
    for s in stats.sweeps:
        assert s.cut_after <= s.cut_before + 1e-9


@_property
def test_kway_stage_invariants_random_connected(n, extra, nparts, seed):
    """The "kway" chain obeys the same contract as the greedy chain: cut
    non-increasing, zero disconnected parts, corridor held when no move
    was forced by connectivity — from arbitrary labels."""
    g = random_connected_graph(n, extra, seed)
    rng = np.random.default_rng(seed + 2)
    parts = rng.integers(0, nparts, n).astype(np.int64)
    parts[rng.choice(n, nparts, replace=False)] = np.arange(nparts)
    w = rng.integers(1, 4, n).astype(np.float64)
    tol = 0.1
    cut0 = edge_cut(g, parts)
    corridor0 = balance_corridor(parts, nparts, w, tol)

    out, stats, _ = run_post_stages(g, parts, nparts, ("repair", "kway"),
                                    weights=w,
                                    post_kw=dict(balance_tol=tol))

    assert stats.cut_after <= cut0 + 1e-9
    assert stats.cut_after == pytest.approx(edge_cut(g, out))
    pm = partition_metrics(g, out, nparts, weights=w)
    assert pm.disconnected_parts == 0
    assert pm.component_count == nparts
    assert stats.corridor == pytest.approx(corridor0)
    part_w = np.bincount(out, weights=w, minlength=nparts)
    if stats.forced_moves == 0:
        assert part_w.max() <= corridor0[1] + 1e-9
    assert set(np.unique(out)) == set(range(nparts))


def test_second_best_feasible_target_moves():
    """When the best-connected target overflows the cap but a second-best
    part has positive gain and fits, the node must move there (the old
    refiner considered only argmax and skipped the node outright)."""
    # node 0 (p0): conn 5 → p1 (over cap), conn 3 → p2 (fits), internal 1
    g = build_csr(np.array([0, 0, 0, 2, 4]), np.array([1, 2, 4, 3, 5]), 6,
                  weights=np.array([1.0, 5.0, 3.0, 1.0, 1.0]))
    parts = np.array([0, 0, 1, 1, 2, 2], dtype=np.int64)
    w = np.array([1.0, 3.0, 2.0, 2.0, 1.0, 1.0])
    # corridor: cap = max(1.05·8/3, 4) = 4 → p1 (4+1) overflows, p2 (2+1)
    # fits; gains: +4 to p1 (infeasible), +2 to p2 (feasible)
    out, stats = refine_boundary(g, parts, 3, weights=w, balance_tol=0.05)
    assert out[0] == 2
    assert stats.moves_applied == 1
    assert edge_cut(g, out) == 6.0  # 8 − the applied gain of 2


def test_corridor_fixed_across_chained_stages():
    """A cap-exceeding forced repair move must NOT widen the corridor the
    later stages enforce: every stage in one chain records the corridor
    computed from the chain's INITIAL part weights."""
    # fragment: node 5 labeled p0 but only adjacent to p1 = {3, 4}, which
    # sits exactly at the cap → repair's move is forced over the cap
    g = build_csr(np.array([0, 5, 3, 6]), np.array([1, 3, 4, 7]), 8)
    parts = np.array([0, 0, 2, 1, 1, 0, 2, 2], dtype=np.int64)
    w = np.array([1.0, 1.0, 1e-4, 1.5, 1.5, 1.0, 1.0, 1.0])
    corridor0 = balance_corridor(parts, 3, w, 0.05)

    out, stats, recs = run_post_stages(g, parts, 3, ("repair", "refine"),
                                       weights=w)

    assert stats.forced_moves == 1       # the fragment move exceeded cap
    assert out[5] == 1
    corridors = [r.info["corridor"] for r in recs]
    assert corridors[0] == pytest.approx(corridor0)
    # the widened post-repair weights must not leak into later stages
    assert corridors[1] == corridors[0]
    assert stats.corridor == pytest.approx(corridor0)


def test_repair_reassigns_to_max_shared_weight():
    """A fragment goes to the neighbor part sharing the most edge weight."""
    # path 0-1-2-3-4-5; parts: [0,0,1,1,2,2] but node 0 mislabeled as 2:
    # part 2 = {0,4,5} is disconnected (fragment {0}).
    g = build_csr(np.array([0, 1, 2, 3, 4]), np.array([1, 2, 3, 4, 5]), 6,
                  weights=np.array([3.0, 1.0, 1.0, 1.0, 1.0]))
    parts = np.array([2, 0, 1, 1, 2, 2], dtype=np.int64)
    out, stats = repair_components(g, parts, 3)
    assert stats.fragments_repaired == 1
    assert out[0] == 0          # only neighbor part via the weight-3 edge
    assert edge_cut(g, out) < edge_cut(g, parts)
    assert partition_metrics(g, out, 3).disconnected_parts == 0


def test_repair_tie_breaks_to_lighter_part():
    """Equal shared weight → the lighter destination part wins."""
    # Node 0 is a fragment of part 2 (part 2's kept component is the
    # heavier anchor {5, 6}), with one unit edge into part 0 and one into
    # part 1 — an exact tie on shared weight.  Node weights make part 0
    # (10) heavier than part 1 (2), so the tie-break sends 0 to part 1.
    g = build_csr(np.array([0, 0, 5]), np.array([1, 2, 6]), 7)
    parts = np.array([2, 0, 1, 0, 1, 2, 2], dtype=np.int64)
    w = np.array([1.0, 5.0, 1.0, 5.0, 1.0, 1.0, 1.0])
    out, stats = repair_components(g, parts, 3, weights=w)
    assert out[0] == 1
    assert stats.fragments_repaired == 1


def test_refine_respects_balance_cap():
    """FM never moves past the weight corridor even for positive gain."""
    # two triangles joined by a heavy bridge: moving the bridge endpoint
    # would improve the cut but overfill part 1
    src = np.array([0, 1, 2, 3, 4, 5, 2])
    dst = np.array([1, 2, 0, 4, 5, 3, 3])
    w = np.array([1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 10.0])
    g = build_csr(src, dst, 6, weights=w)
    parts = np.array([0, 0, 0, 1, 1, 1], dtype=np.int64)
    out, stats = refine_boundary(g, parts, 2, balance_tol=0.05)
    # cap = 3.15 nodes' weight: any single move to either side violates it
    assert stats.moves_applied == 0
    np.testing.assert_array_equal(out, parts)


def test_refine_never_empties_a_part():
    g = grid_graph_2d(4, 4)
    parts = np.zeros(16, dtype=np.int64)
    parts[5] = 1  # single interior node: every edge is cut, gain positive
    out, _ = refine_boundary(g, parts, 2, balance_tol=10.0)
    assert set(np.unique(out)) == {0, 1}


def test_repair_leaves_global_islands_alone():
    """A fragment with no foreign edges (disconnected input graph) stays."""
    g = build_csr(np.array([0, 2]), np.array([1, 3]), 6)
    # nodes 4, 5 isolated; part 0 = {0,1,4}, part 1 = {2,3,5}
    parts = np.array([0, 0, 1, 1, 0, 1], dtype=np.int64)
    out, stats = repair_components(g, parts, 2)
    np.testing.assert_array_equal(out, parts)
    assert stats.fragments_repaired == 0


def test_sweep_records_track_cut():
    g = grid_graph_2d(12, 12)
    rng = np.random.default_rng(3)
    parts = (np.arange(144) // 72).astype(np.int64)
    flip = rng.choice(144, 20, replace=False)
    parts[flip] = 1 - parts[flip]
    out, stats = refine_boundary(g, parts, 2, sweeps=6)
    assert stats.sweeps, "expected at least one sweep record"
    assert stats.sweeps[0].cut_before == edge_cut(g, parts)
    assert stats.sweeps[-1].cut_after == edge_cut(g, out)
    assert stats.cut_after <= stats.cut_before
