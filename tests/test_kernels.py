"""Pallas kernels vs pure-jnp oracles: shape × dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ell_spmv.ops import ell_spmv, ell_spmv_batched, lap_apply
from repro.kernels.ell_spmv.ref import ell_spmv_batched_ref, ell_spmv_ref, lap_apply_ref
from repro.kernels.embedding_bag.ops import embedding_bag as eb_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("n,w", [(128, 4), (256, 27), (1000, 8), (4096, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmv_sweep(n, w, dtype):
    cols = jnp.asarray(RNG.integers(0, n, (n, w)), jnp.int32)
    vals = jnp.asarray(RNG.normal(size=(n, w)), dtype)
    x = jnp.asarray(RNG.normal(size=(n,)), dtype)
    out = ell_spmv(cols, vals, x, prefer="pallas")
    ref = ell_spmv_ref(cols.T, vals.T, x)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_lap_apply_kernel_matches_ref():
    n, w = 512, 6
    cols = jnp.asarray(RNG.integers(0, n, (n, w)), jnp.int32)
    vals = jnp.asarray(np.abs(RNG.normal(size=(n, w))), jnp.float32)
    diag = jnp.asarray(np.asarray(vals).sum(1))
    x = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    out = lap_apply(cols, vals, diag, x, prefer="pallas")
    ref = lap_apply_ref(cols.T, vals.T, diag, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,n,w", [(2, 256, 8), (3, 1000, 5), (4, 128, 27),
                                   (1, 512, 6)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_spmv_batched_sweep(B, n, w, dtype):
    cols = jnp.asarray(RNG.integers(0, n, (B, n, w)), jnp.int32)
    vals = jnp.asarray(RNG.normal(size=(B, n, w)), dtype)
    x = jnp.asarray(RNG.normal(size=(B, n)), dtype)
    out = ell_spmv_batched(cols, vals, x, prefer="pallas")
    ref = ell_spmv_batched_ref(cols.swapaxes(-1, -2), vals.swapaxes(-1, -2), x)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_batched_laplacian_kernel_matches_fallback():
    """Regression for the silent `use_kernel=True` no-op on batched
    (ndim==3) EllLaplacian operators: the kernel and pure-jnp paths must
    agree on real padded engine operators."""
    import dataclasses

    from repro.core.laplacian import ell_laplacian_batched
    from repro.mesh import grid_graph_2d

    graphs = [grid_graph_2d(16, 16), grid_graph_2d(10, 20)]
    op = ell_laplacian_batched(graphs, 256, 8, 2)
    opk = dataclasses.replace(op, use_kernel=True)
    x = jnp.asarray(RNG.normal(size=(2, 256)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(op.apply(x)), np.asarray(opk.apply(x)), atol=2e-5
    )


def test_batched_inverse_kernel_path_matches_oracle():
    """use_kernel=True on the batched inverse path (3-D operators through
    the batched Pallas grid) reaches the same Fiedler eigenvalue."""
    from repro.core import fiedler_from_graph_batched, fiedler_oracle_np
    from repro.mesh import grid_graph_2d

    g = grid_graph_2d(18, 24)
    lam, _ = fiedler_oracle_np(g)
    res = fiedler_from_graph_batched([g], method="inverse", tol=1e-4,
                                     use_kernel=True)[0]
    assert res.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)


def test_ell_kernel_used_by_fiedler():
    """use_kernel=True path of the ELL Laplacian reaches the same Fiedler
    eigenvalue as the jnp path."""
    from repro.core import fiedler_from_graph, fiedler_oracle_np
    from repro.mesh import grid_graph_2d

    g = grid_graph_2d(18, 12)
    lam, _ = fiedler_oracle_np(g)
    res = fiedler_from_graph(g, method="lanczos", tol=1e-4, use_kernel=True)
    assert res.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)


@pytest.mark.parametrize("V,d,nnz,B", [(100, 16, 64, 10), (500, 50, 300, 32),
                                       (64, 128, 128, 8)])
def test_embedding_bag_sweep(V, d, nnz, B):
    dtype = jnp.float32
    table = jnp.asarray(RNG.normal(size=(V, d)), dtype)
    idx = jnp.asarray(RNG.integers(0, V, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(RNG.integers(0, B, nnz)), jnp.int32)
    out = eb_kernel(table, idx, seg, B, prefer="pallas")
    ref = embedding_bag_ref(table, idx, seg, B)
    visited = np.zeros(B, bool)
    visited[np.asarray(seg)] = True
    np.testing.assert_allclose(
        np.asarray(out)[visited], np.asarray(ref)[visited], atol=1e-4
    )


def test_embedding_bag_weighted_and_unsorted():
    V, d, nnz, B = 80, 24, 100, 12
    table = jnp.asarray(RNG.normal(size=(V, d)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, V, nnz), jnp.int32)
    seg = jnp.asarray(RNG.integers(0, B, nnz), jnp.int32)  # UNsorted
    wgt = jnp.asarray(RNG.normal(size=nnz), jnp.float32)
    out = eb_kernel(table, idx, seg, B, weights=wgt, assume_sorted=False,
                    prefer="pallas")
    ref = embedding_bag_ref(table, idx, seg, B, weights=wgt)
    visited = np.zeros(B, bool)
    visited[np.asarray(seg)] = True
    np.testing.assert_allclose(
        np.asarray(out)[visited], np.asarray(ref)[visited], atol=1e-4
    )


@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,D",
    [
        (2, 64, 64, 4, 2, 32),
        (1, 100, 100, 4, 4, 64),
        (2, 1, 200, 8, 2, 64),    # decode shape
        (1, 128, 256, 4, 1, 32),  # continuation chunk
        (1, 48, 48, 2, 2, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, Hkv, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          prefer="pallas")
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.normal(size=(2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 96, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 96, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          prefer="pallas")
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_matches_model_attention():
    """Kernel ≡ the model's blocked_attention (same contraction)."""
    from repro.models.transformer import blocked_attention

    B, S, H, D = 2, 64, 4, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_model = blocked_attention(q, k, v, q_pos=pos, block_q=16, block_kv=16)
    out_kernel = flash_attention(q, k, v, causal=True, block_q=16,
                                 block_k=16, prefer="pallas")
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_kernel),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# segment_sum: the (boundary × nparts) connection table
# ---------------------------------------------------------------------------

def _conn_numpy(labels, cols, wts, nparts):
    """Independent numpy oracle (np.add.at scatter)."""
    out = np.zeros((cols.shape[0], nparts), np.float32)
    ri, ki = np.nonzero(np.ones_like(np.asarray(wts), bool))
    np.add.at(out, (ri, np.asarray(labels)[np.asarray(cols)[ri, ki]]),
              np.asarray(wts)[ri, ki])
    return out


@pytest.mark.parametrize("B,w,m,nparts", [
    (37, 5, 120, 13),     # odd everything
    (8, 1, 9, 1),         # single part, single slot
    (256, 27, 300, 64),   # block-aligned
    (130, 3, 200, 129),   # nparts just past one lane tile
    (5, 4, 16, 2),        # tiny
])
def test_segment_sum_parity(B, w, m, nparts):
    from repro.kernels.segment_sum.ops import connection_table

    labels = jnp.asarray(RNG.integers(0, nparts, m), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, m, (B, w)), jnp.int32)
    wts = jnp.asarray(RNG.integers(1, 5, (B, w)), jnp.float32)
    oracle = _conn_numpy(labels, cols, wts, nparts)
    for prefer in ("pallas", "ref", "auto"):
        out = connection_table(labels, cols, wts, nparts, prefer=prefer)
        np.testing.assert_array_equal(np.asarray(out), oracle), prefer


def test_segment_sum_empty_boundary():
    from repro.kernels.segment_sum.ops import connection_table

    labels = jnp.zeros((7,), jnp.int32)
    out = connection_table(labels, jnp.zeros((0, 4), jnp.int32),
                           jnp.zeros((0, 4), jnp.float32), 7)
    assert out.shape == (0, 7)


def test_segment_sum_padding_is_inert():
    """Weight-0 padding entries contribute nothing regardless of col."""
    from repro.kernels.segment_sum.ops import connection_table

    labels = jnp.asarray([0, 1, 2, 1], jnp.int32)
    cols = jnp.asarray([[1, 3, 0], [2, 0, 0]], jnp.int32)
    wts = jnp.asarray([[2.0, 5.0, 0.0], [3.0, 0.0, 0.0]], jnp.float32)
    for prefer in ("pallas", "ref"):
        out = np.asarray(connection_table(labels, cols, wts, 3,
                                          prefer=prefer))
        np.testing.assert_array_equal(out, [[0.0, 7.0, 0.0],
                                            [0.0, 0.0, 3.0]])


@pytest.mark.parametrize("G,B,w,m,nparts", [(3, 40, 6, 90, 9),
                                            (1, 64, 2, 30, 4),
                                            (5, 17, 3, 50, 33)])
def test_segment_sum_batched_parity(G, B, w, m, nparts):
    """Batched launch ≡ per-problem single launches ≡ numpy oracle."""
    from repro.kernels.segment_sum.ops import (connection_table,
                                               connection_table_batched)

    labels = jnp.asarray(RNG.integers(0, nparts, (G, m)), jnp.int32)
    cols = jnp.asarray(RNG.integers(0, m, (G, B, w)), jnp.int32)
    wts = jnp.asarray(RNG.integers(1, 5, (G, B, w)), jnp.float32)
    for prefer in ("pallas", "ref"):
        out = np.asarray(connection_table_batched(labels, cols, wts, nparts,
                                                  prefer=prefer))
        for g in range(G):
            single = connection_table(labels[g], cols[g], wts[g], nparts,
                                      prefer=prefer)
            np.testing.assert_array_equal(out[g], np.asarray(single))
            np.testing.assert_array_equal(
                out[g], _conn_numpy(labels[g], cols[g], wts[g], nparts))
