"""Paper §5: gather-scatter Laplacian ≡ assembled Laplacian (claim C7)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    aw_apply,
    dense_laplacian_np,
    gs_apply,
    gs_setup,
    unweighted_laplacian,
    weighted_laplacian,
)
from repro.mesh import box_mesh, dual_graph, pebble_mesh
from repro.mesh.graphs import build_csr


def _dense_unweighted(g):
    gu = build_csr(g.rows, g.indices, g.n, weights=np.ones(g.nnz),
                   symmetrize=False, sum_duplicates=False)
    return dense_laplacian_np(gu)


@pytest.mark.parametrize("dims", [(2, 2, 2), (4, 4, 3), (5, 3, 2)])
def test_weighted_gs_matches_dense(dims):
    m = box_mesh(*dims)
    g = dual_graph(m)
    L = weighted_laplacian(m.vert_gid)
    Ld = dense_laplacian_np(g)
    x = np.random.default_rng(1).normal(size=m.nelems)
    y = np.asarray(L.apply(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(y, Ld @ x, atol=1e-3)


@pytest.mark.parametrize("dims", [(3, 3, 3), (4, 2, 3)])
def test_unweighted_gs_matches_dense(dims):
    """Inclusion-exclusion (vertex − edge + face) counts neighbors once."""
    m = box_mesh(*dims)
    g = dual_graph(m)
    L = unweighted_laplacian(m.vert_gid, m.edge_gid, m.face_gid)
    Ld = _dense_unweighted(g)
    x = np.random.default_rng(2).normal(size=m.nelems)
    y = np.asarray(L.apply(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(y, Ld @ x, atol=1e-3)


def test_carved_mesh_gs(box443):
    """Pebble meshes (carved, warped) keep GS ≡ dense."""
    m = pebble_mesh(6, 6, 6, n_pebbles=2, seed=3)
    g = dual_graph(m)
    L = weighted_laplacian(m.vert_gid)
    x = np.random.default_rng(3).normal(size=m.nelems)
    y = np.asarray(L.apply(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(y, dense_laplacian_np(g) @ x, atol=1e-3,
                               rtol=1e-4)


def test_nullspace_ones(box443):
    """L·1 = 0 — row sums vanish (the paper's singleton cancellation)."""
    L = weighted_laplacian(box443.vert_gid)
    ones = jnp.ones((box443.nelems,), jnp.float32)
    assert float(jnp.abs(L.apply(ones)).max()) < 1e-3


def test_gs_qqt_idempotent_structure(box443):
    """Qᵀ then Q: summed values are copied back equal on shared vertices."""
    h = gs_setup(box443.vert_gid)
    u = jnp.asarray(
        np.random.default_rng(0).normal(size=box443.vert_gid.shape), jnp.float32
    )
    w = gs_apply(h, u)
    # entries with the same gid must be identical after QQᵀ
    flat_g = np.asarray(h.gid).ravel()
    flat_w = np.asarray(w).ravel()
    for g in np.unique(flat_g)[:50]:
        vals = flat_w[flat_g == g]
        assert np.allclose(vals, vals[0], atol=1e-4)


def test_gs_linearity(box443):
    h = gs_setup(box443.vert_gid)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=box443.nelems), jnp.float32)
    y = jnp.asarray(rng.normal(size=box443.nelems), jnp.float32)
    lhs = aw_apply(h, 2.0 * x + 3.0 * y)
    rhs = 2.0 * aw_apply(h, x) + 3.0 * aw_apply(h, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-3)


def test_laplacian_symmetry_psd(box443):
    """xᵀLy = yᵀLx and xᵀLx ≥ 0 (Laplacian is symmetric PSD)."""
    L = weighted_laplacian(box443.vert_gid)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=box443.nelems), jnp.float32)
    y = jnp.asarray(rng.normal(size=box443.nelems), jnp.float32)
    xy = float(jnp.vdot(x, L.apply(y)))
    yx = float(jnp.vdot(y, L.apply(x)))
    assert abs(xy - yx) < 1e-2 * max(abs(xy), 1.0)
    assert float(jnp.vdot(x, L.apply(x))) >= -1e-3
