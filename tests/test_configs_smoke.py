"""Per-architecture smoke tests: REDUCED config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement).  The FULL
configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_arch
from repro.data.synthetic import gnn_full_batch, lm_batch, molecule_batches
from repro.mesh.graphs import rmat_graph
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

OPT = AdamWConfig(lr=1e-3, weight_decay=0.0)

LM_ARCHS = ["deepseek-moe-16b", "qwen3-moe-30b-a3b", "mistral-large-123b",
            "tinyllama-1.1b", "command-r-35b"]
GNN_ARCHS = ["mace", "nequip", "graphcast", "meshgraphnet"]


def _one_step(loss_fn, params):
    opt = adamw_init(params)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, gnorm = adamw_update(OPT, grads, opt, params)
    return float(loss), float(gnorm), params


def test_registry_complete():
    assert len(REGISTRY) == 10
    for arch in REGISTRY.values():
        assert arch.shapes, arch.arch_id


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    from repro.models.transformer import forward, init_params, loss_fn

    cfg = get_arch(arch_id).make_smoke_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_batch(np.random.default_rng(0), 2, 16, cfg.vocab)
    logits = forward(cfg, params, batch["tokens"])
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, gnorm, _ = _one_step(lambda p: loss_fn(cfg, p, batch), params)
    assert np.isfinite(loss) and gnorm > 0


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_decode(arch_id):
    from repro.models.transformer import decode_step, init_params, prefill

    cfg = get_arch(arch_id).make_smoke_config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = prefill(cfg, params, toks)
    assert logits.shape == (2, 1, cfg.vocab)
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
             for k, v in cache.items()}
    nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    dl, cache = decode_step(cfg, params, cache, nxt, jnp.int32(8))
    assert dl.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(dl).any())


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
def test_gnn_smoke(arch_id):
    cfg = get_arch(arch_id).make_smoke_config()
    key = jax.random.PRNGKey(0)
    if arch_id in ("mace", "nequip"):
        batch = next(molecule_batches(4, 8, 16, seed=1))
        if arch_id == "mace":
            from repro.models.gnn.mace import init_mace, mace_energy, mace_loss

            params = init_mace(cfg, key)
            e = mace_energy(cfg, params, batch)
            loss_fn = lambda p: mace_loss(cfg, p, batch)
        else:
            from repro.models.gnn.nequip import (init_nequip, nequip_energy,
                                                 nequip_loss)

            params = init_nequip(cfg, key)
            e = nequip_energy(cfg, params, batch)
            loss_fn = lambda p: nequip_loss(cfg, p, batch)
        assert e.shape == (4,)
        assert not bool(jnp.isnan(e).any())
    else:
        g = rmat_graph(60, 240, seed=2)
        if arch_id == "graphcast":
            from repro.models.gnn.graphcast import (graphcast_forward,
                                                    graphcast_loss,
                                                    init_graphcast)

            batch = gnn_full_batch(g, d_feat=cfg.d_in, d_out=cfg.n_vars, seed=3)
            params = init_graphcast(cfg, key)
            out = graphcast_forward(cfg, params, batch)
            assert out.shape == (60, cfg.n_vars)
            loss_fn = lambda p: graphcast_loss(cfg, p, batch)
        else:
            from repro.models.gnn.meshgraphnet import (init_mgn, mgn_forward,
                                                       mgn_loss)

            batch = gnn_full_batch(g, d_feat=cfg.d_in, d_out=cfg.d_out, seed=3)
            params = init_mgn(cfg, key)
            out = mgn_forward(cfg, params, batch)
            assert out.shape == (60, cfg.d_out)
            loss_fn = lambda p: mgn_loss(cfg, p, batch)
        assert not bool(jnp.isnan(out).any())
    loss, gnorm, _ = _one_step(loss_fn, params)
    assert np.isfinite(loss) and gnorm > 0


def test_recsys_smoke():
    from repro.data.synthetic import recsys_batches
    from repro.models.recsys import (init_sasrec, sasrec_score_candidates,
                                     sasrec_train_loss)

    cfg = get_arch("sasrec").make_smoke_config()
    params = init_sasrec(cfg, jax.random.PRNGKey(0))
    batch = next(recsys_batches(4, cfg.seq_len, cfg.n_items, seed=0))
    loss, gnorm, _ = _one_step(lambda p: sasrec_train_loss(cfg, p, batch),
                               params)
    assert np.isfinite(loss) and gnorm > 0
    scores = sasrec_score_candidates(cfg, params, batch["item_seq"],
                                     jnp.arange(50))
    assert scores.shape == (4, 50)
    assert not bool(jnp.isnan(scores).any())


def test_all_cells_enumerate():
    """40 assigned cells = 20 LM (5 skips noted) + 16 GNN + 4 recsys."""
    from repro.configs import all_cells

    cells = list(all_cells())
    assert len(cells) == 40
    skips = [c for c in cells if c[3] is not None]
    assert len(skips) == 5  # long_500k × 5 pure-full-attention LM archs
    for a, s, _, reason in skips:
        assert s == "long_500k" and "full-attention" in reason
