"""Level-synchronous batched RSB engine: parity with the recursive engine
(balance at every level, cut quality, batched-entry-point equivalence)."""

import numpy as np
import pytest

from repro.core import (
    fiedler_from_graph,
    fiedler_from_graph_batched,
    fiedler_from_mesh,
    fiedler_from_mesh_batched,
    fiedler_oracle_np,
    partition,
    partition_metrics,
    rsb_partition_graph,
    rsb_partition_mesh,
)
from repro.core.rsb import _node_seed
from repro.mesh import (
    box_mesh,
    dual_graph,
    extract_subgraphs,
    grid_graph_2d,
    pebble_mesh,
)


@pytest.fixture(scope="module")
def box():
    m = box_mesh(8, 8, 4)
    return m, dual_graph(m)


@pytest.fixture(scope="module")
def pebble():
    m = pebble_mesh(10, 10, 10, n_pebbles=4, warp=0.1, seed=2)
    return m, dual_graph(m)


def _ancestor_balance_ok(parts, nparts):
    """Eq. 2.6 at EVERY level: for power-of-two nparts, the level-l ancestor
    of part p is p >> (k - l); each level's groups must be within one
    element (unit weights)."""
    k = int(np.log2(nparts))
    for level in range(k + 1):
        anc = parts >> (k - level)
        counts = np.bincount(anc, minlength=1 << level)
        if counts.max() - counts.min() > 1:
            return False
    return True


@pytest.mark.parametrize("engine", ["batched", "recursive"])
def test_balance_every_level(box, engine):
    m, _ = box
    for nparts in (4, 8, 16):
        parts, _ = rsb_partition_mesh(
            m, nparts, tol=1e-2, max_restarts=10, engine=engine
        )
        assert _ancestor_balance_ok(parts, nparts), (engine, nparts)
    # non-power-of-two still balances overall
    parts, _ = rsb_partition_mesh(m, 3, tol=1e-2, max_restarts=10, engine=engine)
    counts = np.bincount(parts, minlength=3)
    assert counts.max() - counts.min() <= 1


def test_engine_cut_parity_box(box):
    m, g = box
    pb, rb = rsb_partition_mesh(m, 8, tol=1e-3, engine="batched")
    pr, rr = rsb_partition_mesh(m, 8, tol=1e-3, engine="recursive")
    cb = partition_metrics(g, pb, 8).edge_cut
    cr = partition_metrics(g, pr, 8).edge_cut
    assert cb <= 1.05 * cr and cr <= 1.05 * cb
    assert rb.engine == "batched" and rr.engine == "recursive"


def test_engine_cut_parity_pebble(pebble):
    m, g = pebble
    pb, _ = rsb_partition_mesh(m, 8, tol=1e-3, engine="batched")
    pr, _ = rsb_partition_mesh(m, 8, tol=1e-3, engine="recursive")
    cb = partition_metrics(g, pb, 8).edge_cut
    cr = partition_metrics(g, pr, 8).edge_cut
    assert cb <= 1.05 * cr and cr <= 1.05 * cb


def test_engine_cut_parity_graph(pebble):
    m, g = pebble
    pb, _ = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                engine="batched")
    pr, _ = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                engine="recursive")
    cb = partition_metrics(g, pb, 8).edge_cut
    cr = partition_metrics(g, pr, 8).edge_cut
    assert cb <= 1.05 * cr and cr <= 1.05 * cb


def test_batched_graph_entry_matches_unbatched_on_singleton():
    g = grid_graph_2d(20, 20)  # 400 nodes: above the dense cutoff
    r1 = fiedler_from_graph(g, method="lanczos", seed=7, tol=1e-4)
    rb = fiedler_from_graph_batched([g], seeds=[7], tol=1e-4)[0]
    assert rb.eigenvalue == pytest.approx(r1.eigenvalue, rel=1e-3)
    cos = abs(np.dot(r1.vector, rb.vector)) / (
        np.linalg.norm(r1.vector) * np.linalg.norm(rb.vector)
    )
    assert cos > 0.999
    assert rb.iterations == r1.iterations


def test_batched_mesh_entry_matches_unbatched_on_singleton():
    # 8×6×4: all axes distinct → simple λ₂.  A square cross-section (8×8×4)
    # has an exactly degenerate λ₂ eigenspace whose orientation inside the
    # Ritz problem is set by fp noise — the two entry points then return
    # different (both valid) members and a vector comparison is
    # meaningless (paper §9).
    m = box_mesh(8, 6, 4)
    r1 = fiedler_from_mesh(m.vert_gid, method="lanczos", seed=3, tol=1e-3)
    rb = fiedler_from_mesh_batched([m.vert_gid], seeds=[3], tol=1e-3)[0]
    assert rb.eigenvalue == pytest.approx(r1.eigenvalue, rel=1e-3)
    cos = abs(np.dot(r1.vector, rb.vector)) / (
        np.linalg.norm(r1.vector) * np.linalg.norm(rb.vector)
    )
    assert cos > 0.999


def test_batched_entry_multiproblem_matches_oracle():
    """A heterogeneous batch: every packed subproblem must match its own
    dense eigenpair (no cross-problem coupling through the packing)."""
    graphs = [grid_graph_2d(20, 20), grid_graph_2d(16, 25),
              grid_graph_2d(24, 14)]
    results = fiedler_from_graph_batched(graphs, tol=1e-4, max_restarts=80)
    for g, r in zip(graphs, results):
        lam, _ = fiedler_oracle_np(g)
        assert r.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)


def test_batched_inverse_entry_matches_oracle():
    g = grid_graph_2d(20, 20)
    r = fiedler_from_graph_batched([g], method="inverse", tol=1e-4)[0]
    lam, _ = fiedler_oracle_np(g)
    assert r.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)
    assert r.method == "inverse"


@pytest.mark.parametrize("dims", [(16, 25), (14, 15)])
def test_inverse_gram_breakdown_regression(dims):
    """Regression: near-duplicate projection-window iterates made the fp32
    Gram singular (the old absolute 1e-12 ridge is below fp32 epsilon) and
    NaN vectors were reported as converged — in BOTH inverse paths.

    multilevel=False pins the original cold-noise-start scenario the ridge
    regression was observed under; the multilevel path is covered by
    test_multilevel.py (near-degenerate pairs converge to an eigenvector
    of the low cluster, not necessarily y₂ — paper §9)."""
    g = grid_graph_2d(*dims)
    lam, _ = fiedler_oracle_np(g)
    rb = fiedler_from_graph_batched([g], method="inverse", tol=1e-4,
                                    multilevel=False)[0]
    ru = fiedler_from_graph(g, method="inverse", tol=1e-4, multilevel=False)
    for r in (rb, ru):
        assert np.isfinite(r.vector).all()
        # loose eigenvalue check: the guarded early stop may accept a
        # slightly coarser iterate; the point is finite-and-sane, not tight
        assert r.eigenvalue == pytest.approx(lam, rel=5e-2, abs=1e-4)


def test_batched_dense_tail_matches_unbatched():
    g = grid_graph_2d(8, 8)  # below the dense cutoff
    r1 = fiedler_from_graph(g, tol=1e-4)
    rb = fiedler_from_graph_batched([g], tol=1e-4)[0]
    assert rb.method == "dense"
    np.testing.assert_allclose(rb.vector, r1.vector)


def test_extract_subgraphs_matches_sub(pebble):
    _, g = pebble
    rng = np.random.default_rng(0)
    perm = rng.permutation(g.n)
    lo, hi = perm[: g.n // 2], perm[g.n // 2:]
    g_lo, g_hi = extract_subgraphs(g, [lo, hi])
    for got, idx in ((g_lo, lo), (g_hi, hi)):
        ref = g.sub(idx)
        assert got.n == ref.n
        np.testing.assert_array_equal(got.indptr, ref.indptr)
        np.testing.assert_array_equal(got.indices, ref.indices)
        np.testing.assert_allclose(got.weights, ref.weights)


def test_level_records(box):
    m, _ = box
    _, rep = rsb_partition_mesh(m, 8, tol=1e-3, engine="batched")
    assert rep.levels, "batched engine must emit per-level records"
    assert [L.level for L in rep.levels] == list(range(len(rep.levels)))
    assert all(L.n_nodes >= 1 and L.solve_seconds >= 0 for L in rep.levels)
    # every level covers all elements still being split
    assert rep.levels[0].total_size == m.nelems
    _, rep_r = rsb_partition_mesh(m, 8, tol=1e-3, engine="recursive")
    assert rep_r.levels and rep_r.levels[0].n_nodes == 1


def test_sibling_seeds_differ():
    """Regression: `seed + level` gave every sibling the same start vector."""
    seeds = {_node_seed(0, 3, p_lo) for p_lo in range(8)}
    assert len(seeds) == 8
    assert _node_seed(1, 2, 4) != _node_seed(0, 2, 4)


def test_graph_warm_start_plumbed(pebble):
    """warm_start on the graph path matches the mesh path's behaviour:
    no more restarts than a cold noise start, same balance.  The cold
    reference disables the multilevel warm start (which is itself a warm
    start and would beat the geometric one — see test_multilevel.py)."""
    m, g = pebble
    _, rep_cold = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                      warm_start=False, multilevel=False)
    p_warm, rep_warm = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                           warm_start=True, multilevel=False)
    assert rep_warm.total_iterations <= rep_cold.total_iterations
    counts = np.bincount(p_warm, minlength=8)
    assert counts.max() - counts.min() <= 1


def test_partition_front_door_engine_flag(box):
    # refine="none" pins the raw driver labels (the ≤1-element invariant is
    # the bisector's; the default repair/refine post stage trades up to
    # balance_tol of it for cut — covered in test_pipeline).
    m, g = box
    pb = partition(m, 4, partitioner="rsb", engine="batched", tol=1e-2,
                   max_restarts=10, refine="none")
    pr = partition(m, 4, partitioner="rsb", engine="recursive", tol=1e-2,
                   max_restarts=10, refine="none")
    for p in (pb, pr):
        counts = np.bincount(p, minlength=4)
        assert counts.max() - counts.min() <= 1
    # default (refined) front door: balance within the post-stage corridor
    pd = partition(m, 4, partitioner="rsb", engine="batched", tol=1e-2,
                   max_restarts=10)
    counts = np.bincount(pd, minlength=4)
    assert counts.max() <= 1.06 * counts.mean()
    with pytest.raises(ValueError):
        rsb_partition_mesh(m, 4, engine="nope")
