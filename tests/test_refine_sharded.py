"""Device-resident sharded refinement (repro.dist.refine_sharded).

Invariants: bit-parity of the shard_map sweep loop against the NumPy host
mirror on seeded meshes (integer weights ⇒ f32 sums are exact ⇒ identical
labels), cut monotone per sweep, balance corridor held on globally reduced
part weights, zero disconnected parts after the closing repair, sharded
cut within 1% of the host FM refiner, exactly one boundary-label
all_gather per sweep (trace counters), and the guard fallback path.  The
8-device behaviour runs in a subprocess via the ``multi_device_run``
conftest fixture (the main test process keeps 1 device).
"""

import numpy as np
import pytest

from repro import obs
from repro.core import balance_corridor, edge_cut, partition_metrics, refine_boundary
from repro.core.pipeline import PartitionPipeline, parse_refine
from repro.dist.refine_sharded import (
    build_frontier_plan,
    kway_sharded_stage,
    refine_sharded_host,
    refine_sharded_stage,
    run_sharded_sweeps,
)
from repro.mesh import box_mesh, build_csr


def _seeded_case(mesh, nparts, seed, frac=0.12):
    """RCB partition + a seeded perturbation: refinement has real work and
    the corridor (widened to the perturbed state) has slack."""
    ctx = PartitionPipeline(bisect="rcb", post=()).run(mesh, nparts)
    g = ctx.require_graph()
    rng = np.random.default_rng(seed)
    parts = ctx.parts.copy()
    sel = rng.random(g.n) < frac
    parts[sel] = rng.integers(0, nparts, sel.sum())
    corr = balance_corridor(parts, nparts, ctx.weights, 0.05)
    return g, parts, ctx.weights, corr


CASES = [(box_mesh(8, 8, 6), 8, 3), (box_mesh(6, 6, 4), 4, 5),
         (box_mesh(9, 8, 6), 12, 7)]


@pytest.mark.parametrize("mesh,nparts,seed", CASES)
def test_device_host_bit_parity(mesh, nparts, seed):
    """shard_map sweep loop ≡ NumPy mirror, label for label."""
    g, parts, w, corr = _seeded_case(mesh, nparts, seed)
    fp = build_frontier_plan(g, parts, nparts, weights=w)
    out_d, rec_d, info_d = run_sharded_sweeps(fp, parts, nparts, sweeps=10,
                                              corridor=corr)
    out_h, rec_h, info_h = refine_sharded_host(fp, parts, nparts, sweeps=10,
                                               corridor=corr)
    assert np.array_equal(out_d, out_h)
    assert info_d["moves"] == info_h["moves"]
    assert [r.moves for r in rec_d] == [r.moves for r in rec_h]
    # the internally tracked cut (Σ fresh gains) matches the real cut
    assert info_d["cut"] == pytest.approx(edge_cut(g, out_d))


@pytest.mark.parametrize("mesh,nparts,seed", CASES)
def test_sweeps_monotone_and_corridor(mesh, nparts, seed):
    g, parts, w, corr = _seeded_case(mesh, nparts, seed)
    fp = build_frontier_plan(g, parts, nparts, weights=w)
    out, records, info = run_sharded_sweeps(fp, parts, nparts, sweeps=10,
                                            corridor=corr)
    assert info["moves"] > 0          # the perturbation left real work
    for r in records:
        assert r.cut_after <= r.cut_before + 1e-6
    pw = np.bincount(out, weights=np.asarray(w, float), minlength=nparts)
    assert pw.min() >= corr[0] - 1e-9
    assert pw.max() <= corr[1] + 1e-9
    assert set(np.unique(out)) == set(range(nparts))


@pytest.mark.parametrize("mesh,nparts,seed", CASES)
def test_cut_within_one_percent_of_host_fm(mesh, nparts, seed):
    """The acceptance gate, in-process: sharded refined cut ≤ 1.01 × the
    host FM refined cut from the same start."""
    g, parts, w, corr = _seeded_case(mesh, nparts, seed)
    host, _ = refine_boundary(g, parts.copy(), nparts, weights=w,
                              sweeps=8, corridor=corr)
    fp = build_frontier_plan(g, parts, nparts, weights=w)
    out, _, _ = run_sharded_sweeps(fp, parts, nparts, sweeps=12,
                                   corridor=corr)
    assert edge_cut(g, out) <= 1.01 * edge_cut(g, host) + 1e-9


def test_stage_zero_disconnected_parts():
    """After the closing repair, no part is disconnected — the post-chain
    contract the sharded stage must honor like the host stages."""
    g, parts, w, _ = _seeded_case(box_mesh(8, 8, 6), 8, 11, frac=0.25)
    out, stats = refine_sharded_stage(g, parts, 8, weights=w)
    pm = partition_metrics(g, out, 8, weights=w)
    assert pm.disconnected_parts == 0
    assert pm.component_count == 8
    assert stats.cut_after <= stats.cut_before + 1e-9
    assert stats.cut_after == pytest.approx(edge_cut(g, out))
    assert stats.stages[0] == "refine-sharded"


def test_kway_sharded_stage_polish():
    """kway-sharded ≤ refine-sharded cut (host polish only improves)."""
    g, parts, w, _ = _seeded_case(box_mesh(8, 8, 6), 8, 13)
    out_r, _ = refine_sharded_stage(g, parts.copy(), 8, weights=w)
    out_k, stats = kway_sharded_stage(g, parts.copy(), 8, weights=w)
    assert edge_cut(g, out_k) <= edge_cut(g, out_r) + 1e-9
    assert partition_metrics(g, out_k, 8, weights=w).disconnected_parts == 0
    assert stats.stages[0] == "kway-sharded"


def test_empty_frontier_is_noop():
    """A partition along disconnected components has no cross-shard
    frontier: zero gathers, labels unchanged."""
    # two disconnected 4-cliques → parts == components → halo == 0
    src = np.array([0, 0, 0, 1, 1, 2, 4, 4, 4, 5, 5, 6])
    dst = np.array([1, 2, 3, 2, 3, 3, 5, 6, 7, 6, 7, 7])
    g = build_csr(src, dst, 8)
    parts = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    fp = build_frontier_plan(g, parts, 2)
    assert fp.plan.halo == 0
    corr = balance_corridor(parts, 2, None, 0.05)
    out, records, info = run_sharded_sweeps(fp, parts, 2, sweeps=4,
                                            corridor=corr)
    assert np.array_equal(out, parts)
    assert info["gathers"] == 0 and records == []


def test_pipeline_spec_spans_and_gather_counters():
    """refine="repair+refine-sharded" through the pipeline: the
    post:refine-sharded span exists (and is part of the manifest drift
    guard's expected set), and the trace counters certify exactly one
    boundary-label all_gather per sweep."""
    from repro.obs.export import expected_span_names

    mesh = box_mesh(6, 6, 4)
    post = parse_refine("repair+refine-sharded")
    assert post == ("repair", "refine-sharded")
    pipe = PartitionPipeline(post=post)
    ctx = pipe.run(mesh, 8)
    names = {s.name for s in ctx.trace.walk()}
    assert "post:refine-sharded" in names
    assert "post:refine-sharded" in expected_span_names(ctx.config)
    counters = {}
    for s in ctx.trace.walk():
        for k, v in s.counters.items():
            counters[k] = counters.get(k, 0.0) + v
    assert counters.get("sharded_sweeps", 0) >= 1
    assert counters["sharded_gathers"] == counters["sharded_sweeps"]
    assert counters.get("halo_words", 0) > 0
    assert counters.get("halo_bytes", 0) == pytest.approx(
        4 * counters["halo_words"])
    pm = partition_metrics(ctx.require_graph(), ctx.parts, 8)
    assert pm.disconnected_parts == 0


def test_pipeline_kway_sharded_matches_quality():
    """kway-sharded through the front pipeline lands within 1% of the
    host kway chain on the same mesh."""
    mesh = box_mesh(8, 8, 6)
    cut = {}
    for spec in ("repair+kway", "kway-sharded"):
        ctx = PartitionPipeline(post=parse_refine(spec)).run(mesh, 8)
        cut[spec] = edge_cut(ctx.require_graph(), ctx.parts)
    assert cut["kway-sharded"] <= 1.01 * cut["repair+kway"] + 1e-9


def test_guard_deadline_falls_back_to_host():
    """An expired SolverGuard deadline degrades to the host FM refiner:
    output still refined + repaired, stage records the fallback."""

    class Expired:
        def expired(self):
            return True

    g, parts, w, _ = _seeded_case(box_mesh(8, 8, 6), 8, 17)
    out, stats = refine_sharded_stage(g, parts, 8, weights=w,
                                      guard=Expired())
    assert "host-fallback" in stats.stages
    assert edge_cut(g, out) <= stats.cut_before + 1e-9
    assert partition_metrics(g, out, 8, weights=w).disconnected_parts == 0


def test_device_path_failure_counts_guard_fallback():
    """A broken device path trips the guard escalation counter and still
    returns a host-refined partition."""
    import repro.dist.refine_sharded as rs

    g, parts, w, _ = _seeded_case(box_mesh(6, 6, 4), 4, 19)
    orig = rs.run_sharded_sweeps
    rs.run_sharded_sweeps = lambda *a, **k: (_ for _ in ()).throw(
        RuntimeError("injected device failure"))
    try:
        with obs.trace("t") as root:
            out, stats = refine_sharded_stage(g, parts, 4, weights=w)
    finally:
        rs.run_sharded_sweeps = orig
    total = sum(s.counters.get("guard_fallbacks", 0) for s in root.walk())
    assert total >= 1
    assert "host-fallback" in stats.stages
    assert edge_cut(g, out) <= stats.cut_before + 1e-9


def test_eight_device_parity(multi_device_run):
    """The real 8-device shard_map run reproduces the host mirror bit for
    bit, for P == D and the grouped P = 12, D = 6 case."""
    multi_device_run(r"""
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.core.pipeline import PartitionPipeline
from repro.core.refine import balance_corridor, edge_cut
from repro.dist.refine_sharded import (build_frontier_plan, _pick_devices,
                                       refine_sharded_host,
                                       run_sharded_sweeps)
from repro.mesh import box_mesh

for nparts, seed, dims in ((8, 3, (8, 8, 6)), (12, 7, (9, 8, 6))):
    ctx = PartitionPipeline(bisect="rcb", post=()).run(box_mesh(*dims),
                                                       nparts)
    g = ctx.require_graph()
    rng = np.random.default_rng(seed)
    parts = ctx.parts.copy()
    sel = rng.random(g.n) < 0.12
    parts[sel] = rng.integers(0, nparts, sel.sum())
    corr = balance_corridor(parts, nparts, ctx.weights, 0.05)
    fp = build_frontier_plan(g, parts, nparts, weights=ctx.weights)
    out_d, _, info = run_sharded_sweeps(fp, parts, nparts, sweeps=10,
                                        corridor=corr)
    out_h, _, _ = refine_sharded_host(fp, parts, nparts, sweeps=10,
                                      corridor=corr)
    assert np.array_equal(out_d, out_h), (nparts, "parity")
    assert info["moves"] > 0
    print("nparts", nparts, "devices", _pick_devices(nparts),
          "cut", edge_cut(g, out_d))
""")
