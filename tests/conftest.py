"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device
(multi-device behaviour is exercised via subprocesses in test_distributed).
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def box443():
    from repro.mesh import box_mesh

    return box_mesh(4, 4, 3)


@pytest.fixture(scope="session")
def grid16():
    from repro.mesh import grid_graph_2d

    return grid_graph_2d(16, 16)
