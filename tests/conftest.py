"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 device
(multi-device behaviour is exercised via subprocesses: test_distributed's
``run_sub`` and the ``multi_device_run`` fixture below).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def multi_device_run():
    """Run a code snippet in a subprocess with N forced host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and return
    its stdout; asserts a zero exit."""

    def run(code: str, devices: int = 8, timeout: int = 420) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = os.path.join(_REPO, "src")
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=timeout, env=env,
        )
        assert out.returncode == 0, \
            f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
        return out.stdout

    return run


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def box443():
    from repro.mesh import box_mesh

    return box_mesh(4, 4, 3)


@pytest.fixture(scope="session")
def grid16():
    from repro.mesh import grid_graph_2d

    return grid_graph_2d(16, 16)
