"""Hill-climbing k-way FM semantics (repro.core.kway).

Hand-checkable cases for the climb/rollback contract — tentative
negative-gain moves, rollback to the best prefix, one move per node per
pass, the fixed balance corridor — plus the pipeline/front-door wiring of
the "kway" stage and the KwayStats threading through PostStats.
"""

import numpy as np
import pytest

from repro.core import (
    PartitionPipeline,
    edge_cut,
    kway_fm,
    kway_stage,
    partition,
    partition_metrics,
    refine_boundary,
    run_post_stages,
)
from repro.mesh import build_csr, dual_graph, pebble_mesh


def pair_trap_graph():
    """Two-part local minimum the greedy refiner cannot leave: nodes 2 and
    3 sit in part 0, tied to each other (w=4) and to part 1 (w=2 each);
    moving either alone loses 3, moving both gains 2.  The FM escape is a
    negative-gain prefix: cut 4 → 7 → 2."""
    g = build_csr(np.array([0, 2, 0, 1, 2, 3, 4]),
                  np.array([1, 3, 2, 3, 4, 5, 5]), 6,
                  weights=np.array([3.0, 4.0, 1.0, 1.0, 2.0, 2.0, 3.0]))
    parts = np.array([0, 0, 0, 0, 1, 1], dtype=np.int64)
    return g, parts


def test_hill_climb_escapes_greedy_local_minimum():
    g, parts = pair_trap_graph()
    assert edge_cut(g, parts) == 4.0
    # the greedy positive-gain refiner is stuck: every single move loses
    out_g, st_g = refine_boundary(g, parts, 2)
    assert st_g.moves_applied == 0
    assert edge_cut(g, out_g) == 4.0
    # k-way FM walks through the negative-gain ridge and keeps the prefix
    out_k, st_k = kway_fm(g, parts, 2)
    assert edge_cut(g, out_k) == 2.0
    np.testing.assert_array_equal(out_k, [0, 0, 1, 1, 1, 1])
    assert st_k.cut_after == 2.0
    first = st_k.kway.records[0]
    assert first.attempted == 2 and first.best_prefix == 2
    assert first.cut_before == 4.0 and first.cut_after == 2.0


def test_rollback_to_best_prefix():
    """The convergence pass climbs (tentative moves > 0) but keeps nothing:
    best-prefix index < moves attempted, and the rolled-back moves leave
    the labels untouched."""
    g, parts = pair_trap_graph()
    out_k, st_k = kway_fm(g, parts, 2)
    last = st_k.kway.records[-1]
    assert last.attempted > 0
    assert last.best_prefix < last.attempted
    assert last.rolled_back == last.attempted - last.best_prefix
    assert st_k.kway.rolled_back > 0
    assert edge_cut(g, out_k) == min(r.cut_after for r in st_k.kway.records)


def test_all_negative_climb_rolls_back_fully():
    """At a true local optimum every tentative move is undone: labels and
    cut are bit-for-bit unchanged, yet the climb was exercised."""
    g = build_csr(np.array([0, 1, 2, 3, 4, 5, 2]),
                  np.array([1, 2, 0, 4, 5, 3, 3]), 6,
                  weights=np.array([2.0, 2.0, 2.0, 2.0, 2.0, 2.0, 1.0]))
    parts = np.array([0, 0, 0, 1, 1, 1], dtype=np.int64)
    out, st = kway_fm(g, parts, 2, balance_tol=0.5)
    np.testing.assert_array_equal(out, parts)
    assert st.kway.moves_attempted > 0
    assert st.kway.moves_kept == 0
    assert st.cut_after == st.cut_before


def test_one_move_per_node_per_pass():
    """The lock array bounds every pass's tentative moves by n."""
    g, parts = pair_trap_graph()
    _, st = kway_fm(g, parts, 2, passes=16)
    assert all(r.attempted <= g.n for r in st.kway.records)


def test_kway_respects_fixed_corridor():
    """A heavy node cannot migrate past the cap even for a large gain."""
    src = np.array([0, 1, 2, 3, 4, 5, 2])
    dst = np.array([1, 2, 0, 4, 5, 3, 3])
    w = np.array([1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 10.0])
    g = build_csr(src, dst, 6, weights=w)
    parts = np.array([0, 0, 0, 1, 1, 1], dtype=np.int64)
    out, st = kway_fm(g, parts, 2, balance_tol=0.05)
    # cap = 3.15: any move overfills one side, so nothing can be KEPT;
    # the labels come back unchanged
    np.testing.assert_array_equal(out, parts)
    part_w = np.bincount(out, minlength=2).astype(float)
    assert part_w.max() <= st.corridor[1] + 1e-9


def test_kway_stage_closes_with_repair():
    """The registered stage repairs articulation damage: 0 disconnected
    parts at a cut no worse than the input's."""
    mesh = pebble_mesh(8, 8, 8, n_pebbles=3, seed=2)
    g = dual_graph(mesh)
    rng = np.random.default_rng(0)
    parts = rng.integers(0, 4, g.n).astype(np.int64)
    parts[rng.choice(g.n, 4, replace=False)] = np.arange(4)
    out, st = kway_stage(g, parts, 4, weights=mesh.weights)
    pm = partition_metrics(g, out, 4, weights=mesh.weights)
    assert pm.disconnected_parts == 0
    assert st.cut_after <= st.cut_before + 1e-9
    assert st.cut_after == pytest.approx(edge_cut(g, out))


def test_pipeline_repair_kway_chain():
    """refine="repair+kway" through the front door: stages recorded, stats
    threaded into the report, invariants hold, cut ≤ raw bisection's."""
    mesh = pebble_mesh(8, 8, 8, n_pebbles=3, seed=1)
    g = dual_graph(mesh)
    pipe = PartitionPipeline(post=("repair", "kway"),
                             bisect_kw=dict(tol=1e-2, max_restarts=10))
    ctx = pipe.run(mesh, 8)
    assert ctx.report.post.stages == ["repair", "kway"]
    assert ctx.report.post.kway is not None
    assert ctx.report.post.kway.passes >= 1
    assert ctx.report.post.corridor is not None
    pm = partition_metrics(g, ctx.parts, 8, weights=mesh.weights)
    pm_raw = partition_metrics(g, ctx.parts_raw, 8, weights=mesh.weights)
    assert pm.edge_cut <= pm_raw.edge_cut + 1e-9
    assert pm.disconnected_parts == 0
    # the kway section rides through the JSON row for the bench tables
    row = ctx.report.post.row()
    assert row["kway"]["passes"] == ctx.report.post.kway.passes
    assert row["corridor"] is not None
    # ... and the front door accepts the spec
    labels = partition(mesh, 8, refine="repair+kway", tol=1e-2,
                       max_restarts=10)
    assert partition_metrics(g, labels, 8).disconnected_parts == 0


def test_run_post_stages_greedy_vs_kway_one_solve():
    """What the benchmarks do: two post chains from one bisection, kway at
    or below greedy on this mesh (the smoke gate's cut axis)."""
    mesh = pebble_mesh(8, 8, 8, n_pebbles=3, seed=0)
    g = dual_graph(mesh)
    ctx = PartitionPipeline(bisect_kw=dict(tol=1e-2)).run(mesh, 8)
    greedy_cut = partition_metrics(g, ctx.parts, 8).edge_cut
    parts_k, stats, recs = run_post_stages(
        g, ctx.parts_raw, 8, ("repair", "kway"), weights=ctx.weights)
    kway_cut = partition_metrics(g, parts_k, 8).edge_cut
    assert kway_cut <= greedy_cut + 1e-9
    assert [r.name for r in recs] == ["repair", "kway"]
    assert stats.kway is not None
