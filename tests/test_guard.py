"""Adversarial suite for the fault-tolerance guard (repro.guard).

No `hypothesis` in this container, so the property tests are a seeded
harness: every case is parametrized over seeds and generates its
pathological input from that seed's rng — same coverage style
(generate → assert invariant), fully deterministic replays.

The contract under test, end to end: a pathological input fed to ANY
pipeline preset either raises a typed :class:`GuardError` (strict mode)
or comes back as a full-coverage labeling — and when the preset's post
chain includes "repair", a connected one.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.parrsb import PIPELINE_PRESETS, make_pipeline, make_smoke_config
from repro.core.fiedler import FiedlerResult
from repro.core.pipeline import PartitionPipeline
from repro.core.rsb import _node_seed
from repro.guard import (
    GuardError,
    GuardPolicy,
    GuardReport,
    SolverGuard,
    chaos,
    check_output,
    check_positive_int,
    component_labels,
    count_disconnected,
    enforce_output,
    failure_reason,
    fallback_vector,
    pack_components,
    proportional_budgets,
    validate_graph,
    validate_mesh,
    validate_nparts,
)
from repro.mesh import box_mesh, grid_graph_2d
from repro.mesh.graphs import build_csr

SEEDS = [0, 1, 2, 3, 4]


def _graph_with(n=36, *, rng, self_loops=0, dup_edges=0, bad_w=0,
                neg_w=0):
    """A connected 6x6 grid graph with injected defects, as raw COO fed
    through a non-coalescing CSR build (build_csr would repair them)."""
    g = grid_graph_2d(6, 6)
    src, dst, w = [g.rows], [g.indices], [np.asarray(g.weights, float)]
    if self_loops:
        nodes = rng.choice(n, self_loops, replace=False)
        src.append(nodes)
        dst.append(nodes)
        w.append(np.ones(self_loops))
    if dup_edges:
        pick = rng.choice(g.rows.size, dup_edges, replace=False)
        src.append(g.rows[pick])
        dst.append(g.indices[pick])
        w.append(np.ones(dup_edges))
    src, dst = np.concatenate(src), np.concatenate(dst)
    w = np.concatenate(w)
    if bad_w:
        w[rng.choice(w.size, bad_w, replace=False)] = np.nan
    if neg_w:
        w[rng.choice(w.size, neg_w, replace=False)] = -1.0
    order = np.argsort(src, kind="stable")
    indptr = np.searchsorted(src[order], np.arange(n + 1))
    return dataclasses.replace(g, indptr=indptr, indices=dst[order],
                               weights=w[order])


def _two_component_graph(side=6):
    g = grid_graph_2d(side, side)
    n = g.n
    src = np.concatenate([g.rows, g.rows + n])
    dst = np.concatenate([g.indices, g.indices + n])
    w = np.concatenate([g.weights, g.weights])
    return build_csr(src, dst, 2 * n, weights=w, symmetrize=False)


# ---------------------------------------------------------------------------
# Scalar / CLI front door
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ["x", -1, 0, 2.5, None, float("nan")])
def test_check_positive_int_rejects(bad):
    with pytest.raises(GuardError) as ei:
        check_positive_int("count", bad)
    assert ei.value.code == "bad-argument"
    assert "count" in ei.value.diagnostic()


def test_check_positive_int_accepts():
    assert check_positive_int("count", "7") == 7
    assert check_positive_int("count", 3.0, maximum=3) == 3
    with pytest.raises(GuardError):
        check_positive_int("count", 4, maximum=3)


def test_validate_nparts_range():
    assert validate_nparts("4", 10) == 4
    for bad in (0, 11, "x", None):
        with pytest.raises(GuardError) as ei:
            validate_nparts(bad, 10)
        assert ei.value.code == "bad-nparts"


# ---------------------------------------------------------------------------
# Graph/mesh validation: strict raises typed, sanitize repairs + records
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("defect,code", [
    (dict(self_loops=3), "self-loop"),
    (dict(dup_edges=4), "duplicate-edge"),
    (dict(bad_w=2), "nonfinite-edge-weight"),
    (dict(neg_w=2), "nonpositive-edge-weight"),
])
def test_validate_graph_strict_vs_sanitize(seed, defect, code):
    rng = np.random.default_rng(seed)
    g = _graph_with(rng=rng, **defect)
    with pytest.raises(GuardError) as ei:
        validate_graph(g)
    assert ei.value.code == code

    report = GuardReport()
    g2, _, _ = validate_graph(g, sanitize=True, report=report)
    assert report.sanitize_fixes > 0
    assert any(i.code == code and i.fixed for i in report.issues)
    # the sanitized rebuild is defect-free
    validate_graph(g2)
    assert np.all(np.isfinite(g2.weights)) and np.all(g2.weights > 0)
    assert not np.any(g2.rows == g2.indices)


@pytest.mark.parametrize("seed", SEEDS)
def test_validate_graph_node_data(seed):
    rng = np.random.default_rng(seed)
    g = grid_graph_2d(6, 6)
    w = np.ones(36)
    w[rng.choice(36, 3, replace=False)] = np.nan
    c = rng.random((36, 2))
    c[rng.choice(36, 2, replace=False)] = np.inf
    with pytest.raises(GuardError):
        validate_graph(g, weights=w)
    with pytest.raises(GuardError):
        validate_graph(g, coords=c)
    _, c2, w2 = validate_graph(g, coords=c, weights=w, sanitize=True,
                               report=GuardReport())
    assert np.all(np.isfinite(c2)) and np.all(np.isfinite(w2))
    assert np.all(w2 > 0)


def test_validate_graph_malformed_csr_never_repairable():
    g = grid_graph_2d(4, 4)
    bad = dataclasses.replace(g, indptr=g.indptr[:-1].copy())
    for sanitize in (False, True):
        with pytest.raises(GuardError) as ei:
            validate_graph(bad, sanitize=sanitize)
        assert ei.value.code == "malformed-csr"


def test_validate_mesh_patches(box443):
    coords = np.asarray(box443.coords).copy()
    coords[5] = np.nan
    weights = np.asarray(box443.weights, float).copy()
    weights[7] = -3.0
    bad = dataclasses.replace(box443, coords=coords, weights=weights)
    with pytest.raises(GuardError):
        validate_mesh(bad)
    report = GuardReport()
    fixed = validate_mesh(bad, sanitize=True, report=report)
    assert np.all(np.isfinite(fixed.coords))
    assert np.all(np.asarray(fixed.weights, float) >= 0)
    assert report.sanitize_fixes == 2


def test_zero_degree_nodes_recorded_not_raised():
    g = grid_graph_2d(4, 4)
    # node-induced graph on 18 nodes where 2 have no edges
    g18 = build_csr(g.rows, g.indices, 18, weights=g.weights,
                    symmetrize=False)
    report = GuardReport()
    validate_graph(g18, report=report)          # strict mode: no raise
    assert any(i.code == "zero-degree-node" for i in report.issues)
    _, ncomp = component_labels(g18)
    assert ncomp == 3                           # grid + two singletons


# ---------------------------------------------------------------------------
# Component budgets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_proportional_budgets_properties(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 8))
    nparts = int(rng.integers(k, 40))
    w = rng.random(k) * rng.integers(1, 100)
    b = proportional_budgets(w, nparts)
    assert b.sum() == nparts and b.min() >= 1
    # proportionality: a component's budget is within 1 of its fair share
    # (largest-remainder), up to the floor-of-one distortion
    fair = nparts * w / w.sum()
    assert np.all(b >= np.minimum(1, np.ceil(fair)))
    assert np.all(np.abs(b - np.maximum(fair, 1)) <= k)


def test_proportional_budgets_rejects_too_few_parts():
    with pytest.raises(GuardError) as ei:
        proportional_budgets([1.0, 1.0, 1.0], 2)
    assert ei.value.code == "bad-nparts"


@pytest.mark.parametrize("seed", SEEDS)
def test_pack_components_properties(seed):
    rng = np.random.default_rng(seed)
    nparts = int(rng.integers(2, 6))
    k = int(rng.integers(nparts + 1, 40))
    w = rng.random(k)
    group = pack_components(w, nparts)
    assert group.shape == (k,)
    assert set(np.unique(group)) == set(range(nparts))   # no empty bin
    loads = np.bincount(group, weights=w, minlength=nparts)
    # greedy heaviest-first bound: max bin ≤ mean + heaviest item
    assert loads.max() <= w.sum() / nparts + w.max() + 1e-12


# ---------------------------------------------------------------------------
# Solver guard: health checks + the escalation ladder
# ---------------------------------------------------------------------------

def _res(vec, lam=0.1, residual=1e-6, breakdown=False):
    return FiedlerResult(vector=np.asarray(vec, float), eigenvalue=lam,
                         residual=residual, iterations=3, method="lanczos",
                         breakdown=breakdown)


def test_failure_reason_taxonomy():
    good = np.linspace(-1, 1, 8)
    assert failure_reason(None, 8) == "exception"
    assert failure_reason(_res(good, breakdown=True), 8) == "breakdown"
    v = good.copy()
    v[3] = np.nan
    assert failure_reason(_res(v), 8) == "nonfinite-vector"
    assert failure_reason(_res(good, lam=np.nan), 8) == "nonfinite-eigenpair"
    assert failure_reason(_res(np.zeros(8)), 8) == "degenerate-vector"
    assert failure_reason(_res(good, lam=1e-9, residual=1.0), 8) \
        == "stalled-residual"
    assert failure_reason(_res(good), 8) is None
    # a 1-node problem cannot be "degenerate"
    assert failure_reason(_res(np.zeros(1)), 1) is None


def test_fallback_vector_prefers_longest_axis():
    coords = np.stack([np.linspace(0, 1, 10), np.linspace(0, 5, 10)], 1)
    np.testing.assert_allclose(fallback_vector(10, coords), coords[:, 1])
    np.testing.assert_allclose(fallback_vector(4), np.arange(4.0))
    # degenerate coords (zero span) fall back to the index ramp
    np.testing.assert_allclose(fallback_vector(4, np.zeros((4, 3))),
                               np.arange(4.0))


def _ladder(policy, script, method="lanczos", seed=0):
    """Run one rescue through a scripted solve_fn.  ``script`` maps attempt
    index (in call order) to a result; missing entries raise."""
    calls = []

    def solve_fn(m, s):
        calls.append((m, s))
        i = len(calls) - 1
        if i in script:
            return script[i]
        raise RuntimeError("scripted failure")

    sg = SolverGuard(policy, seed=seed, method=method)
    res, why = sg.admit(_res(np.zeros(16)), level=0, p_lo=0, size=16)
    assert why == "degenerate-vector"
    out = sg.rescue(solve_fn, why, level=0, p_lo=0, size=16)
    return sg, out, calls


def test_ladder_retry_succeeds():
    good = _res(np.linspace(-1, 1, 16))
    sg, out, calls = _ladder(GuardPolicy(max_retries=2), {0: good})
    assert out is good
    assert sg.report.retries == 1 and sg.report.fallbacks == 0
    assert calls[0][0] == "lanczos"          # retried with primary method
    assert calls[0][1] == _node_seed(0, 0, 0, 1)   # attempt-keyed seed


def test_ladder_switch_succeeds():
    good = _res(np.linspace(-1, 1, 16))
    sg, out, calls = _ladder(GuardPolicy(max_retries=1), {1: good})
    assert out is good
    assert sg.report.retries == 1 and sg.report.fallbacks == 1
    assert calls[1][0] == "inverse"          # switched family
    assert any("switched-to-inverse" in d for d in sg.report.degraded)


def test_ladder_exhausts_to_fallback():
    sg, out, calls = _ladder(GuardPolicy(max_retries=2), {})
    assert out.method == "fallback-index" and out.breakdown
    assert float(np.ptp(out.vector)) > 0     # still splittable
    assert sg.report.retries == 2 and sg.report.fallbacks == 2
    assert [m for m, _ in calls] == ["lanczos", "lanczos", "inverse"]


def test_ladder_no_switch_policy():
    sg, out, calls = _ladder(
        GuardPolicy(max_retries=1, switch_method=False), {})
    assert out.method == "fallback-index"
    assert [m for m, _ in calls] == ["lanczos"]


def test_deadline_skips_straight_to_fallback():
    sg = SolverGuard(GuardPolicy(max_retries=5, deadline=0.0), seed=0,
                     method="lanczos")
    import time
    time.sleep(0.01)
    assert sg.expired()
    out = sg.rescue(lambda m, s: pytest.fail("must not re-solve"),
                    "breakdown", level=0, p_lo=0, size=8)
    assert out.method == "fallback-index"
    assert sg.report.deadline_expired
    assert sg.report.retries == 0 and sg.report.fallbacks == 1


# ---------------------------------------------------------------------------
# Deterministic seeds & chaos
# ---------------------------------------------------------------------------

def test_node_seed_attempt_determinism():
    base = _node_seed(7, 2, 5)
    assert base == _node_seed(7, 2, 5, 0)       # attempt=0 is bit-parity
    seen = {_node_seed(7, 2, 5, a) for a in range(6)}
    assert len(seen) == 6                       # attempts never collide
    assert _node_seed(7, 2, 5, 3) == _node_seed(7, 2, 5, 3)


def test_chaos_should_fire_deterministic():
    with chaos.overlay(("solver_nan",), seed=3, rate=0.5):
        draws = [chaos.should_fire("solver_nan", 0, i) for i in range(200)]
        assert draws == [chaos.should_fire("solver_nan", 0, i)
                         for i in range(200)]
        assert 0 < sum(draws) < 200             # rate actually subsamples
        assert not chaos.should_fire("empty_split", 0, 0)  # not enabled
    assert not chaos.active()                   # overlay restored


def test_chaos_suppressed_and_unknown_site():
    with chaos.overlay(("deadline",)):
        assert chaos.enabled("deadline")
        with chaos.suppressed():
            assert not chaos.enabled("deadline")
            assert not chaos.should_fire("deadline")
        assert chaos.enabled("deadline")
    with pytest.raises(ValueError):
        chaos.configure(("not-a-site",))


# ---------------------------------------------------------------------------
# Breakdown flag surfacing (batched + recursive inverse iteration)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["recursive", "batched"])
def test_cg_divergence_sets_breakdown_record(grid16, engine):
    from repro.core.rsb import rsb_partition_graph

    with chaos.overlay(("cg_divergence",)):
        parts, report = rsb_partition_graph(
            grid16, 2, method="inverse", engine=engine)
    # no guard: the breakdown must still surface per bisection record
    # (grid16 is 256 nodes — above the dense cutoff, so inverse runs)
    assert any(r.breakdown for r in report.records)
    assert parts.shape == (grid16.n,)


# ---------------------------------------------------------------------------
# Pipeline integration: guard on/off parity, component dispatch, chaos e2e
# ---------------------------------------------------------------------------

def test_guard_on_off_parity(grid16):
    kw = dict(pre="none", bisect="rsb-batched", post=("repair", "refine"))
    on = PartitionPipeline(guard=True, **kw).run(grid16, 4)
    off = PartitionPipeline(guard=False, **kw).run(grid16, 4)
    np.testing.assert_array_equal(on.parts, off.parts)
    assert on.report.guard is not None and on.report.guard.clean
    assert off.report.guard is None
    assert on.config["guard"] and not off.config["guard"]


def test_guard_env_switch(grid16, monkeypatch):
    monkeypatch.setenv("REPRO_GUARD", "off")
    ctx = PartitionPipeline(pre="none", bisect="rsb-batched").run(grid16, 2)
    assert not ctx.config["guard"] and ctx.report.guard is None
    monkeypatch.setenv("REPRO_GUARD", "on")
    ctx = PartitionPipeline(pre="none", bisect="rsb-batched").run(grid16, 2)
    assert ctx.config["guard"] and ctx.report.guard is not None


def test_two_components_proportional(seed=0):
    g = _two_component_graph(6)                  # two equal 36-node grids
    ctx = PartitionPipeline(pre="none", bisect="rsb-batched",
                            post=("repair", "refine"),
                            guard=True).run(g, 4)
    assert ctx.report.guard.components == 2
    assert count_disconnected(g, ctx.parts, 4) == 0
    counts = np.bincount(ctx.parts, minlength=4)
    assert counts.min() > 0
    # no part spans both components
    comp = np.repeat([0, 1], 36)
    for p in range(4):
        assert np.unique(comp[ctx.parts == p]).size == 1


def test_more_components_than_parts_packs():
    # 12 disjoint edges → 12 components, packed onto 3 parts
    src = np.arange(0, 24, 2)
    gp = build_csr(np.concatenate([src, src + 1]),
                   np.concatenate([src + 1, src]), 24, symmetrize=False)
    ctx = PartitionPipeline(pre="none", bisect="rsb-batched",
                            post=("repair",), guard=True).run(gp, 3)
    assert sorted(np.unique(ctx.parts)) == [0, 1, 2]
    assert any("packed" in d for d in ctx.report.guard.degraded)
    counts = np.bincount(ctx.parts, minlength=3)
    assert counts.max() <= 10                    # greedy-packing balance


@pytest.mark.parametrize("site", ["solver_nan", "empty_split", "deadline"])
def test_chaos_end_to_end(grid16, site):
    ctx = PartitionPipeline(pre="none", bisect="rsb-batched",
                            post=("repair", "refine"), guard=True,
                            guard_kw={"chaos": (site,)}).run(grid16, 4)
    gr = ctx.report.guard
    assert gr.fallbacks > 0
    assert sorted(np.unique(ctx.parts)) == [0, 1, 2, 3]
    assert count_disconnected(grid16, ctx.parts, 4) == 0
    if site == "deadline":
        assert gr.deadline_expired


def test_chaos_runs_are_deterministic(grid16):
    kw = dict(pre="none", bisect="rsb-batched", post=("repair", "refine"),
              guard=True, guard_kw={"chaos": ("solver_nan",)})
    a = PartitionPipeline(**kw).run(grid16, 4)
    b = PartitionPipeline(**kw).run(grid16, 4)
    np.testing.assert_array_equal(a.parts, b.parts)
    assert a.report.guard.fallbacks == b.report.guard.fallbacks


# ---------------------------------------------------------------------------
# Output invariant: check + graceful-degradation closer
# ---------------------------------------------------------------------------

def test_check_output_taxonomy(grid16):
    n = grid16.n
    good = (np.arange(n) // (n // 4)).clip(0, 3)
    assert check_output(grid16, good, 4) == []
    assert check_output(grid16, None, 4) == ["labels-missing"]
    assert check_output(grid16, good[:-1], 4) == ["labels-missing"]
    assert check_output(grid16, good.astype(float), 4) \
        == ["labels-not-integer"]
    assert any("out-of-range" in p
               for p in check_output(grid16, good + 7, 4))
    frag = good.copy()
    frag[0] = 3                                  # corner detached from part 3
    assert any("disconnected" in p for p in check_output(grid16, frag, 4))


@pytest.mark.parametrize("seed", SEEDS)
def test_enforce_output_from_garbage(grid16, seed):
    rng = np.random.default_rng(seed)
    garbage = rng.integers(-5, 9, grid16.n)      # out-of-range labels
    report = GuardReport()
    parts = enforce_output(grid16, garbage, 4, report=report)
    assert check_output(grid16, parts, 4) == []
    assert report.fallbacks >= 1
    assert any("finalize" in d for d in report.degraded)
    # idempotent on a now-valid labeling
    again = enforce_output(grid16, parts, 4, report=GuardReport())
    np.testing.assert_array_equal(parts, again)


def test_enforce_output_none_labels(grid16):
    parts = enforce_output(grid16, None, 4, report=GuardReport())
    assert check_output(grid16, parts, 4) == []


# ---------------------------------------------------------------------------
# Halo plan self-check
# ---------------------------------------------------------------------------

def test_halo_truncate_detected_and_rebuilt(grid16):
    from repro.dist import plan_halo_sharding, verify_halo_plan
    from repro.dist.partition_aware import _truncate_exports

    parts = (np.arange(grid16.n) // (grid16.n // 4)).clip(0, 3)
    clean = plan_halo_sharding(grid16, parts, 4)
    assert verify_halo_plan(clean) == []
    assert verify_halo_plan(_truncate_exports(clean)) != []
    with chaos.overlay(("halo_truncate",)):
        rebuilt = plan_halo_sharding(grid16, parts, 4)
    assert verify_halo_plan(rebuilt) == []
    np.testing.assert_array_equal(rebuilt.export_mask, clean.export_mask)


# ---------------------------------------------------------------------------
# The preset sweep: every PIPELINE_PRESETS entry absorbs pathological input
# ---------------------------------------------------------------------------

def _pathological_mesh(kind, seed):
    rng = np.random.default_rng(seed)
    m = box_mesh(4, 4, 3)
    coords = np.asarray(m.coords).copy()
    weights = np.asarray(m.weights, float).copy()
    if kind == "nan-coords":
        coords[rng.choice(m.nelems, 3, replace=False)] = np.nan
    elif kind == "bad-weights":
        weights[rng.choice(m.nelems, 3, replace=False)] = np.nan
        weights[rng.choice(m.nelems, 2, replace=False)] = -2.0
    return dataclasses.replace(m, coords=coords, weights=weights)


@pytest.mark.parametrize("preset", sorted(PIPELINE_PRESETS))
@pytest.mark.parametrize("kind", ["nan-coords", "bad-weights"])
def test_presets_strict_mode_raises_typed(preset, kind):
    mesh = _pathological_mesh(kind, seed=0)
    pipe = make_pipeline(preset, config=make_smoke_config(), guard=True)
    with pytest.raises(GuardError):
        pipe.run(mesh, 4)


@pytest.mark.parametrize("seed", SEEDS[:3])
@pytest.mark.parametrize("preset", sorted(PIPELINE_PRESETS))
def test_presets_sanitize_mode_upholds_invariant(preset, seed):
    kind = ["nan-coords", "bad-weights"][seed % 2]
    mesh = _pathological_mesh(kind, seed)
    pipe = make_pipeline(preset, config=make_smoke_config(), guard=True,
                         guard_kw={"sanitize": True})
    ctx = pipe.run(mesh, 4)
    gr = ctx.report.guard
    assert gr is not None and gr.validated and gr.sanitize_fixes > 0
    parts = np.asarray(ctx.parts)
    assert parts.shape == (mesh.nelems,)
    assert parts.min() >= 0 and parts.max() < 4      # always-valid labels
    if "repair" in PIPELINE_PRESETS[preset]["post"]:
        # full invariant only where the chain contains the repairer
        assert count_disconnected(ctx.require_graph(), parts, 4) == 0
        assert sorted(np.unique(parts)) == [0, 1, 2, 3]


@pytest.mark.parametrize("preset", sorted(PIPELINE_PRESETS))
def test_presets_disconnected_graph(preset):
    """A two-component dual-graph analogue through every preset: handled
    via per-component dispatch, never a crash."""
    g = _two_component_graph(4)                      # 2 × 16 nodes
    coords = np.concatenate([
        np.mgrid[0:4, 0:4].reshape(2, -1).T,
        np.mgrid[0:4, 0:4].reshape(2, -1).T + 100.0]).astype(float)
    pipe = make_pipeline(preset, config=make_smoke_config(), guard=True)
    ctx = pipe.run(g, 2, coords=coords)
    assert ctx.report.guard.components == 2
    parts = np.asarray(ctx.parts)
    assert parts.shape == (g.n,) and parts.min() >= 0 and parts.max() < 2
    if "repair" in PIPELINE_PRESETS[preset]["post"]:
        assert count_disconnected(g, parts, 2) == 0
