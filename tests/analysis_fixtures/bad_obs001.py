"""OBS001 fixture: span name absent from the declared vocabulary."""

from repro import obs


def stage():
    with obs.span("mystery_stage"):  # <- OBS001
        pass
