"""DIST002 fixture: collective axis name no mesh in the module declares."""

import jax
from jax.sharding import PartitionSpec as P

SPEC = P("shards")


def reduce_all(x):
    return jax.lax.psum(x, "devices")  # <- DIST002
