"""TRC001 fixture: host sync inside a jitted body."""

import jax


@jax.jit
def f(x):
    return x.item()  # <- TRC001
