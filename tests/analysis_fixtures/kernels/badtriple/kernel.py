"""PAL002 fixture: the Pallas half of the triple (contents irrelevant)."""


def badtriple_pallas(x):
    return x
