"""PAL002 fixture: dispatch that never imports the ``ref`` module."""

from tests.analysis_fixtures.kernels.badtriple.kernel import badtriple_pallas


def badtriple(x):
    return badtriple_pallas(x)
