"""PAL002 fixture: the reference half of the triple."""


def badtriple_ref(x):
    return x
