"""GRD002 fixture: kebab-case code not cataloged in KNOWN_CODES."""

from repro.guard.errors import GuardError


def reject():
    raise GuardError("no-such-code", "uncataloged")  # <- GRD002
