"""DET001 fixture: wall-clock read inside a jitted body."""

import time

import jax


@jax.jit
def f(x):
    t = time.perf_counter()  # <- DET001
    return x * t
