"""PAL001 fixture: BlockSpec index_map arity != grid rank."""

import jax
from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((128,), lambda i: (i,))],  # <- PAL001
        out_specs=pl.BlockSpec((128,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
