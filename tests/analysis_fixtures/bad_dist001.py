"""DIST001 fixture: collective inside a loop body of a protocol helper
(the function takes ``axis_name``, so it runs under shard_map at its
call sites)."""

import jax


def leaky_sweep(x, axis_name):
    for _ in range(3):
        x = jax.lax.psum(x, axis_name)  # <- DIST001
    return x
