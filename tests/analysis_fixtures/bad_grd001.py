"""GRD001 fixture: chaos site missing from FAULT_SITES."""

from repro.guard import chaos


def maybe_fail():
    if chaos.should_fire("no-such-site"):  # <- GRD001
        raise RuntimeError("injected")
