"""DET002 fixture: legacy global NumPy RNG."""

import numpy as np


def jitter(n):
    return np.random.normal(size=n)  # <- DET002
