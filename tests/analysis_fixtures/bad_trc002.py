"""TRC002 fixture: Python branch on a traced expression."""

import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    if jnp.any(x > 0):  # <- TRC002
        return x
    return -x
