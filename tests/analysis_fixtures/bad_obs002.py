"""OBS002 fixture: metric name never register()-ed."""

from repro import obs


def stage():
    obs.counter_add("bogus_metric", 1)  # <- OBS002
