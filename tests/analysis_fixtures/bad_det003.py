"""DET003 fixture: hash-ordered set iteration feeding an ordered list."""


def order(items):
    out = []
    for x in set(items):  # <- DET003
        out.append(x)
    return out
