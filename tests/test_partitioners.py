"""RSB driver + geometric baselines: balance (claim C1), quality ordering,
weighted-vs-unweighted (C6), multi-material weighting."""

import numpy as np
import pytest

from repro.core import (
    partition,
    partition_metrics,
    rcb_parts,
    rib_parts,
    rsb_partition_graph,
    rsb_partition_mesh,
    sfc_parts,
)
from repro.mesh import box_mesh, dual_graph, pebble_mesh


@pytest.fixture(scope="module")
def mesh_and_graph():
    m = box_mesh(8, 8, 4)
    return m, dual_graph(m)


def test_rsb_balance_every_level(mesh_and_graph):
    """Eq. 2.6: ≤1 element imbalance for unit weights, every P."""
    m, g = mesh_and_graph
    for nparts in (2, 3, 8):
        parts, _ = rsb_partition_mesh(m, nparts, tol=1e-2, max_restarts=10)
        counts = np.bincount(parts, minlength=nparts)
        assert counts.max() - counts.min() <= 1, (nparts, counts)
        assert set(np.unique(parts)) == set(range(nparts))


def test_rsb_beats_random_cut(mesh_and_graph):
    m, g = mesh_and_graph
    parts, _ = rsb_partition_mesh(m, 8, tol=1e-3)
    rsb = partition_metrics(g, parts, 8)
    rnd = partition_metrics(g, partition(m, 8, partitioner="random"), 8)
    assert rsb.edge_cut < 0.5 * rnd.edge_cut
    assert rsb.total_volume < rnd.total_volume


def test_rsb_competitive_with_rcb(mesh_and_graph):
    """Spectral should match or beat geometric cut on a box mesh."""
    m, g = mesh_and_graph
    parts, _ = rsb_partition_mesh(m, 8, tol=1e-3)
    rsb = partition_metrics(g, parts, 8)
    rcb = partition_metrics(g, rcb_parts(m.coords, 8), 8)
    assert rsb.edge_cut <= 1.25 * rcb.edge_cut  # same ballpark or better


def test_geometric_partitioners_balance(mesh_and_graph):
    m, _ = mesh_and_graph
    for fn in (rcb_parts, rib_parts, sfc_parts):
        parts = fn(m.coords, 8)
        counts = np.bincount(parts, minlength=8)
        assert counts.max() - counts.min() <= 1, fn.__name__


def test_weighted_elements_balance():
    """Multi-material: weighted splits balance WEIGHT, not count."""
    m = pebble_mesh(8, 8, 8, n_pebbles=3, seed=1)
    assert (m.weights > 1).any()
    parts, _ = rsb_partition_mesh(m, 4, tol=1e-2, max_restarts=10)
    wsum = np.bincount(parts, weights=m.weights, minlength=4)
    assert wsum.max() / wsum.mean() < 1.1


def test_graph_rsb_matches_mesh_rsb_quality(mesh_and_graph):
    """RSB on the assembled dual graph ≈ RSB on the matrix-free mesh."""
    m, g = mesh_and_graph
    pm, _ = rsb_partition_mesh(m, 4, tol=1e-3)
    pg, _ = rsb_partition_graph(g, 4, coords=m.coords, tol=1e-3)
    qm = partition_metrics(g, pm, 4).edge_cut
    qg = partition_metrics(g, pg, 4).edge_cut
    assert qg <= 1.3 * qm and qm <= 1.3 * qg


def test_unweighted_vs_weighted_cut(mesh_and_graph):
    """Claim C6: the weighted Laplacian targets comm volume — its ω-cut
    should not be worse than the unweighted variant's."""
    m, g = mesh_and_graph
    pw, _ = rsb_partition_mesh(m, 4, laplacian="weighted", tol=1e-3)
    pu, _ = rsb_partition_mesh(m, 4, laplacian="unweighted", tol=1e-3)
    qw = partition_metrics(g, pw, 4).total_volume
    qu = partition_metrics(g, pu, 4).total_volume
    assert qw <= 1.15 * qu


def test_partition_front_door(mesh_and_graph):
    m, g = mesh_and_graph
    for name in ("rcb", "rib", "sfc", "random"):
        parts = partition(m, 4, partitioner=name)
        assert parts.shape == (m.nelems,)
        assert parts.max() == 3


def test_rcb_order_is_permutation():
    m = box_mesh(5, 4, 3)
    from repro.core import rcb_order

    order = rcb_order(m.coords)
    assert sorted(order.tolist()) == list(range(m.nelems))


def test_grid_graph_rsb_cut_near_optimal(grid16):
    """On a 16×16 grid the optimal bisection cut is 16 (a straight line);
    RSB should land within 2× even with degeneracy (paper §9)."""
    parts, _ = rsb_partition_graph(grid16, 2, tol=1e-4)
    pm = partition_metrics(grid16, parts, 2)
    assert pm.edge_cut <= 32
    assert pm.imbalance <= 1


def test_warm_start_reduces_restarts(mesh_and_graph):
    """Beyond-paper: geometric warm start cuts Lanczos restarts without
    hurting quality."""
    m, g = mesh_and_graph
    _, rep_cold = rsb_partition_mesh(m, 8, tol=1e-3, warm_start=False)
    p_warm, rep_warm = rsb_partition_mesh(m, 8, tol=1e-3, warm_start=True)
    assert rep_warm.total_iterations <= rep_cold.total_iterations
    assert partition_metrics(g, p_warm, 8).imbalance <= 1
