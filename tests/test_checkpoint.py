"""Fault tolerance: atomic checkpointing, torn files, resume, preemption."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import fit, quorum_grad_mean


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)},
            "d": jnp.int32(7)}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    f = save_checkpoint(str(tmp_path), 3, t)
    step, restored, manifest = load_checkpoint(f, t)
    assert step == 3
    for x, y in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_manager_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_torn_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    mgr.save(2, _tree())
    # corrupt the newest file (simulated preemption mid-write after rename)
    with open(os.path.join(str(tmp_path), "ckpt_00000003.npz"), "wb") as f:
        f.write(b"torn!")
    step, tree, _ = mgr.restore_latest(_tree())
    assert step == 2


def test_structure_mismatch_raises(tmp_path):
    f = save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        load_checkpoint(f, {"only": jnp.zeros(1)})


def test_fit_resumes_after_preemption(tmp_path):
    """Kill training mid-run; rerunning fit() continues from the last
    checkpoint and reaches the same final state as an uninterrupted run."""

    def make_problem():
        w = {"w": jnp.zeros((4,))}
        target = jnp.asarray([1.0, -2.0, 3.0, 0.5])

        def loss(p, batch):
            return jnp.sum((p["w"] - target) ** 2) * batch["scale"]

        data = ({"scale": jnp.float32(1.0)} for _ in iter(int, 1))
        return w, loss, data

    class Boom(RuntimeError):
        pass

    def preempt(step):
        if step == 7:
            raise Boom()

    opt = AdamWConfig(lr=0.1, weight_decay=0.0)
    w, loss, data = make_problem()
    d1 = str(tmp_path / "run")
    with pytest.raises(Boom):
        fit(loss, w, data, steps=20, opt_cfg=opt, ckpt_dir=d1, ckpt_every=2,
            log_every=100, preemption_hook=preempt, log=lambda s: None)
    # resume (no preemption this time)
    w2, loss2, data2 = make_problem()
    res = fit(loss2, w2, data2, steps=20, opt_cfg=opt, ckpt_dir=d1,
              ckpt_every=2, log_every=100, log=lambda s: None)

    # uninterrupted reference
    w3, loss3, data3 = make_problem()
    ref = fit(loss3, w3, data3, steps=20, opt_cfg=opt,
              ckpt_dir=str(tmp_path / "ref"), ckpt_every=100, log_every=100,
              log=lambda s: None)
    np.testing.assert_allclose(np.asarray(res.params["w"]),
                               np.asarray(ref.params["w"]), atol=1e-6)


def test_quorum_grad_mean_skips_stragglers():
    g = {"w": jnp.stack([jnp.ones(3), 2 * jnp.ones(3), 100 * jnp.ones(3),
                         3 * jnp.ones(3)])}
    alive = jnp.asarray([1.0, 1.0, 0.0, 1.0])  # shard 2 is a dead straggler
    out = quorum_grad_mean(g, alive)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0 * np.ones(3))
