"""Multilevel k-way V-cycle (bisect="multilevel"): heavy-edge matching
validity, Galerkin weight conservation through the ladder, V-cycle cut /
balance parity with the spectral engine, boundary-restricted FM
semantics, and the stage's pipeline + observability contract."""

import numpy as np
import pytest

from repro import obs
from repro.core import (
    coarsen_graph,
    edge_cut,
    heavy_edge_matching,
    kway_fm,
    kway_fm_boundary,
    multilevel_partition,
    partition,
    partition_metrics,
)
from repro.core.pipeline import PartitionPipeline
from repro.mesh import box_mesh, dual_graph, grid_graph_2d, pebble_mesh
from repro.mesh.graphs import build_csr
from repro.obs.export import expected_span_names


@pytest.fixture(scope="module")
def pebble():
    m = pebble_mesh(10, 10, 10, n_pebbles=4, warp=0.1, seed=2)
    return m, dual_graph(m)


@pytest.fixture(scope="module")
def boxg():
    m = box_mesh(8, 8, 6)
    return m, dual_graph(m)


def _edge_set(g):
    return set(zip(g.rows.tolist(), g.indices.tolist()))


# ---------------------------------------------------------------------------
# Heavy-edge matching
# ---------------------------------------------------------------------------

def test_hem_is_a_valid_matching(grid16):
    agg, n_c = heavy_edge_matching(grid16, seed=3)
    assert agg.shape == (grid16.n,)
    assert n_c == int(agg.max()) + 1
    # total coverage, aggregate sizes ≤ 2 (it is a *matching*)
    sizes = np.bincount(agg, minlength=n_c)
    assert sizes.min() >= 1 and sizes.max() <= 2
    # matched pairs must be actual edges of the graph
    edges = _edge_set(grid16)
    for a in np.flatnonzero(sizes == 2):
        u, v = np.flatnonzero(agg == a)
        assert (int(u), int(v)) in edges
    # a real matching makes progress: close to the n/2 floor on a grid
    assert n_c <= 0.6 * grid16.n


def test_hem_prefers_heavy_edges():
    # path 0-1-2-3 with one dominant edge (1,2): HEM must take it
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 3])
    w = np.array([1.0, 100.0, 1.0])
    g = build_csr(src, dst, 4, weights=w)
    agg, n_c = heavy_edge_matching(g, seed=0)
    assert agg[1] == agg[2]
    # nodes 0 and 3 are not adjacent and their only neighbors are taken,
    # so they stay singletons: {0}, {1,2}, {3}
    assert n_c == 3
    assert agg[0] != agg[1] and agg[3] != agg[1] and agg[0] != agg[3]


def test_hem_weight_cap_limits_aggregates(grid16):
    w = np.ones(grid16.n)
    cap = 1.5  # pairs would weigh 2.0 > cap: nothing may match
    agg, n_c = heavy_edge_matching(grid16, node_weights=w, max_weight=cap,
                                   seed=0)
    assert n_c == grid16.n
    np.testing.assert_array_equal(np.bincount(agg, minlength=n_c),
                                  np.ones(grid16.n))


# ---------------------------------------------------------------------------
# Galerkin coarsening: weight conservation
# ---------------------------------------------------------------------------

def test_coarsen_conserves_weights_through_ladder(pebble):
    _, g = pebble
    rng = np.random.default_rng(7)
    w = rng.uniform(1.0, 3.0, g.n)
    node_total = w.sum()
    edge_total = g.weights.sum()
    for lvl in range(4):
        agg, n_c = heavy_edge_matching(g, seed=lvl)
        g_c, w_c = coarsen_graph(g, agg, n_c, node_weights=w)
        # node weight is conserved EXACTLY (bincount is a sum)
        assert w_c.sum() == pytest.approx(node_total, rel=1e-12)
        assert w_c.shape == (n_c,)
        # edge weight only shrinks (intra-aggregate edges drop out)
        assert g_c.weights.sum() <= edge_total + 1e-9
        # no self-loops survive Galerkin coarsening
        assert np.all(g_c.rows != g_c.indices)
        # exactly the intra-aggregate weight went missing
        intra = g.weights[agg[g.rows] == agg[g.indices]].sum()
        assert g_c.weights.sum() == pytest.approx(
            g.weights.sum() - intra, rel=1e-9)
        g, w, edge_total = g_c, w_c, g_c.weights.sum()


def test_coarsen_graph_backward_compat_single_return(grid16):
    agg, n_c = heavy_edge_matching(grid16, seed=0)
    out = coarsen_graph(grid16, agg, n_c)
    # without node_weights the historical Graph-only return survives
    assert not isinstance(out, tuple)
    assert out.n == n_c


# ---------------------------------------------------------------------------
# kway_fm nodes= restriction
# ---------------------------------------------------------------------------

def test_kway_fm_nodes_none_matches_all_nodes(grid16):
    rng = np.random.default_rng(0)
    parts = rng.integers(0, 4, grid16.n)
    a, _ = kway_fm(grid16, parts, 4, passes=2)
    b, _ = kway_fm(grid16, parts, 4, passes=2,
                   nodes=np.arange(grid16.n))
    np.testing.assert_array_equal(a, b)


def test_kway_fm_restricted_nodes_never_move(grid16):
    rng = np.random.default_rng(1)
    parts = rng.integers(0, 4, grid16.n)
    allowed = np.arange(grid16.n // 3)
    out, st = kway_fm(grid16, parts, 4, passes=2, nodes=allowed)
    frozen = np.setdiff1d(np.arange(grid16.n), allowed)
    np.testing.assert_array_equal(out[frozen], parts[frozen])
    assert st.cut_after <= st.cut_before


def test_kway_fm_boundary_improves_and_reports(grid16):
    rng = np.random.default_rng(2)
    parts = rng.integers(0, 4, grid16.n)
    out, st = kway_fm_boundary(grid16, parts, 4, passes=3)
    assert st.cut_after <= st.cut_before
    assert st.cut_after == pytest.approx(edge_cut(grid16, out))
    assert st.stages and st.stages[0] == "kway"


# ---------------------------------------------------------------------------
# The V-cycle
# ---------------------------------------------------------------------------

def test_multilevel_ladder_invariants(pebble):
    m, g = pebble
    parts, rep = multilevel_partition(g, 8, weights=m.weights, seed=0)
    ml = rep.ml
    assert rep.engine == "multilevel" and rep.multilevel
    assert ml.levels >= 1 and ml.n_fine == g.n
    assert ml.n_coarsest < g.n
    assert 0.0 < ml.coarsen_ratio < 1.0
    # every level strictly coarsens and the records chain n -> n_coarse
    downs = [r for r in ml.records if r.n_coarse < r.n]
    for prev, nxt in zip(downs, downs[1:]):
        assert nxt.n == prev.n_coarse
    assert set(np.unique(parts)) == set(range(8))
    # totals: coarsest-polish moves + per-level moves, never less than the
    # per-level sum alone
    assert ml.fm_moves >= sum(r.fm_moves for r in ml.records)
    assert ml.balance_moves >= sum(r.balance_moves for r in ml.records)


def test_multilevel_cut_parity_and_balance(pebble):
    """Acceptance shape: multilevel within 10% of spectral cut (test-size
    tolerance), balanced to the same corridor, zero disconnected parts."""
    m, g = pebble
    w = m.weights
    ml_parts = partition(m, 8, partitioner="multilevel", weights=w)
    sp_parts = partition(m, 8, partitioner="rsb", weights=w)
    pm_ml = partition_metrics(g, ml_parts, 8, weights=w)
    pm_sp = partition_metrics(g, sp_parts, 8, weights=w)
    assert pm_ml.disconnected_parts == 0
    assert pm_ml.edge_cut <= 1.10 * pm_sp.edge_cut
    assert pm_ml.weighted_imbalance <= 1.10


def test_multilevel_balance_unweighted_box(boxg):
    m, g = boxg
    parts, rep = multilevel_partition(g, 12, seed=1)
    counts = np.bincount(parts, minlength=12)
    assert counts.min() >= 1
    # unweighted: rebalance + boundary FM must land inside ~5% + 1 node
    mean = g.n / 12
    assert counts.max() <= 1.05 * mean + 1
    assert counts.min() >= 0.95 * mean - 1
    assert rep.ml.coarse_cut > 0


def test_multilevel_degenerate_ladder_small_input(grid16):
    # 256 nodes, 4 parts, coarse_factor=64 → target ≥ n: no ladder at all
    parts, rep = multilevel_partition(grid16, 4, coarse_factor=64)
    assert rep.ml.levels == 0
    assert rep.ml.records and rep.ml.records[0].level == 0
    assert set(np.unique(parts)) == set(range(4))


def test_multilevel_validates_inputs(grid16):
    with pytest.raises(ValueError, match="nparts"):
        multilevel_partition(grid16, 0)
    with pytest.raises(ValueError, match="coarse_solver"):
        multilevel_partition(grid16, 4, coarse_solver="metis")


# ---------------------------------------------------------------------------
# Pipeline + observability contract
# ---------------------------------------------------------------------------

def test_multilevel_front_door_and_spans(pebble):
    m, g = pebble
    with obs.trace("partition", pre="none", bisect="multilevel") as root:
        ctx = PartitionPipeline(pre="none", bisect="multilevel",
                                post=("repair", "kway")).run(m, 8)
    names = {s.name for s in root.walk()}
    want = expected_span_names(dict(pre="none", bisect="multilevel",
                                    post=("repair", "kway")))
    missing = want - names - {"partition"}
    assert not missing, f"missing spans: {missing}"
    assert "coarsen" in names and "coarsest" in names
    assert "mlevel:0" in names
    pm = partition_metrics(g, ctx.parts, 8)
    assert pm.disconnected_parts == 0
    # the report carries the V-cycle stats for the bench tables
    assert ctx.report.ml is not None and ctx.report.ml.levels >= 1
    d = ctx.report.to_dict()
    assert d["ml"]["n_fine"] == g.n


def test_multilevel_front_door_partition(boxg):
    m, g = boxg
    parts = partition(m, 6, partitioner="multilevel")
    assert set(np.unique(parts)) == set(range(6))
    assert partition_metrics(g, parts, 6).disconnected_parts == 0


# ---------------------------------------------------------------------------
# Deterministic sweep of the repairability property (the randomized
# hypothesis version lives in test_properties.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nx,ny,nparts,seed", [
    (5, 5, 3, 0), (9, 4, 6, 1), (7, 7, 4, 2), (4, 9, 2, 3),
])
def test_multilevel_repaired_has_no_disconnected_parts(nx, ny, nparts, seed):
    g = grid_graph_2d(nx, ny)
    ctx = PartitionPipeline(
        pre="none", bisect="multilevel", post=("repair",),
        bisect_kw=dict(seed=seed, coarse_factor=4)).run(g, nparts)
    pm = partition_metrics(g, ctx.parts, nparts)
    assert pm.disconnected_parts == 0
    assert set(np.unique(ctx.parts)) == set(range(nparts))
