"""Partition-quality metrics on hand-checkable cases."""

import numpy as np

from repro.core import comm_time_model, m2_words, partition_metrics
from repro.core.metrics import BETA_S_PER_WORD
from repro.mesh import build_csr, grid_graph_2d


def test_metrics_two_halves():
    g = grid_graph_2d(4, 4)  # nodes in row-major (x, y)
    parts = (np.arange(16) // 8).astype(np.int64)  # split along x
    m = partition_metrics(g, parts, 2)
    assert m.imbalance == 0
    assert m.edge_cut == 4.0            # 4 cut edges of weight 1
    assert m.max_neighbors == 1
    assert m.avg_neighbors == 1.0
    assert m.total_volume == 8.0        # 4 out of each side


def test_metrics_weighted_cut():
    g = grid_graph_2d(2, 2)
    parts = np.array([0, 0, 1, 1])
    m = partition_metrics(g, parts, 2)
    assert m.edge_cut == 2.0


def test_message_size_words_scaling():
    g = grid_graph_2d(4, 4)
    parts = (np.arange(16) // 8).astype(np.int64)
    m64 = partition_metrics(g, parts, 2, dofs_per_face=64)
    m16 = partition_metrics(g, parts, 2, dofs_per_face=16)
    assert m64.avg_message_size == 4 * m16.avg_message_size


def test_comm_time_model_regimes():
    g = grid_graph_2d(4, 4)
    parts = (np.arange(16) // 8).astype(np.int64)
    m = partition_metrics(g, parts, 2)
    ct = comm_time_model(m)
    assert ct["dominated_by"] in ("latency", "volume")
    assert ct["m2_words"] == m2_words()
    # paper's argument: m2 for a 50 GB/s link at 1 µs latency ≈ 6k words
    assert 1e3 < m2_words() < 1e4


def test_comm_model_volume_is_per_part_max():
    """W must be the max over parts of the part's OWN outgoing volume in
    words — not max_message_size × max_neighbors, which mixes maxima from
    different parts.  Star part p0 has the most neighbors (3, tiny
    messages); p1/p2 carry the big messages (volume 10 words each)."""
    g = build_csr(np.array([0, 0, 0, 1]), np.array([1, 2, 3, 2]), 4,
                  weights=np.array([1.0, 1.0, 1.0, 9.0]))
    parts = np.array([0, 1, 2, 3], dtype=np.int64)
    m = partition_metrics(g, parts, 4, dofs_per_face=4)  # words == volume
    assert m.max_neighbors == 3          # p0
    assert m.max_message_size == 5.0     # p1/p2: 10 words over 2 neighbors
    # hand-computed per-part outgoing words: p0=3, p1=10, p2=10, p3=1
    assert m.max_part_volume_words == 10.0
    ct = comm_time_model(m)
    assert ct["volume_s"] == BETA_S_PER_WORD * 10.0
    # the old cross-part estimate would have claimed 5 × 3 = 15 words
    assert m.max_message_size * m.max_neighbors == 15.0


def test_single_part_degenerate():
    g = grid_graph_2d(3, 3)
    m = partition_metrics(g, np.zeros(9, np.int64), 1)
    assert m.edge_cut == 0.0
    assert m.max_neighbors == 0
    assert m.disconnected_parts == 0
    assert m.component_count == 1


def test_connected_parts_census():
    """Both halves of a clean split are connected."""
    g = grid_graph_2d(4, 4)
    parts = (np.arange(16) // 8).astype(np.int64)
    m = partition_metrics(g, parts, 2)
    assert m.disconnected_parts == 0
    assert m.component_count == 2


def test_disconnected_parts_detected():
    """Two opposite corners assigned to part 1: part 1 has two components
    (disconnected), part 0 (the remainder) stays connected."""
    g = grid_graph_2d(4, 4)
    parts = np.zeros(16, np.int64)
    parts[0] = parts[15] = 1
    m = partition_metrics(g, parts, 2)
    assert m.disconnected_parts == 1
    assert m.component_count == 3
    # the fields ride through row() for the benchmark tables
    row = m.row()
    assert row["disconnected_parts"] == 1 and row["component_count"] == 3


def test_isolated_nodes_count_as_components():
    """Nodes with no intra-part edges are their own components."""
    g = grid_graph_2d(2, 2)
    parts = np.array([0, 1, 1, 0])  # both parts are diagonal pairs
    m = partition_metrics(g, parts, 2)
    assert m.disconnected_parts == 2
    assert m.component_count == 4
