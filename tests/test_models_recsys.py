"""SASRec + embedding-bag substrate."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import recsys_batches
from repro.models.recsys import (
    SASRecConfig,
    embedding_bag,
    init_sasrec,
    sasrec_score_candidates,
    sasrec_train_loss,
    sasrec_user_state,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

CFG = SASRecConfig(name="s", n_items=500, embed_dim=16, seq_len=12, d_ff=16,
                   pad_rows=64)


def test_table_padding():
    assert CFG.table_rows % 64 == 0 and CFG.table_rows >= CFG.n_items + 1


def test_user_state_shapes():
    params = init_sasrec(CFG, jax.random.PRNGKey(0))
    seq = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, 500)
    h = sasrec_user_state(CFG, params, seq)
    assert h.shape == (4, 12, 16)
    assert not bool(jnp.isnan(h).any())


def test_padding_item_masked():
    """Sequences of all-padding produce no information leakage (masked)."""
    params = init_sasrec(CFG, jax.random.PRNGKey(0))
    seq = jnp.zeros((2, 12), jnp.int32)
    h = sasrec_user_state(CFG, params, seq)
    # all-masked input → identical states across batch
    np.testing.assert_allclose(np.asarray(h[0]), np.asarray(h[1]), atol=1e-6)


def test_causality():
    """Changing a FUTURE item must not change past user states."""
    params = init_sasrec(CFG, jax.random.PRNGKey(0))
    seq1 = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 1, 500)
    seq2 = seq1.at[0, -1].set((seq1[0, -1] + 3) % 499 + 1)
    h1 = sasrec_user_state(CFG, params, seq1)
    h2 = sasrec_user_state(CFG, params, seq2)
    np.testing.assert_allclose(np.asarray(h1[:, :-1]), np.asarray(h2[:, :-1]),
                               atol=1e-5)


def test_training_decreases_loss():
    params = init_sasrec(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    it = recsys_batches(16, 12, CFG.n_items, seed=4)

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(lambda pp: sasrec_train_loss(CFG, pp, b))(p)
        p, o, _ = adamw_update(ocfg, g, o, p)
        return p, o, l

    losses = []
    for i, b in zip(range(25), it):
        params, opt, l = step(params, opt, b)
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_candidate_scoring():
    params = init_sasrec(CFG, jax.random.PRNGKey(0))
    seq = jax.random.randint(jax.random.PRNGKey(5), (3, 12), 1, 500)
    scores = sasrec_score_candidates(CFG, params, seq, jnp.arange(100))
    assert scores.shape == (3, 100)
    # score of item i == dot(user, embed_i)
    h = sasrec_user_state(CFG, params, seq)[:, -1]
    ref = h @ params["item_embed"][:100].T
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref), atol=1e-5)


def test_embedding_bag_modes():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    idx = jnp.asarray([0, 1, 2, 5, 5, 7], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    s = embedding_bag(table, idx, seg, 3, mode="sum")
    np.testing.assert_allclose(np.asarray(s[0]),
                               np.asarray(table[0] + table[1]), atol=1e-6)
    m = embedding_bag(table, idx, seg, 3, mode="mean")
    np.testing.assert_allclose(np.asarray(m[2]),
                               np.asarray((table[5] + table[7]) / 2), atol=1e-6)
    mx = embedding_bag(table, idx, seg, 3, mode="max")
    np.testing.assert_allclose(
        np.asarray(mx[1]), np.asarray(jnp.maximum(table[2], table[5])), atol=1e-6
    )
