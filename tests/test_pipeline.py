"""Composable partition pipeline: stage wiring, front-door compatibility
(bit-for-bit refine="none" parity with the raw drivers), kwarg routing,
presets, and the pipeline-output contract consumers rely on."""

import numpy as np
import pytest

from repro.configs.parrsb import PIPELINE_PRESETS, make_pipeline
from repro.core import (
    PartitionPipeline,
    parse_refine,
    partition,
    partition_metrics,
    rsb_partition_graph,
    rsb_partition_mesh,
)
from repro.dist.partition_aware import plan_halo_sharding
from repro.mesh import box_mesh, dual_graph, grid_graph_2d


@pytest.fixture(scope="module")
def box():
    m = box_mesh(8, 8, 4)
    return m, dual_graph(m)


@pytest.fixture(scope="module")
def default_ctx(box):
    m, _ = box
    return PartitionPipeline().run(m, 8)


def test_refine_none_bit_for_bit(box):
    """The escape hatch reproduces the raw driver labels exactly."""
    m, _ = box
    ref, _ = rsb_partition_mesh(m, 8, tol=1e-3)
    got = partition(m, 8, refine="none", tol=1e-3)
    np.testing.assert_array_equal(got, ref)


def test_refine_none_bit_for_bit_graph(box):
    m, g = box
    ref, _ = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3)
    got = partition(g, 8, coords=m.coords, refine="none", tol=1e-3)
    np.testing.assert_array_equal(got, ref)


def test_default_pipeline_refines(box, default_ctx):
    """Default post stage: cut no worse than raw, zero disconnected parts,
    parts_raw preserved alongside."""
    m, g = box
    ctx = default_ctx
    pm_raw = partition_metrics(g, ctx.parts_raw, 8)
    pm = partition_metrics(g, ctx.parts, 8)
    assert pm.edge_cut <= pm_raw.edge_cut
    assert pm.disconnected_parts == 0
    assert ctx.report.post is not None
    assert ctx.report.post.cut_after == pm.edge_cut
    assert ctx.report.post.stages == ["repair", "refine"]


def test_stage_records(default_ctx):
    ctx = default_ctx
    kinds = [(s.kind, s.name) for s in ctx.stages]
    # the guard brackets every run: validation front door, then the
    # pre/bisect/post chain, then the output-invariant finalizer
    assert kinds == [("guard", "validate"),
                     ("pre", "rcb"), ("bisect", "rsb-batched"),
                     ("post", "repair"), ("post", "refine"),
                     ("guard", "finalize")]
    assert all(s.seconds >= 0 for s in ctx.stages)
    assert ctx.seconds == pytest.approx(ctx.stage_seconds())
    stats = ctx.stats()
    assert stats["nparts"] == 8 and len(stats["stages"]) == 6
    assert "post" in stats


@pytest.mark.parametrize("nparts", [1, 3, 5, 8, 16])
def test_pipeline_nparts_parity(box, nparts):
    """Power-of-two and non-power-of-two nparts, plus the degenerate
    single-part case, all balance and cover through the pipeline."""
    m, g = box
    ctx = PartitionPipeline(bisect_kw=dict(tol=1e-2, max_restarts=10)).run(
        m, nparts)
    assert set(np.unique(ctx.parts)) == set(range(nparts))
    pm = partition_metrics(g, ctx.parts, nparts)
    assert pm.disconnected_parts == 0
    wsum = np.bincount(ctx.parts, weights=m.weights, minlength=nparts)
    assert wsum.max() <= 1.06 * wsum.mean() + m.weights.max()


def test_batch_of_one_matches_direct(box):
    """nparts=2 (a single bisection level, batch of one subproblem) through
    the pipeline matches the direct driver bit-for-bit with refine off."""
    m, _ = box
    ref, _ = rsb_partition_mesh(m, 2, tol=1e-3)
    ctx = PartitionPipeline(post=()).run(m, 2)
    np.testing.assert_array_equal(ctx.parts, ref)
    np.testing.assert_array_equal(ctx.parts_raw, ref)  # raw == final here


def test_geometric_bisect_stages(box):
    m, g = box
    for name in ("rcb", "rib", "sfc", "random"):
        ctx = PartitionPipeline(pre="none", bisect=name, post=()).run(m, 4)
        assert ctx.parts.shape == (m.nelems,)
        assert ctx.report.total_iterations == 0
    # geometric labels healed by the post stage (the "geometric" preset)
    pipe = make_pipeline("geometric")
    ctx = pipe.run(m, 4)
    assert partition_metrics(g, ctx.parts, 4).disconnected_parts == 0


def test_front_door_kwarg_routing(box):
    m, _ = box
    p1 = partition(m, 4, partitioner="sfc", curve="morton", bits=8)
    p2 = partition(m, 4, partitioner="sfc", curve="hilbert")
    assert p1.shape == p2.shape
    with pytest.raises(TypeError, match="unknown keyword"):
        partition(m, 4, partitioner="rcb", curve="hilbert")
    with pytest.raises(TypeError, match="unknown keyword"):
        partition(m, 4, partitioner="rib", bits=4)
    with pytest.raises(TypeError, match="unknown keyword"):
        partition(m, 4, partitioner="random", tol=1e-3)
    with pytest.raises(TypeError, match="unknown keyword"):
        partition(m, 4, partitioner="rsb", curve="hilbert", refine="none")
    with pytest.raises(ValueError, match="unknown curve"):
        partition(m, 4, partitioner="sfc", curve="peano")
    with pytest.raises(ValueError, match="unknown partitioner"):
        partition(m, 4, partitioner="metis")
    with pytest.raises(ValueError, match="unknown engine"):
        partition(m, 4, partitioner="rsb", engine="nope")
    with pytest.raises(ValueError, match="unknown refine"):
        partition(m, 4, refine="polish")


def test_unknown_stage_names_raise():
    with pytest.raises(ValueError, match="unknown pre"):
        PartitionPipeline(pre="metis")
    with pytest.raises(ValueError, match="unknown bisect"):
        PartitionPipeline(bisect="metis")
    with pytest.raises(ValueError, match="unknown post"):
        PartitionPipeline(post=("polish",))


def test_parse_refine():
    assert parse_refine(None) == ("repair", "refine")
    assert parse_refine("none") == ()
    assert parse_refine("repair") == ("repair",)
    assert parse_refine(("refine",)) == ("refine",)
    assert parse_refine("kway") == ("kway",)
    assert parse_refine("repair+kway") == ("repair", "kway")


def test_presets(box):
    m, _ = box
    assert set(PIPELINE_PRESETS) >= {"default", "raw", "quality",
                                     "geometric", "reference", "kway",
                                     "quality-kway", "multilevel",
                                     "multilevel-quality"}
    raw = make_pipeline("raw")
    assert raw.post == ()
    # "quality" flipped its post chain from greedy sweeps to repair+kway
    # when the multilevel bisect stage landed (see configs/parrsb.py).
    q = make_pipeline("quality")
    assert q.pre == "rib" and q.post == ("repair", "kway")
    assert q.post_kw["passes"] == 12 and q.post_kw["balance_tol"] == 0.03
    k = make_pipeline("kway")
    assert k.post == ("repair", "kway") and k.post_kw["passes"] == 8
    qk = make_pipeline("quality-kway")
    assert qk.post == ("repair", "kway")
    assert qk.post_kw["passes"] == 12 and qk.post_kw["balance_tol"] == 0.03
    ml = make_pipeline("multilevel")
    assert ml.bisect == "multilevel" and ml.pre == "none"
    assert ml.post == ("repair", "kway")
    assert ml.bisect_kw["coarse_factor"] == 8     # from the config layer
    mq = make_pipeline("multilevel-quality")
    assert mq.bisect_kw["coarse_factor"] == 16    # preset bisect_kw wins
    assert mq.bisect_kw["stall"] == 128
    # overrides merge; caller bisect_kw beats preset and config
    q2 = make_pipeline("quality", post_kw=dict(passes=2))
    assert q2.post_kw["passes"] == 2 and q2.post_kw["balance_tol"] == 0.03
    ml2 = make_pipeline("multilevel", bisect_kw=dict(coarse_factor=4))
    assert ml2.bisect_kw["coarse_factor"] == 4
    assert ml2.bisect_kw["stall"] == 32
    # config fields are the base layer: default preset + knobs come from it
    from repro.configs.parrsb import ParRSBConfig

    cfg = ParRSBConfig(refine_sweeps=6, balance_tol=0.02, pipeline="raw")
    p = make_pipeline(config=cfg)
    assert p.post == () and p.post_kw["sweeps"] == 6
    assert p.post_kw["balance_tol"] == 0.02
    with pytest.raises(ValueError, match="unknown pipeline preset"):
        make_pipeline("metis")


def test_plan_halo_sharding_accepts_context(box, default_ctx):
    m, g = box
    ctx = default_ctx
    plan_a = plan_halo_sharding(g, ctx)            # context, nparts implied
    plan_b = plan_halo_sharding(g, ctx.parts, 8)   # classic array call
    assert plan_a.n_shards == 8
    np.testing.assert_array_equal(plan_a.shard_of, plan_b.shard_of)
    assert plan_a.halo == plan_b.halo
    # nparts inference for plain arrays
    plan_c = plan_halo_sharding(g, ctx.parts)
    assert plan_c.n_shards == 8


def test_pre_sfc_permutation_mode(box):
    """pre="sfc" reorders the input once, bisects, and maps labels back to
    the caller's element order."""
    m, g = box
    ctx = PartitionPipeline(pre="sfc", post=()).run(m, 4)
    pre_rec = next(s for s in ctx.stages if s.kind == "pre")
    assert pre_rec.info["mode"] == "permute"
    # the permuted run's dual graph is relabeled back for reuse and must
    # equal the caller-order dual graph exactly
    assert ctx.graph is not None
    np.testing.assert_array_equal(ctx.graph.indptr, g.indptr)
    np.testing.assert_array_equal(ctx.graph.indices, g.indices)
    np.testing.assert_allclose(ctx.graph.weights, g.weights)
    pm = partition_metrics(g, ctx.parts, 4)
    assert set(np.unique(ctx.parts)) == set(range(4))
    counts = np.bincount(ctx.parts, minlength=4)
    assert counts.max() - counts.min() <= 1
    # sanity: quality in the same ballpark as the default pre
    ref = PartitionPipeline(post=()).run(m, 4)
    assert pm.edge_cut <= 1.5 * partition_metrics(g, ref.parts, 4).edge_cut


def test_custom_post_stage_registration(box):
    from repro.core import register_post_stage
    from repro.core.refine import PostStats, edge_cut

    calls = []

    def noop_stage(graph, parts, nparts, *, weights, **kw):
        calls.append(nparts)
        c = edge_cut(graph, parts)
        return parts, PostStats(stages=["noop"], cut_before=c, cut_after=c)

    register_post_stage("noop", noop_stage)
    try:
        m, _ = box
        ctx = PartitionPipeline(post=("noop",)).run(m, 4)
        assert calls == [4]
        assert ctx.report.post.stages == ["noop"]
    finally:
        from repro.core import pipeline as _pl

        del _pl._POST_STAGES["noop"]


def test_mesh_weight_overrides_reach_every_stage(box):
    """Caller weights= overrides must steer the bisector (both engines and
    the sfc pre-path), not just the post stage."""
    m, _ = box
    rng = np.random.default_rng(0)
    w = rng.integers(1, 4, m.nelems).astype(np.float64)
    for pipe in (PartitionPipeline(post=()),
                 PartitionPipeline(bisect="rsb-recursive", post=()),
                 PartitionPipeline(pre="sfc", post=())):
        pipe.bisect_kw = dict(tol=1e-2, max_restarts=10)
        ctx = pipe.run(m, 4, weights=w)
        wsum = np.bincount(ctx.parts, weights=w, minlength=4)
        assert wsum.max() / wsum.mean() < 1.1, (pipe.pre, pipe.bisect)


def test_pipeline_graph_input_with_weights():
    g = grid_graph_2d(12, 12)
    coords = np.stack(np.meshgrid(np.arange(12), np.arange(12),
                                  indexing="ij"), -1).reshape(-1, 2).astype(float)
    w = np.ones(g.n)
    ctx = PartitionPipeline().run(g, 4, coords=coords, weights=w)
    pm = partition_metrics(g, ctx.parts, 4)
    assert pm.disconnected_parts == 0
    assert pm.edge_cut <= partition_metrics(g, ctx.parts_raw, 4).edge_cut
