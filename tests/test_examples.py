"""Subprocess smoke-runs of the runnable examples, so the entry points the
README advertises can't silently rot (the seed's failure mode: examples
importing a module that didn't exist).

Each example is its own process because it forces its own device count /
XLA flags.
"""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(name: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    inherited = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = src + (os.pathsep + inherited if inherited else "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_partition_mesh_example():
    out = run_example("partition_mesh.py")
    # the partitioner comparison table covers all five methods
    for name in ("rsb", "rcb", "rib", "sfc", "random"):
        assert name in out
    assert "redistributed coords" in out


def test_partition_aware_gnn_example():
    out = run_example("partition_aware_gnn.py")
    assert "gather words" in out
    assert "communication optimizer" in out
    # RSB must win the collective-volume column against random
    words = {}
    for line in out.splitlines():
        cells = line.split()
        if cells and cells[0] in ("random", "rcb", "rsb") and len(cells) >= 4:
            words[cells[0]] = int(cells[3])
    assert set(words) == {"random", "rcb", "rsb"}
    assert words["rsb"] < words["random"]
